"""The paper's contribution, end to end:

1. Take a mixed integer/FP kernel (expf, Fig. 1b of the paper), lower it
   with all three methodologies and simulate on the Snitch machine model —
   IPC, throughput and energy as in Fig. 3.
2. Show the same queue idea at the TPU kernel level: queue_matmul with
   depth 1 (COPIFT-style staging) vs depth 4 (COPIFTv2 multi-buffer).

  PYTHONPATH=src python examples/copiftv2_demo.py
"""
import jax
import jax.numpy as jnp

from repro.core import (KERNELS, MachineConfig, TransformConfig, lower,
                        simulate)
from repro.core.policy import ExecutionPolicy as P
from repro.kernels import queue_matmul
from repro.kernels.queue_matmul.ref import matmul_ref


def main():
    print("== 1. the paper's methodology on the Snitch machine model ==")
    tc = TransformConfig(n_samples=256)
    for name in ("expf", "poly_lcg", "dequant_dot"):
        dfg = KERNELS[name]
        print(f"\n{name}:")
        base = None
        for pol in (P.BASELINE, P.COPIFT, P.COPIFTV2):
            res = simulate(lower(dfg, pol, tc), MachineConfig())
            base = base or res
            print(f"  {pol.value:<9} IPC={res.ipc:5.2f}  "
                  f"samples/cycle={res.throughput:6.4f} "
                  f"({res.throughput/base.throughput:4.2f}x)  "
                  f"samples/J={res.efficiency:8.6f} "
                  f"({res.efficiency/base.efficiency:4.2f}x)")

    print("\n== 2. the same queue idea as a TPU Pallas kernel ==")
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 512), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (512, 256), jnp.float32)
    ref = matmul_ref(x, w)
    for depth, label in ((1, "COPIFT-style: stage tile, barrier, compute"),
                         (4, "COPIFTv2: 4-slot VMEM queue, DMA overlaps MXU")):
        y = queue_matmul(x, w, depth=depth)
        err = float(jnp.max(jnp.abs(y - ref)))
        print(f"  depth={depth}  max|err|={err:.2e}   # {label}")
    print("\n(depth is the VMEM slot-ring size — the hardware FIFO depth "
          "of the paper;\n wall-clock overlap shows on real TPU hardware, "
          "interpret mode checks semantics)")


if __name__ == "__main__":
    main()
