"""Design-space exploration CLI over the Snitch/FPSS machine model.

Sweeps (kernel x policy x queue_depth x queue_latency x unroll) grids through
the simulator, prints per-kernel Pareto fronts (IPC vs energy), writes the
full sweep and the fronts as CSV, and re-checks on *every* swept point that
the lowered program computes bit-identical outputs to the sequential baseline
interpreter — the sweep doubles as the repo's largest semantics fuzzer.

Usage (defaults sweep 22680 configurations: 7 kernels x 3 policies x
5 depths x 4 latencies x 2 unrolls x 3x3 asymmetric overrides x 3 core
counts — the cluster axis joined the default grid in PR 8, when the
lockstep batch engine learned to advance clustered and pipelined points
too (``core.batch_cluster``); an estimated-cost line blending each
point's actual engine rate prints before the sweep launches):

    PYTHONPATH=src python examples/explore.py
    PYTHONPATH=src python examples/explore.py \
        --kernels expf,dequant_dot --policies copift,copiftv2 \
        --depths 1,2,4,8,16 --latencies 1,2,4 --unrolls 4,8 \
        --n-samples 64 --workers 2 --out-dir artifacts/dse

Cluster axes (``core.cluster``): ``--cores`` sweeps Snitch-cluster core
counts (the kernel is work-partitioned into disjoint per-core sample
ranges; ``n_samples`` must divide evenly) and ``--banks`` sweeps TCDM bank
counts ('inf' = conflict-free).  Cluster records report aggregate IPC /
throughput over the makespan, per-core IPC, energy including interconnect
energy, and the ``*_bank`` stall cause.  ``--cores 1`` with ``--banks inf``
is bit-identical to the single-PE machine — the contract
``tests/test_cluster.py`` gates differentially:

    PYTHONPATH=src python examples/explore.py \
        --kernels poly_lcg,histf --policies copiftv2 \
        --cores 1,2,4 --banks inf,8,2

Pipelined-cluster axes (``transform.partition_pipeline``): ``--pipeline``
adds producer/consumer points where each core *pair* splits one kernel —
the INT core streams operands through bounded inter-core channels to the
FP-heavy core, with DMA double-buffering hiding the loads.  ``--cq-depths``
sweeps the channel FIFO depth and ``--dma-buffers`` the double-buffering
degree.  Pipelined points need an even core count and the COPIFTv2 policy
(others are rejected, not errors); stall columns ``cq_stalls`` /
``dma_stalls`` report channel back-pressure and DMA waits:

    PYTHONPATH=src python examples/explore.py \
        --kernels cluster_matmul --policies copiftv2 --pipeline both \
        --cores 2,4 --banks 2,8 --cq-depths 2,4,8 --dma-buffers 1,2,4

``--engine`` picks the simulation core: ``batch`` (default) groups every
point sharing a lowered program (single-PE: ``core.batch_machine``) or a
partitioned program set (clustered/pipelined: ``core.batch_cluster``) and
advances the whole group in one numpy max-recurrence pass — bit-identical
to ``event`` (the per-point event-driven time-skip engine), which is in
turn bit-identical to ``cycle`` (the naive per-cycle reference stepper).
Batch-inexpressible programs and predicted bank-conflict/deadlock points
fall back to the scalar engines automatically, so the batch path is
always sound.  A timing report (wall time, points/sec, ms/config) prints
either way; ``--engine event``/``cycle`` exist for differential checking
and benchmarking.

``--strategy`` picks the search discipline: ``exhaustive`` (default)
evaluates every grid point; ``adaptive`` runs front-guided successive
halving (``core.search``) — coarse low-fidelity rungs prune points more
than ``--search-tolerance`` beyond the running per-kernel Pareto fronts,
and only survivors are re-simulated at full fidelity (their records are
exact; pruned points simply don't appear in the output CSVs).

Outputs ``sweep.csv`` (every record) and ``pareto.csv`` (front members only)
under ``--out-dir``; exits non-zero if any configuration fails the
equivalence check or deadlocks.

Calibration (the calibrate → consume flow)
------------------------------------------

    PYTHONPATH=src python examples/explore.py calibrate
    PYTHONPATH=src python examples/explore.py calibrate \
        --objective energy-bounded-ipc --energy-budget 20000 \
        --kernels expf,dequant_dot --out-dir artifacts/calibration
    PYTHONPATH=src python examples/explore.py calibrate \
        --objective serve-slo --slo-p99 250 --kernels expf

``calibrate`` runs the same sweep, reduces it to per-kernel Pareto fronts,
selects one operating point per kernel under ``--objective`` (``max-ipc``,
``min-energy``, ``energy-bounded-ipc`` with ``--energy-budget``, or
``serve-slo`` — max throughput s.t. estimated p99 ≤ ``--slo-p99``
cycles/token and J/token ≤ ``--energy-budget``), and
persists each selection as a versioned, schema-checked JSON artifact
``artifacts/calibration/<kernel>.json`` (grid, front, git provenance and
selection rationale embedded; since schema v5 every artifact also carries
per-traffic-level ``serve-slo`` selections, whatever the global objective).
Downstream consumers load the artifacts at
startup through ``repro.core.policy.PolicyTable``:

* ``kernels/queue_matmul`` takes its ring depth / unroll from the
  ``dequant_dot`` artifact (workload proxy table in ``core.policy``);
* ``serve.ServeEngine`` and ``train.make_train_step`` resolve the ``serve``
  / ``train`` workloads' policies once, at startup;
* explicit arguments always override, and with no artifact (or a stale
  schema version) everything falls back to the paper's defaults with a
  warning — calibration can never brick a run.

Set ``REPRO_CALIBRATION_DIR`` to point consumers (and this command's
default output) at a different artifact directory.
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (KERNELS, STRATEGIES, SWEEP_ENGINES, ExecutionPolicy,
                        calibrate, format_front, grid, pareto_by_kernel,
                        resolve_workers, run_search, sweep_summary, write_csv)
from repro.core.calibrate import OBJECTIVES, calibration_dir
from repro.core.search import DEFAULT_LADDER, DEFAULT_TOLERANCE

#: rough single-worker engine rates (points/sec) for the estimated-cost
#: line, from ``artifacts/BENCH_sweep_scale.json`` (single-PE grids) and
#: ``artifacts/BENCH_cluster_sweep_scale.json`` (cluster/pipeline grids) —
#: an expectation-setter before a long sweep launches, not a promise.
#: ``batch_cluster`` is the lockstep cluster engine's rate: clustered and
#: pipelined points on ``--engine batch`` run there, not at the single-PE
#: batch rate, so the estimate blends per point (the pre-PR-8 line quoted
#: 4000 pts/s for grids that actually ran at the ~180 pts/s event rate).
NOMINAL_RATES = {"batch": 4000.0, "batch_cluster": 1500.0,
                 "event": 180.0, "cycle": 45.0}


def _ints(s):
    return tuple(int(x) for x in s.split(",") if x)


def _point_rate(pt, engine):
    """Nominal points/sec for one sweep point under ``engine``."""
    if engine == "batch":
        return NOMINAL_RATES["batch_cluster" if pt.clustered else "batch"]
    return NOMINAL_RATES.get(engine, NOMINAL_RATES["event"])


def _estimated_cost_line(pts, engine, workers, strategy):
    """Blended cost estimate: each point contributes at the rate of the
    engine that will actually simulate it (clustered/pipelined points on
    the batch engine run through the lockstep cluster engine), so mixed
    grids no longer quote the single-PE batch nominal for everything."""
    w = max(1, workers)
    seconds = sum(1.0 / _point_rate(pt, engine) for pt in pts) / w
    rate = len(pts) / seconds if seconds else 0.0
    n_cl = sum(1 for pt in pts if pt.clustered)
    mix = (f"; {n_cl}/{len(pts)} clustered" if 0 < n_cl < len(pts) else "")
    note = (" (adaptive search prunes dominated points after the first "
            "low-fidelity rung)" if strategy == "adaptive" else "")
    return (f"estimated cost: {len(pts)} points / ~{rate:.0f} pts/s "
            f"[{engine}, {workers} worker(s){mix}] ~= {seconds:.1f}s"
            f"{note}")


def _opt_ints(s):
    """Comma list where '-'/'none'/'inf' means the None sentinel (symmetric
    queue depth, or the conflict-free bank count).  Prefer the word forms on
    the command line: a leading '-' needs ``--flag=-,8`` argparse syntax."""
    return tuple(None if x in ("-", "none", "inf") else int(x)
                 for x in s.split(",") if x)


def calibrate_main(argv) -> int:
    ap = argparse.ArgumentParser(
        prog="explore.py calibrate",
        description="Sweep, reduce to per-kernel Pareto fronts, select an "
                    "operating point per objective, and write versioned "
                    "calibration artifacts consumed by queue_matmul / serve "
                    "/ train (see the module docstring).")
    ap.add_argument("--kernels", default=None,
                    help="comma list (default: all seven)")
    ap.add_argument("--policies", default=None,
                    help="comma list of baseline,copift,copiftv2")
    ap.add_argument("--depths", type=_ints, default=(1, 2, 4, 8))
    ap.add_argument("--latencies", type=_ints, default=(1, 2))
    ap.add_argument("--unrolls", type=_ints, default=(4, 8))
    ap.add_argument("--n-samples", type=int, default=32)
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--engine", choices=SWEEP_ENGINES, default="batch",
                    help="simulation core (default: the vectorized batch "
                         "engine; event/cycle are the per-point steppers)")
    ap.add_argument("--strategy", choices=STRATEGIES, default="exhaustive",
                    help="search discipline: exhaustive evaluates every "
                         "grid point; adaptive prunes via front-guided "
                         "successive halving (the artifact provenance "
                         "records strategy + fidelity ladder)")
    ap.add_argument("--search-tolerance", type=float,
                    default=DEFAULT_TOLERANCE,
                    help="adaptive only: relative dominance slack a point "
                         "may have to the running front and still advance "
                         "to full fidelity")
    ap.add_argument("--fidelity-ladder", type=_ints, default=DEFAULT_LADDER,
                    help="adaptive only: comma list of n-samples divisors "
                         "per rung, strictly decreasing, ending at 1")
    ap.add_argument("--objective", choices=OBJECTIVES, default="max-ipc")
    ap.add_argument("--energy-budget", type=float, default=None,
                    help="required for --objective energy-bounded-ipc; for "
                         "serve-slo it is the joules-per-token bound")
    ap.add_argument("--slo-p99", type=float, default=None,
                    help="serve-slo p99 bound in cycles-equivalent per "
                         "work-token (default: auto-derived with headroom "
                         "from the front's best attainable estimate); the "
                         "per-traffic selections (selected_by_traffic, "
                         "schema v5) use it too")
    ap.add_argument("--tolerance", type=float, default=0.0,
                    help="dominance tolerance: candidates within this "
                         "relative distance of the best primary axis tie, "
                         "resolved on the secondary axis")
    ap.add_argument("--out-dir", default=None,
                    help="artifact directory (default: REPRO_CALIBRATION_DIR "
                         "or artifacts/calibration)")
    args = ap.parse_args(argv)
    if args.objective == "energy-bounded-ipc" and args.energy_budget is None:
        ap.error("--objective energy-bounded-ipc requires --energy-budget")

    kernels = args.kernels.split(",") if args.kernels else None
    grid_kw = dict(queue_depths=args.depths, queue_latencies=args.latencies,
                   unrolls=args.unrolls, n_samples=args.n_samples,
                   engine=args.engine)
    if args.policies:
        grid_kw["policies"] = [ExecutionPolicy.parse(p)
                               for p in args.policies.split(",")]
    out_dir = args.out_dir or calibration_dir()
    pts_est = grid(kernels=kernels, **grid_kw)
    print(_estimated_cost_line(
        pts_est, args.engine, resolve_workers(len(pts_est), args.workers),
        args.strategy))
    search_kw = (dict(tolerance=args.search_tolerance,
                      fidelity_ladder=args.fidelity_ladder)
                 if args.strategy == "adaptive" else None)
    t0 = time.time()
    recs = calibrate(kernels=kernels, objective=args.objective,
                     energy_budget=args.energy_budget,
                     tolerance=args.tolerance, slo_p99=args.slo_p99,
                     grid_kw=grid_kw,
                     workers=args.workers, out_dir=out_dir,
                     strategy=args.strategy, search_kw=search_kw)
    dt = time.time() - t0
    for kernel in sorted(recs):
        r = recs[kernel]
        s = r.selected
        print(f"== {kernel}: {r.objective} -> {s['policy']} "
              f"depth={s['queue_depth']} lat={s['queue_latency']} "
              f"unroll={s['unroll']} (ipc={s['ipc']:.3f}, "
              f"energy={s['energy']:.1f}; front {len(r.front)}; "
              f"{len(r.selected_by_latency)} latency classes, "
              f"{len(r.selected_by_traffic)} traffic levels) ==")
        print(f"   {r.rationale}")
    print(f"\ncalibrated {len(recs)} kernels in {dt:.2f}s; wrote "
          f"{out_dir}/<kernel>.json (consumers honour REPRO_CALIBRATION_DIR)")
    return 0


def main(argv=None) -> int:
    if argv is None:
        argv = sys.argv[1:]
    if argv and argv[0] == "calibrate":
        return calibrate_main(argv[1:])
    ap = argparse.ArgumentParser(
        description=__doc__.split("\n\n")[0] +
        "  (Run 'explore.py calibrate --help' for the calibration "
        "subcommand.)")
    ap.add_argument("--kernels", default=None,
                    help="comma list (default: all seven)")
    ap.add_argument("--policies", default=None,
                    help="comma list of baseline,copift,copiftv2 (default: all)")
    ap.add_argument("--depths", type=_ints, default=(1, 2, 4, 8, 16),
                    help="queue depths to sweep")
    ap.add_argument("--latencies", type=_ints, default=(1, 2, 4, 8),
                    help="queue visibility latencies to sweep")
    ap.add_argument("--unrolls", type=_ints, default=(4, 8),
                    help="schedule interleave factors to sweep")
    ap.add_argument("--depths-i2f", type=_opt_ints, default=(None, 2, 8),
                    help="asymmetric I2F depth overrides (comma list; "
                         "'-' = symmetric)")
    ap.add_argument("--depths-f2i", type=_opt_ints, default=(None, 2, 8),
                    help="asymmetric F2I depth overrides (comma list; "
                         "'-' = symmetric)")
    ap.add_argument("--cores", type=_ints, default=(1, 2, 4),
                    help="cluster core counts to sweep (work-partitioned "
                         "disjoint sample ranges; n-samples must divide "
                         "evenly; 1 = the single-PE machine, bit-identical "
                         "to the plain stepper; multi-core points ride the "
                         "lockstep batch-cluster engine by default)")
    ap.add_argument("--banks", type=_opt_ints, default=(None,),
                    help="TCDM bank counts to sweep (comma list; 'inf' = "
                         "conflict-free/infinite banks)")
    ap.add_argument("--pipeline", choices=("off", "on", "both"),
                    default="off",
                    help="pipelined producer/consumer core pairs "
                         "(transform.partition_pipeline): 'on' sweeps only "
                         "pipelined points, 'both' adds them next to the "
                         "work-partitioned ones; needs an even --cores "
                         "value and the copiftv2 policy (other combinations "
                         "are rejected, not errors)")
    ap.add_argument("--cq-depths", type=_ints, default=(4,),
                    help="inter-core channel FIFO depths to sweep "
                         "(pipelined points; runtime property like --banks)")
    ap.add_argument("--dma-buffers", type=_ints, default=(2,),
                    help="producer DMA double-buffering degrees to sweep "
                         "(pipelined points; shapes the lowered schedule)")
    ap.add_argument("--n-samples", type=int, default=32)
    ap.add_argument("--workers", type=int, default=None,
                    help="process-pool width (0/1 = serial)")
    ap.add_argument("--engine", choices=SWEEP_ENGINES, default="batch",
                    help="simulation core: the vectorized batch engine "
                         "(default; one numpy pass per lowered program, "
                         "bit-identical to event), the per-point "
                         "event-driven time-skip engine, or the naive "
                         "per-cycle reference")
    ap.add_argument("--strategy", choices=STRATEGIES, default="exhaustive",
                    help="search discipline: exhaustive evaluates every "
                         "point; adaptive (core.search) prunes points more "
                         "than --search-tolerance beyond the running "
                         "per-kernel Pareto fronts at coarse fidelity and "
                         "only refines survivors at full fidelity")
    ap.add_argument("--search-tolerance", type=float,
                    default=DEFAULT_TOLERANCE,
                    help="adaptive only: relative dominance slack kept "
                         "alive while pruning")
    ap.add_argument("--fidelity-ladder", type=_ints, default=DEFAULT_LADDER,
                    help="adaptive only: comma list of n-samples divisors "
                         "per rung, strictly decreasing, ending at 1")
    ap.add_argument("--out-dir", default=os.path.join("artifacts", "dse"))
    args = ap.parse_args(argv)

    kernels = args.kernels.split(",") if args.kernels else None
    policies = ([ExecutionPolicy.parse(p) for p in args.policies.split(",")]
                if args.policies else None)
    pipelines = {"off": (False,), "on": (True,),
                 "both": (False, True)}[args.pipeline]
    pts = grid(kernels=kernels, policies=policies, queue_depths=args.depths,
               queue_latencies=args.latencies, unrolls=args.unrolls,
               n_samples=args.n_samples, engine=args.engine,
               i2f_depths=args.depths_i2f, f2i_depths=args.depths_f2i,
               n_cores=args.cores, tcdm_banks=args.banks,
               pipelines=pipelines, cq_depths=args.cq_depths,
               dma_buffers=args.dma_buffers)
    if not pts:
        ap.error("empty sweep grid: every axis needs at least one value")
    workers = resolve_workers(len(pts), args.workers)
    print(f"sweeping {len(pts)} configurations "
          f"({len(kernels) if kernels else len(KERNELS)} kernels x "
          f"{len(policies) if policies else len(ExecutionPolicy)} policies x "
          f"{len(args.depths)} depths x {len(args.latencies)} latencies x "
          f"{len(args.unrolls)} unrolls x {len(args.cores)} core-counts x "
          f"{len(args.banks)} bank-geometries; n_samples={args.n_samples}) "
          f"[engine={args.engine}, strategy={args.strategy}, "
          f"workers={workers}] ...")
    print(_estimated_cost_line(pts, args.engine, workers, args.strategy))
    search_kw = (dict(tolerance=args.search_tolerance,
                      fidelity_ladder=args.fidelity_ladder)
                 if args.strategy == "adaptive" else {})
    t0 = time.time()
    recs, meta = run_search(pts, strategy=args.strategy,
                            workers=args.workers, **search_kw)
    dt = time.time() - t0
    print(f"== timing ==\n  engine: {args.engine}\n  wall: {dt:.2f}s"
          f"\n  points/sec: {len(pts) / dt:.1f}"
          f"\n  ms/config: {dt / len(pts) * 1e3:.1f}")
    if args.strategy == "adaptive":
        print(f"  adaptive: {meta['n_full_fidelity']}/{meta['n_points']} "
              f"points reached full fidelity "
              f"(rungs {meta['rungs']}, tolerance {meta['tolerance']:g})")
    print()

    fronts = pareto_by_kernel(recs)
    for kernel, front in fronts.items():
        print(f"== {kernel}: Pareto front (maximize IPC, minimize energy), "
              f"{len(front)} of {sum(r.kernel == kernel for r in recs)} configs ==")
        print(format_front(front))
        print()

    s = sweep_summary(recs)
    print("== sweep summary ==")
    for k, v in sorted(s.items()):
        print(f"  {k}: {v:.4f}" if isinstance(v, float) else f"  {k}: {v}")

    os.makedirs(args.out_dir, exist_ok=True)
    sweep_csv = os.path.join(args.out_dir, "sweep.csv")
    pareto_csv = os.path.join(args.out_dir, "pareto.csv")
    write_csv(recs, sweep_csv)
    write_csv([r for front in fronts.values() for r in front], pareto_csv)
    print(f"\nwrote {sweep_csv} ({len(recs)} rows) and {pareto_csv} "
          f"({sum(len(f) for f in fronts.values())} rows)")

    bad = [r for r in recs if r.status == "deadlock"
           or (r.ok and (not r.equivalent or r.fifo_violations))]
    if bad:
        print(f"EQUIVALENCE FAILURE on {len(bad)} configurations, e.g.:\n"
              f"  {bad[0]}", file=sys.stderr)
        return 1
    n_rej = sum(r.status == "rejected" for r in recs)
    print(f"all {len(recs) - n_rej} simulated configurations match the "
          f"baseline interpreter bit-for-bit"
          + (f" ({n_rej} rejected at lowering)" if n_rej else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main())
