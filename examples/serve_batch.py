"""Batched serving: continuous batching over a reduced model, several
concurrent requests of different lengths, with chunked prefill and
measured-traffic operating points.

No ``traffic`` argument is passed to the engine, so it runs in
measured-traffic mode: a TrafficEstimator watches the arrival stream and,
once warm, re-resolves the calibrated per-traffic operating point at the
next refill boundary.  The burst of same-clock submissions below saturates
the estimate, so the engine retargets to the "high" traffic point mid-run
— watch the traffic history it prints.

  PYTHONPATH=src python examples/serve_batch.py --arch recurrentgemma-2b
"""
import argparse
import time

import jax

from repro.config import RunConfig
from repro.configs import ARCHS, get_reduced
from repro.models import init_model_params
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b",
                    choices=[a for a in ARCHS if a != "hubert-xlarge"])
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=10)
    ap.add_argument("--prefill", choices=("chunked", "token"),
                    default="chunked")
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    rc = RunConfig(dtype="float32", param_dtype="float32", remat=False)
    params = init_model_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, rc, batch_slots=3, max_len=128,
                      prefill=args.prefill)
    print(f"measured-traffic mode: level starts {eng.traffic_level} "
          f"(estimator cold), prefill={args.prefill}")

    # a same-clock burst: offered load saturates -> the estimator reads
    # "high" and the engine retargets at the first refill boundary
    for i in range(args.requests):
        prompt = list(range(1 + i, 5 + 2 * i))
        eng.submit(prompt, max_new=args.max_new)

    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    n_tok = sum(len(r.generated) for r in done.values())
    print(f"{cfg.name}: {len(done)} requests, {n_tok} tokens, "
          f"{n_tok/dt:.1f} tok/s, {eng._n_steps} engine steps "
          f"({eng.prefill_compiles} prefill chunk programs)")
    print(f"measured traffic level: {eng.traffic_level}; "
          f"{len(eng.traffic_history)} retarget(s)")
    for h in eng.traffic_history:
        print(f"  @{h['clock']:.0f} cyc -> {h['level']} "
              f"(rho~{h['offered_load']:.2f}, policy={h['policy']})")
    for rid in sorted(done):
        r = done[rid]
        print(f"  req{rid} prompt[:4]={r.prompt[:4]} -> {r.generated}")


if __name__ == "__main__":
    main()
