"""Batched serving: continuous batching over a reduced model, several
concurrent requests of different lengths.

  PYTHONPATH=src python examples/serve_batch.py --arch recurrentgemma-2b
"""
import argparse
import time

import jax

from repro.config import RunConfig
from repro.configs import ARCHS, get_reduced
from repro.models import init_model_params
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b",
                    choices=[a for a in ARCHS if a != "hubert-xlarge"])
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=10)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    rc = RunConfig(dtype="float32", param_dtype="float32", remat=False)
    params = init_model_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, rc, batch_slots=3, max_len=128)

    for i in range(args.requests):
        prompt = list(range(1 + i, 5 + 2 * i))
        eng.submit(prompt, max_new=args.max_new)

    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    n_tok = sum(len(r.generated) for r in done.values())
    print(f"{cfg.name}: {len(done)} requests, {n_tok} tokens, "
          f"{n_tok/dt:.1f} tok/s")
    for rid in sorted(done):
        r = done[rid]
        print(f"  req{rid} prompt[:4]={r.prompt[:4]} -> {r.generated}")


if __name__ == "__main__":
    main()
