"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
through the full stack — data prefetch queue, fault-tolerant trainer, async
checkpointing, straggler monitor.

The default invocation trains a 115M-param phi3-style model; on this CPU
container use --preset small (~19M) for a quick demonstration, or pass
--steps/--batch/--seq explicitly.

  PYTHONPATH=src python examples/train_lm.py --preset small --steps 200
  PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""
import argparse
import time

import jax

from repro.config import ModelConfig, RunConfig, ShapeConfig
from repro.models import init_model_params
from repro.runtime import FaultTolerantTrainer
from repro.launch.mesh import make_local_mesh

PRESETS = {
    # ~19M params: quick CPU demo
    "small": dict(n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
                  d_ff=1024, vocab=8192, seq=256, batch=8),
    # ~115M params: the "train ~100M for a few hundred steps" deliverable
    "100m": dict(n_layers=8, d_model=512, n_heads=8, n_kv_heads=4,
                 d_ff=2048, vocab=32768, seq=512, batch=8),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="small", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    p = PRESETS[args.preset]

    cfg = ModelConfig(name=f"lm-{args.preset}", family="dense",
                      n_layers=p["n_layers"], d_model=p["d_model"],
                      n_heads=p["n_heads"], n_kv_heads=p["n_kv_heads"],
                      d_ff=p["d_ff"], vocab=p["vocab"])
    print(f"model: {cfg.n_params()/1e6:.1f}M params")
    rc = RunConfig(dtype="float32", param_dtype="float32", remat=False,
                   lr=args.lr, warmup_steps=args.steps // 20 + 1,
                   total_steps=args.steps)
    shape = ShapeConfig("train", p["seq"], p["batch"], "train")
    params = init_model_params(jax.random.PRNGKey(0), cfg)

    trainer = FaultTolerantTrainer(cfg, shape, rc, make_local_mesh,
                                   args.ckpt_dir, ckpt_every=50)
    t0 = time.time()
    out = trainer.run(params, num_steps=args.steps)
    dt = time.time() - t0
    losses = [l for _, l in out["metrics"]]
    k = max(len(losses) // 10, 1)
    tok = p["seq"] * p["batch"] * args.steps
    print(f"{args.steps} steps / {tok/1e6:.2f}M tokens in {dt:.0f}s")
    print(f"loss: {sum(losses[:k])/k:.4f} -> {sum(losses[-k:])/k:.4f}")
    assert sum(losses[-k:]) / k < sum(losses[:k]) / k, "loss did not improve"


if __name__ == "__main__":
    main()
