"""Quickstart: train a tiny assigned-architecture model for a few steps and
greedily decode from it — the public API in ~40 lines.

  PYTHONPATH=src python examples/quickstart.py [--arch glm4-9b]
"""
import argparse

import jax

from repro.config import RunConfig
from repro.configs import ARCHS, get_reduced
from repro.models import init_model_params
from repro.optim import init_opt_state
from repro.serve import ServeEngine
from repro.train import train_step
from repro.data import SyntheticLMStream


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b", choices=ARCHS)
    ap.add_argument("--steps", type=int, default=20)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    rc = RunConfig(dtype="float32", param_dtype="float32", remat=False,
                   lr=1e-2, warmup_steps=2, total_steps=args.steps)
    params = init_model_params(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params)
    stream = SyntheticLMStream(cfg.vocab, seq_len=64, global_batch=8)

    step = jax.jit(lambda p, o, b: train_step(p, o, b, cfg, rc))
    for i in range(args.steps):
        params, opt, metrics = step(params, opt, stream.batch_at(i))
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:>3}  loss={float(metrics['loss']):.4f}  "
                  f"acc={float(metrics['accuracy']):.3f}")

    if cfg.causal:
        eng = ServeEngine(params, cfg, rc, batch_slots=2, max_len=64)
        rid = eng.submit([1, 2, 3, 4], max_new=8)
        out = eng.run()
        print("generated:", out[rid].generated)


if __name__ == "__main__":
    main()
