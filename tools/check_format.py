"""Mechanical layout gate for CI: stdlib-only, so it runs anywhere the
tests run (no formatter dependency to install or pin).

``ruff check`` (the lint step) gates correctness-class findings; this gate
covers the purely mechanical layout invariants a formatter would enforce,
without imposing a full reformat of the hand-wrapped code:

* no tab characters (indentation is spaces-only),
* no trailing whitespace,
* LF line endings (no CR),
* every file ends with exactly one newline,
* no line longer than :data:`MAX_LINE` columns (mirrors ``ruff.toml``'s
  ``line-length``).

Exit is non-zero with a ``path:line: finding`` list when anything is off;
``--fix`` rewrites the fixable findings (tabs are reported only — expanding
them needs a human to pick the intended column).
"""
import argparse
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
#: directories whose Python sources are gated
SCAN_DIRS = ("src", "tests", "benchmarks", "examples", "tools")
#: mirrors line-length in ruff.toml
MAX_LINE = 100


def python_files():
    for base in SCAN_DIRS:
        for dirpath, dirnames, filenames in os.walk(os.path.join(ROOT, base)):
            dirnames[:] = [d for d in dirnames if d != "__pycache__"]
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    yield os.path.join(dirpath, fn)


def check_file(path, fix=False):
    with open(path, "rb") as f:
        raw = f.read()
    rel = os.path.relpath(path, ROOT)
    problems = []
    if b"\r" in raw:
        problems.append(f"{rel}: CR line endings (expected LF)")
    text = raw.decode("utf-8").replace("\r\n", "\n").replace("\r", "\n")
    lines = text.split("\n")
    for i, line in enumerate(lines, 1):
        if "\t" in line:
            problems.append(f"{rel}:{i}: tab character")
        if line != line.rstrip():
            problems.append(f"{rel}:{i}: trailing whitespace")
        if len(line) > MAX_LINE:
            problems.append(f"{rel}:{i}: line too long "
                            f"({len(line)} > {MAX_LINE})")
    if raw and not text.endswith("\n"):
        problems.append(f"{rel}: missing final newline")
    elif text.endswith("\n\n"):
        problems.append(f"{rel}: multiple trailing newlines")
    if fix and problems:
        fixed = "\n".join(ln.rstrip() for ln in lines)
        fixed = fixed.rstrip("\n") + "\n" if fixed.strip() else ""
        with open(path, "w", newline="\n") as f:
            f.write(fixed)
    return problems


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fix", action="store_true",
                    help="rewrite fixable findings in place (whitespace, "
                         "line endings, final newline)")
    args = ap.parse_args(argv)
    problems = []
    n = 0
    for path in python_files():
        n += 1
        problems.extend(check_file(path, fix=args.fix))
    verb = "fixed/remaining" if args.fix else "found"
    print(f"checked {n} files, {len(problems)} findings {verb}")
    if problems:
        print("\n".join(problems))
        if not args.fix:
            sys.exit(1)


if __name__ == "__main__":
    main()
