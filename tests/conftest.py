"""Shared test configuration: deterministic seeds, a pinned JAX platform,
and the ``tier1`` / ``slow`` marker convention.

Tier policy: the bare tier-1 command (``PYTHONPATH=src python -m pytest -x -q``)
runs everything *not* marked ``slow``; ``slow``-marked tests (large sweep
grids, subprocess-heavy paths) only run with ``--slow``.  ``tier1`` labels the
fast core set so ``-m tier1`` gives a sub-second sanity loop.
"""
import os
import random

# Pin the JAX platform before any test module imports jax: CPU everywhere,
# so results do not depend on what accelerator the host happens to expose.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import pytest  # noqa: E402

SEED = 20260801


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "tier1: fast core test, part of the sub-second sanity set")
    config.addinivalue_line(
        "markers", "slow: expensive test, skipped unless --slow is given")


def pytest_addoption(parser):
    parser.addoption("--slow", action="store_true", default=False,
                     help="also run tests marked slow")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--slow"):
        return
    skip_slow = pytest.mark.skip(reason="slow test: pass --slow to run")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)


@pytest.fixture(autouse=True)
def _deterministic_seeds():
    """Reseed the stdlib and NumPy PRNGs before every test."""
    random.seed(SEED)
    try:
        import numpy as np
        np.random.seed(SEED)
    except ImportError:
        pass
    yield
