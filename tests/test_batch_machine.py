"""Differential tests for the vectorized batch engine (PR 7).

The contract under test extends PR 2's: :class:`repro.core.BatchStepper`
(one numpy max-recurrence pass over B machine configs of the same lowered
program) is **bit-identical** to :class:`Stepper` (the event engine, itself
bit-identical to the per-cycle reference) on every point of a fuzzed
multi-axis grid — cycles, energy, stall breakdown, FIFO push/pop sequences,
occupancy highwater, FIFO-discipline violations, the functional
environment, and deadlock behavior (same message at the same cycle with the
same stall state, surfaced as :class:`BatchDeadlock` instead of an
exception so one bad point cannot take down a batch).

Randomized configurations are drawn with ``hypothesis`` when available
(via tests/_hypothesis_compat.py) and with a seeded stdlib PRNG otherwise,
so the differential property always runs.
"""
import dataclasses
import itertools
import os
import random
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from _hypothesis_compat import given, settings, st  # noqa: E402

from repro.core import (KERNELS, BatchDeadlock, BatchStepper,
                        BatchUnsupported, DeadlockError, MachineConfig,
                        Program, Stepper, SweepPoint, TransformConfig,
                        batch_simulate, batch_supported, grid, lower,
                        run_point, run_sweep)
from repro.core.isa import Instr, OpKind, Queue, Unit
from repro.core.policy import ExecutionPolicy as P

#: every SimResult facet the engines must agree on (the PR-2 set)
FACETS = ("cycles", "energy", "instrs", "stalls", "push_seq", "pop_seq",
          "max_queue_occupancy", "fifo_violations", "env")


def _assert_batch_matches_scalar(prog, cfgs):
    """One batched run vs B scalar event-engine runs, all facets."""
    outs = BatchStepper(prog, cfgs).run()
    assert len(outs) == len(cfgs)
    for cfg, got in zip(cfgs, outs):
        scalar = Stepper(prog, cfg)
        try:
            ref = scalar.run()
        except DeadlockError as e:
            assert isinstance(got, BatchDeadlock), \
                f"scalar deadlocked, batch completed ({cfg})"
            assert (got.message, got.cycle, got.stalls) == \
                (str(e), scalar.cycle, dict(scalar.stalls))
            assert isinstance(got.error(), DeadlockError)
            continue
        assert not isinstance(got, BatchDeadlock), \
            f"batch deadlocked, scalar completed ({cfg}): {got.message}"
        for facet in FACETS:
            assert getattr(ref, facet) == getattr(got, facet), (facet, cfg)


def _config_axis(rng=None):
    """A multi-axis spread of machine configs: symmetric and asymmetric
    depths, latency stretches, and tight deadlock limits."""
    cfgs = []
    for d, lat in itertools.product((1, 2, 4, 8), (1, 3, 8)):
        cfgs.append(MachineConfig(queue_depth=d, queue_latency=lat))
    for di, df in ((1, 8), (8, 1), (2, 16), (16, 2)):
        cfgs.append(MachineConfig(
            queue_depth=4, queue_latency=2,
            queue_depths={Queue.I2F: di, Queue.F2I: df}))
    for lim in (1, 3, 50):
        cfgs.append(MachineConfig(queue_depth=1, queue_latency=8,
                                  deadlock_limit=lim))
    if rng is not None:
        rng.shuffle(cfgs)
    return cfgs


# ---------------------------------------------------------------------------
# Dense small grid (tier1) + randomized fuzz
# ---------------------------------------------------------------------------

@pytest.mark.tier1
@pytest.mark.parametrize("policy", list(P), ids=[p.value for p in P])
def test_batch_engine_matches_stepper_small_grid(policy):
    for kernel in ("expf", "box_muller", "histf"):
        tcfg = TransformConfig(n_samples=8, queue_depth=4, unroll=4)
        try:
            prog = lower(KERNELS[kernel], policy, tcfg)
        except ValueError:
            continue                  # infeasible schedule: nothing to diff
        _assert_batch_matches_scalar(prog, _config_axis())


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_batch_engine_matches_stepper_random_configs(seed):
    """Seeded-PRNG differential fuzz across the whole configuration space."""
    rng = random.Random(seed)
    for _ in range(6):
        kernel = rng.choice(sorted(KERNELS))
        policy = rng.choice(list(P))
        tcfg = TransformConfig(n_samples=rng.choice((8, 16)),
                               queue_depth=rng.choice((1, 2, 4, 8)),
                               unroll=rng.choice((2, 4, 8)))
        try:
            prog = lower(KERNELS[kernel], policy, tcfg)
        except ValueError:
            continue
        _assert_batch_matches_scalar(prog, _config_axis(rng)[:10])


@given(st.sampled_from(sorted(KERNELS)), st.sampled_from(list(P)),
       st.integers(min_value=1, max_value=8),
       st.sampled_from((2, 4, 8)),
       st.sampled_from((8, 16)))
@settings(max_examples=10, deadline=None)
def test_batch_engine_matches_stepper_hypothesis(kernel, policy, depth,
                                                 unroll, n):
    """Property form of the differential check (skips without hypothesis)."""
    tcfg = TransformConfig(n_samples=n, queue_depth=depth, unroll=unroll)
    try:
        prog = lower(KERNELS[kernel], policy, tcfg)
    except ValueError:
        return
    _assert_batch_matches_scalar(prog, _config_axis()[:8])


# ---------------------------------------------------------------------------
# Deadlock parity + API edges
# ---------------------------------------------------------------------------

def _circular_wait_program():
    """INT pops F2I before pushing I2F; FP pops I2F before pushing F2I."""
    ins_i = Instr(uid=0, kind=OpKind.MV, label="i0", srcs=(Queue.F2I,),
                  dst="a", pushes=(Queue.I2F,), push_val="a")
    ins_f = Instr(uid=1, kind=OpKind.FADD, label="f0", srcs=(Queue.I2F,),
                  dst="b", pushes=(Queue.F2I,), push_val="b")
    return Program(name="dead", policy=P.COPIFTV2, mode="dual",
                   streams={Unit.INT: [ins_i], Unit.FP: [ins_f]}, n_samples=1)


@pytest.mark.tier1
def test_batch_deadlock_parity_same_cycle_same_message_same_stalls():
    """A guaranteed deadlock comes back as a BatchDeadlock carrying exactly
    the scalar engine's terminal state, for every point in the batch."""
    prog = _circular_wait_program()
    cfgs = [MachineConfig(evaluate=False, deadlock_limit=lim)
            for lim in (10, 300)]
    _assert_batch_matches_scalar(prog, cfgs)


@pytest.mark.tier1
def test_batch_empty_batch_and_empty_program():
    prog = lower(KERNELS["histf"], P.BASELINE, TransformConfig(n_samples=8))
    assert BatchStepper(prog, []).run() == []
    empty = Program(name="empty", policy=P.BASELINE, mode="single",
                    streams={Unit.INT: []}, n_samples=0)
    for res in BatchStepper(empty, [MachineConfig(), MachineConfig()]).run():
        assert res.cycles == 0 and res.ipc == 0.0


@pytest.mark.tier1
def test_batch_rejects_mixed_evaluate_modes():
    prog = lower(KERNELS["histf"], P.BASELINE, TransformConfig(n_samples=8))
    with pytest.raises(BatchUnsupported):
        BatchStepper(prog, [MachineConfig(evaluate=True),
                            MachineConfig(evaluate=False)])


@pytest.mark.tier1
def test_batch_simulate_and_supported_api():
    prog = lower(KERNELS["expf"], P.COPIFTV2, TransformConfig(n_samples=8))
    assert batch_supported(prog) is None   # None == no unsupported reason
    cfgs = [MachineConfig(queue_depth=d) for d in (4, 8)]
    outs = batch_simulate(prog, cfgs)
    for cfg, got in zip(cfgs, outs):
        ref = Stepper(prog, cfg).run()
        assert (ref.cycles, ref.energy) == (got.cycles, got.energy)


# ---------------------------------------------------------------------------
# Sweep integration: engine="batch" through run_point / run_sweep
# ---------------------------------------------------------------------------

def _strip_engine(rec):
    d = dataclasses.asdict(rec)
    d.pop("engine")
    return d


@pytest.mark.tier1
def test_sweep_batch_engine_matches_event_engine_records():
    """The wired sweep path: identical records (minus the engine column) for
    engine="batch" vs engine="event", including asymmetric geometries and a
    clustered point (routed through the lockstep cluster engine since PR 8;
    tests/test_batch_cluster.py pins that contract in depth)."""
    pts_e = grid(kernels=("expf", "histf"),
                 policies=(P.COPIFT, P.COPIFTV2),
                 queue_depths=(1, 4), queue_latencies=(1, 8),
                 i2f_depths=(None, 2), n_samples=16)
    pts_e += [SweepPoint(kernel="expf", policy="copiftv2", n_samples=16,
                         n_cores=2)]
    pts_b = [dataclasses.replace(p, engine="batch") for p in pts_e]
    recs_e = run_sweep(pts_e, workers=1)
    recs_b = run_sweep(pts_b, workers=1)
    for a, b in zip(recs_e, recs_b):
        assert b.engine == "batch"
        assert _strip_engine(a) == _strip_engine(b)


@pytest.mark.tier1
def test_run_point_batch_single_point_and_unknown_engine():
    pt = SweepPoint(kernel="expf", policy="copiftv2", n_samples=16,
                    engine="batch")
    rec = run_point(pt)
    assert rec.ok and rec.engine == "batch" and rec.equivalent
    ref = run_point(dataclasses.replace(pt, engine="event"))
    assert _strip_engine(rec) == _strip_engine(ref)
    with pytest.raises(ValueError):
        grid(kernels=("expf",), engine="warp")
