"""DSE engine tests: golden-trace regressions locking the machine model's
cycle counts / IPC / energy at pinned design points, monotonicity properties
of the queue geometry, FIFO-discipline and cross-policy equivalence properties
over randomly sampled sweep configurations, Pareto-front laws, and the
``benchmarks.run --smoke`` CI gate."""
import os
import random
import subprocess
import sys

import pytest

from repro.core import (KERNELS, MachineConfig, Stepper, SweepPoint,
                        TransformConfig, dominates, grid, lower,
                        pareto_by_kernel, pareto_front, run_point, run_sweep,
                        simulate, sweep_summary, write_csv)
from repro.core.policy import ExecutionPolicy as P

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# ---------------------------------------------------------------------------
# Golden traces: the machine model is deterministic pure Python, so cycle
# counts, instruction counts and energy are locked exactly.  A diff here means
# the simulator's timing/energy semantics changed — bump deliberately, with a
# changelog note, never incidentally.
# ---------------------------------------------------------------------------

GOLDEN = [
    # (kernel, policy, queue_depth, queue_latency, cycles, instrs, energy)
    ("expf", "baseline", 4, 1, 1232, 1232, 30495.199999999975),
    ("expf", "copift", 4, 1, 1124, 1506, 29132.599999999922),
    ("expf", "copiftv2", 4, 1, 721, 1232, 19073.99999999996),
    ("expf", "copiftv2", 1, 1, 870, 1232, 22351.99999999996),
    ("expf", "copiftv2", 8, 2, 708, 1232, 18787.99999999996),
    ("poly_lcg", "copift", 4, 1, 565, 728, 14982.199999999983),
    ("poly_lcg", "copiftv2", 2, 1, 407, 592, 10898.799999999996),
    ("dequant_dot", "copiftv2", 4, 1, 420, 784, 11715.999999999987),
    ("box_muller", "copiftv2", 4, 1, 1374, 784, 32998.39999999998),
    ("logf", "baseline", 4, 1, 917, 912, 23110.799999999985),
    ("logf", "copiftv2", 4, 2, 608, 912, 16184.799999999977),
    ("histf", "copiftv2", 4, 1, 350, 464, 9228.8),
]


@pytest.mark.tier1
@pytest.mark.parametrize("kernel,policy,depth,lat,cycles,instrs,energy",
                         GOLDEN, ids=[f"{g[0]}-{g[1]}-d{g[2]}l{g[3]}"
                                      for g in GOLDEN])
def test_golden_trace(kernel, policy, depth, lat, cycles, instrs, energy):
    rec = run_point(SweepPoint(kernel=kernel, policy=policy, queue_depth=depth,
                               queue_latency=lat, n_samples=64))
    assert rec.ok, rec.detail
    assert rec.cycles == cycles
    assert rec.instrs_int + rec.instrs_fp == instrs
    assert rec.energy == pytest.approx(energy, rel=1e-12)
    assert rec.ipc == pytest.approx(instrs / cycles, rel=1e-12)
    assert rec.equivalent


# ---------------------------------------------------------------------------
# Monotonicity / bound properties of the design space
# ---------------------------------------------------------------------------

DEPTHS = (1, 2, 4, 8)


def _v2_at_depth(kernel, depth, n=64):
    return run_point(SweepPoint(kernel=kernel, policy="copiftv2",
                                queue_depth=depth, n_samples=n))


@pytest.mark.tier1
@pytest.mark.parametrize("kernel", sorted(KERNELS))
def test_ipc_monotone_in_queue_depth(kernel):
    """Widening the hardware FIFOs never hurts: IPC is non-decreasing (and
    cycles non-increasing) as queue depth grows."""
    recs = [_v2_at_depth(kernel, d) for d in DEPTHS]
    for shallow, deep in zip(recs, recs[1:]):
        assert deep.cycles <= shallow.cycles, kernel
        assert deep.ipc >= shallow.ipc - 1e-12, kernel


@pytest.mark.tier1
@pytest.mark.parametrize("kernel", sorted(KERNELS))
def test_dual_issue_ipc_bounds(kernel):
    """Dual-issue IPC >= single-issue IPC on every kernel, and every policy
    respects the structural issue-width bounds (<=1 single, <=2 dual)."""
    base = run_point(SweepPoint(kernel=kernel, policy="baseline"))
    v2 = run_point(SweepPoint(kernel=kernel, policy="copiftv2"))
    assert base.ipc <= 1.0 + 1e-9
    assert v2.ipc <= 2.0 + 1e-9
    assert v2.ipc >= base.ipc - 1e-12, kernel


@pytest.mark.tier1
def test_stall_breakdown_accounts_idle_cycles():
    """The stepper attributes stall causes; a depth-1 queue must surface
    queue-full/empty pressure that depth 8 relieves."""
    shallow = _v2_at_depth("expf", 1)
    deep = _v2_at_depth("expf", 8)
    q_shallow = sum(v for k, v in shallow.stalls.items() if "queue" in k)
    q_deep = sum(v for k, v in deep.stalls.items() if "queue" in k)
    assert q_shallow > q_deep
    assert all(v >= 0 for v in shallow.stalls.values())


# ---------------------------------------------------------------------------
# Property tests over randomly sampled sweep configurations (no hypothesis
# needed: a seeded stdlib PRNG draws the configurations)
# ---------------------------------------------------------------------------

def _sample_points(n, seed):
    rng = random.Random(seed)
    kernels = sorted(KERNELS)
    return [SweepPoint(kernel=rng.choice(kernels),
                       policy=rng.choice([p.value for p in P]),
                       queue_depth=rng.choice(DEPTHS),
                       queue_latency=rng.choice((1, 2, 4)),
                       unroll=rng.choice((2, 4, 8)),
                       n_samples=32)
            for _ in range(n)]


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_random_configs_equivalent_to_baseline_interpreter(seed):
    """Every sampled configuration that lowers must compute bit-identical
    outputs to the sequential interpreter — the sweep as semantics fuzzer."""
    for rec in map(run_point, _sample_points(8, seed)):
        assert rec.status in ("ok", "rejected"), rec
        if rec.ok:
            assert rec.equivalent, rec
            assert rec.fifo_violations == 0, rec


@pytest.mark.parametrize("seed", [3, 4])
def test_fifo_discipline_push_order_equals_pop_order(seed):
    """Per queue, the runtime push sequence equals the pop sequence exactly:
    both queues fully drain and values arrive in FIFO order."""
    rng = random.Random(seed)
    for _ in range(4):
        kernel = rng.choice(sorted(KERNELS))
        depth = rng.choice(DEPTHS)
        tc = TransformConfig(n_samples=32, queue_depth=depth,
                             unroll=rng.choice((4, 8)))
        prog = lower(KERNELS[kernel], P.COPIFTV2, tc)
        res = simulate(prog, MachineConfig(queue_depth=depth))
        for q, pushed in res.push_seq.items():
            assert pushed == res.pop_seq[q], (kernel, depth, q)
        assert not res.fifo_violations


@pytest.mark.tier1
def test_stepper_is_reentrant_and_resumable():
    """Two interleaved Stepper instances must not interfere, and manual
    stepping must reach the same result as one-shot simulate()."""
    tc = TransformConfig(n_samples=16)
    mk = lambda: lower(KERNELS["expf"], P.COPIFTV2, tc)  # noqa: E731
    a, b = Stepper(mk(), MachineConfig()), Stepper(mk(), MachineConfig())
    while a.step() | b.step():      # non-short-circuit: advance both
        pass
    ra, rb = a.result(), b.result()
    ref = simulate(mk(), MachineConfig())
    for r in (ra, rb):
        assert r.cycles == ref.cycles
        assert r.energy == pytest.approx(ref.energy, rel=1e-12)
        assert r.instrs == ref.instrs


# ---------------------------------------------------------------------------
# Sweep engine + Pareto laws
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_grid_enumerates_cartesian_product():
    pts = grid(kernels=["expf", "logf"], queue_depths=(2, 4),
               queue_latencies=(1, 2), unrolls=(4, 8), n_samples=16)
    assert len(pts) == 2 * 3 * 2 * 2 * 2
    assert len(set(pts)) == len(pts)          # hashable + unique
    with pytest.raises(KeyError):
        grid(kernels=["nope"])


def test_run_sweep_serial_matches_parallel():
    pts = grid(kernels=["dequant_dot"], queue_depths=(2, 4), n_samples=32)
    serial = run_sweep(pts, workers=1)
    parallel = run_sweep(pts, workers=2)
    assert serial == parallel


def test_pareto_front_is_nondominated_and_complete():
    pts = grid(kernels=["expf"], queue_depths=DEPTHS, queue_latencies=(1, 2),
               n_samples=32)
    recs = run_sweep(pts, workers=1)
    front = pareto_front(recs)
    assert front, "front must be non-empty"
    for f in front:                          # no front member dominates another
        assert not any(dominates(g, f) for g in front)
    for r in recs:                           # every off-front point is dominated
        if r.ok and r not in front:
            assert any(dominates(f, r) for f in front), r
    # per-kernel partition covers the same records
    assert pareto_by_kernel(recs)["expf"] == front


def test_sweep_summary_and_csv(tmp_path):
    recs = run_sweep(grid(kernels=["histf", "poly_lcg"], queue_depths=(2, 4),
                          n_samples=16), workers=1)
    s = sweep_summary(recs)
    assert s["n_points"] == len(recs) == 12
    assert s["n_ok"] == s["n_equivalent"] == 12
    assert 0 < s["geomean_ipc_copiftv2"] <= 2.0
    out = tmp_path / "sweep.csv"
    assert write_csv(recs, str(out)) == 12
    lines = out.read_text().strip().splitlines()
    assert len(lines) == 13 and lines[0].startswith("kernel,policy,")


@pytest.mark.slow
def test_full_grid_sweep_all_equivalent():
    """The full default exploration grid (288 configs): everything simulates
    and matches the interpreter.  Slow; the tier-1 proxy is the sampled
    fuzz above plus the benchmark smoke gate."""
    recs = run_sweep(grid(queue_depths=DEPTHS, queue_latencies=(1, 2),
                          unrolls=(4, 8), n_samples=32))
    assert len(recs) == 288
    assert all(r.ok and r.equivalent and not r.fifo_violations for r in recs)


# ---------------------------------------------------------------------------
# CI smoke gate: benchmark sections must run without swallowing failures
# ---------------------------------------------------------------------------

def test_benchmarks_run_smoke():
    env = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}
    res = subprocess.run([sys.executable, "-m", "benchmarks.run", "--smoke"],
                         cwd=ROOT, env=env, capture_output=True, text=True,
                         timeout=300)
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    assert "dse_peak_ipc" in res.stdout
    assert "claims_peak_ipc_v2" in res.stdout
