"""DSE engine tests: golden-trace regressions locking the machine model's
cycle counts / IPC / energy at pinned design points, monotonicity properties
of the queue geometry, FIFO-discipline and cross-policy equivalence properties
over randomly sampled sweep configurations, Pareto-front laws, and the
``benchmarks.run --smoke`` CI gate."""
import dataclasses
import os
import random
import subprocess
import sys

import pytest

from repro.core import (KERNELS, MachineConfig, Stepper, SweepPoint,
                        TransformConfig, clear_worker_caches, dominates,
                        grid, lower, pareto_by_kernel, pareto_front,
                        partition_points, resolve_workers, run_point,
                        run_sweep, simulate, sweep_summary, write_csv)
from repro.core.policy import ExecutionPolicy as P
from repro.core.sweep import _lower_key

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# ---------------------------------------------------------------------------
# Golden traces: the machine model is deterministic pure Python, so cycle
# counts, instruction counts and energy are locked exactly.  A diff here means
# the simulator's timing/energy semantics changed — bump deliberately, with a
# changelog note, never incidentally.
# ---------------------------------------------------------------------------

GOLDEN = [
    # (kernel, policy, queue_depth, queue_latency, cycles, instrs, energy)
    ("expf", "baseline", 4, 1, 1232, 1232, 30495.199999999975),
    ("expf", "copift", 4, 1, 1124, 1506, 29132.599999999922),
    ("expf", "copiftv2", 4, 1, 721, 1232, 19073.99999999996),
    ("expf", "copiftv2", 1, 1, 870, 1232, 22351.99999999996),
    ("expf", "copiftv2", 8, 2, 708, 1232, 18787.99999999996),
    ("poly_lcg", "copift", 4, 1, 565, 728, 14982.199999999983),
    ("poly_lcg", "copiftv2", 2, 1, 407, 592, 10898.799999999996),
    ("dequant_dot", "copiftv2", 4, 1, 420, 784, 11715.999999999987),
    ("box_muller", "copiftv2", 4, 1, 1374, 784, 32998.39999999998),
    ("logf", "baseline", 4, 1, 917, 912, 23110.799999999985),
    ("logf", "copiftv2", 4, 2, 608, 912, 16184.799999999977),
    ("histf", "copiftv2", 4, 1, 350, 464, 9228.8),
    # high-latency points (the event engine's time-skip territory; values
    # locked against the naive reference stepper)
    ("expf", "copiftv2", 1, 8, 1269, 1232, 31129.99999999996),
    ("box_muller", "copiftv2", 1, 4, 1377, 784, 33064.39999999998),
    ("logf", "copiftv2", 2, 8, 729, 912, 18846.799999999977),
    ("dequant_dot", "copift", 4, 8, 807, 984, 21323.799999999974),
    ("poly_lcg", "baseline", 4, 8, 602, 592, 15291.199999999997),
]


@pytest.mark.tier1
@pytest.mark.parametrize("kernel,policy,depth,lat,cycles,instrs,energy",
                         GOLDEN, ids=[f"{g[0]}-{g[1]}-d{g[2]}l{g[3]}"
                                      for g in GOLDEN])
def test_golden_trace(kernel, policy, depth, lat, cycles, instrs, energy):
    rec = run_point(SweepPoint(kernel=kernel, policy=policy, queue_depth=depth,
                               queue_latency=lat, n_samples=64))
    assert rec.ok, rec.detail
    assert rec.cycles == cycles
    assert rec.instrs_int + rec.instrs_fp == instrs
    assert rec.energy == pytest.approx(energy, rel=1e-12)
    assert rec.ipc == pytest.approx(instrs / cycles, rel=1e-12)
    assert rec.equivalent


# ---------------------------------------------------------------------------
# Monotonicity / bound properties of the design space
# ---------------------------------------------------------------------------

DEPTHS = (1, 2, 4, 8)


def _v2_at_depth(kernel, depth, n=64):
    return run_point(SweepPoint(kernel=kernel, policy="copiftv2",
                                queue_depth=depth, n_samples=n))


@pytest.mark.tier1
@pytest.mark.parametrize("kernel", sorted(KERNELS))
def test_ipc_monotone_in_queue_depth(kernel):
    """Widening the hardware FIFOs never hurts: IPC is non-decreasing (and
    cycles non-increasing) as queue depth grows."""
    recs = [_v2_at_depth(kernel, d) for d in DEPTHS]
    for shallow, deep in zip(recs, recs[1:]):
        assert deep.cycles <= shallow.cycles, kernel
        assert deep.ipc >= shallow.ipc - 1e-12, kernel


@pytest.mark.tier1
@pytest.mark.parametrize("kernel", sorted(KERNELS))
def test_dual_issue_ipc_bounds(kernel):
    """Dual-issue IPC >= single-issue IPC on every kernel, and every policy
    respects the structural issue-width bounds (<=1 single, <=2 dual)."""
    base = run_point(SweepPoint(kernel=kernel, policy="baseline"))
    v2 = run_point(SweepPoint(kernel=kernel, policy="copiftv2"))
    assert base.ipc <= 1.0 + 1e-9
    assert v2.ipc <= 2.0 + 1e-9
    assert v2.ipc >= base.ipc - 1e-12, kernel


@pytest.mark.tier1
def test_stall_breakdown_accounts_idle_cycles():
    """The stepper attributes stall causes; a depth-1 queue must surface
    queue-full/empty pressure that depth 8 relieves."""
    shallow = _v2_at_depth("expf", 1)
    deep = _v2_at_depth("expf", 8)
    q_shallow = sum(v for k, v in shallow.stalls.items() if "queue" in k)
    q_deep = sum(v for k, v in deep.stalls.items() if "queue" in k)
    assert q_shallow > q_deep
    assert all(v >= 0 for v in shallow.stalls.values())


# ---------------------------------------------------------------------------
# Property tests over randomly sampled sweep configurations (no hypothesis
# needed: a seeded stdlib PRNG draws the configurations)
# ---------------------------------------------------------------------------

def _sample_points(n, seed):
    rng = random.Random(seed)
    kernels = sorted(KERNELS)
    return [SweepPoint(kernel=rng.choice(kernels),
                       policy=rng.choice([p.value for p in P]),
                       queue_depth=rng.choice(DEPTHS),
                       queue_latency=rng.choice((1, 2, 4)),
                       unroll=rng.choice((2, 4, 8)),
                       n_samples=32)
            for _ in range(n)]


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_random_configs_equivalent_to_baseline_interpreter(seed):
    """Every sampled configuration that lowers must compute bit-identical
    outputs to the sequential interpreter — the sweep as semantics fuzzer."""
    for rec in map(run_point, _sample_points(8, seed)):
        assert rec.status in ("ok", "rejected"), rec
        if rec.ok:
            assert rec.equivalent, rec
            assert rec.fifo_violations == 0, rec


@pytest.mark.parametrize("seed", [3, 4])
def test_fifo_discipline_push_order_equals_pop_order(seed):
    """Per queue, the runtime push sequence equals the pop sequence exactly:
    both queues fully drain and values arrive in FIFO order."""
    rng = random.Random(seed)
    for _ in range(4):
        kernel = rng.choice(sorted(KERNELS))
        depth = rng.choice(DEPTHS)
        tc = TransformConfig(n_samples=32, queue_depth=depth,
                             unroll=rng.choice((4, 8)))
        prog = lower(KERNELS[kernel], P.COPIFTV2, tc)
        res = simulate(prog, MachineConfig(queue_depth=depth))
        for q, pushed in res.push_seq.items():
            assert pushed == res.pop_seq[q], (kernel, depth, q)
        assert not res.fifo_violations


@pytest.mark.tier1
def test_stepper_is_reentrant_and_resumable():
    """Two interleaved Stepper instances must not interfere, and manual
    stepping must reach the same result as one-shot simulate()."""
    tc = TransformConfig(n_samples=16)
    mk = lambda: lower(KERNELS["expf"], P.COPIFTV2, tc)  # noqa: E731
    a, b = Stepper(mk(), MachineConfig()), Stepper(mk(), MachineConfig())
    while a.step() | b.step():      # non-short-circuit: advance both
        pass
    ra, rb = a.result(), b.result()
    ref = simulate(mk(), MachineConfig())
    for r in (ra, rb):
        assert r.cycles == ref.cycles
        assert r.energy == pytest.approx(ref.energy, rel=1e-12)
        assert r.instrs == ref.instrs


# ---------------------------------------------------------------------------
# Sweep engine + Pareto laws
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_grid_enumerates_cartesian_product():
    pts = grid(kernels=["expf", "logf"], queue_depths=(2, 4),
               queue_latencies=(1, 2), unrolls=(4, 8), n_samples=16)
    assert len(pts) == 2 * 3 * 2 * 2 * 2
    assert len(set(pts)) == len(pts)          # hashable + unique
    with pytest.raises(KeyError):
        grid(kernels=["nope"])


def test_run_sweep_serial_matches_parallel():
    pts = grid(kernels=["dequant_dot"], queue_depths=(2, 4), n_samples=32)
    serial = run_sweep(pts, workers=1)
    parallel = run_sweep(pts, workers=2)
    assert serial == parallel


def test_pareto_front_is_nondominated_and_complete():
    pts = grid(kernels=["expf"], queue_depths=DEPTHS, queue_latencies=(1, 2),
               n_samples=32)
    recs = run_sweep(pts, workers=1)
    front = pareto_front(recs)
    assert front, "front must be non-empty"
    for f in front:                          # no front member dominates another
        assert not any(dominates(g, f) for g in front)
    for r in recs:                           # every off-front point is dominated
        if r.ok and r not in front:
            assert any(dominates(f, r) for f in front), r
    # per-kernel partition covers the same records
    assert pareto_by_kernel(recs)["expf"] == front


def test_sweep_summary_and_csv(tmp_path):
    recs = run_sweep(grid(kernels=["histf", "poly_lcg"], queue_depths=(2, 4),
                          n_samples=16), workers=1)
    s = sweep_summary(recs)
    assert s["n_points"] == len(recs) == 12
    assert s["n_ok"] == s["n_equivalent"] == 12
    assert 0 < s["geomean_ipc_copiftv2"] <= 2.0
    out = tmp_path / "sweep.csv"
    assert write_csv(recs, str(out)) == 12
    lines = out.read_text().strip().splitlines()
    assert len(lines) == 13 and lines[0].startswith("kernel,policy,")


@pytest.mark.slow
def test_full_grid_sweep_all_equivalent():
    """The full default exploration grid (336 configs): everything simulates
    and matches the interpreter.  Slow; the tier-1 proxy is the sampled
    fuzz above plus the benchmark smoke gate."""
    recs = run_sweep(grid(queue_depths=DEPTHS, queue_latencies=(1, 2),
                          unrolls=(4, 8), n_samples=32))
    assert len(recs) == 336
    assert all(r.ok and r.equivalent and not r.fifo_violations for r in recs)


# ---------------------------------------------------------------------------
# Worker sizing, grid partitioning, and the per-worker caches
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_resolve_workers_small_sweeps_parallelize(monkeypatch):
    """The old ``len(points) // 8`` floor forced sweeps under 16 points
    serial on any host; sizing is now ``min(cpu, n_points)``."""
    monkeypatch.delenv("REPRO_SWEEP_WORKERS", raising=False)
    monkeypatch.setattr(os, "cpu_count", lambda: 8)
    assert resolve_workers(4) == 4            # small sweep: one worker/point
    assert resolve_workers(100) == 8          # big sweep: bounded by cpus
    assert resolve_workers(0) == 1            # floor
    assert resolve_workers(100, workers=3) == 3   # explicit wins


@pytest.mark.tier1
def test_resolve_workers_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_SWEEP_WORKERS", "1")
    assert resolve_workers(1000) == 1
    monkeypatch.setenv("REPRO_SWEEP_WORKERS", "5")
    assert resolve_workers(1000) == 5
    assert resolve_workers(1000, workers=2) == 2  # explicit beats env


@pytest.mark.tier1
def test_partition_points_is_complete_presized_and_cache_friendly():
    pts = grid(queue_depths=(1, 2, 4, 8), queue_latencies=(1, 2, 4),
               n_samples=8)
    for workers in (1, 3, 7, len(pts), len(pts) + 9):
        parts = partition_points(pts, workers)
        flat = sorted(i for part in parts for i in part)
        assert flat == list(range(len(pts)))          # exact partition
        assert len(parts) <= workers
        # presized: no worker exceeds ceil(n/workers) by more than one
        # whole lowering-key group (groups are never split)
        for part in parts:
            keys = [_lower_key(pts[i]) for i in part]
            for key in set(keys):
                owners = [p for p in parts
                          if any(_lower_key(pts[i]) == key for i in p)]
                assert len(owners) == 1               # group stays together


@pytest.mark.tier1
def test_lowering_key_drops_latency_always_and_depth_for_queue_free():
    base = dict(kernel="expf", n_samples=16)
    assert (_lower_key(SweepPoint(policy="copiftv2", queue_latency=1, **base))
            == _lower_key(SweepPoint(policy="copiftv2", queue_latency=8,
                                     **base)))
    v2_d = {_lower_key(SweepPoint(policy="copiftv2", queue_depth=d, **base))
            for d in (1, 8)}
    assert len(v2_d) == 2                     # depth shapes the v2 schedule
    for pol in ("baseline", "copift"):        # queue-free: depth normalized
        keys = {_lower_key(SweepPoint(policy=pol, queue_depth=d, **base))
                for d in (1, 8)}
        assert len(keys) == 1, pol


def test_cached_pipeline_records_match_uncached():
    """The memoized lowering/reference caches (including the COPIFTv2
    prefix + depth-saturation reuse) must be invisible in the records."""
    pts = grid(kernels=["expf", "box_muller"], queue_depths=(1, 8, 16),
               queue_latencies=(1, 4), n_samples=16)
    clear_worker_caches()
    cached = [run_point(p) for p in pts]
    uncached = [run_point(p, use_caches=False) for p in pts]
    assert cached == uncached


@pytest.mark.tier1
def test_asymmetric_queue_depths_sweep():
    """Asymmetric I2F/F2I FIFO geometries: the tighter queue binds its own
    occupancy, the grid crosses the override axes, and every point still
    matches the interpreter."""
    tight = run_point(SweepPoint(kernel="expf", policy="copiftv2",
                                 queue_depth=4, queue_depth_i2f=1,
                                 queue_depth_f2i=8, n_samples=32))
    assert tight.ok and tight.equivalent
    assert tight.max_occ_i2f <= 1 and tight.max_occ_f2i <= 8
    # same schedule (both target min depth 1), one queue relaxed: widening
    # F2I from 1 to 8 can only help
    sym1 = run_point(SweepPoint(kernel="expf", policy="copiftv2",
                                queue_depth=1, n_samples=32))
    asym = run_point(SweepPoint(kernel="expf", policy="copiftv2",
                                queue_depth=1, queue_depth_f2i=8,
                                n_samples=32))
    assert asym.cycles <= sym1.cycles
    pts = grid(kernels=["expf"], policies=[P.COPIFTV2], queue_depths=(4,),
               i2f_depths=(None, 1), f2i_depths=(None, 2), n_samples=16)
    assert len(pts) == 4
    recs = run_sweep(pts, workers=1)
    assert all(r.ok and r.equivalent for r in recs)
    assert {(r.queue_depth_i2f, r.queue_depth_f2i) for r in recs} == \
        {(None, None), (None, 2), (1, None), (1, 2)}


def test_run_point_engines_agree():
    """Both engines must produce identical sweep records (mod the tag)."""
    pts = grid(kernels=["logf"], queue_depths=(1, 4), queue_latencies=(1, 8),
               n_samples=16)
    for p in pts:
        ev = run_point(p)
        cy = run_point(dataclasses.replace(p, engine="cycle"))
        assert ev.engine == "event" and cy.engine == "cycle"
        assert dataclasses.replace(ev, engine="x") == \
            dataclasses.replace(cy, engine="x")


# ---------------------------------------------------------------------------
# CI smoke gate: benchmark sections must run without swallowing failures
# ---------------------------------------------------------------------------

def test_benchmarks_run_smoke():
    env = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}
    res = subprocess.run([sys.executable, "-m", "benchmarks.run", "--smoke"],
                         cwd=ROOT, env=env, capture_output=True, text=True,
                         timeout=300)
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    assert "dse_peak_ipc" in res.stdout
    assert "claims_peak_ipc_v2" in res.stdout
    assert "sweep_perf_speedup_event_cached" in res.stdout
    assert "sweep_scale_speedup_cached" in res.stdout
    assert "cluster_sweep_scale_speedup_cached" in res.stdout
    assert "calibration_expf_ipc_gain" in res.stdout
    assert "cluster_headline_speedup_4c" in res.stdout
    assert "cluster_pipeline_cluster_matmul_x4_ipc_ratio" in res.stdout
    assert "front_diff_drift_findings" in res.stdout
    assert "serve_slo_bursty_tput_at_slo_gain" in res.stdout
    assert "serve_prefill_ttft_wall_gain" in res.stdout
    # per-section pass/fail summary: every section reports, none failed
    assert "# --- summary ---" in res.stdout
    assert "# FAIL" not in res.stdout
    assert res.stdout.count("# PASS:") == 11


# ---------------------------------------------------------------------------
# Batch engine x worker partitioning (PR 7): grouping happens once, inside
# each worker's partition — never double-partitioned, never starving workers
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_batch_partition_no_double_partition_and_no_starvation(monkeypatch):
    """`partition_points` fans the grid out once; each worker's
    `_run_indexed` then groups its own slice by lowered program.  On small
    grids the partition must neither submit empty workers nor split a
    lowering-key group (which would shrink batch widths across workers)."""
    from repro.core import sweep as sweep_mod
    pts = grid(kernels=["expf"], policies=(P.COPIFT,),
               queue_depths=(1, 2, 4, 8), queue_latencies=(1, 8),
               n_samples=16, engine="batch")
    # 8 points, 1 lowering-key group (COPIFT is depth/latency-insensitive):
    # many workers must collapse to one non-empty partition, not 7 idle ones
    parts = [p for p in partition_points(pts, 16) if p]
    assert len(parts) == 1 and sorted(parts[0]) == list(range(len(pts)))
    # the batch path sees each group exactly once per worker: count
    # BatchStepper constructions through the serial run_sweep path
    calls = []
    real = sweep_mod.BatchStepper

    class CountingBatchStepper(real):
        def __init__(self, prog, cfgs):
            calls.append(len(cfgs))
            super().__init__(prog, cfgs)

    monkeypatch.setattr(sweep_mod, "BatchStepper", CountingBatchStepper)
    recs = run_sweep(pts, workers=1)
    assert all(r.ok for r in recs)
    assert calls == [len(pts)]       # one group-wide batch, no re-partition


@pytest.mark.tier1
def test_batch_records_group_by_program_identity():
    """Depth-insensitive policies share one lowered program across the whole
    machine axis; the grouped batch path must merge them into a single
    BatchStepper call and still return records in input order."""
    from repro.core.sweep import _batch_records, _batch_eligible
    pts = grid(kernels=["expf"], policies=(P.COPIFT, P.COPIFTV2),
               queue_depths=(2, 4, 8), queue_latencies=(1, 4),
               n_samples=16, engine="batch")
    assert all(_batch_eligible(p) for p in pts)
    clear_worker_caches()
    out = _batch_records(list(enumerate(pts)))
    assert sorted(i for i, _ in out) == list(range(len(pts)))
    for i, rec in out:
        ref = run_point(dataclasses.replace(pts[i], engine="event"))
        assert dataclasses.replace(rec, engine="x") == \
            dataclasses.replace(ref, engine="x")


@pytest.mark.tier1
def test_batch_engine_mixed_with_cluster_and_invalid_geometry():
    """_run_indexed peels batch-eligible points; clustered and malformed
    points take the per-point path — one record per index either way."""
    from repro.core.sweep import _run_indexed
    pts = [SweepPoint(kernel="expf", policy="copiftv2", n_samples=16,
                      engine="batch"),
           SweepPoint(kernel="expf", policy="copiftv2", n_samples=16,
                      engine="batch", n_cores=2),
           SweepPoint(kernel="expf", policy="copiftv2", n_samples=16,
                      engine="batch", n_cores=0)]
    out = dict(_run_indexed(list(enumerate(pts))))
    assert len(out) == 3
    assert out[0].ok and out[0].engine == "batch"
    assert out[1].ok and out[1].n_cores == 2      # event-engine fallback
    assert out[2].status == "rejected"
