"""Calibration-layer tests: lossless CSV round trips, strict artifact
schema (missing/extra fields rejected, stale versions fall back with a
warning), objective-aware selection laws, and the consumer paths —
queue_matmul / serve / train demonstrably load operating points from a tmp
``REPRO_CALIBRATION_DIR``."""
import copy
import dataclasses
import io
import json
import os
import sys

import pytest

from repro.config import ModelConfig, RunConfig
from repro.core import (CalibrationError, OperatingPoint, StaleArtifactError,
                        SweepPoint, SweepRecord, calibrate,
                        clear_policy_table_cache, default_table, grid,
                        pareto_front, read_csv, run_point, run_sweep,
                        select_operating_point, validate_artifact, write_csv)
from repro.core.calibrate import (SCHEMA_VERSION, artifact_path,
                                  load_artifact, never_dominated_by)
from repro.core.policy import ExecutionPolicy as P

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: small but real grid that includes the hard-coded default configuration
#: (copiftv2, depth 4, latency 1, unroll 8)
TINY_GRID = dict(queue_depths=(1, 2, 4), queue_latencies=(1,),
                 unrolls=(4, 8), n_samples=16)


@pytest.fixture
def tmp_calibration(tmp_path, monkeypatch):
    """Point every consumer at an isolated artifact directory."""
    monkeypatch.setenv("REPRO_CALIBRATION_DIR", str(tmp_path))
    clear_policy_table_cache()
    yield tmp_path
    clear_policy_table_cache()


# ---------------------------------------------------------------------------
# CSV emission <-> re-parse round trip
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_csv_round_trip_is_lossless(tmp_path):
    pts = grid(kernels=["expf", "histf"], queue_depths=(1, 4),
               i2f_depths=(None, 1), n_samples=16)
    recs = run_sweep(pts, workers=1)
    # adversarial rows: rejected status with CSV-hostile detail text, and a
    # deadlock-shaped record with empty metrics
    recs.append(dataclasses.replace(
        copy.deepcopy(recs[0]), status="rejected", equivalent=False,
        detail='unroll=3 infeasible, "quoted", comma,\nand a newline',
        stalls={}))
    path = str(tmp_path / "sweep.csv")
    assert write_csv(recs, path) == len(recs)
    assert read_csv(path) == recs
    # text-handle round trip too (what the CLI pipes through)
    buf = io.StringIO()
    write_csv(recs, buf)
    buf.seek(0)
    assert read_csv(buf) == recs


@pytest.mark.tier1
def test_read_csv_rejects_foreign_header(tmp_path):
    path = tmp_path / "bad.csv"
    path.write_text("kernel,ipc\nexpf,1.0\n")
    with pytest.raises(ValueError, match="header"):
        read_csv(str(path))


# ---------------------------------------------------------------------------
# Objective-aware selection over the Pareto front
# ---------------------------------------------------------------------------

def _rec(ipc, energy, **kw):
    base = dict(kernel="synth", policy="copiftv2", queue_depth=4,
                queue_latency=1, unroll=8, unroll_int=None, n_samples=16,
                status="ok", cycles=100, efficiency=1.0 / energy)
    base.update(kw)
    return SweepRecord(ipc=ipc, energy=energy, **base)


SYNTH_FRONT = [_rec(2.0, 100.0, queue_depth=8), _rec(1.9, 50.0),
               _rec(1.0, 10.0, queue_depth=1)]


@pytest.mark.tier1
def test_selection_objectives_and_tolerance():
    pick, why = select_operating_point(SYNTH_FRONT, "max-ipc")
    assert pick.ipc == 2.0 and "max-ipc" in why
    pick, _ = select_operating_point(SYNTH_FRONT, "min-energy")
    assert pick.energy == 10.0
    # bounded: best IPC whose energy fits the budget
    pick, _ = select_operating_point(SYNTH_FRONT, "energy-bounded-ipc",
                                     energy_budget=60.0)
    assert pick.ipc == 1.9
    # infeasible budget degrades to min-energy, and says so
    pick, why = select_operating_point(SYNTH_FRONT, "energy-bounded-ipc",
                                       energy_budget=5.0)
    assert pick.energy == 10.0 and "infeasible" in why
    # dominance tolerance: a 5% IPC concession buys the 2x cheaper point
    pick, _ = select_operating_point(SYNTH_FRONT, "max-ipc", tolerance=0.1)
    assert pick.ipc == 1.9 and pick.energy == 50.0
    with pytest.raises(ValueError):
        select_operating_point(SYNTH_FRONT, "max-ipc-typo")
    with pytest.raises(ValueError):
        select_operating_point(SYNTH_FRONT, "energy-bounded-ipc")
    with pytest.raises(CalibrationError):
        select_operating_point([], "max-ipc")


@pytest.mark.tier1
def test_selection_prefers_cheaper_hardware_on_exact_ties():
    """Equal (ipc, energy): the shallower FIFO / smaller unroll wins."""
    tie = [_rec(1.5, 40.0, queue_depth=8, unroll=8),
           _rec(1.5, 40.0, queue_depth=2, unroll=4)]
    for objective in ("max-ipc", "min-energy"):
        pick, _ = select_operating_point(tie, objective)
        assert (pick.queue_depth, pick.unroll) == (2, 4), objective


def test_calibrated_point_on_front_never_dominated_by_default(tmp_calibration):
    """The acceptance contract: per kernel, the selection is a front member
    and the old hard-coded default never dominates it."""
    recs = calibrate(kernels=["expf", "poly_lcg"], grid_kw=TINY_GRID,
                     workers=1, write=False)
    for kernel, rec in recs.items():
        assert rec.selected in rec.front
        default = run_point(SweepPoint(kernel=kernel, policy="copiftv2",
                                       queue_depth=4, queue_latency=1,
                                       unroll=8, n_samples=16))
        assert default.ok
        assert never_dominated_by(rec, default), kernel
        # and the front really is the Pareto front of a sweep containing
        # the default config, so the selection is globally non-dominated
        front = pareto_front(run_sweep(
            grid(kernels=[kernel], **TINY_GRID), workers=1))
        assert rec.selected in [
            {f: getattr(r, f) for f in rec.selected} for r in front]


# ---------------------------------------------------------------------------
# Artifact schema strictness + stale fallback
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def one_artifact_dict():
    recs = calibrate(kernels=["histf"], grid_kw=TINY_GRID, workers=1,
                     write=False)
    return recs["histf"].to_dict()


@pytest.mark.tier1
def test_artifact_schema_accepts_the_emitted_layout(one_artifact_dict):
    validate_artifact(one_artifact_dict)     # must not raise


@pytest.mark.tier1
@pytest.mark.parametrize("missing", ["kernel", "objective", "selected",
                                     "front", "grid", "provenance",
                                     "rationale"])
def test_artifact_schema_rejects_missing_fields(one_artifact_dict, missing):
    d = copy.deepcopy(one_artifact_dict)
    d.pop(missing)
    with pytest.raises(CalibrationError, match=missing):
        validate_artifact(d)


@pytest.mark.tier1
def test_artifact_schema_rejects_extra_and_malformed_fields(one_artifact_dict):
    d = copy.deepcopy(one_artifact_dict)
    d["surprise"] = 1
    with pytest.raises(CalibrationError, match="surprise"):
        validate_artifact(d)
    d = copy.deepcopy(one_artifact_dict)
    d["selected"].pop("queue_depth")
    with pytest.raises(CalibrationError, match="queue_depth"):
        validate_artifact(d)
    d = copy.deepcopy(one_artifact_dict)
    d["front"][0]["bonus"] = 2
    with pytest.raises(CalibrationError, match="bonus"):
        validate_artifact(d)
    d = copy.deepcopy(one_artifact_dict)
    d["objective"]["name"] = "fastest-vibes"
    with pytest.raises(CalibrationError, match="fastest-vibes"):
        validate_artifact(d)
    d = copy.deepcopy(one_artifact_dict)
    d["selected"] = dict(d["front"][0], queue_depth=999)
    with pytest.raises(CalibrationError, match="front member"):
        validate_artifact(d)


@pytest.mark.tier1
def test_artifact_version_bump_is_stale(one_artifact_dict):
    d = copy.deepcopy(one_artifact_dict)
    d["schema_version"] = SCHEMA_VERSION + 1
    with pytest.raises(StaleArtifactError):
        validate_artifact(d)


def test_stale_artifact_falls_back_to_defaults_with_warning(tmp_calibration):
    calibrate(kernels=["expf", "dequant_dot"], grid_kw=TINY_GRID, workers=1)
    stale = artifact_path("dequant_dot")
    d = json.load(open(stale))
    d["schema_version"] = SCHEMA_VERSION + 1
    json.dump(d, open(stale, "w"))
    clear_policy_table_cache()
    with pytest.warns(UserWarning, match="stale"):
        table = default_table()
    # the stale kernel's consumers degrade to defaults ...
    assert table.resolve("queue_matmul").source == "default"
    assert table.resolve("train").source == "default"
    # ... while intact artifacts keep serving their workloads
    assert table.resolve("serve").source == "calibrated"


def test_corrupt_artifact_also_falls_back(tmp_calibration):
    calibrate(kernels=["expf"], grid_kw=TINY_GRID, workers=1)
    with open(artifact_path("expf"), "a") as fh:
        fh.write("not json")
    clear_policy_table_cache()
    with pytest.warns(UserWarning, match="ignoring calibration artifact"):
        table = default_table()
    assert table.resolve("serve").source == "default"


# ---------------------------------------------------------------------------
# Consumers load calibration through REPRO_CALIBRATION_DIR
# ---------------------------------------------------------------------------

def test_queue_matmul_loads_calibrated_operating_point(tmp_calibration):
    import jax
    import numpy as np
    from repro.kernels import queue_matmul
    from repro.kernels.queue_matmul import ops
    from repro.kernels.queue_matmul.ref import matmul_ref

    calibrate(kernels=["dequant_dot"], grid_kw=TINY_GRID, workers=1)
    art = load_artifact(artifact_path("dequant_dot"))
    op = ops.operating_point()
    assert op.source == "calibrated"
    assert op.queue_depth == art.selected["queue_depth"]
    assert op.unroll == art.selected["unroll"]
    # explicit arguments still beat the table, and the calibrated path
    # actually runs the kernel (ring depth/unroll come from the artifact)
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 256))
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 32))
    y = queue_matmul(x, w, block=(128, 128, 128))
    np.testing.assert_allclose(np.asarray(y), np.asarray(matmul_ref(x, w)),
                               rtol=2e-4, atol=2e-4)


def test_queue_matmul_explicit_depth_survives_calibrated_policy(
        tmp_calibration, monkeypatch):
    """An explicit depth sweep must stay a depth sweep even when the table
    would resolve a policy (BASELINE/COPIFT) that discards depth."""
    from repro.kernels.queue_matmul import ops

    calls = []
    monkeypatch.setattr(
        ops, "_queue_matmul",
        lambda x, w, **kw: calls.append(kw) or x @ w)
    # a table whose resolved policy would ignore depth entirely
    monkeypatch.setattr(
        ops, "operating_point",
        lambda: OperatingPoint(policy=P.BASELINE, source="calibrated"))
    import jax.numpy as jnp
    x = jnp.ones((4, 4)); w = jnp.ones((4, 4))
    ops.queue_matmul(x, w, depth=3)
    assert calls[-1]["depth_x"] == calls[-1]["depth_w"] == 3
    assert calls[-1]["policy"] is P.COPIFTV2     # the depth-honouring path
    # a single-ring override keeps the other ring on the symmetric depth
    ops.queue_matmul(x, w, depth=3, depth_w=1)
    assert (calls[-1]["depth_x"], calls[-1]["depth_w"]) == (3, 1)
    assert calls[-1]["policy"] is P.COPIFTV2
    ops.queue_matmul(x, w)                       # no explicit depth: table wins
    assert calls[-1]["policy"] is P.BASELINE


def test_queue_matmul_asymmetric_ring_depths_from_calibration(
        tmp_calibration, monkeypatch):
    """Satellite contract: the x ring takes the calibrated I2F depth, the w
    ring the F2I depth, each falling back to the symmetric queue_depth."""
    from repro.kernels.queue_matmul import ops

    calls = []
    monkeypatch.setattr(
        ops, "_queue_matmul",
        lambda x, w, **kw: calls.append(kw) or x @ w)
    monkeypatch.setattr(
        ops, "operating_point",
        lambda: OperatingPoint(policy=P.COPIFTV2, queue_depth=4,
                               queue_depth_i2f=2, queue_depth_f2i=8,
                               unroll=4, source="calibrated"))
    import jax.numpy as jnp
    x = jnp.ones((4, 4)); w = jnp.ones((4, 4))
    ops.queue_matmul(x, w)
    assert (calls[-1]["depth_x"], calls[-1]["depth_w"]) == (2, 8)
    assert calls[-1]["unroll"] == 4
    # explicit per-ring override beats the calibrated asymmetric geometry
    ops.queue_matmul(x, w, depth_x=16)
    assert (calls[-1]["depth_x"], calls[-1]["depth_w"]) == (16, 8)


def test_serve_engine_resolves_policy_at_startup(tmp_calibration):
    import jax.numpy as jnp                              # noqa: F401
    from repro.serve import ServeEngine

    # a COPIFT-only sweep forces the calibrated policy to differ from the
    # RunConfig default (COPIFTV2), so loading is observable
    calibrate(kernels=["expf"], grid_kw=dict(policies=(P.COPIFT,),
                                             **TINY_GRID), workers=1)
    cfg = ModelConfig(name="tiny", family="dense", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=1, d_ff=32, vocab=64)
    rc = RunConfig(dtype="float32", param_dtype="float32", remat=False)
    eng = ServeEngine({}, cfg, rc, batch_slots=2, max_len=8)
    assert eng.operating_point.source == "calibrated"
    assert eng.rc.policy is P.COPIFT
    # explicit override wins
    eng = ServeEngine({}, cfg, rc, batch_slots=2, max_len=8,
                      operating_point=OperatingPoint(policy=P.BASELINE))
    assert eng.operating_point.source == "override"
    assert eng.rc.policy is P.BASELINE


def test_train_step_resolves_policy_at_startup(tmp_calibration):
    from repro.train.step import resolve_run_config

    calibrate(kernels=["dequant_dot"],
              grid_kw=dict(policies=(P.COPIFT,), **TINY_GRID), workers=1)
    rc, op = resolve_run_config(RunConfig(), "train")
    assert op.source == "calibrated" and rc.policy is P.COPIFT
    rc, op = resolve_run_config(
        RunConfig(), "train",
        operating_point=OperatingPoint(policy=P.BASELINE))
    assert op.source == "override" and rc.policy is P.BASELINE
    # a caller-pinned (non-default) RunConfig policy stays authoritative,
    # while the calibrated queue geometry still applies
    cal = default_table().resolve("train")
    rc, op = resolve_run_config(RunConfig(policy=P.BASELINE), "train")
    assert op.source == "override" and rc.policy is P.BASELINE
    assert (op.queue_depth, op.unroll) == (cal.queue_depth, cal.unroll)
    # no artifact for the workload or its proxy: paper defaults
    clear_policy_table_cache()
    os.remove(artifact_path("dequant_dot"))
    rc, op = resolve_run_config(RunConfig(), "train")
    assert op.source == "default" and rc.policy is P.COPIFTV2


@pytest.mark.tier1
def test_policy_table_resolution_order(tmp_calibration):
    table = default_table()
    assert table.entries == {}                       # empty tmp dir
    assert table.resolve("queue_matmul").source == "default"
    pin = OperatingPoint(policy=P.BASELINE, queue_depth=2)
    got = table.resolve("queue_matmul", override=pin)
    assert got.source == "override" and got.queue_depth == 2
    got = table.resolve("serve", queue_depth=16)
    assert got.source == "override" and got.queue_depth == 16


@pytest.mark.tier1
def test_resolve_through_workload_queue_latency_class(tmp_calibration):
    """Schema-v4 consumers: a workload whose fabric pins the queue-latency
    class gets that class's per-latency selection, with the global point as
    fallback for classes the calibration never swept."""
    from repro.core.policy import WORKLOAD_QUEUE_LATENCIES
    calibrate(kernels=["dequant_dot"],
              grid_kw=dict(queue_depths=(1, 2, 4), queue_latencies=(1, 2),
                           unrolls=(4, 8), n_samples=16), workers=1)
    clear_policy_table_cache()
    table = default_table()
    rec = load_artifact(artifact_path("dequant_dot"))
    assert set(rec.selected_by_latency) == {"1", "2"}
    # train streams through the shared-TCDM interconnect: latency class 2,
    # so its resolution is the class-2 selection, not the global winner
    assert WORKLOAD_QUEUE_LATENCIES["train"] == 2
    got = table.resolve("train")
    assert got.source == "calibrated"
    assert got == rec.operating_point_for(2) and got.queue_latency == 2
    # an explicit class pin beats the workload's table entry
    assert table.resolve("train", queue_latency=1) == \
        rec.operating_point_for(1)
    # a class the calibration never swept falls back to the global point
    assert table.resolve("train", queue_latency=7) == rec.operating_point()
    # field overrides still apply on top of the class selection
    assert table.resolve("train", queue_depth=16).queue_depth == 16


# ---------------------------------------------------------------------------
# serve-slo objective + schema v5 (per-traffic selections)
# ---------------------------------------------------------------------------

#: synthetic serve-slo front: close enough throughputs that none saturates
#: at medium load, with the energy/throughput trade inverted (the fastest
#: point is the hungriest) so the J/token bound is discriminating
SERVE_FRONT = [
    _rec(2.0, 160.0, cycles=50, throughput=16 / 50, queue_depth=8),  # 10 J/tok
    _rec(1.8, 80.0, cycles=60, throughput=16 / 60),                  # 5 J/tok
    _rec(1.5, 32.0, cycles=80, throughput=16 / 80, queue_depth=1),   # 2 J/tok
]


@pytest.mark.tier1
def test_estimated_p99_sojourn_is_a_queueing_estimate():
    from repro.core.calibrate import estimated_p99_sojourn
    r = SERVE_FRONT[0]
    light, heavy = (estimated_p99_sojourn(r, 0.1 * r.throughput),
                    estimated_p99_sojourn(r, 0.9 * r.throughput))
    assert 0 < light < heavy                     # queueing delay grows
    assert estimated_p99_sojourn(r, r.throughput) == float("inf")  # rho>=1


@pytest.mark.tier1
def test_serve_slo_selection_max_throughput_under_bounds():
    # unconstrained enough: the fastest point wins
    pick, why = select_operating_point(SERVE_FRONT, "serve-slo",
                                       slo_p99=100.0)
    assert pick.cycles == 50 and "serve-slo" in why
    # the J/token budget excludes the hungry fast point
    pick, why = select_operating_point(SERVE_FRONT, "serve-slo",
                                       slo_p99=100.0, energy_budget=6.0)
    assert pick.cycles == 60 and "J/tok" in why
    # an unmeetable bound degrades to the closest point and says so
    pick, why = select_operating_point(SERVE_FRONT, "serve-slo",
                                       slo_p99=10.0)
    assert pick.cycles == 50 and "INFEASIBLE" in why
    # no declared bound: the auto headroom keeps the selection meaningful
    pick, why = select_operating_point(SERVE_FRONT, "serve-slo")
    assert "auto bound" in why


def test_serve_slo_calibration_v5_round_trip(tmp_calibration):
    from repro.core.policy import TRAFFIC_LEVELS
    rec = calibrate(kernels=["expf"], objective="serve-slo", slo_p99=400.0,
                    grid_kw=TINY_GRID, workers=1)["expf"]
    assert rec.schema_version == SCHEMA_VERSION
    assert set(rec.selected_by_traffic) == set(TRAFFIC_LEVELS)
    for lvl, entry in rec.selected_by_traffic.items():
        assert entry["traffic"] == TRAFFIC_LEVELS[lvl]
        assert "serve-slo" in entry["rationale"]
        assert rec.operating_point_for_traffic(lvl) is not None
    validate_artifact(rec.to_dict())             # strict schema accepts v5
    loaded = load_artifact(artifact_path("expf"))
    assert loaded.to_dict() == rec.to_dict()     # disk round trip lossless
    # a level the artifact never analysed falls through to None
    bare = copy.deepcopy(rec)
    bare.selected_by_traffic = {}
    assert bare.operating_point_for_traffic("high") is None


def test_every_objective_emits_per_traffic_selections(one_artifact_dict):
    """v5 contract: ``selected_by_traffic`` is computed for every
    calibration, not only under the serve-slo objective, so serve
    consumers can steer by traffic regardless of how the artifact was
    calibrated."""
    from repro.core.policy import TRAFFIC_LEVELS
    assert set(one_artifact_dict["selected_by_traffic"]) \
        == set(TRAFFIC_LEVELS)


def test_v4_artifact_is_stale_and_falls_back(tmp_calibration):
    """Pre-traffic (schema v4) artifacts must not be silently reinterpreted:
    they are stale, warn, and degrade to defaults until recalibrated."""
    calibrate(kernels=["expf"], grid_kw=TINY_GRID, workers=1)
    path = artifact_path("expf")
    d = json.load(open(path))
    d["schema_version"] = SCHEMA_VERSION - 1
    d.pop("selected_by_traffic")
    del d["objective"]["slo_p99"]
    json.dump(d, open(path, "w"))
    with pytest.raises(StaleArtifactError):
        validate_artifact(d)
    clear_policy_table_cache()
    with pytest.warns(UserWarning, match="stale"):
        table = default_table()
    assert table.resolve("serve").source == "default"


def test_resolve_serve_by_traffic_level(tmp_calibration):
    calibrate(kernels=["expf"], objective="serve-slo", slo_p99=400.0,
              grid_kw=TINY_GRID, workers=1)
    clear_policy_table_cache()
    table = default_table()
    rec = load_artifact(artifact_path("expf"))
    for lvl in ("low", "high"):
        got = table.resolve("serve", traffic=lvl)
        assert got.source == "calibrated"
        assert got == rec.operating_point_for_traffic(lvl)
    # no traffic pin: the global selection (possibly via the latency class)
    assert table.resolve("serve").source == "calibrated"
    # an unanalysed level falls back instead of raising
    assert table.resolve("serve", traffic="flash-crowd") == \
        table.resolve("serve")
    # overrides still beat the traffic selection
    assert table.resolve("serve", traffic="high", queue_depth=16) \
        .queue_depth == 16


# ---------------------------------------------------------------------------
# benchmarks.run smoke: per-section summary + non-zero exit on failure
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_run_sections_summarizes_and_fails_nonzero(capsys):
    sys.path.insert(0, ROOT)
    try:
        from benchmarks.run import _run_sections
    finally:
        sys.path.pop(0)

    def boom():
        raise RuntimeError("kaput")

    _run_sections([("fine", lambda: print("ok"))])     # all-pass: no exit
    with pytest.raises(SystemExit) as ei:
        _run_sections([("fine", lambda: None), ("broken", boom)])
    assert "broken" in str(ei.value)
    out = capsys.readouterr().out
    assert "# PASS: fine" in out
    assert "# FAIL: broken (RuntimeError: kaput)" in out
