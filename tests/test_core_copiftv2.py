"""Tests for the COPIFTv2 reproduction layer (transforms + machine model)."""
import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (KERNELS, MachineConfig,
                        TransformConfig, lower, run_suite, simulate,
                        summarize)
from repro.core.dfg import LoopDFG, Node, s
from repro.core.isa import OpKind, Unit
from repro.core.policy import ExecutionPolicy as P

TC = TransformConfig(n_samples=128)
MC = MachineConfig()
POLICIES = [P.BASELINE, P.COPIFT, P.COPIFTV2]


@pytest.fixture(scope="module")
def suite():
    return run_suite(512, TransformConfig(n_samples=512), MachineConfig())


# ---------------------------------------------------------------------------
# Transform correctness: every policy computes the same values
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(KERNELS))
@pytest.mark.parametrize("policy", POLICIES)
def test_outputs_match_reference(name, policy):
    dfg = KERNELS[name]
    ref = dfg.eval_reference(TC.n_samples)
    prog = lower(dfg, policy, TC)
    res = simulate(prog, MC)
    for node in dfg.outputs():
        got = [res.env.get(f"{node.name}@{i}") for i in range(TC.n_samples)]
        assert got == ref[node.name], f"{name}/{policy.value}: {node.name}"


@pytest.mark.parametrize("name", sorted(KERNELS))
def test_fifo_discipline(name):
    """Queue pops receive exactly the value the consumer expects (the FIFO
    law push-order == pop-order, checked value-by-value)."""
    res = simulate(lower(KERNELS[name], P.COPIFTV2, TC), MC)
    assert not res.fifo_violations


@pytest.mark.parametrize("name", sorted(KERNELS))
def test_queue_occupancy_bounded(name):
    mc = MachineConfig(queue_depth=4)
    res = simulate(lower(KERNELS[name], P.COPIFTV2, TC), mc)
    for q, occ in res.max_queue_occupancy.items():
        assert occ <= 4


def test_copiftv2_removes_overhead_instructions():
    """COPIFTv2 eliminates COPIFT's spill loads/stores and batch sync."""
    for name, dfg in KERNELS.items():
        v2 = lower(dfg, P.COPIFTV2, TC)
        cp = lower(dfg, P.COPIFT, TC)
        assert v2.total_instrs() <= cp.total_instrs(), name


# ---------------------------------------------------------------------------
# Paper claims (§III / abstract) — reproduced within calibration bands
# ---------------------------------------------------------------------------

def test_ipc_bounded_by_dual_issue(suite):
    for name, c in suite.items():
        for p in POLICIES:
            assert c.ipc(p) <= 2.0 + 1e-9
        assert c.ipc(P.BASELINE) <= 1.0 + 1e-9      # single shared issue port


def test_peak_ipc(suite):
    peak = max(c.ipc(P.COPIFTV2) for c in suite.values())
    assert 1.6 <= peak <= 2.0           # paper: 1.81


def test_throughput_gain_on_all_kernels(suite):
    """Paper: 'COPIFTv2 still achieves a higher overall throughput
    (samples/cycle) than COPIFT on all benchmarks'."""
    for name, c in suite.items():
        assert c.speedup(P.COPIFTV2, P.COPIFT) >= 1.0, name


def test_poly_lcg_anomaly(suite):
    """Paper: COPIFT's overhead load/stores balance the threads on poly lcg,
    so COPIFT's *IPC* is higher there — but not its throughput."""
    c = suite["poly_lcg"]
    assert c.ipc(P.COPIFT) > c.ipc(P.COPIFTV2)
    assert c.speedup(P.COPIFTV2, P.COPIFT) >= 1.0


def test_speedup_and_energy_bands(suite):
    st_ = summarize(suite)
    assert 1.3 <= st_["max_speedup_vs_copift"] <= 1.8        # paper 1.49
    assert 1.1 <= st_["geomean_speedup_vs_copift"] <= 1.3    # paper 1.19
    assert 1.3 <= st_["max_energy_vs_copift"] <= 1.8         # paper 1.47
    assert 1.1 <= st_["geomean_energy_vs_copift"] <= 1.35    # paper 1.21
    assert 1.7 <= st_["max_speedup_vs_baseline"] <= 2.0      # paper 1.96
    assert 1.5 <= st_["max_energy_vs_baseline"] <= 2.0       # paper 1.75
    assert 1.4 <= st_["geomean_ipc_copift_vs_baseline"] <= 1.8   # [1]: 1.6


def test_power_comparable(suite):
    """Paper Fig. 3b: power consumption remains comparable between COPIFT
    and COPIFTv2 (two opposing effects balance)."""
    for name, c in suite.items():
        r = c.results[P.COPIFTV2].power / c.results[P.COPIFT].power
        assert 0.85 <= r <= 1.15, (name, r)


# ---------------------------------------------------------------------------
# Machine-model unit behaviour
# ---------------------------------------------------------------------------

def _mini_kernel() -> LoopDFG:
    return LoopDFG("mini", [
        Node("a", OpKind.IALU, (s("v"),), fn=lambda v: v + 1),
        Node("f", OpKind.CVT_I2F, (s("a"),), fn=float),
        Node("g", OpKind.FMUL, (s("f"),), fn=lambda f: f * 2.0, out=True),
    ], inputs={"v": lambda i: i}, input_homes={"v": Unit.INT})


def test_blocking_fp_ops_serialize_unit():
    dfg = LoopDFG("sq", [
        Node("r", OpKind.FSQRT, (s("x"),), fn=math.sqrt, out=True),
    ], inputs={"x": lambda i: float(i + 1)}, input_homes={"x": Unit.FP})
    tc = TransformConfig(n_samples=16)
    res = simulate(lower(dfg, P.BASELINE, tc), MC)
    # non-pipelined sqrt: >= latency cycles each
    assert res.cycles >= 16 * 13


def test_queue_depth_one_still_correct():
    dfg = _mini_kernel()
    tc = TransformConfig(n_samples=32, queue_depth=1)
    res = simulate(lower(dfg, P.COPIFTV2, tc), MachineConfig(queue_depth=1))
    ref = dfg.eval_reference(32)
    got = [res.env.get(f"g@{i}") for i in range(32)]
    assert got == ref["g"]
    assert max(res.max_queue_occupancy.values()) <= 1


def test_deeper_queues_not_slower():
    dfg = KERNELS["dequant_dot"]
    c1 = simulate(lower(dfg, P.COPIFTV2, TransformConfig(n_samples=128, queue_depth=2)),
                  MachineConfig(queue_depth=2)).cycles
    c8 = simulate(lower(dfg, P.COPIFTV2, TransformConfig(n_samples=128, queue_depth=8)),
                  MachineConfig(queue_depth=8)).cycles
    assert c8 <= c1


# ---------------------------------------------------------------------------
# Property-based: random mixed DFGs survive all transforms semantically
# ---------------------------------------------------------------------------

_INT_KINDS = [OpKind.IALU, OpKind.IMUL]
_FP_KINDS_ = [OpKind.FMUL, OpKind.FADD, OpKind.FMA]


def _int_fn(*a):
    return (sum(int(x) for x in a) * 7 + 3) & 0xFFFFFFFF


def _fp_fn(*a):
    return sum(float(x) for x in a) * 0.5 + 1.25


@st.composite
def random_dfg(draw):
    n_nodes = draw(st.integers(3, 14))
    nodes = []
    # anchor nodes so raw inputs never cross the partition directly
    int_vals, fp_vals = ["v0"], ["f0"]
    for j in range(n_nodes):
        choice = draw(st.integers(0, 3))
        name = f"n{j}"
        if choice == 0:      # integer op
            k = draw(st.sampled_from(_INT_KINDS))
            nsrc = draw(st.integers(1, 2))
            srcs = tuple(s(draw(st.sampled_from(int_vals + fp_vals)))
                         for _ in range(nsrc))
            nodes.append(Node(name, k, srcs, fn=_int_fn))
            int_vals.append(name)
        elif choice == 1:    # FP op
            k = draw(st.sampled_from(_FP_KINDS_))
            nsrc = draw(st.integers(1, 2))
            srcs = tuple(s(draw(st.sampled_from(fp_vals + int_vals)))
                         for _ in range(nsrc))
            nodes.append(Node(name, k, srcs, fn=_fp_fn))
            fp_vals.append(name)
        elif choice == 2:    # int -> fp convert
            nodes.append(Node(name, OpKind.CVT_I2F,
                              (s(draw(st.sampled_from(int_vals))),), fn=float))
            fp_vals.append(name)
        else:                # fp -> int convert
            nodes.append(Node(name, OpKind.CVT_F2I,
                              (s(draw(st.sampled_from(fp_vals))),),
                              fn=lambda v: int(v) & 0xFFFF))
            int_vals.append(name)
    sinks = [nd for nd in nodes
             if nd.kind in set(_FP_KINDS_) | {OpKind.CVT_I2F}]
    if not sinks:
        nodes.append(Node("out", OpKind.FMUL,
                          (s(fp_vals[draw(st.integers(0, len(fp_vals) - 1))]),),
                          fn=_fp_fn))
        sinks = [nodes[-1]]
    last = sinks[-1]
    nodes[nodes.index(last)] = Node(last.name, last.kind, last.srcs,
                                    fn=last.fn, out=True)
    # inputs are consumed only by same-side anchor nodes
    nodes.insert(0, Node("f0", OpKind.FMUL, (s("x0"),), fn=_fp_fn))
    nodes.insert(0, Node("v0", OpKind.IALU, (s("seed"),), fn=_int_fn))
    return LoopDFG("rand", nodes,
                   inputs={"x0": lambda i: 0.25 * i + 1.0,
                           "seed": lambda i: i * 3 + 1},
                   input_homes={"x0": Unit.FP, "seed": Unit.INT})


@given(random_dfg(), st.sampled_from([P.COPIFT, P.COPIFTV2]),
       st.integers(1, 8))
@settings(max_examples=60, deadline=None)
def test_random_dfgs_preserve_semantics(dfg, policy, depth):
    """The lowering either rejects cleanly at compile time (a schedule that
    cannot exist at this queue depth) or produces a program that runs to
    completion — never a runtime deadlock — with reference semantics."""
    n = 32
    tc = TransformConfig(n_samples=n, unroll=4, batch=8, queue_depth=depth)
    ref = dfg.eval_reference(n)
    try:
        prog = lower(dfg, policy, tc)
    except ValueError:
        assert policy is P.COPIFTV2 and depth < 8   # shallow-queue rejection
        return
    res = simulate(prog, MachineConfig(queue_depth=depth))
    assert not res.fifo_violations
    assert res.ipc <= 2.0 + 1e-9
    for node in dfg.outputs():
        got = [res.env.get(f"{node.name}@{i}") for i in range(n)]
        assert got == ref[node.name]


@given(random_dfg())
@settings(max_examples=30, deadline=None)
def test_random_dfgs_always_lower_at_default_depth(dfg):
    tc = TransformConfig(n_samples=16, unroll=4, batch=8)
    for policy in (P.COPIFT, P.COPIFTV2):
        prog = lower(dfg, policy, tc)
        res = simulate(prog, MachineConfig(queue_depth=tc.queue_depth))
        assert not res.fifo_violations
