"""Validate the multi-pod dry-run artifacts (the sweep itself runs via
``python -m repro.launch.dryrun --all --mesh both`` — these tests check its
outputs are complete and coherent; they skip if the sweep hasn't run)."""
import glob
import json
import os

import pytest

from repro.config import supported_shapes
from repro.configs import ARCHS, get_config

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def _load_all():
    arts = {}
    for p in glob.glob(os.path.join(ART, "*.json")):
        with open(p) as f:
            a = json.load(f)
        arts[(a["arch"], a["shape"], a["mesh"], a["variant"])] = a
    return arts


ARTS = _load_all()
pytestmark = pytest.mark.skipif(
    len(ARTS) < 10, reason="dry-run sweep artifacts not present")


def test_cell_counts_match_skip_rules():
    cells = [(a, s) for a in ARCHS for s in supported_shapes(get_config(a))]
    assert len(cells) == 31                       # 40 - 9 skipped
    missing = [(a, s, m, v) for (a, s) in cells
               for m, v in [("pod16x16", "deploy"), ("pod16x16", "analysis"),
                            ("pod2x16x16", "deploy")]
               if (a, s, m, v) not in ARTS]
    assert not missing, f"missing cells: {missing[:6]} (+{len(missing)} total)"


def test_all_cells_compiled_ok():
    assert all(a.get("ok") for a in ARTS.values())


def test_multipod_reduces_per_device_flops():
    """The pod axis is pure DP: doubling chips reduces per-device compute for
    batch-sharded cells.  Head-indivisible archs (granite 24H, minicpm 40H)
    use 2-D batch-over-(data,model) sharding that cannot extend to 512 chips
    at batch 256 — exempt (recorded in EXPERIMENTS.md)."""
    exempt = {("granite-moe-3b-a800m", "train_4k"),
              ("minicpm3-4b", "train_4k")}
    checked = 0
    for (arch, shape, mesh, var), a in ARTS.items():
        if mesh != "pod16x16" or var != "deploy" or shape == "long_500k":
            continue
        if (arch, shape) in exempt:
            continue
        twin = ARTS.get((arch, shape, "pod2x16x16", "deploy"))
        if not twin:
            continue
        f1 = a["roofline"]["per_device_flops"]
        f2 = twin["roofline"]["per_device_flops"]
        if f1 > 1e9:
            assert f2 <= f1 * 0.85, (arch, shape, f1, f2)
            checked += 1
    assert checked >= 15


def test_analysis_flops_exceed_deploy():
    """Loop unrolling must multiply the counted work."""
    for (arch, shape, mesh, var), a in ARTS.items():
        if var != "analysis":
            continue
        dep = ARTS.get((arch, shape, mesh, "deploy"))
        if dep is None:
            continue
        assert a["roofline"]["per_device_flops"] >= \
            dep["roofline"]["per_device_flops"] * 0.9, (arch, shape)


def test_analysis_useful_ratio_sane():
    """MODEL_FLOPS can never exceed the compiled total (ratio <= 1); ratios
    far above 1 would mean the extrapolation lost compute."""
    for (arch, shape, mesh, var), a in ARTS.items():
        if var != "analysis":
            continue
        r = a["roofline"]["useful_flops_ratio"]
        assert r <= 1.2, (arch, shape, r)


def test_train_cells_have_collectives():
    """Gradient synchronization must appear in every train cell's HLO."""
    for (arch, shape, mesh, var), a in ARTS.items():
        if shape != "train_4k" or var != "deploy":
            continue
        assert a["collectives"]["total"] > 0, (arch, mesh)


def test_analytic_state_fits_hbm():
    """Exact per-device persistent state (params + opt + caches, computed
    from the real leaf shardings) must fit v5e HBM (16 GB) for every cell
    except nemotron-340B training at 256 chips (documented capacity
    finding: fp32 Adam state of a 341B model wants >2 pods or ZeRO-beyond-
    pod/bf16 state)."""
    over = []
    for (arch, shape, mesh, var), a in ARTS.items():
        if var != "deploy" or "analytic_device_gb" not in a:
            continue
        total = a["analytic_device_gb"]["total_gb"]
        if total > 16:
            over.append((arch, shape, mesh, round(total, 1)))
    assert {o[0] for o in over} <= {"nemotron-4-340b"}, over
