"""Serve-path tests: admission backpressure, continuous vs static (wave)
slot refill, straggler-aware host dispatch, SLO accounting on the
virtual-time simulation, the live engine's continuous-batching equivalence
(a mid-run admitted request decodes the same tokens as on a fresh engine),
chunked-prefill bit-exactness on mixed-phase batches, and measured-traffic
operating-point retargeting."""
import jax
import jax.numpy as jnp
import pytest

from repro.config import RunConfig
from repro.configs import get_reduced
from repro.models import init_model_params
from repro.serve import (AdmissionControl, AdmissionError,
                         ContinuousScheduler, HostDispatch, ServeEngine,
                         ServeSLO, StepCostModel, TraceRequest,
                         TrafficEstimator, simulate_serve)

RC = RunConfig(remat=False, dtype="float32")
KEY = jax.random.PRNGKey(0)

#: flat cost model for scheduler-level tests: no machine-model dependency,
#: round numbers make the virtual-time arithmetic auditable by hand
FLAT = StepCostModel(cycles_decode_token=10.0, energy_decode_token=5.0,
                     cycles_prefill_token=2.5, energy_prefill_token=1.25,
                     overhead_cycles=20.0, source="flat-test")


def _cfg():
    return get_reduced("phi3-mini-3.8b")


# --- admission control ------------------------------------------------------

@pytest.mark.tier1
def test_admission_queue_backpressure():
    sched = ContinuousScheduler(2, admission=AdmissionControl(max_pending=2))
    sched.submit(0, prompt_len=3, max_new=4, now=0.0)
    sched.submit(1, prompt_len=3, max_new=4, now=0.0)
    with pytest.raises(AdmissionError, match="queue full"):
        sched.submit(2, prompt_len=3, max_new=4, now=0.0)
    assert sched.n_rejected == 1
    # draining the queue re-opens admission
    sched.refill(now=0.0)
    sched.submit(2, prompt_len=3, max_new=4, now=1.0)


@pytest.mark.tier1
def test_admission_rejects_unservable_shapes():
    ac = AdmissionControl(max_pending=8, max_total_len=8)
    sched = ContinuousScheduler(2, admission=ac)
    with pytest.raises(AdmissionError, match="cache rows"):
        sched.submit(0, prompt_len=6, max_new=4, now=0.0)
    with pytest.raises(AdmissionError, match="empty request"):
        sched.submit(1, prompt_len=0, max_new=4, now=0.0)
    assert sched.n_rejected == 2
    assert not sched.requests                # rejected requests leave no state


# --- continuous vs static refill -------------------------------------------

@pytest.mark.tier1
def test_continuous_refill_reuses_freed_slot_immediately():
    sched = ContinuousScheduler(2, mode="continuous")
    for rid in range(3):
        sched.submit(rid, prompt_len=1, max_new=2, now=0.0)
    placed = sched.refill(now=0.0)
    assert [r.rid for _, r in placed] == [0, 1]      # FIFO admission
    sched.advance_prefill(0, 1, now=1.0)
    sched.record_token(0, now=1.0)
    assert sched.record_token(0, now=2.0)            # rid 0 finished
    placed = sched.refill(now=2.0)
    assert [(i, r.rid) for i, r in placed] == [(0, 2)]
    assert sched.requests[1].phase != "done"         # rid 1 still mid-flight


@pytest.mark.tier1
def test_static_refill_waits_for_the_whole_wave():
    sched = ContinuousScheduler(2, mode="static")
    for rid in range(3):
        sched.submit(rid, prompt_len=1, max_new=1, now=0.0)
    assert len(sched.refill(now=0.0)) == 2
    sched.advance_prefill(0, 1, now=1.0)
    assert sched.record_token(0, now=1.0)            # slot 0 drained ...
    assert sched.refill(now=1.0) == []               # ... but the wave holds
    sched.advance_prefill(1, 1, now=2.0)
    assert sched.record_token(1, now=2.0)
    assert [r.rid for _, r in sched.refill(now=2.0)] == [2]


@pytest.mark.tier1
def test_request_lifecycle_phases_and_timestamps():
    sched = ContinuousScheduler(1)
    req = sched.submit(0, prompt_len=2, max_new=2, now=5.0)
    assert req.phase == "queued"
    sched.refill(now=6.0)
    assert req.phase == "prefill" and req.admit_time == 6.0
    sched.advance_prefill(0, 2, now=7.0)
    assert req.phase == "decode" and req.prefill_end == 7.0
    sched.record_token(0, now=8.0)
    assert req.first_token == 8.0
    sched.record_token(0, now=9.0)
    assert req.phase == "done" and req.finish == 9.0
    assert 5.0 <= req.admit_time <= req.prefill_end <= req.first_token \
        <= req.finish


# --- step-cost model --------------------------------------------------------

def test_step_cost_model_from_default_point():
    cost = StepCostModel.from_operating_point(None)
    assert cost.source == "default"
    assert 0 < cost.cycles_prefill_token < cost.cycles_decode_token
    c1, e1 = cost.step_cost(1)
    c8, e8 = cost.step_cost(8)
    assert c8 > c1 and e8 > e1               # padded width is paid for
    cp, ep = cost.step_cost(8, prefill_tokens=4)
    assert cp > c8 and ep > e8               # chunked prefill costs extra


# --- straggler-aware dispatch ----------------------------------------------

def _drive(dispatch, steps=64):
    total = 0.0
    now = 0.0
    for _ in range(steps):
        dt = dispatch.step(100.0, now)
        total += dt
        now += dt
    return total


@pytest.mark.tier1
def test_host_dispatch_flags_only_the_slow_host():
    disp = HostDispatch(4, min_samples=8)
    disp.set_speed(2, 3.0)
    adaptive_cycles = _drive(disp)
    assert disp.flagged_hosts == [2]
    assert disp.weights[2] < 1.0             # work shifted off the straggler
    assert disp.weights[0] == disp.weights[1] == disp.weights[3] == 1.0
    assert disp.dead(64 * 400.0) == []       # slow-but-beating is not dead

    rigid = HostDispatch(4, min_samples=8, threshold=float("inf"))
    rigid.set_speed(2, 3.0)
    assert _drive(rigid) / adaptive_cycles > 1.5


@pytest.mark.tier1
def test_host_dispatch_healthy_cluster_stays_unflagged():
    disp = HostDispatch(4, min_samples=8)
    _drive(disp)
    assert disp.flagged_hosts == []
    assert disp.weights == [1.0] * 4


# --- virtual-time simulation ------------------------------------------------

def _mini_trace():
    """Two bursts of 4 on 2 slots: short and long requests mixed so wave
    batching leaves slots idle behind the longest request."""
    out = []
    for b in range(2):
        for i in range(4):
            rid = 4 * b + i
            out.append(TraceRequest(rid, arrival=b * 2000.0 + i * 5.0,
                                    prompt_len=2 + (i % 2) * 2,
                                    max_new=2 if i % 2 else 10))
    return out


@pytest.mark.tier1
def test_simulate_serve_is_deterministic_and_complete():
    slo = ServeSLO(p99_cycles_per_token=1e6)
    a = simulate_serve(_mini_trace(), 2, FLAT, mode="continuous", slo=slo)
    b = simulate_serve(_mini_trace(), 2, FLAT, mode="continuous", slo=slo)
    assert a.to_dict() == b.to_dict()
    assert a.n_completed == 8 and a.n_unfinished == 0 and a.n_rejected == 0
    assert a.tokens_out == sum(r.max_new for r in _mini_trace())
    assert a.p50_latency <= a.p99_latency
    assert 0.0 <= a.slo["attainment"] <= 1.0
    assert a.slo["throughput_at_slo"] <= a.throughput + 1e-12


@pytest.mark.tier1
def test_continuous_beats_static_on_bursty_mix():
    slo = ServeSLO(p99_cycles_per_token=1e6)
    cont = simulate_serve(_mini_trace(), 2, FLAT, mode="continuous", slo=slo)
    stat = simulate_serve(_mini_trace(), 2, FLAT, mode="static", slo=slo)
    # freed slots refill behind the long requests: strictly fewer steps, so
    # less total time, less padded-slot energy, and lower p99
    assert cont.total_cycles < stat.total_cycles
    assert cont.energy_per_token < stat.energy_per_token
    assert cont.p99_latency < stat.p99_latency


def test_simulate_serve_sheds_load_beyond_max_pending():
    trace = [TraceRequest(i, arrival=0.0, prompt_len=1, max_new=4)
             for i in range(8)]
    rep = simulate_serve(trace, 2, FLAT, mode="continuous",
                         slo=ServeSLO(p99_cycles_per_token=1e6),
                         admission=AdmissionControl(max_pending=3))
    # 2 go straight to slots on the first refill sweep is NOT how admission
    # works: all 8 arrive at t=0, the queue holds 3, the rest are shed
    assert rep.n_rejected == 5
    assert rep.n_completed == 3
    assert rep.n_unfinished == 0


# --- live engine ------------------------------------------------------------

def test_engine_midrun_admission_matches_fresh_engine():
    """The continuous-batching core: a request admitted into a freed slot
    mid-run decodes exactly the tokens it would on a fresh engine."""
    cfg = _cfg()
    params = init_model_params(KEY, cfg)
    prompt, max_new = [7, 3, 9, 1], 5

    eng = ServeEngine(params, cfg, RC, batch_slots=2, max_len=64)
    eng.submit([1, 2, 3], max_new=8)
    eng.submit([4, 5, 6], max_new=2)         # finishes early, frees its slot
    for _ in range(4):
        eng.step()
    rid = eng.submit(prompt, max_new=max_new)
    done = eng.run()
    assert len(done) == 3

    fresh = ServeEngine(params, cfg, RC, batch_slots=2, max_len=64)
    rid_f = fresh.submit(prompt, max_new=max_new)
    assert done[rid].generated == fresh.run()[rid_f].generated


def test_engine_admission_error_and_metrics():
    cfg = _cfg()
    params = init_model_params(KEY, cfg)
    eng = ServeEngine(params, cfg, RC, batch_slots=2, max_len=16)
    with pytest.raises(AdmissionError, match="cache rows"):
        eng.submit(list(range(14)), max_new=8)
    eng.submit([1, 2, 3], max_new=4)
    eng.run()
    rep = eng.metrics(slo=ServeSLO(p99_cycles_per_token=1e9))
    assert rep.mode == "continuous"
    assert rep.n_completed == 1 and rep.n_rejected == 1
    assert rep.tokens_out == 4
    assert rep.slo["attainment"] == 1.0
    assert rep.cost_source in ("calibrated", "default", "flat-fallback")


def test_engine_static_mode_still_serves_everything():
    cfg = _cfg()
    params = init_model_params(KEY, cfg)
    eng = ServeEngine(params, cfg, RC, batch_slots=2, max_len=64,
                      mode="static")
    rids = [eng.submit([1 + i, 2, 3], max_new=3) for i in range(3)]
    done = eng.run()
    assert set(done) == set(rids)
    assert all(len(r.generated) == 3 for r in done.values())


@pytest.mark.tier1
def test_engine_refuses_empty_prompt_before_any_state():
    """Regression: an empty prompt must be shed at admission, before any
    engine-side Request state exists — never reach the batch-assembly path
    (which indexes ``prompt[-1]``)."""
    cfg = _cfg()
    eng = ServeEngine({}, cfg, RC, batch_slots=2, max_len=16)
    with pytest.raises(AdmissionError, match="empty request"):
        eng.submit([], max_new=4)
    with pytest.raises(AdmissionError, match="empty request"):
        eng.submit([1, 2], max_new=0)
    assert not eng.requests and not eng.sched.requests
    assert eng.sched.n_rejected == 2


# --- live-engine chunked prefill -------------------------------------------

def _slot_rows(cache, i):
    """Slot ``i``'s rows of every cache leaf (batch is axis 0 of ``len``,
    axis 1 of stacked leaves)."""
    return {k: (v if v.ndim == 0 else v[i] if v.ndim == 1 else v[:, i])
            for k, v in cache.items()}


def test_engine_chunked_prefill_mixed_phase_bit_exact():
    """One slot mid-prefill-chunk while its neighbour decodes: the chunked
    engine's generated tokens and each request's cache rows *at its
    completion step* are bit-exact with the token-by-token reference.
    (Rows are snapshotted at completion: once a slot frees, later steps may
    overwrite it with junk that the next refill zeroes — comparing
    end-of-run rows of freed slots would compare that junk.)"""
    cfg = _cfg()
    params = init_model_params(KEY, cfg)
    # rid 0: short prompt, decodes while rid 1 is still chunk-prefilling
    reqs = [([5, 9], 8), (list(range(1, 19)), 3)]

    def run_with_snapshots(prefill):
        eng = ServeEngine(params, cfg, RC, batch_slots=2, max_len=64,
                          prefill=prefill, prefill_chunk=4)
        rids = [eng.submit(p, max_new=m) for p, m in reqs]
        snaps, slot_of = {}, {}
        for _ in range(200):
            if not eng.sched.busy:
                break
            for i, s in enumerate(eng.sched.slots):
                if s is not None:
                    slot_of[s.rid] = i
            eng.step()
            for rid in eng.finished:
                if rid not in snaps:
                    snaps[rid] = _slot_rows(eng.cache, slot_of[rid])
        assert set(eng.finished) == set(rids)
        return eng, snaps

    chunked, snaps_c = run_with_snapshots("chunked")
    token, snaps_t = run_with_snapshots("token")
    for rid in chunked.finished:
        assert chunked.finished[rid].generated == \
            token.finished[rid].generated
        rows_c, rows_t = snaps_c[rid], snaps_t[rid]
        assert set(rows_c) == set(rows_t)
        for k in rows_c:
            assert bool(jnp.array_equal(rows_c[k], rows_t[k])), \
                f"rid {rid} cache leaf {k!r} diverged"
    # the chunked run actually took fewer engine steps (that is the point)
    assert chunked._n_steps < token._n_steps


def test_engine_readmission_during_neighbour_prefill():
    """A request admitted into a freed slot while its neighbour is still
    mid-prefill decodes exactly the tokens it would on a fresh engine."""
    cfg = _cfg()
    params = init_model_params(KEY, cfg)
    prompt, max_new = [7, 3, 9, 1], 5

    eng = ServeEngine(params, cfg, RC, batch_slots=2, max_len=64,
                      prefill_chunk=4)
    eng.submit([4, 5, 6], max_new=2)          # finishes early, frees slot 0
    eng.submit(list(range(1, 25)), max_new=4)  # long prefill in slot 1
    for _ in range(3):
        eng.step()
    rid = eng.submit(prompt, max_new=max_new)
    # the readmission lands while slot 1 is still prefilling
    assert any(s is not None and s.phase == "prefill"
               for s in eng.sched.slots)
    done = eng.run()
    assert len(done) == 3

    fresh = ServeEngine(params, cfg, RC, batch_slots=2, max_len=64,
                        prefill_chunk=4)
    rid_f = fresh.submit(prompt, max_new=max_new)
    assert done[rid].generated == fresh.run()[rid_f].generated


def test_engine_chunk_bucket_jit_cache_is_bounded():
    """Varied prompt lengths across many requests hit at most
    log2(prefill_chunk) + 1 chunk buckets — the jit cache never grows past
    that, however long the engine runs."""
    cfg = _cfg()
    params = init_model_params(KEY, cfg)
    eng = ServeEngine(params, cfg, RC, batch_slots=2, max_len=64,
                      prefill_chunk=8)
    for plen in (1, 2, 3, 5, 8, 13, 21, 6, 17):
        eng.submit(list(range(1, plen + 1)), max_new=2)
    eng.run(max_steps=4000)
    assert not eng.sched.busy
    max_compiles = 4                      # log2(8) + 1: widths 1, 2, 4, 8
    assert 1 <= eng.prefill_compiles <= max_compiles
    assert set(eng._prefill_jit) <= {1, 2, 4, 8}


# --- measured-traffic operating points --------------------------------------

@pytest.mark.tier1
def test_traffic_estimator_levels():
    est = TrafficEstimator(capacity_tokens_per_cycle=0.01, min_arrivals=4)
    assert est.level() is None            # cold: no evidence, no level
    # a thundering herd (zero gaps) saturates offered load -> "high"
    for i in range(6):
        est.observe(now=0.0, prompt_len=8, max_new=8)
    assert est.offered_load() == 1.0 and est.level() == "high"
    # sparse arrivals (gap >> work/capacity) decay the estimate -> "low"
    est2 = TrafficEstimator(capacity_tokens_per_cycle=0.01, min_arrivals=4)
    for i in range(8):
        est2.observe(now=i * 1e6, prompt_len=8, max_new=8)
    assert est2.offered_load() < 0.3 and est2.level() == "low"


@pytest.mark.tier1
def test_scheduler_estimator_observes_shed_arrivals_too():
    est = TrafficEstimator(capacity_tokens_per_cycle=0.01, min_arrivals=1)
    sched = ContinuousScheduler(1, admission=AdmissionControl(max_pending=1),
                                estimator=est)
    sched.submit(0, prompt_len=2, max_new=4, now=0.0)
    with pytest.raises(AdmissionError):
        sched.submit(1, prompt_len=2, max_new=4, now=1.0)
    assert est.n_arrivals == 2            # rejected arrivals are load too


def test_engine_measured_traffic_retargets_at_refill():
    """With neither an operating point nor a --traffic pin, the engine
    estimates the level from arrivals and re-resolves the operating point
    at a refill boundary; retargeting never changes generated tokens."""
    cfg = _cfg()
    params = init_model_params(KEY, cfg)
    eng = ServeEngine(params, cfg, RC, batch_slots=2, max_len=64)
    assert eng.sched.estimator is not None and eng.traffic_level is None
    rids = [eng.submit([1 + i, 2, 3], max_new=2) for i in range(5)]
    done = eng.run()
    assert set(done) == set(rids)
    # 5 same-clock arrivals saturate the estimator -> "high" at first refill
    assert eng.traffic_level == "high"
    assert eng.traffic_history and \
        eng.traffic_history[0]["level"] == "high"

    pinned = ServeEngine(params, cfg, RC, batch_slots=2, max_len=64,
                         traffic="medium")
    assert pinned.sched.estimator is None        # static override
    assert pinned.traffic_level == "medium" and not pinned.traffic_history
    rids_p = [pinned.submit([1 + i, 2, 3], max_new=2) for i in range(5)]
    done_p = pinned.run()
    # the operating point only steers accounting, never the tokens
    for a, b in zip(rids, rids_p):
        assert done[a].generated == done_p[b].generated
