"""Optional-``hypothesis`` shim.

The property-based tests use ``hypothesis`` when it is installed.  When it is
absent (the benchmark container does not ship it, and the repo installs no
extra packages), importing this module still succeeds: ``given`` becomes a
decorator whose wrapped test skips with a clear reason, and ``st`` / its
``composite`` decorator become inert stand-ins so strategy construction at
module import time keeps working.

Test modules import the trio from here instead of from ``hypothesis``::

    from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
"""
import functools

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False
    SKIP_REASON = "hypothesis not installed; property-based test skipped"

    class _StubStrategy:
        """Placeholder for a hypothesis strategy; never drawn from."""

        def __init__(self, desc):
            self._desc = desc

        def __repr__(self):
            return f"<stub strategy {self._desc}>"

    class _StubStrategies:
        """Any ``st.<name>(...)`` call yields a placeholder strategy."""

        @staticmethod
        def composite(fn):
            @functools.wraps(fn)
            def build(*args, **kwargs):
                return _StubStrategy(f"composite:{fn.__name__}")
            return build

        def __getattr__(self, name):
            def make(*args, **kwargs):
                return _StubStrategy(name)
            return make

    st = _StubStrategies()

    def given(*_args, **_kwargs):
        def deco(fn):
            # deliberately NOT functools.wraps: the wrapper must present a
            # zero-argument signature or pytest resolves the strategy
            # parameters as fixtures
            def wrapper():
                pytest.skip(SKIP_REASON)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
