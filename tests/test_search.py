"""Front-guided adaptive search tests (PR 7, ``core.search``).

The exhaustive sweep is the differential oracle: on a small grid,
``adaptive_sweep`` must return exact full-fidelity records (a subsequence
of the exhaustive run, in input order) whose per-kernel Pareto fronts
cover the exhaustive fronts within the dominance tolerance.
"""
import dataclasses

import pytest

from repro.core import (SweepPoint, SweepRecord, adaptive_sweep,
                        eps_dominated, front_matches, grid, pareto_by_kernel,
                        run_search, run_sweep, scale_fidelity)
from repro.core.policy import ExecutionPolicy as P


def _small_grid(engine="batch"):
    return grid(kernels=("expf", "histf"), policies=(P.COPIFT, P.COPIFTV2),
                queue_depths=(1, 2, 4, 8), queue_latencies=(2, 8),
                i2f_depths=(None, 2), n_samples=64, engine=engine)


def _rec(ipc, energy, kernel="k"):
    """A minimal ok record at an (ipc, energy) coordinate."""
    return SweepRecord(kernel=kernel, policy="copiftv2", queue_depth=4,
                       queue_latency=1, unroll=8, unroll_int=None,
                       n_samples=64, status="ok", ipc=ipc, energy=energy)


# ---------------------------------------------------------------------------
# Dominance-tolerance primitives
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_eps_dominated_semantics():
    front = [_rec(2.0, 100.0)]
    assert eps_dominated(_rec(1.0, 200.0), front, tolerance=0.0)
    # within 10% of the front on both axes: survives at tolerance=0.1
    assert not eps_dominated(_rec(1.85, 108.0), front, tolerance=0.1)
    assert eps_dominated(_rec(1.5, 150.0), front, tolerance=0.1)
    # front members never eps-dominate themselves
    assert not eps_dominated(front[0], front, tolerance=0.0)
    assert not eps_dominated(front[0], front, tolerance=0.2)


@pytest.mark.tier1
def test_front_matches_cover_and_slack():
    ref = [_rec(2.0, 100.0), _rec(1.0, 50.0)]
    ok, slack = front_matches(ref, ref, tolerance=0.0)
    assert ok and slack == 0.0
    # candidate 5% short on ipc: covered at tol 0.1, not at tol 0.01
    cand = [_rec(1.9, 100.0), _rec(0.95, 50.0)]
    ok, slack = front_matches(cand, ref, tolerance=0.1)
    assert ok and slack == pytest.approx(0.05)
    assert not front_matches(cand, ref, tolerance=0.01)[0]
    # empty candidate cannot cover a non-empty reference
    ok, slack = front_matches([], ref)
    assert not ok and slack == float("inf")
    assert front_matches([], [], tolerance=0.0) == (True, 0.0)


@pytest.mark.tier1
def test_scale_fidelity_feasible_multiples():
    pt = SweepPoint(kernel="expf", policy="copiftv2", unroll=8, n_samples=128)
    assert scale_fidelity(pt, 8).n_samples == 16   # multiple of unroll
    assert scale_fidelity(pt, 1) is pt
    # never rounds below one unroll step, never above the original
    assert scale_fidelity(pt, 1000).n_samples == 8
    tiny = dataclasses.replace(pt, n_samples=8)
    assert scale_fidelity(tiny, 8) is tiny
    # cluster points stay partitionable: multiple of unroll x cores
    cl = dataclasses.replace(pt, n_cores=4)
    assert scale_fidelity(cl, 8).n_samples % (8 * 4) == 0


@pytest.mark.tier1
def test_adaptive_sweep_validates_inputs():
    pts = _small_grid()[:2]
    with pytest.raises(ValueError):
        adaptive_sweep(pts, fidelity_ladder=(8, 2))     # must end at 1
    with pytest.raises(ValueError):
        adaptive_sweep(pts, fidelity_ladder=(2, 8, 1))  # must decrease
    with pytest.raises(ValueError):
        adaptive_sweep(pts, fidelity_ladder=())
    with pytest.raises(ValueError):
        adaptive_sweep(pts, tolerance=1.5)
    with pytest.raises(ValueError):
        run_search(pts, strategy="random")
    with pytest.raises(TypeError):
        run_search(pts, strategy="exhaustive", tolerance=0.1)


# ---------------------------------------------------------------------------
# Differential oracle: adaptive vs exhaustive on a small grid
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_adaptive_front_matches_exhaustive_within_tolerance():
    pts = _small_grid()
    tol = 0.1
    exhaustive = run_sweep(pts, workers=1)
    adaptive, meta = adaptive_sweep(pts, workers=1, tolerance=tol)
    # meta provenance: strategy + fidelity ladder + monotone survivor counts
    assert meta["strategy"] == "adaptive"
    assert meta["fidelity_ladder"][-1] == 1
    assert meta["n_points"] == len(pts)
    assert meta["n_full_fidelity"] == len(adaptive) <= len(pts)
    evs = [r["evaluated"] for r in meta["rungs"]]
    assert evs[0] == len(pts) and evs == sorted(evs, reverse=True)
    # every surviving record is exact: it equals the exhaustive record
    by_key = {}
    for rec in exhaustive:
        by_key[(rec.kernel, rec.policy, rec.queue_depth, rec.queue_latency,
                rec.queue_depth_i2f, rec.queue_depth_f2i)] = rec
    for rec in adaptive:
        key = (rec.kernel, rec.policy, rec.queue_depth, rec.queue_latency,
               rec.queue_depth_i2f, rec.queue_depth_f2i)
        assert rec == by_key[key]
    # the recovered per-kernel fronts cover the exhaustive fronts within tol
    fx, fa = pareto_by_kernel(exhaustive), pareto_by_kernel(adaptive)
    for kernel, ref_front in fx.items():
        ok, slack = front_matches(fa.get(kernel, []), ref_front, tol)
        assert ok, f"{kernel}: front slack {slack} > {tol}"


@pytest.mark.tier1
def test_run_search_dispatch_and_run_sweep_strategy():
    pts = _small_grid()[:8]
    recs_x, meta_x = run_search(pts, strategy="exhaustive", workers=1)
    assert meta_x == {"strategy": "exhaustive", "n_points": len(pts)}
    assert recs_x == run_sweep(pts, workers=1)
    recs_a, meta_a = run_search(pts, strategy="adaptive", workers=1)
    assert meta_a["strategy"] == "adaptive"
    assert recs_a == run_sweep(pts, workers=1, strategy="adaptive")
    with pytest.raises(ValueError):
        run_sweep(pts, workers=1, strategy="random")
