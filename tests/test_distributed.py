"""Distribution-layer tests: sharding rules, collective matmul policies,
HLO collective-byte parsing, and cell lowering."""
import os
import subprocess
import sys

import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.config import SHAPES, RunConfig
from repro.configs import get_config, get_reduced
from repro.distributed.sharding import _leaf_pspec, param_pspecs
from repro.roofline import Roofline, collective_bytes

def _abstract_mesh(shape, names):
    """AbstractMesh across JAX API generations: >=0.5 takes (shape, names);
    0.4.x takes one ((name, size), ...) tuple."""
    try:
        return AbstractMesh(shape, names)
    except TypeError:
        return AbstractMesh(tuple(zip(names, shape)))


MESH = _abstract_mesh((16, 16), ("data", "model"))
RC = RunConfig()
RC_FSDP = RunConfig(fsdp=True)


def _find(tree, path):
    for k in path.split("/"):
        tree = tree[k]
    return tree


def test_attention_heads_sharded_over_model():
    specs = param_pspecs(get_config("phi3-mini-3.8b"), MESH, RC)
    wq = _find(specs, "blocks/attn/wq")          # (L, d, H, hd)
    assert wq == P(None, None, "model")


def test_fsdp_adds_data_axis_on_embed_dim():
    specs = param_pspecs(get_config("phi3-mini-3.8b"), MESH, RC_FSDP)
    wq = _find(specs, "blocks/attn/wq")
    assert wq == P(None, "data", "model")


def test_glm4_kv_heads_replicated_when_indivisible():
    specs = param_pspecs(get_config("glm4-9b"), MESH, RC)
    wk = _find(specs, "blocks/attn/wk")          # kv_heads=2 < model=16
    assert wk == P(None, None, None) or wk == P()


def test_olmoe_experts_sharded_granite_falls_back():
    olmoe = param_pspecs(get_config("olmoe-1b-7b"), MESH, RC)
    assert _find(olmoe, "blocks/ffn/wi") == P(None, "model")
    granite = param_pspecs(get_config("granite-moe-3b-a800m"), MESH, RC)
    # 40 experts % 16 != 0 -> the expert hidden dim takes the model axis
    assert _find(granite, "blocks/ffn/wi") == P(None, None, None, "model")


def test_vocab_sharded():
    specs = param_pspecs(get_config("phi3-mini-3.8b"), MESH, RC)
    assert specs["embed"] == P("model")
    assert specs["head"] == P("model")


def test_leaf_pspec_never_reuses_axis():
    spec = _leaf_pspec((64, 64), ("heads", "ff"), MESH, fsdp=False)
    used = [a for a in spec if a is not None]
    assert len(used) == len(set(used))


# --- collective bytes parser -------------------------------------------------

HLO_SNIPPET = """
HloModule test
ENTRY main {
  %p0 = f32[16,128]{1,0} parameter(0)
  %p1 = bf16[8,256]{1,0} parameter(1)
  %ag = f32[64,128]{1,0} all-gather(%p0), dimensions={0}
  %ar = bf16[8,256]{1,0} all-reduce(%p1), to_apply=add
  %cp = bf16[8,256]{1,0} collective-permute(%ar), source_target_pairs={{0,1}}
}
"""


def test_collective_bytes_parser():
    out = collective_bytes(HLO_SNIPPET)
    assert out["all-gather"] == 16 * 128 * 4
    assert out["all-reduce"] == 8 * 256 * 2
    assert out["collective-permute"] == 8 * 256 * 2
    assert out["total"] == out["all-gather"] + out["all-reduce"] + \
        out["collective-permute"]


def test_roofline_terms():
    r = Roofline(arch="a", shape="s", mesh="m", chips=256,
                 per_device_flops=197e12, per_device_bytes=819e9,
                 per_device_coll_bytes=200e9, model_flops=197e12 * 256 * 0.5)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 1.0) < 1e-9
    assert abs(r.t_collective - 1.0) < 1e-9
    assert abs(r.mfu - 0.5) < 1e-9
    assert r.useful_flops_ratio == 0.5


# --- cell lowering machinery (1-device mesh; the 512-chip sweep runs via
#     launch.dryrun against the production meshes) ---------------------------

@pytest.mark.parametrize("shape_name", ["train_4k", "prefill_32k", "decode_32k"])
def test_lower_cell_reduced(shape_name, monkeypatch):
    import dataclasses
    from repro.launch.dryrun import lower_cell, default_runconfig
    from repro.launch.mesh import make_local_mesh
    cfg = get_reduced("glm4-9b")
    shape = dataclasses.replace(SHAPES[shape_name], seq_len=64, global_batch=2)
    mesh = make_local_mesh(1, 1)
    lowered = lower_cell(cfg, shape, mesh, default_runconfig(shape))
    compiled = lowered.compile()
    assert compiled.cost_analysis() is not None


def test_ring_matmul_multidevice_subprocess():
    """Ring (COPIFTv2) == bulk (COPIFT) numerically on an 8-device mesh, and
    their HLO uses collective-permute vs all-gather respectively."""
    child = (
        "import jax, jax.numpy as jnp, numpy as np\n"
        "from repro.distributed.collective_matmul import tp_matmul\n"
        "from repro.core.policy import ExecutionPolicy as EP\n"
        "from repro.launch.mesh import make_local_mesh\n"
        "mesh = make_local_mesh(2, 4)\n"
        "x = jax.random.normal(jax.random.PRNGKey(0), (64, 32))\n"
        "w = jax.random.normal(jax.random.PRNGKey(1), (32, 48))\n"
        "ref = x @ w\n"
        "for pol in (EP.COPIFT, EP.COPIFTV2):\n"
        "    y = tp_matmul(x, w, mesh, policy=pol)\n"
        "    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),\n"
        "                               rtol=1e-5, atol=1e-5)\n"
        "t_b = jax.jit(lambda a, b: tp_matmul(a, b, mesh, policy=EP.COPIFT)"
        ").lower(x, w).compile().as_text()\n"
        "t_r = jax.jit(lambda a, b: tp_matmul(a, b, mesh, policy=EP.COPIFTV2)"
        ").lower(x, w).compile().as_text()\n"
        "assert 'all-gather' in t_b and 'collective-permute' not in t_b\n"
        "assert 'collective-permute' in t_r and 'all-gather' not in t_r\n"
        "print('SUBPROCESS_OK')\n")
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": "src"}
    res = subprocess.run([sys.executable, "-c", child], env=env,
                         capture_output=True, text=True, timeout=300,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "SUBPROCESS_OK" in res.stdout, res.stderr[-2000:]
