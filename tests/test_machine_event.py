"""Differential tests for the event-driven time-skip engine.

The contract under test: :class:`repro.core.machine.Stepper` (event-driven,
the default) is **bit-identical** to :class:`ReferenceStepper` (naive
per-cycle) on every program — cycles, energy, stall breakdown, FIFO push/pop
sequences, occupancy highwater, the functional environment, and deadlock
behavior (same exception at the same cycle with the same stall state).

Randomized configurations are drawn with ``hypothesis`` when available
(via tests/_hypothesis_compat.py) and with a seeded stdlib PRNG otherwise,
so the differential property always runs.
"""
import itertools
import random
import sys
import os

import pytest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from _hypothesis_compat import given, settings, st  # noqa: E402

from repro.core import (KERNELS, MachineConfig, Program, ReferenceStepper,
                        Stepper, TransformConfig, lower, simulate,
                        stepper_for)
from repro.core.isa import Instr, OpKind, Queue, Unit
from repro.core.policy import ExecutionPolicy as P

#: every SimResult facet the two engines must agree on
FACETS = ("cycles", "energy", "instrs", "stalls", "push_seq", "pop_seq",
          "max_queue_occupancy", "fifo_violations", "env")


def _assert_equal_runs(prog, mcfg):
    ref = ReferenceStepper(prog, mcfg).run()
    ev = Stepper(prog, mcfg).run()
    for facet in FACETS:
        assert getattr(ref, facet) == getattr(ev, facet), facet
    return ref, ev


def _check_config(kernel, policy, depth, lat, unroll, n):
    tcfg = TransformConfig(n_samples=n, queue_depth=depth, unroll=unroll)
    try:
        prog = lower(KERNELS[kernel], policy, tcfg)
    except ValueError:
        return                        # infeasible schedule: nothing to diff
    _assert_equal_runs(prog, MachineConfig(queue_depth=depth,
                                           queue_latency=lat))


# ---------------------------------------------------------------------------
# Dense small grid (tier1) + randomized fuzz
# ---------------------------------------------------------------------------

@pytest.mark.tier1
@pytest.mark.parametrize("policy", list(P), ids=[p.value for p in P])
def test_event_engine_matches_reference_small_grid(policy):
    for kernel, depth, lat in itertools.product(
            ("expf", "box_muller", "histf"), (1, 4), (1, 8)):
        _check_config(kernel, policy, depth, lat, 8, 16)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_event_engine_matches_reference_random_configs(seed):
    """Seeded-PRNG differential fuzz across the whole configuration space."""
    rng = random.Random(seed)
    for _ in range(10):
        _check_config(kernel=rng.choice(sorted(KERNELS)),
                      policy=rng.choice(list(P)),
                      depth=rng.choice((1, 2, 3, 4, 8, 16)),
                      lat=rng.choice((1, 2, 3, 5, 8)),
                      unroll=rng.choice((1, 2, 4, 8)),
                      n=rng.choice((8, 16, 32)))


@given(st.sampled_from(sorted(KERNELS)), st.sampled_from(list(P)),
       st.integers(min_value=1, max_value=16),
       st.integers(min_value=1, max_value=8),
       st.sampled_from((1, 2, 4, 8)),
       st.sampled_from((8, 16, 24)))
@settings(max_examples=25, deadline=None)
def test_event_engine_matches_reference_hypothesis(kernel, policy, depth,
                                                   lat, unroll, n):
    """Property form of the differential check (skips without hypothesis)."""
    _check_config(kernel, policy, depth, lat, unroll, n)


# ---------------------------------------------------------------------------
# High-latency stretches: the configurations the time-skip exists for
# ---------------------------------------------------------------------------

@pytest.mark.tier1
@pytest.mark.parametrize("lat", [8, 32])
def test_event_engine_matches_reference_deep_stalls(lat):
    tcfg = TransformConfig(n_samples=32, queue_depth=1)
    prog = lower(KERNELS["box_muller"], P.COPIFTV2, tcfg)
    _assert_equal_runs(prog, MachineConfig(queue_depth=1, queue_latency=lat))


def test_event_engine_host_steps_are_sublinear_in_latency():
    """The whole point of the engine: simulated cycles grow with queue
    latency but host step() invocations stay ~O(instructions)."""
    tcfg = TransformConfig(n_samples=32, queue_depth=1)
    prog = lower(KERNELS["box_muller"], P.COPIFTV2, tcfg)

    def host_steps(lat):
        st_ = Stepper(prog, MachineConfig(queue_depth=1, queue_latency=lat))
        steps = 0
        while st_.step():
            steps += 1
        return steps, st_.result().cycles

    steps_lo, cycles_lo = host_steps(2)
    steps_hi, cycles_hi = host_steps(64)
    assert cycles_hi > 2 * cycles_lo          # simulated time exploded
    assert steps_hi < 1.2 * steps_lo          # host work did not


# ---------------------------------------------------------------------------
# Deadlock parity + degenerate programs
# ---------------------------------------------------------------------------

def _circular_wait_program():
    """INT pops F2I before pushing I2F; FP pops I2F before pushing F2I."""
    ins_i = Instr(uid=0, kind=OpKind.MV, label="i0", srcs=(Queue.F2I,),
                  dst="a", pushes=(Queue.I2F,), push_val="a")
    ins_f = Instr(uid=1, kind=OpKind.FADD, label="f0", srcs=(Queue.I2F,),
                  dst="b", pushes=(Queue.F2I,), push_val="b")
    return Program(name="dead", policy=P.COPIFTV2, mode="dual",
                   streams={Unit.INT: [ins_i], Unit.FP: [ins_f]}, n_samples=1)


@pytest.mark.tier1
def test_deadlock_parity_same_cycle_same_message_same_stalls():
    mcfg = MachineConfig(evaluate=False, deadlock_limit=300)
    outcomes = []
    for cls in (ReferenceStepper, Stepper):
        stepper = cls(_circular_wait_program(), mcfg)
        with pytest.raises(Exception) as exc:
            stepper.run()
        outcomes.append((str(exc.value), stepper.cycle, dict(stepper.stalls)))
    assert outcomes[0] == outcomes[1]


@pytest.mark.tier1
def test_empty_program_yields_zero_rates_not_zero_division():
    prog = Program(name="empty", policy=P.BASELINE, mode="single",
                   streams={Unit.INT: []}, n_samples=0)
    for engine in ("event", "cycle"):
        res = simulate(prog, MachineConfig(), engine=engine)
        assert res.cycles == 0
        assert res.ipc == res.power == res.throughput == res.efficiency == 0.0


@pytest.mark.tier1
def test_stepper_for_selects_engine_and_rejects_unknown():
    prog = lower(KERNELS["histf"], P.BASELINE, TransformConfig(n_samples=8))
    assert isinstance(stepper_for(prog, engine="event"), Stepper)
    cyc = stepper_for(prog, engine="cycle")
    assert isinstance(cyc, ReferenceStepper) and not isinstance(cyc, Stepper)
    with pytest.raises(ValueError):
        stepper_for(prog, engine="warp")


@pytest.mark.tier1
def test_issue_plan_is_the_spec_for_exec_facts():
    """``Instr.issue_plan`` documents the issue-condition order;
    ``exec_facts`` is its packed hot-path twin.  They must never drift."""
    prog = lower(KERNELS["expf"], P.COPIFTV2, TransformConfig(n_samples=8))
    for lst in prog.streams.values():
        for ins in lst:
            plan_ops = [(c == "queue_empty", op, k)
                        for c, op, k in ins.issue_plan if c != "queue_full"]
            plan_pushes = [(op, k) for c, op, k in ins.issue_plan
                           if c == "queue_full"]
            facts = ins.exec_facts
            assert [o[:3] for o in facts[12]] == plan_ops
            assert [p[:2] for p in facts[13]] == plan_pushes


@pytest.mark.tier1
def test_skip_soundness_counts_init_env_overwrites():
    """Regression: a register seeded in ``init_env`` and overwritten once by
    the other unit has a non-final ready time — the per-unit skip must not
    treat it as single-write.  (Found by review: the FP unit was skip-granted
    past the overwrite and issued one cycle early.)"""
    ints = [Instr(uid=i, kind=OpKind.IALU, label=f"c{i}",
                  srcs=(f"c{i-1}",) if i else (), dst=f"c{i}")
            for i in range(9)]
    ints.append(Instr(uid=9, kind=OpKind.IMUL, label="x1", srcs=("c8",),
                      dst="x"))
    fps = [Instr(uid=10, kind=OpKind.FDIV, label="d", srcs=("a",), dst="d"),
           Instr(uid=11, kind=OpKind.FADD, label="y", srcs=("x",), dst="y")]
    prog = Program(name="initwrite", policy=P.COPIFTV2, mode="dual",
                   streams={Unit.INT: ints, Unit.FP: fps}, n_samples=1,
                   init_env={"a": 8.0, "x": 1.0})
    _assert_equal_runs(prog, MachineConfig(evaluate=False))


def test_event_stepper_resumable_and_interleavable():
    """Manual stepping of two interleaved event steppers must match a
    one-shot reference run (mid-run result() included)."""
    tcfg = TransformConfig(n_samples=16)
    mk = lambda: lower(KERNELS["expf"], P.COPIFTV2, tcfg)  # noqa: E731
    a, b = Stepper(mk(), MachineConfig()), Stepper(mk(), MachineConfig())
    for _ in range(50):                       # mid-run result() is safe
        a.step()
    assert a.result().instrs["int"] >= 0
    while a.step() | b.step():                # non-short-circuit
        pass
    ref = ReferenceStepper(mk(), MachineConfig()).run()
    for r in (a.result(), b.result()):
        assert (r.cycles, r.instrs) == (ref.cycles, ref.instrs)
        assert r.energy == pytest.approx(ref.energy, rel=1e-12)
