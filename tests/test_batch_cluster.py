"""Differential tests for the vectorized lockstep *cluster* engine (PR 8).

The contract extends PR 7's single-PE one: :class:`repro.core.
BatchClusterStepper` (one numpy max-recurrence pass over B cluster configs
of the same partitioned program set) is **bit-identical** to
:class:`ClusterStepper` (the scalar event engine, itself bit-identical to
the per-cycle reference) on every point of fuzzed multi-axis grids —
per-core cycles, energy, stall breakdown (including the ``*_bank`` /
``cq_empty`` / ``cq_full`` / ``dma`` causes), FIFO push/pop sequences,
occupancy highwater, FIFO-discipline violations, the functional
environment, the cluster aggregates (makespan, energy, channel
push/pop/violation counts), and deadlock behavior (same message, surfaced
as :class:`BatchClusterDeadlock` instead of an exception so one wedged
point cannot take down a batch).

Soundness comes from delegation, and the delegation paths are pinned here
too: predicted bank conflicts, infeasible channel/DMA geometry, and
circular cross-core dataflow all silently re-run on the scalar engine and
must still match it exactly.

Randomized configurations are drawn with ``hypothesis`` when available
(via tests/_hypothesis_compat.py) and with a seeded stdlib PRNG otherwise,
so the differential property always runs.
"""
import dataclasses
import itertools
import os
import random
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from _hypothesis_compat import given, settings, st  # noqa: E402

from repro.core import (KERNELS, BatchClusterDeadlock, BatchClusterStepper,
                        BatchClusterUnsupported, ClusterConfig,
                        ClusterStepper, DeadlockError, Instr, MachineConfig,
                        OpKind, Program, SweepPoint, TransformConfig, Unit,
                        batch_cluster_simulate, batch_cluster_supported,
                        grid, partition_kernel, partition_pipeline,
                        run_sweep)
from repro.core.policy import ExecutionPolicy as P

#: every per-core SimResult facet the engines must agree on
CORE_FACETS = ("cycles", "energy", "instrs", "stalls", "push_seq",
               "pop_seq", "max_queue_occupancy", "fifo_violations", "env")


def _assert_matches(progs, cfgs):
    """One batched run vs B scalar event-engine runs, all facets."""
    assert batch_cluster_supported(progs) is None
    outs = BatchClusterStepper(progs, cfgs).run()
    assert len(outs) == len(cfgs)
    for cfg, got in zip(cfgs, outs):
        try:
            ref = ClusterStepper(progs, cfg).run()
        except DeadlockError as e:
            assert isinstance(got, BatchClusterDeadlock), \
                f"scalar deadlocked, batch completed ({cfg})"
            assert got.message == str(e)
            assert isinstance(got.error(), DeadlockError)
            continue
        assert not isinstance(got, BatchClusterDeadlock), \
            f"batch deadlocked, scalar completed ({cfg}): {got.message}"
        for agg in ("cycles", "energy", "cq_pushes", "cq_pops",
                    "cq_violations"):
            assert getattr(ref, agg) == getattr(got, agg), (agg, cfg)
        for rc, rr in zip(got.core_results, ref.core_results):
            for facet in CORE_FACETS:
                assert getattr(rr, facet) == getattr(rc, facet), \
                    (facet, rc.name, cfg)


def _work_progs(kernel, n_cores, policy=P.COPIFTV2, n_samples=24, **tk):
    tcfg = TransformConfig(n_samples=n_samples, queue_depth=4, **tk)
    return partition_kernel(KERNELS[kernel], policy, tcfg, n_cores)


def _pipeline_progs(n_cores=2, n=64, dma_buffers=2):
    tcfg = TransformConfig(unroll=8, batch=min(32, n), queue_depth=4,
                           n_samples=n)
    return partition_pipeline(KERNELS["cluster_matmul"], tcfg, n_cores,
                              dma_buffers=dma_buffers,
                              use_prefix_cache=False)


def _cluster_axis(n_cores, rng=None):
    """A multi-axis spread of cluster configs: bank geometries (including
    the conflict-prone small counts that force scalar delegation), queue
    geometry stretches, and tight deadlock limits."""
    cfgs = []
    for banks, depth, lat in itertools.product((None, 8, 1), (2, 4), (1, 3)):
        cfgs.append(ClusterConfig(
            n_cores=n_cores, tcdm_banks=banks,
            machine=MachineConfig(queue_depth=depth, queue_latency=lat)))
    cfgs.append(ClusterConfig(
        n_cores=n_cores, tcdm_banks=2, bank_conflict_penalty=4,
        machine=MachineConfig(queue_depth=4)))
    cfgs.append(ClusterConfig(
        n_cores=n_cores,
        machine=MachineConfig(queue_depth=1, queue_latency=8,
                              deadlock_limit=3)))
    if rng is not None:
        rng.shuffle(cfgs)
    return cfgs


def _pipeline_axis(n_cores, rng=None):
    """Channel/DMA geometry spread for pipelined points, including
    infeasibly tight FIFOs/buffers that must delegate, not diverge."""
    cfgs = []
    for cqd, cql, setup in itertools.product((1, 2, 4), (1, 2), (0, 8)):
        cfgs.append(ClusterConfig(n_cores=n_cores, cq_depth=cqd,
                                  cq_latency=cql, dma_setup=setup))
    cfgs.append(ClusterConfig(n_cores=n_cores, tcdm_banks=2, cq_depth=4))
    cfgs.append(ClusterConfig(n_cores=n_cores, cq_depth=4, dma_buffers=1))
    if rng is not None:
        rng.shuffle(cfgs)
    return cfgs


# ---------------------------------------------------------------------------
# Dense small grids (tier1) + randomized fuzz
# ---------------------------------------------------------------------------

@pytest.mark.tier1
@pytest.mark.parametrize("n_cores", [2, 4])
def test_batch_cluster_matches_stepper_work_partitioned(n_cores):
    for kernel in ("poly_lcg", "histf"):
        progs = _work_progs(kernel, n_cores,
                            n_samples=24 if n_cores != 4 else 32)
        _assert_matches(progs, _cluster_axis(n_cores))


@pytest.mark.tier1
def test_batch_cluster_matches_stepper_pipelined():
    progs = _pipeline_progs(n_cores=2)
    _assert_matches(progs, _pipeline_axis(2))


@pytest.mark.parametrize("n_cores", [4])
def test_batch_cluster_matches_stepper_pipelined_wide(n_cores):
    progs = _pipeline_progs(n_cores=n_cores)
    _assert_matches(progs, _pipeline_axis(n_cores))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_batch_cluster_matches_stepper_random_configs(seed):
    """Seeded-PRNG differential fuzz across kernels, policies, core counts
    and the whole cluster-geometry space."""
    rng = random.Random(seed)
    for _ in range(4):
        kernel = rng.choice(("poly_lcg", "dequant_dot", "histf", "expf"))
        policy = rng.choice(list(P))
        nc = rng.choice((2, 4))
        try:
            progs = _work_progs(
                kernel, nc, policy=policy,
                n_samples=rng.choice((16, 32)),
                unroll=rng.choice((2, 4)))
        except ValueError:
            continue                  # infeasible partition: nothing to diff
        _assert_matches(progs, _cluster_axis(nc, rng)[:8])


@given(st.sampled_from(("poly_lcg", "dequant_dot", "histf")),
       st.sampled_from(list(P)), st.sampled_from((2, 4)),
       st.sampled_from((None, 8, 2)),
       st.integers(min_value=1, max_value=4))
@settings(max_examples=8, deadline=None)
def test_batch_cluster_matches_stepper_hypothesis(kernel, policy, n_cores,
                                                  banks, qlat):
    """Property form of the differential check (skips without hypothesis)."""
    try:
        progs = _work_progs(kernel, n_cores, policy=policy, n_samples=16)
    except ValueError:
        return
    cfg = ClusterConfig(n_cores=n_cores, tcdm_banks=banks,
                        machine=MachineConfig(queue_latency=qlat))
    _assert_matches(progs, [cfg])


# ---------------------------------------------------------------------------
# Delegation paths stay sound: contention, deadlock, infeasible geometry
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_bank_contention_delegates_with_exact_parity():
    """Heavy TCDM contention (few banks, long conflict windows) trips the
    zero-contention oracle; the silent scalar re-run must still match the
    reference on every facet, bank stalls included."""
    progs = _work_progs("histf", 4, n_samples=32)
    cfgs = [ClusterConfig(n_cores=4, tcdm_banks=banks,
                          bank_conflict_penalty=pen)
            for banks in (1, 2, 4) for pen in (1, 8)]
    _assert_matches(progs, cfgs)
    outs = [o for o in BatchClusterStepper(progs, cfgs).run()
            if not isinstance(o, BatchClusterDeadlock)]
    assert any(sum(v for r in o.core_results
                   for k, v in r.stalls.items() if k.endswith("_bank")) > 0
               for o in outs)        # the axis actually exercises conflicts


@pytest.mark.tier1
def test_cross_core_cyclic_deadlock_delegates_same_message():
    """Two cores each popping the channel the other would fill: circular
    dataflow makes the functional pass incomplete, every config delegates,
    and the scalar engine's cross-core deadlock annotation comes back
    verbatim as a BatchClusterDeadlock.  Alarm-guarded: raising beats
    wedging the suite."""
    import signal

    def cyclic_core(core, pop_chan, push_chan):
        magic = f"%cq{pop_chan}"
        pop = Instr(uid=0, kind=OpKind.CQ_POP, label=f"pop{core}",
                    srcs=(magic,), dst=f"v@{core}", fn=lambda v: v,
                    cq=pop_chan)
        push = Instr(uid=1, kind=OpKind.CQ_PUSH, label=f"push{core}",
                     srcs=(f"v@{core}",), push_val=f"v@{core}",
                     cq=push_chan)
        return Program(name=f"cyclic@core{core}/2", policy=P.COPIFTV2,
                       mode="dual", streams={Unit.INT: [pop, push]},
                       n_samples=0, init_env={magic: 0},
                       base_name="cyclic")

    progs = [cyclic_core(0, pop_chan=0, push_chan=1),
             cyclic_core(1, pop_chan=1, push_chan=0)]
    cfgs = [ClusterConfig(n_cores=2,
                          machine=MachineConfig(deadlock_limit=200)),
            ClusterConfig(n_cores=2, cq_depth=1,
                          machine=MachineConfig(deadlock_limit=50))]
    signal.alarm(60)
    try:
        outs = BatchClusterStepper(progs, cfgs).run()
        for got in outs:
            assert isinstance(got, BatchClusterDeadlock)
            assert "cross-core deadlock" in got.message
        _assert_matches(progs, cfgs)
    finally:
        signal.alarm(0)


@pytest.mark.tier1
def test_infeasibly_tight_fifos_delegate_with_parity():
    """Channel FIFOs / DMA buffers / intra-core queues below the static
    requirement cannot be expressed in lockstep (pushes would block) —
    those configs take the scalar path and still match exactly."""
    progs = _pipeline_progs(n_cores=2, n=32, dma_buffers=1)
    cfgs = [ClusterConfig(n_cores=2, cq_depth=1, dma_buffers=1,
                          machine=MachineConfig(queue_depth=d))
            for d in (1, 2, 4)]
    _assert_matches(progs, cfgs)


# ---------------------------------------------------------------------------
# API edges
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_batch_cluster_api_edges():
    progs = _work_progs("poly_lcg", 2)
    assert BatchClusterStepper(progs, []).run() == []
    with pytest.raises(ValueError, match="n_cores=4"):
        BatchClusterStepper(progs, [ClusterConfig(n_cores=4)])
    with pytest.raises(BatchClusterUnsupported, match="evaluate"):
        BatchClusterStepper(progs, [
            ClusterConfig(n_cores=2,
                          machine=MachineConfig(evaluate=True)),
            ClusterConfig(n_cores=2,
                          machine=MachineConfig(evaluate=False))])
    with pytest.raises(ValueError, match="0 per-core programs"):
        BatchClusterStepper([], [])
    assert batch_cluster_supported(progs) is None
    # None config slots default to the degenerate geometry, like the scalar
    # constructor
    outs = batch_cluster_simulate(progs, [None])
    ref = ClusterStepper(progs, ClusterConfig(n_cores=2)).run()
    assert (outs[0].cycles, outs[0].energy) == (ref.cycles, ref.energy)


@pytest.mark.tier1
def test_batch_cluster_compile_cache_reused_across_steppers():
    """The compiled tables hang off the program set (keyed by identity +
    evaluate mode), so repeated sweep groups over the same memoized
    partitioning skip recompilation."""
    progs = _work_progs("poly_lcg", 2)
    s1 = BatchClusterStepper(progs, [ClusterConfig(n_cores=2)])
    s2 = BatchClusterStepper(progs, [ClusterConfig(
        n_cores=2, machine=MachineConfig(queue_latency=3))])
    assert s1._t is s2._t
    assert s1.run()[0].cycles == ClusterStepper(
        progs, ClusterConfig(n_cores=2)).run().cycles


# ---------------------------------------------------------------------------
# Sweep integration: mixed grids through run_sweep (satellite 4)
# ---------------------------------------------------------------------------

def _strip_engine(rec):
    d = dataclasses.asdict(rec)
    d.pop("engine")
    return d


@pytest.mark.tier1
def test_sweep_batch_matches_event_on_interleaved_mixed_grid():
    """The wired sweep path over a grid interleaving non-clustered,
    work-partitioned, banked, pipelined and rejected points: engine="batch"
    records are bit-identical to the all-event sweep (the grouping +
    fallback regression the satellite asks for)."""
    pts_e = grid(kernels=("poly_lcg", "histf"),
                 policies=(P.COPIFT, P.COPIFTV2),
                 queue_depths=(2, 4), queue_latencies=(1, 4),
                 n_cores=(1, 2), tcdm_banks=(None, 8), n_samples=16)
    pts_e += grid(kernels=("cluster_matmul",), policies=(P.COPIFTV2,),
                  queue_depths=(4,), queue_latencies=(1, 2),
                  n_cores=(2,), pipelines=(True,), cq_depths=(2, 4),
                  n_samples=64, unrolls=(8,))
    # pipelined points on the wrong policy/core-count are rejections the
    # batch path must reproduce, not crash on
    pts_e += [SweepPoint(kernel="expf", policy="copift", n_samples=16,
                         pipeline=True, n_cores=2),
              SweepPoint(kernel="expf", policy="copiftv2", n_samples=16,
                         pipeline=True, n_cores=3)]
    pts_b = [dataclasses.replace(p, engine="batch") for p in pts_e]
    recs_e = run_sweep(pts_e, workers=1)
    recs_b = run_sweep(pts_b, workers=1)
    assert len(recs_e) == len(recs_b) == len(pts_e)
    assert any(r.n_cores > 1 and r.ok for r in recs_b)
    assert any(r.pipeline and r.ok for r in recs_b)
    assert any(r.status == "rejected" for r in recs_b)
    for a, b in zip(recs_e, recs_b):
        assert b.engine == "batch"
        assert _strip_engine(a) == _strip_engine(b)


@pytest.mark.tier1
def test_sweep_batch_cluster_tight_geometry_point_matches_event():
    """A clustered point with the tightest queue geometry (the regime where
    lockstep infeasibility and deadlocks live) must come back as the same
    record under both engines, whatever its status ends up being."""
    pt = SweepPoint(kernel="histf", policy="copiftv2", n_samples=16,
                    n_cores=2, queue_depth=1, queue_latency=8,
                    engine="batch")
    recs_b = run_sweep([pt], workers=1)
    recs_e = run_sweep([dataclasses.replace(pt, engine="event")], workers=1)
    assert _strip_engine(recs_b[0]) == _strip_engine(recs_e[0])
