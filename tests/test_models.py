"""Model-zoo tests: per-arch smoke, decode/forward consistency, and
reference-implementation equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import RunConfig, supported_shapes
from repro.configs import ARCHS, get_config, get_reduced
from repro.models import decode_step, forward, init_cache, init_model_params
from repro.models.attention import flash_attention_ref
from repro.models.moe import moe_apply, moe_apply_grouped
from repro.models.ssm import mamba_apply, mamba_specs
from repro.models.rglru import rglru_apply, rglru_specs
from repro.models.layers import init_params

RC = RunConfig(remat=False, dtype="float32", param_dtype="float32")
KEY = jax.random.PRNGKey(0)


def _batch(cfg, B, S, key):
    batch = {}
    if cfg.frontend == "audio":
        batch["frames"] = jax.random.normal(key, (B, S, cfg.d_model)) * 0.1
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
        if cfg.frontend == "vision":
            batch["patches"] = jax.random.normal(
                key, (B, cfg.n_frontend_tokens, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_shapes_and_finite(arch):
    cfg = get_reduced(arch)
    params = init_model_params(KEY, cfg)
    B, S = 2, 32
    batch = _batch(cfg, B, S, KEY)
    logits = forward(params, batch, cfg, RC)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step_no_nans(arch):
    """One SGD step on the reduced config: finite loss and grads."""
    cfg = get_reduced(arch)
    params = init_model_params(KEY, cfg)
    B, S = 2, 16
    batch = _batch(cfg, B, S, KEY)
    batch["labels"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab)

    def loss_fn(p):
        logits = forward(p, batch, cfg, RC)
        lp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.take_along_axis(lp, batch["labels"][..., None],
                                    axis=-1).mean()

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    flat = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat)


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_reduced(a).causal])
def test_decode_matches_forward(arch):
    """Token-by-token decode with the cache reproduces the full forward
    logits — the strongest cache-correctness check."""
    cfg = get_reduced(arch)
    params = init_model_params(KEY, cfg)
    B, S = 2, 8
    if cfg.frontend == "vision":
        # decode path starts from plain tokens; skip the vision prefix here
        cfg_tokens_only = cfg
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab)}
    if cfg.frontend == "vision":
        import dataclasses
        cfg = dataclasses.replace(cfg, frontend=None, n_frontend_tokens=0)
    full = forward(params, batch, cfg, RC)

    cache = init_cache(cfg, B, 16, jnp.float32)
    outs = []
    for t in range(S):
        logits, cache = decode_step(params, cache,
                                    {"tokens": batch["tokens"][:, t:t + 1]},
                                    cfg, RC)
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_matches_naive():
    B, H, S, D = 2, 4, 96, 16
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (B, H, S, D))
    k = jax.random.normal(k2, (B, H, S, D))
    v = jax.random.normal(k3, (B, H, S, D))
    for causal in (True, False):
        for window in (None, 24):
            s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(D)
            i, j = jnp.arange(S)[:, None], jnp.arange(S)[None]
            mask = jnp.ones((S, S), bool)
            if causal:
                mask &= j <= i
            if window is not None:
                mask &= j > i - window
            s = jnp.where(mask, s, -1e30)
            ref = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s, -1), v)
            out = flash_attention_ref(q, k, v, causal=causal, window=window,
                                      block_q=32, block_k=16)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=2e-5, atol=2e-5)


def test_flash_attention_gqa_grouping():
    B, Hq, Hkv, S, D = 1, 8, 2, 64, 16
    q = jax.random.normal(KEY, (B, Hq, S, D))
    k = jax.random.normal(KEY, (B, Hkv, S, D))
    v = jax.random.normal(KEY, (B, Hkv, S, D))
    out = flash_attention_ref(q, k, v, causal=True)
    kf = jnp.repeat(k, Hq // Hkv, axis=1)
    vf = jnp.repeat(v, Hq // Hkv, axis=1)
    ref = flash_attention_ref(q, kf, vf, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_mamba_chunked_scan_invariant_to_chunk_size():
    cfg = get_reduced("falcon-mamba-7b")
    p = init_params(KEY, mamba_specs(cfg))
    x = jax.random.normal(KEY, (2, 40, cfg.d_model)) * 0.3
    y1 = mamba_apply(p, x, cfg, chunk=8)
    y2 = mamba_apply(p, x, cfg, chunk=40)
    y3 = mamba_apply(p, x, cfg, chunk=64)   # with padding
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y3), rtol=1e-4, atol=1e-5)


def test_rglru_chunked_scan_invariant_to_chunk_size():
    cfg = get_reduced("recurrentgemma-2b")
    p = init_params(KEY, rglru_specs(cfg))
    x = jax.random.normal(KEY, (2, 40, cfg.d_model)) * 0.3
    y1 = rglru_apply(p, x, cfg, chunk=8)
    y2 = rglru_apply(p, x, cfg, chunk=40)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-5)


def test_moe_grouped_matches_dense_at_high_capacity():
    from repro.models.moe import moe_specs
    cfg = get_reduced("olmoe-1b-7b")
    p = init_params(KEY, moe_specs(cfg))
    x = jax.random.normal(KEY, (2, 16, cfg.d_model)) * 0.3
    dense = moe_apply(p, x, cfg)
    grouped = moe_apply_grouped(p, x, cfg, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(grouped), np.asarray(dense),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("arch", ARCHS)
def test_supported_shapes_follow_skip_rules(arch):
    cfg = get_config(arch)
    shapes = supported_shapes(cfg)
    assert "train_4k" in shapes and "prefill_32k" in shapes
    if cfg.family in ("ssm", "hybrid"):
        assert "long_500k" in shapes
    elif cfg.causal:
        assert "long_500k" not in shapes       # quadratic attention
    if not cfg.causal:
        assert "decode_32k" not in shapes      # encoder-only
