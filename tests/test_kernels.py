"""Pallas kernel tests: interpret-mode allclose vs pure-jnp oracles, with
hypothesis sweeps over shapes and dtypes (per-kernel, per DESIGN.md §5)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.policy import ExecutionPolicy as EP
from repro.kernels import (flash_attention, moe_gemm, queue_matmul,
                           rglru_scan, ssm_scan)
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.moe_gemm.ref import moe_gemm_ref
from repro.kernels.queue_matmul.ref import matmul_ref
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.ssm_scan.ref import ssm_scan_ref

KEY = jax.random.PRNGKey(0)
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


# --- queue_matmul -----------------------------------------------------------

@given(m=st.integers(1, 300), k=st.integers(1, 300), n=st.integers(1, 300),
       depth=st.integers(1, 4), di=st.integers(0, 1))
@settings(max_examples=12, deadline=None)
def test_queue_matmul_shapes(m, k, n, depth, di):
    dtype = DTYPES[di]
    x = jax.random.normal(KEY, (m, k), dtype)
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (k, n), dtype)
    y = queue_matmul(x, w, depth=depth, block=(128, 128, 128))
    ref = matmul_ref(x, w).astype(dtype)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("policy", list(EP))
def test_queue_matmul_policies_agree(policy):
    x = jax.random.normal(KEY, (130, 260))
    w = jax.random.normal(KEY, (260, 70))
    y = queue_matmul(x, w, policy=policy)
    np.testing.assert_allclose(np.asarray(y), np.asarray(matmul_ref(x, w)),
                               rtol=2e-4, atol=2e-4)


# --- flash_attention --------------------------------------------------------

@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 48), (False, 48)])
def test_flash_attention_vs_ref(dtype, causal, window):
    B, Hq, Hkv, S, D = 2, 4, 2, 150, 32
    q = jax.random.normal(KEY, (B, Hq, S, D), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, Hkv, S, D), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, Hkv, S, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, bq=64, bk=64)
    kr = jnp.repeat(k, Hq // Hkv, 1).reshape(B * Hq, S, D)
    vr = jnp.repeat(v, Hq // Hkv, 1).reshape(B * Hq, S, D)
    ref = attention_ref(q.reshape(B * Hq, S, D), kr, vr, causal=causal,
                        window=window).reshape(B, Hq, S, D)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@given(s=st.integers(2, 200), d=st.sampled_from([16, 32, 64]))
@settings(max_examples=8, deadline=None)
def test_flash_attention_shape_sweep(s, d):
    q = jax.random.normal(KEY, (1, 2, s, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 2, s, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 2, s, d))
    out = flash_attention(q, k, v, causal=True, bq=64, bk=64)
    ref = attention_ref(q.reshape(2, s, d), k.reshape(2, s, d),
                        v.reshape(2, s, d), causal=True).reshape(1, 2, s, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-4, atol=3e-4)


# --- ssm_scan ---------------------------------------------------------------

@given(t=st.integers(1, 150), d=st.integers(1, 100),
       n=st.sampled_from([4, 8, 16]))
@settings(max_examples=10, deadline=None)
def test_ssm_scan_shape_sweep(t, d, n):
    x = jax.random.normal(KEY, (2, t, d)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 1),
                                           (2, t, d))) * 0.1
    A = -jnp.abs(jax.random.normal(jax.random.fold_in(KEY, 2), (d, n)))
    Bm = jax.random.normal(jax.random.fold_in(KEY, 3), (2, t, n))
    C = jax.random.normal(jax.random.fold_in(KEY, 4), (2, t, n))
    y = ssm_scan(x, dt, A, Bm, C, bt=64, bd=64)
    ref = ssm_scan_ref(x, dt, A, Bm, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_ssm_scan_state_carries_across_time_blocks():
    """T spanning several time blocks must match the sequential oracle —
    catches any state reset at block boundaries."""
    t, d, n = 200, 8, 4
    x = jnp.ones((1, t, d)) * 0.1
    dt = jnp.ones((1, t, d)) * 0.05
    A = -jnp.ones((d, n))
    Bm = jnp.ones((1, t, n))
    C = jnp.ones((1, t, n))
    y = ssm_scan(x, dt, A, Bm, C, bt=32, bd=8)
    ref = ssm_scan_ref(x, dt, A, Bm, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# --- rglru_scan -------------------------------------------------------------

@given(t=st.integers(1, 150), w=st.integers(1, 100))
@settings(max_examples=10, deadline=None)
def test_rglru_scan_shape_sweep(t, w):
    a = jax.nn.sigmoid(jax.random.normal(KEY, (2, t, w)))
    bx = jax.random.normal(jax.random.fold_in(KEY, 1), (2, t, w))
    h = rglru_scan(a, bx, bt=64, bw=64)
    np.testing.assert_allclose(np.asarray(h), np.asarray(rglru_scan_ref(a, bx)),
                               rtol=2e-4, atol=2e-4)


# --- moe_gemm ---------------------------------------------------------------

@given(e=st.integers(1, 6), c=st.integers(1, 150), d=st.integers(1, 200),
       f=st.integers(1, 200), depth=st.integers(1, 3))
@settings(max_examples=10, deadline=None)
def test_moe_gemm_shape_sweep(e, c, d, f, depth):
    x = jax.random.normal(KEY, (e, c, d))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (e, d, f))
    y = moe_gemm(x, w, bc=64, bf=64, bk=64, depth=depth)
    np.testing.assert_allclose(np.asarray(y), np.asarray(moe_gemm_ref(x, w)),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", DTYPES)
def test_moe_gemm_dtypes(dtype):
    x = jax.random.normal(KEY, (2, 64, 128), dtype)
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 128, 64), dtype)
    y = moe_gemm(x, w)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(moe_gemm_ref(x, w), np.float32),
                               **_tol(dtype))
