"""Cluster machine-model tests — the PR-5 contracts.

The load-bearing one: :class:`repro.core.cluster.ClusterStepper` with
``n_cores=1, tcdm_banks=None`` is **bit-identical** to the single-core
:class:`~repro.core.machine.Stepper` — cycles, energy, stall breakdown,
FIFO push/pop sequences, occupancy highwater and the functional environment
— across the *default sweep grid* (every kernel x policy x depth x latency
x unroll).  Plus: contention-free N-core clusters equal N independent
single-core runs; work partitioning preserves reference semantics; the bank
arbiter behaves (monotone degradation, bank stalls, event/cycle parity);
and the cluster columns round-trip through CSV with legacy CSVs still
readable.
"""
import dataclasses
import io

import pytest

from repro.core import (KERNELS, ClusterConfig, ClusterStepper,
                        MachineConfig, OperatingPoint, Stepper, SweepPoint,
                        TransformConfig, grid, lower, partition_kernel,
                        read_csv, run_point, run_sweep, simulate_cluster,
                        write_csv)
from repro.core.isa import E_TCDM_INTERCONNECT, MEM_KINDS
from repro.core.policy import ExecutionPolicy as P
from repro.core.sweep import LEGACY_CSV_FIELDS, record_to_row

#: every SimResult facet the single-core engine and the degenerate cluster
#: must agree on bit-for-bit
FACETS = ("cycles", "energy", "instrs", "stalls", "push_seq", "pop_seq",
          "max_queue_occupancy", "fifo_violations", "env")

#: the default exploration grid (the 336-config space explore.py sweeps)
DEFAULT_GRID = dict(queue_depths=(1, 2, 4, 8), queue_latencies=(1, 2),
                    unrolls=(4, 8), n_samples=32)


def _lowered(pt: SweepPoint):
    tcfg = TransformConfig(n_samples=pt.n_samples, queue_depth=pt.queue_depth,
                           unroll=pt.unroll, batch=min(32, pt.n_samples))
    try:
        return lower(KERNELS[pt.kernel], P.parse(pt.policy), tcfg)
    except ValueError:
        return None                   # infeasible schedule: nothing to diff


# ---------------------------------------------------------------------------
# The bit-identity contract, differentially across the default sweep grid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kernel", sorted(KERNELS))
def test_single_core_cluster_bit_identical_across_default_grid(kernel):
    """CI gate for the PR-5 acceptance criterion: the degenerate cluster
    (one core, conflict-free TCDM) matches the plain Stepper exactly on
    every point of the default sweep grid."""
    for pt in grid(kernels=[kernel], **DEFAULT_GRID):
        prog = _lowered(pt)
        if prog is None:
            continue
        mcfg = MachineConfig(queue_depth=pt.queue_depth,
                             queue_latency=pt.queue_latency)
        ref = Stepper(prog, mcfg).run()
        cres = ClusterStepper([prog], ClusterConfig(machine=mcfg)).run()
        core = cres.core_results[0]
        for facet in FACETS:
            assert getattr(ref, facet) == getattr(core, facet), (pt, facet)
        assert (cres.cycles, cres.energy) == (ref.cycles, ref.energy), pt
        assert cres.stalls == ref.stalls and cres.ipc == ref.ipc, pt


@pytest.mark.tier1
def test_single_core_record_identical_through_run_point():
    """A cluster-path record (tcdm_banks set, one core, no memory pressure)
    equals the plain single-core record field-for-field."""
    plain = run_point(SweepPoint(kernel="expf", policy="copiftv2",
                                 n_samples=32))
    # expf has no TCDM accesses, so any bank count is contention-free
    clus = run_point(SweepPoint(kernel="expf", policy="copiftv2",
                                n_samples=32, tcdm_banks=7))
    assert dataclasses.replace(clus, tcdm_banks=None) == plain


# ---------------------------------------------------------------------------
# Contention-free N-core == N independent single-core runs
# ---------------------------------------------------------------------------

@pytest.mark.tier1
@pytest.mark.parametrize("kernel", sorted(KERNELS))
def test_contention_free_ncore_equals_independent_runs(kernel):
    tcfg = TransformConfig(n_samples=32, queue_depth=4)
    progs = partition_kernel(KERNELS[kernel], P.COPIFTV2, tcfg, n_cores=4)
    cres = simulate_cluster(progs, ClusterConfig(n_cores=4))
    assert cres.n_cores == 4 and cres.n_samples == 32
    solo_energy = 0.0
    mem_accesses = 0
    for prog, core in zip(progs, cres.core_results):
        solo = Stepper(prog, MachineConfig()).run()
        # per-core cycles (and all timing behavior) match an independent run
        for facet in ("cycles", "instrs", "stalls", "push_seq", "pop_seq",
                      "max_queue_occupancy", "env"):
            assert getattr(solo, facet) == getattr(core, facet), facet
        # energy differs only by the per-access interconnect charge
        n_mem = sum(1 for lst in prog.streams.values()
                    for ins in lst if ins.kind in MEM_KINDS)
        mem_accesses += n_mem
        solo_energy += solo.energy
        assert core.energy == pytest.approx(
            solo.energy + E_TCDM_INTERCONNECT * n_mem, rel=1e-12)
    assert cres.cycles == max(r.cycles for r in cres.core_results)
    assert cres.energy == pytest.approx(
        solo_energy + E_TCDM_INTERCONNECT * mem_accesses, rel=1e-12)
    assert cres.bank_stalls == 0


@pytest.mark.tier1
def test_partitioned_outputs_match_sequential_reference():
    """Disjoint sample ranges with fast-forwarded loop-carried state: the
    concatenated per-core outputs equal the sequential interpreter even for
    serial-dependence kernels (LCG chains, running accumulators)."""
    for kernel in ("poly_lcg", "dequant_dot", "histf"):
        rec = run_point(SweepPoint(kernel=kernel, policy="copiftv2",
                                   n_samples=32, n_cores=4))
        assert rec.ok and rec.equivalent and not rec.fifo_violations, rec


@pytest.mark.tier1
def test_partition_rejects_indivisible_and_deep_lags():
    from repro.core import LoopDFG, Node, OpKind, s
    tcfg = TransformConfig(n_samples=32)
    with pytest.raises(ValueError, match="divisible"):
        partition_kernel(KERNELS["expf"], P.COPIFTV2, tcfg, n_cores=5)
    rec = run_point(SweepPoint(kernel="expf", policy="copiftv2",
                               n_samples=30, n_cores=4))
    assert rec.status == "rejected"
    lag2 = LoopDFG("lag2", [Node("a", OpKind.IALU, (s("a", 2),),
                                 fn=lambda x: x + 1, out=True)],
                   init={"a": 0})
    with pytest.raises(ValueError, match="lag 1"):
        partition_kernel(lag2, P.BASELINE, tcfg, n_cores=2)


@pytest.mark.tier1
def test_partition_single_core_is_plain_lowering():
    tcfg = TransformConfig(n_samples=16)
    progs = partition_kernel(KERNELS["expf"], P.COPIFTV2, tcfg, n_cores=1)
    assert len(progs) == 1
    assert progs[0].name == "expf"     # no @core tag: the program itself


# ---------------------------------------------------------------------------
# Bank contention semantics
# ---------------------------------------------------------------------------

def _cluster(kernel, n_cores, banks, penalty=1, engine="event", n=32):
    tcfg = TransformConfig(n_samples=n, queue_depth=4)
    progs = partition_kernel(KERNELS[kernel], P.COPIFTV2, tcfg, n_cores)
    return simulate_cluster(
        progs, ClusterConfig(n_cores=n_cores, tcdm_banks=banks,
                             bank_conflict_penalty=penalty), engine=engine)


@pytest.mark.tier1
def test_bank_contention_slows_and_attributes():
    free = _cluster("histf", 4, None)
    tight = _cluster("histf", 4, 2, penalty=4)
    assert tight.cycles > free.cycles          # contention costs cycles
    assert tight.bank_stalls > 0
    assert any(k.endswith("_bank") for k in tight.stalls)
    assert free.bank_stalls == 0
    # scarcer banks can only be slower than the conflict-free TCDM
    mid = _cluster("histf", 4, 8, penalty=4)
    assert free.cycles <= mid.cycles <= tight.cycles


@pytest.mark.tier1
def test_contended_cluster_event_cycle_engine_parity():
    """Issue timing, energy, FIFO sequences, env and per-unit stall totals
    agree between the event-driven and naive per-cycle cluster engines on a
    contended configuration (the cause split inside a bank-blocked stretch
    is allowed to differ; the totals are not)."""
    ev = _cluster("histf", 4, 2, penalty=4)
    cy = _cluster("histf", 4, 2, penalty=4, engine="cycle")
    assert (ev.cycles, ev.energy, ev.instrs) == (cy.cycles, cy.energy,
                                                 cy.instrs)
    for a, b in zip(ev.core_results, cy.core_results):
        assert a.env == b.env
        assert a.push_seq == b.push_seq and a.pop_seq == b.pop_seq
        assert a.cycles == b.cycles
        for unit in ("int", "fp"):
            ta = sum(v for k, v in a.stalls.items() if k.startswith(unit))
            tb = sum(v for k, v in b.stalls.items() if k.startswith(unit))
            assert ta == tb, unit


@pytest.mark.tier1
def test_contention_free_cluster_engines_bit_identical():
    ev = _cluster("dequant_dot", 2, None)
    cy = _cluster("dequant_dot", 2, None, engine="cycle")
    for a, b in zip(ev.core_results, cy.core_results):
        for facet in FACETS:
            assert getattr(a, facet) == getattr(b, facet), facet


@pytest.mark.tier1
def test_malformed_cluster_geometry_rejected_not_raised():
    """run_point never raises for model-level outcomes: a bad cluster
    geometry yields one rejected record (and never masquerades as a cheap
    single-PE point), and grid() refuses to enumerate one."""
    for kw in (dict(n_cores=0), dict(tcdm_banks=0), dict(n_cores=-1)):
        rec = run_point(SweepPoint(kernel="expf", policy="copiftv2",
                                   n_samples=16, **kw))
        assert rec.status == "rejected" and "cluster geometry" in rec.detail
    with pytest.raises(ValueError, match="n_cores"):
        grid(kernels=["expf"], n_cores=(0,))
    with pytest.raises(ValueError, match="tcdm_banks"):
        grid(kernels=["expf"], tcdm_banks=(0,))


@pytest.mark.tier1
def test_cluster_config_validation():
    with pytest.raises(ValueError):
        ClusterConfig(n_cores=0)
    with pytest.raises(ValueError):
        ClusterConfig(tcdm_banks=0)
    with pytest.raises(ValueError):
        ClusterConfig(bank_conflict_penalty=0)
    prog = lower(KERNELS["expf"], P.COPIFTV2, TransformConfig(n_samples=8))
    with pytest.raises(ValueError, match="n_cores=2"):
        ClusterStepper([prog], ClusterConfig(n_cores=2))
    with pytest.raises(ValueError, match="engine"):
        ClusterStepper([prog], ClusterConfig(), engine="warp")


# ---------------------------------------------------------------------------
# Sweep / CSV / policy integration
# ---------------------------------------------------------------------------

def test_cluster_sweep_grid_and_equivalence():
    pts = grid(kernels=["expf", "histf"], queue_depths=(2, 4), n_samples=32,
               n_cores=(1, 2, 4), tcdm_banks=(None, 4))
    assert len(pts) == 2 * 3 * 2 * 3 * 2
    recs = run_sweep(pts, workers=1)
    assert all(r.ok and r.equivalent and not r.fifo_violations for r in recs)
    # aggregate IPC scales past the dual-issue bound; per-core IPC does not
    multi = [r for r in recs if r.n_cores == 4 and r.policy == "copiftv2"]
    assert multi and all(r.ipc > 2.0 for r in multi)
    assert all(r.ipc_per_core <= 2.0 + 1e-9 for r in recs)


@pytest.mark.tier1
def test_cluster_csv_round_trip_and_legacy_read(tmp_path):
    """Satellite contract: the new cluster columns round-trip losslessly
    AND PR-2-era CSVs without them still read (n_cores defaults to 1)."""
    import csv as _csv
    recs = run_sweep(grid(kernels=["histf"], queue_depths=(2,), n_samples=16,
                          n_cores=(1, 2), tcdm_banks=(None, 2)), workers=1)
    path = str(tmp_path / "cluster.csv")
    assert write_csv(recs, path) == len(recs)
    assert read_csv(path) == recs
    # legacy emission: the same single-core records minus the cluster columns
    legacy = [r for r in recs if r.n_cores == 1 and r.tcdm_banks is None]
    buf = io.StringIO()
    w = _csv.DictWriter(buf, fieldnames=list(LEGACY_CSV_FIELDS))
    w.writeheader()
    for r in legacy:
        row = record_to_row(r)
        w.writerow({k: row[k] for k in LEGACY_CSV_FIELDS})
    buf.seek(0)
    back = read_csv(buf)
    assert back == legacy
    assert all(r.n_cores == 1 and r.tcdm_banks is None and
               r.ipc_per_core == r.ipc for r in back)


@pytest.mark.tier1
def test_serve_engine_batch_slots_scale_with_cluster_point():
    from repro.config import ModelConfig, RunConfig
    from repro.serve import ServeEngine

    cfg = ModelConfig(name="tiny", family="dense", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=1, d_ff=32, vocab=64)
    rc = RunConfig(dtype="float32", param_dtype="float32", remat=False)
    op = OperatingPoint(policy=P.COPIFTV2, n_cores=4)
    eng = ServeEngine({}, cfg, rc, max_len=8, operating_point=op)
    assert len(eng.slots) == ServeEngine.SLOTS_PER_CORE * 4
    # explicit batch_slots always wins
    eng = ServeEngine({}, cfg, rc, batch_slots=2, max_len=8,
                      operating_point=op)
    assert len(eng.slots) == 2


@pytest.mark.tier1
def test_operating_point_carries_cluster_fields_through_calibration():
    from repro.core.calibrate import POINT_FIELDS, point_to_dict

    rec = run_point(SweepPoint(kernel="expf", policy="copiftv2",
                               n_samples=16, n_cores=2))
    d = point_to_dict(rec)
    assert set(POINT_FIELDS) == set(d)
    assert d["n_cores"] == 2 and d["tcdm_banks"] is None


# ---------------------------------------------------------------------------
# Front-diff gate unit checks (the drift detector itself)
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_front_diff_detects_drift_and_moves():
    import copy
    import sys
    import os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.front_diff import diff_fronts

    base = {"expf": [
        {"kernel": "expf", "policy": "copiftv2", "queue_depth": 4,
         "queue_latency": 1, "unroll": 8, "n_cores": 1, "tcdm_banks": None,
         "cycles": 100, "ipc": 1.5, "energy": 2000.0}]}
    assert diff_fronts(base, copy.deepcopy(base)) == []
    moved = copy.deepcopy(base)
    moved["expf"][0]["cycles"] = 101
    assert any("cycles moved" in p for p in diff_fronts(base, moved))
    drifted = copy.deepcopy(base)
    drifted["expf"][0]["energy"] *= 1.001
    assert any("energy drifted" in p for p in diff_fronts(base, drifted))
    gone = {"expf": []}
    assert any("vanished" in p for p in diff_fronts(base, gone))
    extra = copy.deepcopy(base)
    extra["expf"].append(dict(base["expf"][0], queue_depth=8))
    assert any("appeared" in p for p in diff_fronts(base, extra))


# ---------------------------------------------------------------------------
# PR-6: pipelined producer/consumer clusters (inter-core channels + DMA)
# ---------------------------------------------------------------------------

def _pipeline_progs(kernel="cluster_matmul", n=64, n_cores=4, dma_buffers=2):
    from repro.core import partition_pipeline
    tcfg = TransformConfig(unroll=8, batch=min(32, n), queue_depth=4,
                           n_samples=n)
    return partition_pipeline(KERNELS[kernel], tcfg, n_cores,
                              dma_buffers=dma_buffers,
                              use_prefix_cache=False)


@pytest.mark.tier1
def test_pipeline_partition_matches_reference_interpreter():
    """Producer/consumer pairs preserve kernel semantics: the consumer
    cores' concatenated outputs are bit-identical to the sequential
    interpreter, with zero FIFO/channel-order violations."""
    n, n_cores = 64, 4
    progs = _pipeline_progs(n=n, n_cores=n_cores)
    res = ClusterStepper(progs, ClusterConfig(n_cores=n_cores, tcdm_banks=2,
                                              cq_depth=4)).run()
    assert res.fifo_violations == 0 and res.cq_violations == 0
    assert res.cq_pushes > 0 and res.cq_pushes == res.cq_pops
    dfg = KERNELS["cluster_matmul"]
    ref = dfg.eval_reference(n)
    consumers = res.core_results[1::2]
    chunk = n // len(consumers)
    for node in dfg.outputs():
        got = [core.env.get(f"{node.name}@{i}")
               for core in consumers for i in range(chunk)]
        assert got == ref[node.name]


@pytest.mark.tier1
def test_pipeline_engine_parity_event_vs_cycle():
    """The event-driven cores agree with the per-cycle reference on every
    timing/energy/stall facet of a pipelined run — including the degenerate
    per-cycle stepping the event engine falls back to while channel-blocked."""
    progs = _pipeline_progs(n=32, n_cores=2, dma_buffers=1)
    ccfg = ClusterConfig(n_cores=2, tcdm_banks=2, cq_depth=2, dma_buffers=1)
    ev = ClusterStepper(progs, ccfg, engine="event").run()
    cy = ClusterStepper(progs, ccfg, engine="cycle").run()
    assert ev.cycles == cy.cycles
    assert ev.energy == cy.energy
    assert ev.stalls == cy.stalls
    assert ev.cq_pushes == cy.cq_pushes and ev.cq_pops == cy.cq_pops
    for a, b in zip(ev.core_results, cy.core_results):
        assert a.env == b.env


@pytest.mark.tier1
def test_pipeline_sweep_point_runs_and_invalid_points_reject():
    """The sweep spine carries the pipeline axes end to end; infeasible
    combinations reject instead of raising."""
    rec = run_point(SweepPoint(kernel="cluster_matmul", policy="copiftv2",
                               n_samples=64, n_cores=4, tcdm_banks=2,
                               pipeline=True, cq_depth=4, dma_buffers=2))
    assert rec.ok and rec.equivalent and rec.fifo_violations == 0
    assert rec.pipeline and rec.cq_stalls >= 0 and rec.ipc > 0
    bad_policy = run_point(SweepPoint(kernel="expf", policy="copift",
                                      n_samples=16, n_cores=2, pipeline=True))
    assert bad_policy.status == "rejected"
    odd_cores = run_point(SweepPoint(kernel="expf", policy="copiftv2",
                                     n_samples=16, n_cores=3, pipeline=True))
    assert odd_cores.status == "rejected"


@pytest.mark.tier1
def test_cluster_result_channel_columns_sum_the_right_stall_keys():
    progs = _pipeline_progs(n=64, n_cores=2)
    res = ClusterStepper(progs, ClusterConfig(n_cores=2, tcdm_banks=2)).run()
    assert res.cq_stalls == sum(
        v for k, v in res.stalls.items()
        if k.endswith(("_cq_empty", "_cq_full")))
    assert res.dma_stalls == sum(
        v for k, v in res.stalls.items() if k.endswith("_dma"))
    s = res.summary()
    assert s["cq_stalls"] == res.cq_stalls
    assert s["dma_stalls"] == res.dma_stalls
    assert s["cq_pushes"] == res.cq_pushes > 0


@pytest.mark.tier1
def test_cross_core_cyclic_channel_deadlock_raises_not_hangs():
    """Satellite contract: two cores each popping the channel the *other*
    one would fill is a cross-core cycle the per-core detector must catch
    (annotated as such), never an infinite hang.  Guarded by a hard alarm
    so a regression fails instead of wedging the suite."""
    import signal

    from repro.core import DeadlockError, Instr, OpKind, Program, Unit

    def cyclic_core(core, pop_chan, push_chan):
        magic = f"%cq{pop_chan}"
        pop = Instr(uid=0, kind=OpKind.CQ_POP, label=f"pop{core}",
                    srcs=(magic,), dst=f"v@{core}", fn=lambda v: v,
                    cq=pop_chan)
        push = Instr(uid=1, kind=OpKind.CQ_PUSH, label=f"push{core}",
                     srcs=(f"v@{core}",), push_val=f"v@{core}",
                     cq=push_chan)
        return Program(name=f"cyclic@core{core}/2", policy=P.COPIFTV2,
                       mode="dual", streams={Unit.INT: [pop, push]},
                       n_samples=0, init_env={magic: 0},
                       base_name="cyclic")

    progs = [cyclic_core(0, pop_chan=0, push_chan=1),
             cyclic_core(1, pop_chan=1, push_chan=0)]
    mcfg = MachineConfig(deadlock_limit=200)
    signal.alarm(60)                  # hard stop: raising beats hanging
    try:
        for engine in ("event", "cycle"):
            with pytest.raises(DeadlockError, match="cross-core deadlock"):
                ClusterStepper(progs, ClusterConfig(n_cores=2, machine=mcfg),
                               engine=engine).run()
    finally:
        signal.alarm(0)


# ---------------------------------------------------------------------------
# Satellite contracts: cache hygiene + hostile kernel names
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_banked_cluster_run_leaves_shared_program_state_intact():
    """Regression guard for skip-table cache poisoning: running a Program
    under a banked cluster core (which disables per-unit time skipping)
    must not perturb a later single-core run of the *same object* — it
    stays bit-identical to a fresh Program on every facet."""
    tcfg = TransformConfig(n_samples=16, queue_depth=4, unroll=8, batch=16)
    mcfg = MachineConfig()
    shared = lower(KERNELS["histf"], P.COPIFTV2, tcfg, use_prefix_cache=False)
    fresh = lower(KERNELS["histf"], P.COPIFTV2, tcfg, use_prefix_cache=False)
    baseline = Stepper(fresh, mcfg).run()
    ClusterStepper([shared], ClusterConfig(n_cores=1, tcdm_banks=2,
                                           machine=mcfg)).run()
    after = Stepper(shared, mcfg).run()
    for facet in FACETS:
        assert getattr(after, facet) == getattr(baseline, facet), facet


@pytest.mark.tier1
def test_hostile_kernel_name_containing_at_core_round_trips():
    """A user kernel whose name itself contains "@core" must survive
    partition -> cluster -> sweep CSV intact: the cluster result reports
    the carried base name, never a parse of the decorated per-core one."""
    import copy as _copy

    hostile = "evil@core0/8"
    dfg = _copy.copy(KERNELS["expf"])
    dfg.name = hostile
    tcfg = TransformConfig(n_samples=16, queue_depth=4, unroll=8, batch=16)
    progs = partition_kernel(dfg, P.COPIFTV2, tcfg, 2,
                             use_prefix_cache=False)
    assert [p.name for p in progs] == [f"{hostile}@core0/2",
                                       f"{hostile}@core1/2"]
    res = ClusterStepper(progs, ClusterConfig(n_cores=2)).run()
    assert res.name == hostile
    KERNELS[hostile] = dfg
    try:
        recs = run_sweep(grid(kernels=[hostile], policies=[P.COPIFTV2],
                              queue_depths=(4,), queue_latencies=(1,),
                              unrolls=(8,), n_samples=16, n_cores=(2,)),
                         workers=1)
        assert all(r.ok and r.equivalent for r in recs)
        buf = io.StringIO()
        write_csv(recs, buf)
        buf.seek(0)
        back = read_csv(buf)
        assert back == recs and all(r.kernel == hostile for r in back)
    finally:
        del KERNELS[hostile]
