"""Substrate tests: optimizer, train step, data pipeline, checkpointing,
fault-tolerant driver, straggler detection, compression, serving."""
import os

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.checkpoint import CheckpointManager, restore, save
from repro.checkpoint.manager import latest_step
from repro.config import RunConfig, ShapeConfig
from repro.configs import get_reduced
from repro.data import PrefetchLoader, SyntheticLMStream
from repro.distributed.compression import dequantize_int8, quantize_int8
from repro.models import init_model_params
from repro.optim import (clip_by_global_norm, init_opt_state,
                         lr_schedule)
from repro.runtime import FaultTolerantTrainer, InjectedFault, StragglerMonitor
from repro.serve import ServeEngine
from repro.train import train_step

RC = RunConfig(remat=False, dtype="float32", lr=1e-2, warmup_steps=5,
               total_steps=100)
KEY = jax.random.PRNGKey(0)


def _cfg():
    return get_reduced("phi3-mini-3.8b")


def _batch(cfg, B=4, S=16, seed=0):
    s = SyntheticLMStream(cfg.vocab, S, B, seed=seed)
    return {k: jnp.asarray(v) for k, v in s.batch_at(0).items()}


# --- optimizer --------------------------------------------------------------

def test_lr_schedule_shape():
    rc = RunConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(jnp.asarray(s), rc)) for s in range(0, 101, 10)]
    assert lrs[0] < lrs[1]                      # warmup rises
    assert lrs[-1] < lrs[2]                     # cosine decays
    assert abs(lrs[1] - 1e-3) < 1e-4            # peak at end of warmup


def test_grad_clip():
    g = {"a": jnp.full((4,), 10.0), "b": jnp.full((4,), -10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in
                         jax.tree_util.tree_leaves(clipped)))
    assert abs(float(total) - 1.0) < 1e-5
    assert float(norm) > 1.0


def test_train_loss_decreases():
    cfg = _cfg()
    params = init_model_params(KEY, cfg)
    opt = init_opt_state(params)
    batch = _batch(cfg)
    step = jax.jit(lambda p, o, b: train_step(p, o, b, cfg, RC))
    first = None
    for _ in range(30):
        params, opt, metrics = step(params, opt, batch)
        if first is None:
            first = float(metrics["loss"])
    assert float(metrics["loss"]) < first * 0.8


def test_microbatch_accumulation_matches_full_batch():
    cfg = _cfg()
    params = init_model_params(KEY, cfg)
    batch = _batch(cfg, B=4)
    rc_full = RunConfig(remat=False, dtype="float32")
    rc_mb = RunConfig(remat=False, dtype="float32", microbatch=2)
    from repro.train.step import _grads
    g1, _ = _grads(params, batch, cfg, rc_full)
    g2, _ = _grads(params, batch, cfg, rc_mb)
    flat1 = jax.tree_util.tree_leaves(g1)
    flat2 = jax.tree_util.tree_leaves(g2)
    for a, b in zip(flat1, flat2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-3)


# --- data -------------------------------------------------------------------

def test_stream_deterministic_and_seekable():
    s = SyntheticLMStream(100, 16, 4, seed=7)
    a = s.batch_at(12)
    b = s.batch_at(12)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = s.batch_at(13)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token shifted
    full_a = np.concatenate([a["tokens"], a["labels"][:, -1:]], axis=1)
    np.testing.assert_array_equal(full_a[:, 1:], a["labels"])


def test_stream_dp_sharding_partitions_batch():
    full = SyntheticLMStream(100, 8, 4, seed=3)
    parts = [SyntheticLMStream(100, 8, 4, seed=3, dp_rank=r, dp_size=2)
             for r in range(2)]
    b = [p.batch_at(5)["tokens"] for p in parts]
    assert b[0].shape == (2, 8)
    assert not np.array_equal(b[0], b[1])      # ranks see different data


def test_prefetch_loader_orders_batches():
    s = SyntheticLMStream(100, 8, 2, seed=1)
    loader = PrefetchLoader(s, start_step=3, depth=2)
    try:
        got = loader.get()
        np.testing.assert_array_equal(got["tokens"], s.batch_at(3)["tokens"])
        got2 = loader.get()
        np.testing.assert_array_equal(got2["tokens"], s.batch_at(4)["tokens"])
    finally:
        loader.close()


# --- compression ------------------------------------------------------------

@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=20, deadline=None)
def test_int8_quantization_unbiased(seed):
    key = jax.random.PRNGKey(seed)
    g = jax.random.normal(key, (64,)) * 0.37
    qs = [dequantize_int8(*quantize_int8(jax.random.fold_in(key, i), g))
          for i in range(64)]
    mean = jnp.stack(qs).mean(0)
    scale = float(jnp.max(jnp.abs(g))) / 127.0
    np.testing.assert_allclose(np.asarray(mean), np.asarray(g),
                               atol=scale * 0.6)
    # single round trip error bounded by one quantization step
    assert float(jnp.max(jnp.abs(qs[0] - g))) <= scale + 1e-6


# --- checkpointing ----------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    state = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
             "b": {"c": jnp.asarray(3)}}
    save(str(tmp_path), 7, state, extra={"data_step": 7})
    step, back, extra = restore(str(tmp_path), state)
    assert step == 7 and extra["data_step"] == 7
    np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(state["a"]))


def test_checkpoint_manager_async_keep_k(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    for s in (10, 20, 30, 40):
        m.save_async(s, {"x": jnp.asarray([s])})
    m.wait()
    m.close()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [30, 40]
    assert latest_step(str(tmp_path)) == 40


def test_checkpoint_atomicity_no_tmp_left(tmp_path):
    save(str(tmp_path), 1, {"x": jnp.ones(3)})
    assert not [d for d in os.listdir(tmp_path) if d.startswith(".tmp")]


# --- fault tolerance ---------------------------------------------------------

def test_trainer_recovers_from_injected_fault(tmp_path):
    cfg = _cfg()
    shape = ShapeConfig("tiny", 16, 4, "train")
    params = init_model_params(KEY, cfg)
    faults = {17}

    def fault_hook(step):
        if step in faults:
            faults.discard(step)
            raise InjectedFault(f"device loss @ {step}")

    def mesh_factory():
        from repro.launch.mesh import make_local_mesh
        return make_local_mesh(1, 1)

    tr = FaultTolerantTrainer(cfg, shape, RC, mesh_factory, str(tmp_path),
                              ckpt_every=10, fault_hook=fault_hook)
    out = tr.run(params, num_steps=25)
    assert out["restarts"] == 1
    assert out["step"] == 25
    # the rerun re-executed steps 10..16 after restoring the step-10 ckpt
    steps_seen = [s for s, _ in out["metrics"]]
    assert steps_seen.count(12) == 2


def test_trainer_resume_determinism(tmp_path):
    """Same data at a given step whether or not a restart happened."""
    s = SyntheticLMStream(64, 8, 2, seed=0)
    np.testing.assert_array_equal(s.batch_at(11)["tokens"],
                                  s.batch_at(11)["tokens"])


# --- straggler --------------------------------------------------------------

def test_straggler_detection():
    events = []
    mon = StragglerMonitor(window=20, threshold=4.0, min_samples=10,
                           on_straggler=lambda s, t, z: events.append(s))
    for i in range(30):
        mon.record(i, 0.10 + 0.001 * (i % 3))
    mon.record(30, 0.50)                       # 5x median
    assert events == [30]
    assert not mon.record(31, 0.101)           # baseline unpolluted


def test_heartbeat():
    from repro.runtime.straggler import Heartbeat
    hb = Heartbeat(["h0", "h1"], timeout=10.0)
    hb.beat("h0", 100.0)
    hb.beat("h1", 95.0)
    assert hb.dead(106.0) == ["h1"]


# --- serving ----------------------------------------------------------------

def test_serve_engine_batched_requests():
    cfg = _cfg()
    params = init_model_params(KEY, cfg)
    eng = ServeEngine(params, cfg, RC, batch_slots=2, max_len=64)
    r1 = eng.submit([1, 2, 3], max_new=4)
    r2 = eng.submit([4, 5], max_new=4)
    done = eng.run()
    assert set(done) == {r1, r2}
    for r in done.values():
        assert len(r.generated) == 4
        assert all(0 <= t < cfg.vocab for t in r.generated)


def test_heartbeat_no_false_dead_on_startup():
    """A monitor created at a large wall-clock time must give every host a
    full timeout window before declaring it dead — the last-beat table is
    seeded from the start time, not an implicit 0.0."""
    from repro.runtime.straggler import Heartbeat
    hb = Heartbeat(["h0", "h1"], timeout=10.0, start=1000.0)
    assert hb.dead(1005.0) == []            # nobody has beaten yet: alive
    hb.beat("h0", 1009.0)
    assert hb.dead(1011.0) == ["h1"]        # h1 never beat, window expired
    assert hb.dead(1030.0) == ["h0", "h1"]  # h0's beat aged out too


def test_serve_engine_second_wave_matches_fresh_engine():
    """Readmission must not reuse stale KV state: a request served in the
    second wave of a 2-slot engine generates the same tokens as the same
    request on a fresh engine."""
    cfg = _cfg()
    params = init_model_params(KEY, cfg)
    prompt, max_new = [7, 3, 9, 1], 5

    eng = ServeEngine(params, cfg, RC, batch_slots=2, max_len=64)
    eng.submit([1, 2, 3], max_new=4)
    eng.submit([4, 5, 6], max_new=4)
    eng.run()                               # wave 1 drains all slots
    rid = eng.submit(prompt, max_new=max_new)
    second_wave = eng.run()[rid].generated

    fresh = ServeEngine(params, cfg, RC, batch_slots=2, max_len=64)
    rid_f = fresh.submit(prompt, max_new=max_new)
    assert second_wave == fresh.run()[rid_f].generated
