"""recurrentgemma-2b — RG-LRU + local attention, pattern (rec, rec, attn)
[arXiv:2402.19427]."""
from ..config import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid", n_layers=26, d_model=2560,
    n_heads=10, n_kv_heads=1, d_ff=7680, vocab=256000, head_dim=256,
    rglru=RGLRUConfig(lru_width=2560, conv_width=4, window=2048))

def reduced() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-smoke", family="hybrid", n_layers=5, d_model=64,
        n_heads=2, n_kv_heads=1, d_ff=128, vocab=128, head_dim=16,
        rglru=RGLRUConfig(lru_width=64, conv_width=4, window=16))
