"""phi3-mini-3.8b — dense, RoPE + SwiGLU, MHA (kv=32) [arXiv:2404.14219]."""
from ..config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b", family="dense", n_layers=32, d_model=3072,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab=32064)

def reduced() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=128)
