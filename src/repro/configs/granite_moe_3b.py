"""granite-moe-3b-a800m — MoE 40 experts top-8
[hf:ibm-granite/granite-3.0-3b-a800m-base]."""
from ..config import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe", n_layers=32, d_model=1536,
    n_heads=24, n_kv_heads=8, d_ff=512, vocab=49155,
    moe=MoEConfig(num_experts=40, top_k=8, d_ff_expert=512))

def reduced() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=64, vocab=128,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=64))
