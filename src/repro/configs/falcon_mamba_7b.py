"""falcon-mamba-7b — Mamba-1, attention-free [arXiv:2410.05355]."""
from ..config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm", n_layers=64, d_model=4096,
    n_heads=1, n_kv_heads=1, d_ff=0, vocab=65024, rope=False,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2))

def reduced() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b-smoke", family="ssm", n_layers=2, d_model=64,
        n_heads=1, n_kv_heads=1, d_ff=0, vocab=128, rope=False,
        ssm=SSMConfig(d_state=4, d_conv=4, expand=2))
