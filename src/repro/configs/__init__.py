"""Architecture registry: --arch <id> -> ModelConfig (+ reduced smoke)."""
from importlib import import_module
from typing import List

_MODULES = {
    "falcon-mamba-7b": "falcon_mamba_7b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "minicpm3-4b": "minicpm3_4b",
    "nemotron-4-340b": "nemotron_4_340b",
    "glm4-9b": "glm4_9b",
    "pixtral-12b": "pixtral_12b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "hubert-xlarge": "hubert_xlarge",
}

ARCHS: List[str] = list(_MODULES)


def get_config(arch: str):
    mod = import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_reduced(arch: str):
    mod = import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.reduced()
