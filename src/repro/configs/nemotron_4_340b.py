"""nemotron-4-340b — dense GQA kv=8, squared-ReLU FFN [arXiv:2402.16819]."""
from ..config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense", n_layers=96, d_model=18432,
    n_heads=96, n_kv_heads=8, d_ff=73728, vocab=256000, ffn_act="relu2")

def reduced() -> ModelConfig:
    return ModelConfig(
        name="nemotron-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=8, n_kv_heads=2, d_ff=256, vocab=128, ffn_act="relu2")
