"""hubert-xlarge — encoder-only audio transformer [arXiv:2106.07447].
The CNN feature extractor is a STUB: input_specs provides precomputed frame
embeddings; no autoregressive decode (decode shapes are skipped)."""
from ..config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio", n_layers=48, d_model=1280,
    n_heads=16, n_kv_heads=16, d_ff=5120, vocab=504, causal=False,
    rope=True, frontend="audio")

def reduced() -> ModelConfig:
    return ModelConfig(
        name="hubert-smoke", family="audio", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=32, causal=False,
        rope=True, frontend="audio")
