"""olmoe-1b-7b — MoE 64 experts top-8 [arXiv:2409.02060]."""
from ..config import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe", n_layers=16, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1024, vocab=50304,
    moe=MoEConfig(num_experts=64, top_k=8, d_ff_expert=1024))

def reduced() -> ModelConfig:
    return ModelConfig(
        name="olmoe-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=64, vocab=128,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=64))
