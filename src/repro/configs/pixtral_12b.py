"""pixtral-12b — pixtral-ViT frontend (stub) + mistral-nemo decoder
[hf:mistralai/Pixtral-12B-2409].  The vision tower is a STUB: input_specs
provides precomputed patch embeddings that replace the first
``n_frontend_tokens`` token positions."""
from ..config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm", n_layers=40, d_model=5120,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=131072, head_dim=128,
    frontend="vision", n_frontend_tokens=256)

def reduced() -> ModelConfig:
    return ModelConfig(
        name="pixtral-smoke", family="vlm", n_layers=2, d_model=64,
        n_heads=8, n_kv_heads=2, d_ff=128, vocab=128, head_dim=8,
        frontend="vision", n_frontend_tokens=8)
