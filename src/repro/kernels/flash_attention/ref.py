"""Oracle: naive attention with explicit masks (small shapes only)."""
import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None) -> jax.Array:
    """q/k/v: (BH, S, D) -> (BH, S, D)."""
    S = q.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(q.shape[-1])
    i, j = jnp.arange(S)[:, None], jnp.arange(S)[None]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= j <= i
    if window is not None:
        mask &= j > i - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(v.dtype)
