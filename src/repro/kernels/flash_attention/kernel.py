"""Blocked online-softmax attention (flash attention) for TPU.

Grid (BH, n_q, n_k) with the KV dimension innermost; the running
(max, denom, acc) state lives in VMEM scratch and persists across the k
blocks of one q block.  Causal and sliding-window masks are applied
per-block; fully-masked blocks still execute (correct, not yet skipped —
see EXPERIMENTS.md §Perf for the block-skip iteration)."""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bq: int, bk: int, nk: int, causal: bool, window, seq_k: int,
            scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)               # (bq, d)
    k = k_ref[0].astype(jnp.float32)               # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < seq_k
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, bq: int, bk: int, causal: bool,
                           window, seq_k: int, interpret: bool) -> jax.Array:
    """q/k/v: (BH, S_padded, D); ``seq_k`` = true (unpadded) key length."""
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    nq, nk = Sq // bq, Sk // bk
    kern = functools.partial(_kernel, bq=bq, bk=bk, nk=nk, causal=causal,
                             window=window, seq_k=seq_k,
                             scale=1.0 / math.sqrt(D))
    return pl.pallas_call(
        kern,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
