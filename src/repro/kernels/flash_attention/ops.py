"""Jitted wrapper: (B, H, S, D) API with GQA expansion + padding."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import flash_attention_kernel


@partial(jax.jit, static_argnames=("causal", "window", "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: (B, Hq, S, D); k/v: (B, Hkv, S, D) — GQA expands KV heads."""
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    if Hkv != Hq:
        rep = Hq // Hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    qf = q.reshape(B * Hq, S, D)
    kf = k.reshape(B * Hq, S, D)
    vf = v.reshape(B * Hq, S, D)
    pad = (-S) % max(bq, bk)
    if pad:
        qf = jnp.pad(qf, ((0, 0), (0, pad), (0, 0)))
        kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0)))
    out = flash_attention_kernel(qf, kf, vf, bq=bq, bk=bk, causal=causal,
                                 window=window, seq_k=S, interpret=interpret)
    return out[:, :S].reshape(B, Hq, S, D)
