"""Jitted wrapper with padding.  Note: zero-padding time is safe (h carries
through; padded outputs are sliced off) and padded channels stay zero."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import rglru_scan_kernel


@partial(jax.jit, static_argnames=("bt", "bw", "interpret"))
def rglru_scan(a, bx, *, bt: int = 128, bw: int = 128,
               interpret: bool = True) -> jax.Array:
    B, T, w = a.shape
    pt, pw = (-T) % bt, (-w) % bw
    if pt or pw:
        a = jnp.pad(a, ((0, 0), (0, pt), (0, pw)))
        bx = jnp.pad(bx, ((0, 0), (0, pt), (0, pw)))
    h = rglru_scan_kernel(a, bx, bt=bt, bw=bw, interpret=interpret)
    return h[:, :T, :w]
