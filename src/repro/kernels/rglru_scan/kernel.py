"""RG-LRU linear-recurrence kernel: diagonal gated scan with the hidden
state resident in VMEM across time blocks (same scheme as ssm_scan, without
the state dimension)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, h_out, h_scr, *, bt: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[0].astype(jnp.float32)          # (bt, bw)
    bx = b_ref[0].astype(jnp.float32)

    def step(t, _):
        h = a[t] * h_scr[...] + bx[t]
        h_scr[...] = h
        h_out[0, t, :] = h.astype(h_out.dtype)
        return ()

    jax.lax.fori_loop(0, bt, step, ())


def rglru_scan_kernel(a, bx, *, bt: int, bw: int, interpret: bool) -> jax.Array:
    B, T, w = a.shape
    grid = (B, w // bw, T // bt)
    kern = functools.partial(_kernel, bt=bt)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, bw), lambda b, wi, ti: (b, ti, wi)),
            pl.BlockSpec((1, bt, bw), lambda b, wi, ti: (b, ti, wi)),
        ],
        out_specs=pl.BlockSpec((1, bt, bw), lambda b, wi, ti: (b, ti, wi)),
        out_shape=jax.ShapeDtypeStruct((B, T, w), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bw,), jnp.float32)],
        interpret=interpret,
    )(a, bx)
