"""Oracle: sequential RG-LRU gated recurrence."""
import jax
import jax.numpy as jnp


def rglru_scan_ref(a, bx):
    """a/bx: (B, T, w) -> h sequence (B, T, w) fp32.
    h_t = a_t * h_{t-1} + bx_t"""
    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h
    B, T, w = a.shape
    h0 = jnp.zeros((B, w), jnp.float32)
    _, hs = jax.lax.scan(step, h0, (a.astype(jnp.float32).transpose(1, 0, 2),
                                    bx.astype(jnp.float32).transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2)
