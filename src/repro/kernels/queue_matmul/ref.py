"""Pure-jnp oracle for the queue matmul kernel."""
import jax.numpy as jnp


def matmul_ref(x, w):
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
