"""Jitted public wrapper: padding to MXU-aligned tiles + policy plumbing."""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ...core.policy import ExecutionPolicy
from .kernel import queue_matmul_kernel
from .ref import matmul_ref


def _pad_to(a: jax.Array, mults: Tuple[int, int]) -> jax.Array:
    pads = [(-a.shape[i]) % mults[i] for i in range(2)]
    if any(pads):
        a = jnp.pad(a, ((0, pads[0]), (0, pads[1])))
    return a


@partial(jax.jit, static_argnames=("block", "depth", "interpret", "policy"))
def queue_matmul(x: jax.Array, w: jax.Array, *,
                 block: Tuple[int, int, int] = (128, 128, 128),
                 depth: int = 2,
                 policy: Optional[ExecutionPolicy] = None,
                 interpret: bool = True) -> jax.Array:
    """y = x @ w through the queue-pipelined kernel.

    ``policy`` overrides ``depth``: BASELINE falls back to the XLA matmul,
    COPIFT forces depth=1 (batch-synchronized staging), COPIFTV2 keeps the
    requested multi-buffer depth."""
    if policy is ExecutionPolicy.BASELINE:
        return matmul_ref(x, w).astype(x.dtype)
    if policy is ExecutionPolicy.COPIFT:
        depth = 1
    m0, n0 = x.shape[0], w.shape[1]
    bm, bn, bk = block
    xp = _pad_to(x, (bm, bk))
    wp = _pad_to(w, (bk, bn))
    out = queue_matmul_kernel(xp, wp, bm=bm, bn=bn, bk=bk, depth=depth,
                              interpret=interpret, out_dtype=x.dtype)
    return out[:m0, :n0]
