"""Jitted public wrapper: padding to MXU-aligned tiles + policy plumbing.

Queue geometry is no longer hard-coded: when the depth / ``policy`` /
``unroll`` knobs are left unset, they resolve once (outside the jit) from
the calibration-backed :class:`~repro.core.policy.PolicyTable` — the
``queue_matmul`` workload proxies to the ``dequant_dot`` machine-model
kernel whose DSE Pareto front picked the operating point
(``examples/explore.py calibrate``; override the artifact directory with
``REPRO_CALIBRATION_DIR``).  Explicit arguments always win, and with no
artifact present the paper's headline point (COPIFTv2, depth 4, unroll 8)
is the fallback.

The two operand rings are sized independently (asymmetric FIFO geometry):
the activation (x) ring takes the calibrated ``queue_depth_i2f`` and the
weight (w) ring ``queue_depth_f2i``, each falling back to the symmetric
``queue_depth`` — so a DSE selection that found one direction needs less
buffering shows up directly as saved VMEM.  The symmetric ``depth``
argument (and per-ring ``depth_x``/``depth_w``) remain explicit overrides.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ...core.policy import ExecutionPolicy, OperatingPoint, default_table
from .kernel import queue_matmul_kernel
from .ref import matmul_ref


def _pad_to(a: jax.Array, mults: Tuple[int, int]) -> jax.Array:
    pads = [(-a.shape[i]) % mults[i] for i in range(2)]
    if any(pads):
        a = jnp.pad(a, ((0, pads[0]), (0, pads[1])))
    return a


def operating_point() -> OperatingPoint:
    """The operating point ``queue_matmul`` runs at when called without
    explicit ``depth``/``policy``/``unroll`` (resolution is a startup-time
    table lookup, never a per-call sweep)."""
    return default_table().resolve("queue_matmul")


@partial(jax.jit,
         static_argnames=("block", "depth_x", "depth_w", "unroll",
                          "interpret", "policy"))
def _queue_matmul(x: jax.Array, w: jax.Array, *,
                  block: Tuple[int, int, int], depth_x: int, depth_w: int,
                  unroll: int, policy: ExecutionPolicy,
                  interpret: bool) -> jax.Array:
    if policy is ExecutionPolicy.BASELINE:
        return matmul_ref(x, w).astype(x.dtype)
    if policy is ExecutionPolicy.COPIFT:
        depth_x = depth_w = 1
    m0, n0 = x.shape[0], w.shape[1]
    bm, bn, bk = block
    xp = _pad_to(x, (bm, bk))
    wp = _pad_to(w, (bk, bn))
    out = queue_matmul_kernel(xp, wp, bm=bm, bn=bn, bk=bk, depth_x=depth_x,
                              depth_w=depth_w, unroll=unroll,
                              interpret=interpret, out_dtype=x.dtype)
    return out[:m0, :n0]


def queue_matmul(x: jax.Array, w: jax.Array, *,
                 block: Tuple[int, int, int] = (128, 128, 128),
                 depth: Optional[int] = None,
                 depth_x: Optional[int] = None,
                 depth_w: Optional[int] = None,
                 unroll: Optional[int] = None,
                 policy: Optional[ExecutionPolicy] = None,
                 interpret: bool = True) -> jax.Array:
    """y = x @ w through the queue-pipelined kernel.

    ``policy`` overrides the depths: BASELINE falls back to the XLA matmul,
    COPIFT forces both rings to depth 1 (batch-synchronized staging),
    COPIFTV2 keeps the requested multi-buffer depths.  Unset knobs come
    from the calibration table (see module docstring): the x ring maps to
    the calibrated I2F depth and the w ring to the F2I depth (each
    defaulting to the symmetric ``queue_depth``).  Explicit arguments
    always win — ``depth`` pins both rings, ``depth_x``/``depth_w`` pin one
    each; in particular any explicit depth with ``policy`` unset runs the
    depth-honouring COPIFTv2 path (the pre-calibration behavior), never a
    table policy that would discard it.
    """
    if depth is not None:
        depth_x = depth if depth_x is None else depth_x
        depth_w = depth if depth_w is None else depth_w
    if depth_x is None or depth_w is None or unroll is None or policy is None:
        if policy is None and (depth_x is not None or depth_w is not None):
            policy = ExecutionPolicy.COPIFTV2
        pt = operating_point()
        if policy is None:
            policy = pt.policy
        cal_x, cal_w = pt.effective_depths()
        if depth_x is None:
            depth_x = cal_x
        if depth_w is None:
            depth_w = cal_w
        if unroll is None:
            unroll = pt.unroll
    return _queue_matmul(x, w, block=block, depth_x=depth_x, depth_w=depth_w,
                         unroll=unroll, policy=policy, interpret=interpret)
