"""queue_matmul — COPIFTv2's queue mechanism as a TPU matmul kernel.

Mapping (DESIGN.md §4): the scalar core issuing async HBM→VMEM copies is the
paper's *integer thread* (pure address generation); the MXU loop consuming
arrived tiles is the *FP thread*.  The two are coupled by per-operand VMEM
rings with DMA-semaphore handshakes — exactly the blocking FIFO semantics of
the hardware queues, with the queue *depth* as the ring's slot count:

 * ``depth=1``  — COPIFT analogue: stage a tile, barrier (sem wait), compute,
   repeat: communication and compute fully serialized.
 * ``depth>=2`` — COPIFTv2 analogue: copies for tile j+1..j+depth-1 are in
   flight while tile j multiplies; the semaphore wait *is* the queue pop.

The two operand streams have their own rings (``depth_x`` for activations,
``depth_w`` for weights), mirroring the paper's asymmetric I2F vs F2I FIFO
geometry: a DSE sweep that finds one direction needs less buffering maps its
``queue_depth_i2f``/``queue_depth_f2i`` selection onto the x-/w-ring depths
and saves the VMEM the symmetric ring wasted.

Operands live in ANY (HBM) memory space; the kernel owns its VMEM explicitly
(slots + fp32 accumulator), with MXU-aligned (128-multiple) tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_hbm, w_hbm, o_ref, xs, ws, acc, sx, sw, *,
            bm: int, bn: int, bk: int, nk: int, depth_x: int, depth_w: int,
            unroll: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    # integer-thread work: compute tile addresses, push the copies — one
    # ring per operand stream, each with its own depth
    def start_x(t, slot):
        pltpu.make_async_copy(
            x_hbm.at[pl.ds(i * bm, bm), pl.ds(t * bk, bk)],
            xs.at[slot], sx.at[slot]).start()

    def start_w(t, slot):
        pltpu.make_async_copy(
            w_hbm.at[pl.ds(t * bk, bk), pl.ds(j * bn, bn)],
            ws.at[slot], sw.at[slot]).start()

    # prologue: fill each ring to its own depth
    for d in range(min(depth_x, nk)):
        start_x(d, d)
    for d in range(min(depth_w, nk)):
        start_w(d, d)

    acc[...] = jnp.zeros_like(acc)

    def body(t, _):
        slot_x = t % depth_x
        slot_w = t % depth_w
        # FP-thread pop: blocking wait on each ring's slot semaphore
        pltpu.make_async_copy(
            x_hbm.at[pl.ds(i * bm, bm), pl.ds(t * bk, bk)],
            xs.at[slot_x], sx.at[slot_x]).wait()
        pltpu.make_async_copy(
            w_hbm.at[pl.ds(t * bk, bk), pl.ds(j * bn, bn)],
            ws.at[slot_w], sw.at[slot_w]).wait()
        acc[...] += jax.lax.dot_general(
            xs[slot_x], ws[slot_w], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

        # integer thread refills each ring independently
        @pl.when(t + depth_x < nk)
        def _():
            start_x(t + depth_x, slot_x)

        @pl.when(t + depth_w < nk)
        def _():
            start_w(t + depth_w, slot_w)
        return ()

    # the calibrated schedule-interleave factor maps to K-loop unrolling (the
    # FP thread retiring several queue pops per trip), clamped to the trip
    # count so tiny problems still lower
    jax.lax.fori_loop(0, nk, body, (), unroll=max(1, min(unroll, nk)))
    o_ref[...] = acc[...].astype(o_ref.dtype)


def queue_matmul_kernel(x: jax.Array, w: jax.Array, *, bm: int, bn: int,
                        bk: int, depth_x: int, depth_w: int, interpret: bool,
                        out_dtype, unroll: int = 1) -> jax.Array:
    m, k = x.shape
    _, n = w.shape
    nk = k // bk
    grid = (m // bm, n // bn)
    kern = functools.partial(_kernel, bm=bm, bn=bn, bk=bk, nk=nk,
                             depth_x=depth_x, depth_w=depth_w, unroll=unroll)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pl.ANY),
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((depth_x, bm, bk), x.dtype),
            pltpu.VMEM((depth_w, bk, bn), w.dtype),
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.SemaphoreType.DMA((depth_x,)),
            pltpu.SemaphoreType.DMA((depth_w,)),
        ],
        interpret=interpret,
    )(x, w)
