"""Oracle: sequential selective-scan recurrence in pure jnp."""
import jax
import jax.numpy as jnp


def ssm_scan_ref(x, dt, A, Bm, C):
    """x/dt: (B,T,d); A: (d,N); Bm/C: (B,T,N) -> y: (B,T,d) fp32.
    h_t = exp(dt_t ⊙ A) * h_{t-1} + (dt_t ⊙ x_t) B_t ;  y_t = h_t · C_t"""
    dA = jnp.exp(dt.astype(jnp.float32)[..., None] * A.astype(jnp.float32))
    dBx = (dt * x).astype(jnp.float32)[..., None] * \
        Bm.astype(jnp.float32)[:, :, None, :]

    def step(h, inp):
        da, dbx, c = inp
        h = da * h + dbx
        return h, jnp.einsum("bdn,bn->bd", h, c)

    B, T, d = x.shape
    N = A.shape[1]
    h0 = jnp.zeros((B, d, N), jnp.float32)
    _, ys = jax.lax.scan(step, h0,
                         (dA.transpose(1, 0, 2, 3), dBx.transpose(1, 0, 2, 3),
                          C.astype(jnp.float32).transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2)
