"""Jitted wrapper with padding over time/channel tiles."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import ssm_scan_kernel


@partial(jax.jit, static_argnames=("bt", "bd", "interpret"))
def ssm_scan(x, dt, A, Bm, C, *, bt: int = 128, bd: int = 128,
             interpret: bool = True) -> jax.Array:
    B, T, d = x.shape
    pt, pd = (-T) % bt, (-d) % bd
    if pt or pd:
        x = jnp.pad(x, ((0, 0), (0, pt), (0, pd)))
        dt = jnp.pad(dt, ((0, 0), (0, pt), (0, pd)))
        A = jnp.pad(A, ((0, pd), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pt), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pt), (0, 0)))
    y = ssm_scan_kernel(x, dt, A, Bm, C, bt=bt, bd=bd, interpret=interpret)
    return y[:, :T, :d]
