"""Fused selective-scan kernel (Mamba-1 inner loop).

TPU-native adaptation of the CUDA selective-scan: instead of one thread
block per (batch, channel-tile) with shared-memory staging, the grid walks
(batch, channel-tile, time-block) with the recurrent state (bd, N) resident
in VMEM scratch across time blocks — the state never round-trips to HBM,
which is the entire point of the fusion.  dA/dBx are computed on the fly
from (x, dt, A, B) per time step, so HBM traffic is the *inputs* only, never
the (B,T,d,N) state tensor."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, y_ref, h_scr, *,
            bt: int, bd: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _():
        h_scr[...] = jnp.zeros_like(h_scr)

    A = A_ref[...].astype(jnp.float32)                   # (bd, N)
    x = x_ref[0].astype(jnp.float32)                     # (bt, bd)
    dt = dt_ref[0].astype(jnp.float32)                   # (bt, bd)
    Bm = B_ref[0].astype(jnp.float32)                    # (bt, N)
    Cm = C_ref[0].astype(jnp.float32)                    # (bt, N)

    def step(t, _):
        dA = jnp.exp(dt[t][:, None] * A)                 # (bd, N)
        dBx = (dt[t] * x[t])[:, None] * Bm[t][None, :]   # (bd, N)
        h = dA * h_scr[...] + dBx
        h_scr[...] = h
        y_ref[0, t, :] = jnp.sum(h * Cm[t][None, :], axis=1).astype(y_ref.dtype)
        return ()

    jax.lax.fori_loop(0, bt, step, ())


def ssm_scan_kernel(x, dt, A, Bm, C, *, bt: int, bd: int,
                    interpret: bool) -> jax.Array:
    B, T, d = x.shape
    N = A.shape[1]
    grid = (B, d // bd, T // bt)
    kern = functools.partial(_kernel, bt=bt, bd=bd)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, bd), lambda b, di, ti: (b, ti, di)),
            pl.BlockSpec((1, bt, bd), lambda b, di, ti: (b, ti, di)),
            pl.BlockSpec((bd, N), lambda b, di, ti: (di, 0)),
            pl.BlockSpec((1, bt, N), lambda b, di, ti: (b, ti, 0)),
            pl.BlockSpec((1, bt, N), lambda b, di, ti: (b, ti, 0)),
        ],
        out_specs=pl.BlockSpec((1, bt, bd), lambda b, di, ti: (b, ti, di)),
        out_shape=jax.ShapeDtypeStruct((B, T, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, C)
