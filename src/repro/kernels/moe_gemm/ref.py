"""Oracle: per-expert batched GEMM."""
import jax.numpy as jnp


def moe_gemm_ref(x, w):
    """x: (E, C, d); w: (E, d, f) -> (E, C, f) fp32."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32))
