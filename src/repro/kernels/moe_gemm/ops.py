"""Jitted wrapper with padding over (capacity, feature, contraction) tiles."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import moe_gemm_kernel


@partial(jax.jit, static_argnames=("bc", "bf", "bk", "depth", "interpret"))
def moe_gemm(x, w, *, bc: int = 128, bf: int = 128, bk: int = 128,
             depth: int = 2, interpret: bool = True) -> jax.Array:
    E, C, d = x.shape
    f = w.shape[2]
    pc, pk, pf = (-C) % bc, (-d) % bk, (-f) % bf
    if pc or pk:
        x = jnp.pad(x, ((0, 0), (0, pc), (0, pk)))
    if pk or pf:
        w = jnp.pad(w, ((0, 0), (0, pk), (0, pf)))
    y = moe_gemm_kernel(x, w, bc=bc, bf=bf, bk=bk, depth=depth,
                        interpret=interpret)
    return y[:, :C, :f]
