"""Grouped expert GEMM with the queue-pipelined DMA scheme.

The MoE hot loop is the framework's closest structural analogue to the
paper's I2F dependency: the *integer stream* (routing: top-k, counts,
capacity slots — see models.moe) produces the dispatch layout that this
kernel's address generator consumes, tile by tile, through the same
``depth``-slot VMEM ring as queue_matmul.  Expert weight tiles stream
HBM→VMEM ahead of the MXU (depth≥2 = COPIFTv2; depth=1 = staged/COPIFT)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_hbm, w_hbm, o_ref, xs, ws, acc, sx, sw, *,
            bc: int, bf: int, bk: int, nk: int, depth: int):
    e = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)

    def start(t, slot):
        pltpu.make_async_copy(
            x_hbm.at[e, pl.ds(i * bc, bc), pl.ds(t * bk, bk)],
            xs.at[slot], sx.at[slot]).start()
        pltpu.make_async_copy(
            w_hbm.at[e, pl.ds(t * bk, bk), pl.ds(j * bf, bf)],
            ws.at[slot], sw.at[slot]).start()

    for d in range(min(depth, nk)):
        start(d, d)

    acc[...] = jnp.zeros_like(acc)

    def body(t, _):
        slot = t % depth
        pltpu.make_async_copy(
            x_hbm.at[e, pl.ds(i * bc, bc), pl.ds(t * bk, bk)],
            xs.at[slot], sx.at[slot]).wait()
        pltpu.make_async_copy(
            w_hbm.at[e, pl.ds(t * bk, bk), pl.ds(j * bf, bf)],
            ws.at[slot], sw.at[slot]).wait()
        acc[...] += jax.lax.dot_general(
            xs[slot], ws[slot], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

        @pl.when(t + depth < nk)
        def _():
            start(t + depth, slot)
        return ()

    jax.lax.fori_loop(0, nk, body, ())
    o_ref[0] = acc[...].astype(o_ref.dtype)


def moe_gemm_kernel(x, w, *, bc: int, bf: int, bk: int, depth: int,
                    interpret: bool) -> jax.Array:
    E, C, d = x.shape
    f = w.shape[2]
    grid = (E, C // bc, f // bf)
    kern = functools.partial(_kernel, bc=bc, bf=bf, bk=bk, nk=d // bk,
                             depth=depth)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pl.ANY),
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, i, j: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, f), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((depth, bc, bk), x.dtype),
            pltpu.VMEM((depth, bk, bf), w.dtype),
            pltpu.VMEM((bc, bf), jnp.float32),
            pltpu.SemaphoreType.DMA((depth,)),
            pltpu.SemaphoreType.DMA((depth,)),
        ],
        interpret=interpret,
    )(x, w)
