"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel ships as <name>/{kernel.py (pl.pallas_call + BlockSpec),
ops.py (jitted wrapper), ref.py (pure-jnp oracle)} and is validated in
interpret mode on CPU (tests/test_kernels.py sweeps shapes and dtypes)."""
from .flash_attention.ops import flash_attention
from .moe_gemm.ops import moe_gemm
from .queue_matmul.ops import queue_matmul
from .rglru_scan.ops import rglru_scan
from .ssm_scan.ops import ssm_scan

__all__ = ["flash_attention", "moe_gemm", "queue_matmul", "rglru_scan",
           "ssm_scan"]
