import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first backend initialization (see MULTI-POD DRY-RUN contract).

"""Multi-pod dry-run: AOT lower+compile every (arch × shape × mesh) cell.

For each cell we build the real pjit-ed step (train_step / forward /
decode_step) with production shardings, lower it against ShapeDtypeStructs
(params, optimizer state, batch, caches — nothing is ever allocated),
compile, and extract:
  - memory_analysis()  -> per-device HBM footprint (proves it fits)
  - cost_analysis()    -> per-device FLOPs / bytes accessed
  - compiled HLO text  -> per-collective byte counts (roofline term 3)
Artifacts are cached as JSON under artifacts/dryrun/.
"""
import argparse
import json
import time
import traceback
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..config import SHAPES, ModelConfig, RunConfig, ShapeConfig, supported_shapes
from ..configs import ARCHS, get_config
from ..distributed.sharding import (cache_pspecs, input_pspecs, logits_pspec,
                                    param_pspecs)
from ..models.model import decode_step, forward, input_specs, param_shapes
from ..optim import opt_state_shapes
from ..roofline import Roofline, collective_bytes, model_flops_for
from ..train.step import train_step
from .mesh import make_production_mesh

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")


def _ns(mesh: Mesh, tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s, tree,
        is_leaf=lambda x: isinstance(x, P))


def resolved_operating_point(shape: ShapeConfig):
    """The cell's machine-model operating point — cluster geometry included
    — from the calibration-backed :class:`~repro.core.policy.PolicyTable`
    (``REPRO_CALIBRATION_DIR`` honoured): training shapes resolve the
    ``train`` workload, prefill/decode the ``serve`` one.  The dry-run cost
    model no longer implicitly assumes one PE; the resolved point is
    embedded in every cell artifact (``machine_model`` block)."""
    from ..core.policy import default_table
    workload = "train" if shape.mode == "train" else "serve"
    return default_table().resolve(workload)


def default_runconfig(shape: ShapeConfig, policy: Optional[str] = None,
                      analysis: bool = False) -> RunConfig:
    from ..core.policy import ExecutionPolicy
    if policy is None:        # calibrated table point; explicit string wins
        policy = resolved_operating_point(shape).policy.value
    return RunConfig(policy=ExecutionPolicy.parse(policy),
                     dtype="bfloat16",
                     param_dtype="float32" if shape.mode == "train" else "bfloat16",
                     remat=(shape.mode == "train"),
                     fsdp=True,    # ZeRO-style weight sharding over 'data'
                     #   in inference too: a 341B model's bf16 weights are
                     #   43 GB/chip under TP=16 alone (EXPERIMENTS §Dry-run)
                     moe_dispatch="grouped",       # deployable dispatch path
                     attn_batch_shard=True,        # see EXPERIMENTS.md §Perf
                     analysis_mode=analysis)


def _mesh_context(mesh: Mesh):
    """Enter a mesh so PartitionSpec sharding constraints resolve: newer JAX
    uses jax.set_mesh; on 0.4.x the Mesh itself is the context manager."""
    return jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               rc: Optional[RunConfig] = None):
    """Build + lower the pjit step for one cell (traced inside a mesh
    context so PartitionSpec sharding constraints resolve)."""
    rc = rc or default_runconfig(shape)
    with _mesh_context(mesh):
        return _lower_cell_inner(cfg, shape, mesh, rc)


def _lower_cell_inner(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                      rc: RunConfig):
    pdt = jnp.dtype(rc.param_dtype)
    pspec = param_pspecs(cfg, mesh, rc)
    pshapes = param_shapes(cfg, pdt)
    batch_specs = input_specs(cfg, shape, rc)
    batch_pspecs = input_pspecs(cfg, shape, mesh)

    if shape.mode == "train":
        from ..optim import OptState
        ospec = OptState(step=P(), mu=pspec, nu=pspec)
        oshapes = opt_state_shapes(pshapes)
        fn = jax.jit(partial(train_step, cfg=cfg, rc=rc),
                     in_shardings=(_ns(mesh, pspec), _ns(mesh, ospec),
                                   _ns(mesh, batch_pspecs)),
                     out_shardings=(_ns(mesh, pspec), _ns(mesh, ospec), None),
                     donate_argnums=(0, 1))
        return fn.lower(pshapes, oshapes, batch_specs)

    if shape.mode == "prefill":
        fn = jax.jit(partial(forward, cfg=cfg, rc=rc),
                     in_shardings=(_ns(mesh, pspec), _ns(mesh, batch_pspecs)),
                     out_shardings=_ns(mesh, logits_pspec(cfg, shape, mesh)))
        return fn.lower(pshapes, batch_specs)

    # decode
    cache_shapes = batch_specs["cache"]
    cpspec = cache_pspecs(cfg, shape, mesh)
    fn = jax.jit(partial(decode_step, cfg=cfg, rc=rc),
                 in_shardings=(_ns(mesh, pspec), _ns(mesh, cpspec),
                               _ns(mesh, {"tokens": P(None, None)})),
                 out_shardings=(_ns(mesh, logits_pspec(cfg, shape, mesh)),
                                _ns(mesh, cpspec)),
                 donate_argnums=(1,))
    return fn.lower(pshapes, cache_shapes,
                    {"tokens": batch_specs["tokens"]})


def _measure(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
             rc: RunConfig) -> Dict[str, Any]:
    """Lower + compile one configuration and extract cost metrics."""
    t0 = time.time()
    lowered = lower_cell(cfg, shape, mesh, rc)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0) or 0)
            + (getattr(mem, "temp_size_in_bytes", 0) or 0),
        }
    except Exception:                                    # backend-dependent
        mem_info = {"argument_bytes": None, "output_bytes": None,
                    "temp_bytes": None, "peak_bytes": None}
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll,
        "cost": {k: float(v) for k, v in cost.items()
                 if isinstance(v, (int, float))},
        "memory": mem_info,
        "lower_s": t_lower, "compile_s": t_compile,
    }


def _with_layers(cfg: ModelConfig, units: int) -> ModelConfig:
    """A config with ``units`` repeating units (layers, or hybrid macros) —
    the tail of a hybrid config is kept verbatim."""
    import dataclasses
    if cfg.family == "hybrid":
        pat = len(cfg.rglru.pattern)
        tail = cfg.n_layers % pat
        return dataclasses.replace(cfg, n_layers=pat * units + tail)
    return dataclasses.replace(cfg, n_layers=units)


def _n_units(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // len(cfg.rglru.pattern)
    return cfg.n_layers


def analytic_device_bytes(cfg: ModelConfig, shape: ShapeConfig,
                          mesh: Mesh, rc: RunConfig) -> Dict[str, float]:
    """Exact per-device bytes of the *persistent* state (params, optimizer,
    decode caches) from the actual leaf shardings — the trustworthy HBM
    check (XLA:CPU memory_analysis reports logical buffer bytes)."""
    import numpy as np
    from ..models.model import cache_spec

    pdt = jnp.dtype(rc.param_dtype).itemsize
    pspec = param_pspecs(cfg, mesh, rc)
    shapes = param_shapes(cfg, jnp.dtype(rc.param_dtype))
    leaves = jax.tree_util.tree_leaves(shapes)
    specs = jax.tree_util.tree_leaves(
        pspec, is_leaf=lambda x: isinstance(x, P))

    def per_dev(shape_, spec):
        n = int(np.prod(shape_)) if shape_ else 1
        div = 1
        for i, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            for a in axes:
                div *= mesh.shape[a]
        return n / div

    params = sum(per_dev(l.shape, s) * pdt for l, s in zip(leaves, specs))
    out = {"params_gb": params / 1e9}
    if shape.mode == "train":
        out["opt_gb"] = 2 * sum(per_dev(l.shape, s) * 4
                                for l, s in zip(leaves, specs)) / 1e9
    if shape.mode == "decode":
        cdt = jnp.dtype(rc.dtype).itemsize
        cspec = cache_pspecs(cfg, shape, mesh)
        cshape = cache_spec(cfg, shape.global_batch, shape.seq_len,
                            jnp.dtype(rc.dtype))
        cl = jax.tree_util.tree_leaves(cshape)
        cs = jax.tree_util.tree_leaves(cspec,
                                       is_leaf=lambda x: isinstance(x, P))
        out["cache_gb"] = sum(per_dev(l.shape, s) * l.dtype.itemsize
                              for l, s in zip(cl, cs)) / 1e9
    out["total_gb"] = sum(v for k, v in out.items() if k.endswith("_gb"))
    return out


def cell_tag(arch: str, shape_name: str, multi_pod: bool,
             policy: Optional[str], analysis: bool) -> str:
    """The one source of truth for a cell's artifact tag (and hence its
    cache filename): ``policy=None`` resolves the workload's calibrated
    operating point exactly like :func:`run_cell` does."""
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    variant = "analysis" if analysis else "deploy"
    if policy is None:
        policy = resolved_operating_point(SHAPES[shape_name]).policy.value
    return f"{arch}_{shape_name}_{mesh_name}_{policy}_{variant}"


def cell_path(arch: str, shape_name: str, multi_pod: bool,
              policy: Optional[str], analysis: bool) -> str:
    return os.path.join(
        ART_DIR, f"{cell_tag(arch, shape_name, multi_pod, policy, analysis)}"
        ".json")


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             policy: Optional[str] = None, rc: Optional[RunConfig] = None,
             save: bool = True, analysis: bool = False) -> Dict[str, Any]:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    variant = "analysis" if analysis else "deploy"
    op = resolved_operating_point(SHAPES[shape_name])
    if policy is None:
        policy = op.policy.value
    tag = cell_tag(arch, shape_name, multi_pod, policy, analysis)
    path = os.path.join(ART_DIR, f"{tag}.json")
    if save and os.path.exists(path):
        with open(path) as f:
            return json.load(f)

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    rc = rc or default_runconfig(shape, policy, analysis=analysis)

    if analysis:
        # Two-point extrapolation: XLA's cost analysis counts loop bodies
        # once, so we lower FULLY UNROLLED models with 1 and 2 repeating
        # units; per-unit costs are their difference (layers are uniform),
        # totals are exact: A(L) = A(1) + (L-1)·(A(2)-A(1)).
        m1 = _measure(_with_layers(cfg, 1), shape, mesh, rc)
        m2 = _measure(_with_layers(cfg, 2), shape, mesh, rc)
        L = _n_units(cfg)
        flops = m1["flops"] + (L - 1) * (m2["flops"] - m1["flops"])
        bytes_accessed = m1["bytes"] + (L - 1) * (m2["bytes"] - m1["bytes"])
        coll = {}
        keys = set(m1["coll"]) | set(m2["coll"])
        for k in keys:
            a, b = m1["coll"].get(k, 0), m2["coll"].get(k, 0)
            coll[k] = int(a + (L - 1) * (b - a))
        mem_info = m1["memory"]                  # footprint: see deploy cell
        cost = {"flops": flops, "bytes accessed": bytes_accessed,
                "extrapolated_from_units": [1, 2]}
        t_lower = m1["lower_s"] + m2["lower_s"]
        t_compile = m1["compile_s"] + m2["compile_s"]
    else:
        m = _measure(cfg, shape, mesh, rc)
        flops, bytes_accessed, coll = m["flops"], m["bytes"], m["coll"]
        mem_info, cost = m["memory"], m["cost"]
        t_lower, t_compile = m["lower_s"], m["compile_s"]

    rl = Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        per_device_flops=flops, per_device_bytes=bytes_accessed,
        per_device_coll_bytes=float(coll.get("total", 0)),
        model_flops=model_flops_for(cfg, shape),
        per_device_hbm_peak=mem_info["peak_bytes"])
    analytic = analytic_device_bytes(cfg, shape, mesh, rc)
    art = {
        "tag": tag, "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "policy": policy, "chips": chips, "variant": variant,
        "analytic_device_gb": analytic,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "cost_analysis": {k: (float(v) if isinstance(v, (int, float)) else v)
                          for k, v in cost.items()},
        "memory": mem_info,
        "collectives": coll,
        "roofline": rl.to_dict(),
        # the machine-model operating point the cost model assumes: the
        # calibrated (or default) cluster-level point for this workload —
        # per-PE queue geometry plus how many PEs share the TCDM.  An
        # explicit --policy / caller rc pin overrides the table's policy;
        # the block reports the policy the cell actually ran under.
        "machine_model": {
            "workload": "train" if shape.mode == "train" else "serve",
            "source": (op.source if rc.policy is op.policy else "override"),
            "policy": rc.policy.value,
            "queue_depth": op.queue_depth,
            "queue_depth_i2f": op.queue_depth_i2f,
            "queue_depth_f2i": op.queue_depth_f2i,
            "unroll": op.unroll,
            "n_cores": op.n_cores,
            "tcdm_banks": op.tcdm_banks,
        },
        "ok": True,
    }
    if save:
        os.makedirs(ART_DIR, exist_ok=True)
        with open(path, "w") as f:
            json.dump(art, f, indent=1)
    return art


def all_cells(multi_pod_also: bool = True, analysis_also: bool = True):
    """(arch, shape, multi_pod, analysis) triples: the deployable lowering on
    both meshes (compile gate + memory) and the unrolled analysis lowering on
    the single pod (roofline terms)."""
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape_name in supported_shapes(cfg):
            yield arch, shape_name, False, False
            if analysis_also:
                yield arch, shape_name, False, True
            if multi_pod_also:
                yield arch, shape_name, True, False


def main() -> None:
    ap = argparse.ArgumentParser(description="Multi-pod AOT dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--policy", default=None,
                    help="pin the execution policy (default: resolve the "
                         "workload's calibrated operating point)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--fresh", action="store_true", help="ignore cache")
    ap.add_argument("--analysis", action="store_true",
                    help="unrolled analysis lowering (true roofline totals)")
    ap.add_argument("--no-analysis", action="store_true",
                    help="with --all: skip analysis variants")
    args = ap.parse_args()

    cells = []
    if args.all:
        cells = list(all_cells(
            multi_pod_also=(args.mesh in ("multipod", "both")),
            analysis_also=not args.no_analysis))
        if args.mesh == "multipod":
            cells = [c for c in cells if c[2]]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        meshes = {"pod": [False], "multipod": [True], "both": [False, True]}
        cells = [(args.arch, args.shape, mp, args.analysis)
                 for mp in meshes[args.mesh]]

    failures = []
    for arch, shape_name, mp, analysis in cells:
        var = "analysis" if analysis else "deploy"
        tag = f"{arch}/{shape_name}/{'2x16x16' if mp else '16x16'}/{var}"
        path = cell_path(arch, shape_name, mp, args.policy, analysis)
        if args.fresh and os.path.exists(path):
            os.remove(path)
        try:
            art = run_cell(arch, shape_name, mp, policy=args.policy,
                           analysis=analysis)
            rl = art["roofline"]
            print(f"OK  {tag:<58} compile={art['compile_s']:>7.1f}s "
                  f"bottleneck={rl['bottleneck']:<10} "
                  f"t=({rl['t_compute']:.2e},{rl['t_memory']:.2e},"
                  f"{rl['t_collective']:.2e})s mfu={rl['mfu']:.3f}",
                  flush=True)
        except Exception as e:
            failures.append((tag, repr(e)))
            print(f"FAIL {tag}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed")


if __name__ == "__main__":
    main()
