"""Serving launcher: batched decode over the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --reduced \\
      --requests 6 --max-new 12 --traffic high
"""
import argparse
import time

import jax

from ..config import RunConfig
from ..configs import ARCHS, get_config, get_reduced
from ..core.policy import TRAFFIC_LEVELS
from ..models import init_model_params
from ..serve import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser(description="Serve an assigned architecture")
    ap.add_argument("--arch", required=True, choices=ARCHS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=None,
                    help="decode batch slots (default: 4 per cluster core "
                         "of the calibrated 'serve' operating point)")
    ap.add_argument("--mode", choices=("continuous", "static"),
                    default="continuous",
                    help="slot refill discipline: continuous (refill per "
                         "step as sequences finish) or static (wave "
                         "batching, the measurable baseline)")
    ap.add_argument("--traffic", choices=sorted(TRAFFIC_LEVELS),
                    default=None,
                    help="OVERRIDE the measured offered-load level: pins "
                         "the calibration artifact's per-traffic serve-slo "
                         "operating point (schema v5). Without it the "
                         "engine estimates the level from the arrival "
                         "stream and re-resolves at refill boundaries")
    ap.add_argument("--prefill", choices=("chunked", "token"),
                    default="chunked",
                    help="prompt ingestion: chunked (jitted prefill_step, "
                         "C tokens per call) or token (one-token steps, "
                         "the measurable TTFT baseline)")
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="max prompt tokens per prefilling slot per step")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if not cfg.causal:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving")
    rc = RunConfig(dtype="float32", param_dtype="float32", remat=False)
    params = init_model_params(jax.random.PRNGKey(args.seed), cfg)
    eng = ServeEngine(params, cfg, rc, batch_slots=args.slots, max_len=256,
                      mode=args.mode, traffic=args.traffic,
                      prefill=args.prefill, prefill_chunk=args.prefill_chunk)
    op = eng.operating_point
    traffic = (f"traffic={args.traffic} (pinned)" if args.traffic
               else "traffic=measured")
    print(f"policy={op.policy.value} (source={op.source}, "
          f"cores={op.n_cores}, slots={len(eng.slots)}, mode={args.mode}, "
          f"prefill={args.prefill}, {traffic})")

    rng = jax.random.PRNGKey(args.seed + 1)
    rids = []
    for i in range(args.requests):
        rng, k = jax.random.split(rng)
        plen = 3 + int(jax.random.randint(k, (), 0, 6))
        prompt = [int(t) for t in
                  jax.random.randint(k, (plen,), 0, cfg.vocab)]
        rids.append((eng.submit(prompt, max_new=args.max_new), prompt))

    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    total_tokens = sum(len(r.generated) for r in done.values())
    print(f"served {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.1f}s ({total_tokens/dt:.1f} tok/s)")
    rep = eng.metrics()
    print(f"calibrated accounting ({rep.cost_source}): "
          f"{rep.throughput:.5f} tok/cycle, "
          f"{rep.energy_per_token:.1f} J-equiv/token, "
          f"p50/p99 latency {rep.p50_latency:.1f}/{rep.p99_latency:.1f} "
          f"cyc/tok, p50 TTFT {rep.p50_ttft:.0f} cyc")
    if args.traffic is None:
        level = eng.traffic_level or "still cold (too few arrivals)"
        print(f"measured traffic: {level}; "
              f"{len(eng.traffic_history)} retarget(s)")
        for h in eng.traffic_history:
            print(f"  @{h['clock']:.0f} cyc -> {h['level']} "
                  f"(rho~{h['offered_load']:.2f}, policy={h['policy']}, "
                  f"source={h['source']})")
    for rid, prompt in rids:
        r = done[rid]
        print(f"  req{rid}: prompt={prompt} -> {r.generated}")


if __name__ == "__main__":
    main()
