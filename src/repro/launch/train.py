"""Training launcher: end-to-end driver over the fault-tolerant runtime.

On real hardware this runs under the production mesh; in this container it
trains reduced/custom-width configs on the host devices.  Examples:

  PYTHONPATH=src python -m repro.launch.train --arch phi3-mini-3.8b \\
      --reduced --steps 50 --batch 8 --seq 128
  PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b --reduced \\
      --steps 30 --policy copift
"""
import argparse
import dataclasses
import time

import jax

from ..config import RunConfig, ShapeConfig
from ..configs import ARCHS, get_config, get_reduced
from ..models import init_model_params
from ..runtime import FaultTolerantTrainer
from .mesh import make_local_mesh


def main() -> None:
    ap = argparse.ArgumentParser(description="Train an assigned architecture")
    ap.add_argument("--arch", required=True, choices=ARCHS)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-friendly)")
    ap.add_argument("--width", type=int, default=0,
                    help="override d_model (scales a custom mid-size model)")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--policy", default=None,
                    help="pin the execution policy (default: resolve the "
                         "'train' workload from the calibration table, "
                         "see REPRO_CALIBRATION_DIR)")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.width:
        cfg = dataclasses.replace(cfg, d_model=args.width)
    if args.layers:
        cfg = dataclasses.replace(cfg, n_layers=args.layers)
    from ..core.policy import ExecutionPolicy, default_table
    # a CLI pin overrides only the policy field: the calibrated queue
    # geometry (depth/unroll) for the train workload still applies
    op = (default_table().resolve(
              "train", policy=ExecutionPolicy.parse(args.policy))
          if args.policy else None)
    rc = RunConfig(dtype="float32", param_dtype="float32", remat=False,
                   lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                   total_steps=args.steps, microbatch=args.microbatch,
                   seed=args.seed)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")

    n = cfg.n_params()
    print(f"arch={cfg.name} params={n/1e6:.1f}M layers={cfg.n_layers} "
          f"d_model={cfg.d_model} batch={args.batch} seq={args.seq}")
    params = init_model_params(jax.random.PRNGKey(args.seed), cfg)

    trainer = FaultTolerantTrainer(cfg, shape, rc, make_local_mesh,
                                   args.ckpt_dir, ckpt_every=args.ckpt_every,
                                   operating_point=op)
    top = trainer.operating_point
    print(f"policy={top.policy.value} (source={top.source}, "
          f"depth={top.queue_depth}, unroll={top.unroll}, "
          f"cores={top.n_cores}, banks={top.tcdm_banks or 'inf'})")
    t0 = time.time()
    out = trainer.run(params, num_steps=args.steps)
    dt = time.time() - t0
    losses = out["metrics"]
    print(f"finished {out['step']} steps in {dt:.1f}s "
          f"({dt/max(len(losses),1):.2f}s/step)")
    k = max(len(losses) // 10, 1)
    first = sum(l for _, l in losses[:k]) / k
    last = sum(l for _, l in losses[-k:]) / k
    print(f"loss: first~{first:.4f} -> last~{last:.4f}")


if __name__ == "__main__":
    main()
