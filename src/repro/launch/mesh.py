"""Production mesh definitions.

A function, not a module-level constant: importing this module never touches
jax device state.  Single-pod: 16x16 = 256 chips (data, model); multi-pod:
2x16x16 = 512 chips with a pure-DP 'pod' outer axis (gradient all-reduce
crosses pods once per step over DCN; TP/EP collectives stay inside a pod's
ICI — how v5e pods actually compose)."""
from __future__ import annotations

import jax


def _make_mesh(shape, axes) -> jax.sharding.Mesh:
    """jax.make_mesh across API generations: newer JAX wants explicit Auto
    axis_types; 0.4.x has neither the kwarg nor jax.sharding.AxisType (all
    axes are Auto implicitly)."""
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests, smoke runs)."""
    return _make_mesh((data, model), ("data", "model"))
