"""Straggler detection: robust z-score over per-step wall times (median/MAD),
with a mitigation hook.  On real clusters the hook re-shards or evicts the
slow host; in this container tests inject synthetic timings."""
from __future__ import annotations

from collections import deque
from typing import Callable, Deque, List, Optional, Tuple


class StragglerMonitor:
    def __init__(self, window: int = 50, threshold: float = 4.0,
                 min_samples: int = 10,
                 on_straggler: Optional[Callable[[int, float, float], None]] = None):
        self.window: Deque[float] = deque(maxlen=window)
        self.threshold = threshold
        self.min_samples = min_samples
        self.on_straggler = on_straggler
        self.events: List[Tuple[int, float, float]] = []

    @staticmethod
    def _median(xs: List[float]) -> float:
        s = sorted(xs)
        n = len(s)
        return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])

    def record(self, step: int, seconds: float) -> bool:
        """Record a step time; returns True if it is flagged as a straggler.
        Flagged samples are excluded from the baseline window."""
        flagged = False
        if len(self.window) >= self.min_samples:
            med = self._median(list(self.window))
            mad = self._median([abs(x - med) for x in self.window]) or 1e-9
            z = 0.6745 * (seconds - med) / mad
            if z > self.threshold:
                flagged = True
                self.events.append((step, seconds, z))
                if self.on_straggler:
                    self.on_straggler(step, seconds, z)
        if not flagged:
            self.window.append(seconds)
        return flagged


class Heartbeat:
    """Host liveness tracking (simulated clock injectable for tests).

    Hosts start their timeout clock at ``start`` (the monitor's creation
    time), not at an implicit 0.0: a monitor created at a large wall-clock
    ``now`` must not declare every host dead before any has had a chance
    to beat."""

    def __init__(self, hosts: List[str], timeout: float = 60.0,
                 start: float = 0.0):
        self.timeout = timeout
        self.last: dict = {h: start for h in hosts}

    def beat(self, host: str, now: float) -> None:
        self.last[host] = now

    def dead(self, now: float) -> List[str]:
        return [h for h, t in self.last.items() if now - t > self.timeout]
