from .driver import FaultTolerantTrainer, InjectedFault
from .straggler import StragglerMonitor

__all__ = ["FaultTolerantTrainer", "InjectedFault", "StragglerMonitor"]
