"""Fault-tolerant training driver.

The loop owns: data prefetch, periodic async checkpoints, straggler
monitoring, and restart-on-failure.  A failure (real exception or an
injected :class:`InjectedFault` simulating device loss) triggers:
rebuild mesh from survivors -> re-make the jitted step -> restore the latest
checkpoint (elastic resharding) -> seek the data stream -> continue.
Exactly the recovery path a 1000-node run needs, exercised in tests by
injection."""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

from ..checkpoint.manager import CheckpointManager, latest_step, restore
from ..config import ModelConfig, RunConfig, ShapeConfig
from ..core.policy import OperatingPoint, PolicyTable
from ..data.pipeline import SyntheticLMStream
from ..optim import init_opt_state
from ..train.step import make_train_step, resolve_run_config
from .straggler import StragglerMonitor

Pytree = Any


class InjectedFault(RuntimeError):
    """Simulated device/host failure for resilience testing."""


class FaultTolerantTrainer:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, rc: RunConfig,
                 mesh_factory: Callable[[], Any], ckpt_dir: str,
                 ckpt_every: int = 50,
                 fault_hook: Optional[Callable[[int], None]] = None,
                 operating_point: Optional[OperatingPoint] = None,
                 policy_table: Optional[PolicyTable] = None):
        # policy resolution happens once here; restarts re-make the jitted
        # step with the SAME pinned operating point, never a fresh lookup
        rc, self.operating_point = resolve_run_config(
            rc, "train", operating_point, policy_table)
        self.cfg, self.shape, self.rc = cfg, shape, rc
        self.mesh_factory = mesh_factory
        self.ckpt = CheckpointManager(ckpt_dir, keep=3)
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.fault_hook = fault_hook
        self.monitor = StragglerMonitor()
        self.restarts = 0
        self.metrics_log: list = []

    def _build(self, params, opt):
        mesh = self.mesh_factory()
        step_fn, _ = make_train_step(self.cfg, self.shape, self.rc, mesh,
                                     operating_point=self.operating_point)
        return mesh, step_fn

    def run(self, params: Pytree, opt=None, start_step: int = 0,
            num_steps: int = 100) -> Dict[str, Any]:
        rc = self.rc
        opt = opt if opt is not None else init_opt_state(params)
        mesh, step_fn = self._build(params, opt)
        stream = SyntheticLMStream(self.cfg.vocab, self.shape.seq_len,
                                   self.shape.global_batch, seed=rc.seed)
        step = start_step
        while step < start_step + num_steps:
            try:
                batch = stream.batch_at(step)
                t0 = time.monotonic()
                if self.fault_hook:
                    self.fault_hook(step)
                params, opt, metrics = step_fn(params, opt, batch)
                loss = float(metrics["loss"])
                self.monitor.record(step, time.monotonic() - t0)
                self.metrics_log.append((step, loss))
                step += 1
                if step % self.ckpt_every == 0:
                    self.ckpt.save_async(step, {"params": params, "opt": opt},
                                         extra={"data_step": step})
            except InjectedFault:
                # device loss: rebuild the world and resume from durable state
                self.restarts += 1
                self.ckpt.wait()
                last = latest_step(self.ckpt_dir)
                mesh, step_fn = self._build(params, opt)
                if last is not None:
                    last, state, extra = restore(
                        self.ckpt_dir, {"params": params, "opt": opt})
                    params, opt = state["params"], state["opt"]
                    step = extra.get("data_step", last)
                else:
                    step = start_step
        self.ckpt.save_async(step, {"params": params, "opt": opt},
                             extra={"data_step": step})
        self.ckpt.wait()
        return {"params": params, "opt": opt, "step": step,
                "restarts": self.restarts, "metrics": self.metrics_log}
