"""Checkpointing: atomic, async, keep-k, elastic-restore.

Layout: <dir>/step_<N>/{manifest.json, arrays.npz}; a save writes into
``.tmp_step_<N>`` then ``os.rename``s (atomic publish — a crashed save can
never be mistaken for a valid checkpoint).  Saves run on a single background
writer behind a bounded queue (host-level COPIFTv2 analogue); ``wait()``
drains it.  Restore rebuilds the pytree from the manifest and ``device_put``s
leaves with *target* shardings — the mesh at restore time may differ from
the mesh at save time (elastic scaling)."""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

Pytree = Any


def _flatten(state: Pytree) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(state)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    return arrays, treedef


def save(path: str, step: int, state: Pytree,
         extra: Optional[Dict[str, Any]] = None) -> str:
    """Synchronous atomic save.  Returns the final checkpoint dir."""
    os.makedirs(path, exist_ok=True)
    final = os.path.join(path, f"step_{step:08d}")
    tmp = os.path.join(path, f".tmp_step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays, treedef = _flatten(state)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {"step": step, "n_leaves": len(arrays),
                "treedef": str(treedef), "extra": extra or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(path)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(path: str, like: Pytree, step: Optional[int] = None,
            shardings: Optional[Pytree] = None
            ) -> Tuple[int, Pytree, Dict[str, Any]]:
    """Restore into the structure of ``like``.  ``shardings`` (optional
    pytree of NamedSharding for the *current* mesh) makes restore elastic:
    arrays are resharded onto whatever topology is alive now."""
    step = step if step is not None else latest_step(path)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {path}")
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    leaves, treedef = jax.tree_util.tree_flatten(like)
    assert len(leaves) == manifest["n_leaves"], "checkpoint/model mismatch"
    loaded: List[Any] = []
    sh_leaves = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: hasattr(x, "spec"))
        if shardings is not None else [None] * len(leaves))
    for i, (ref, sh) in enumerate(zip(leaves, sh_leaves)):
        arr = data[f"leaf_{i}"]
        if hasattr(ref, "dtype"):
            arr = arr.astype(ref.dtype)
        loaded.append(jax.device_put(arr, sh) if sh is not None
                      else jax.device_put(arr))
    return step, jax.tree_util.tree_unflatten(treedef, loaded), manifest["extra"]


class CheckpointManager:
    """Async writer with bounded queue + keep-last-k garbage collection."""

    def __init__(self, path: str, keep: int = 3, queue_depth: int = 2):
        self.path = path
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue(maxsize=queue_depth)
        self._errors: List[BaseException] = []
        self._thread = threading.Thread(target=self._writer, daemon=True)
        self._thread.start()

    def _writer(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            step, state, extra = item
            try:
                save(self.path, step, state, extra)
                self._gc()
            except BaseException as e:       # surfaced via .wait()
                self._errors.append(e)
            finally:
                self._q.task_done()

    def _gc(self) -> None:
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.path)
                       if d.startswith("step_"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.path, f"step_{s:08d}"),
                          ignore_errors=True)

    def save_async(self, step: int, state: Pytree,
                   extra: Optional[Dict[str, Any]] = None) -> None:
        # snapshot to host first so donated/overwritten buffers are safe
        host_state = jax.tree_util.tree_map(np.asarray, state)
        self._q.put((step, host_state, extra))

    def wait(self) -> None:
        self._q.join()
        if self._errors:
            raise self._errors[0]

    def close(self) -> None:
        self._q.put(None)
        self._thread.join(timeout=10.0)
