from .pipeline import PrefetchLoader, SyntheticLMStream

__all__ = ["PrefetchLoader", "SyntheticLMStream"]
