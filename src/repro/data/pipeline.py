"""Data pipeline: deterministic, *seekable* synthetic LM stream + a bounded
host-side prefetch queue (the host-level COPIFTv2 analogue: producer thread
and consumer training loop coupled by a blocking FIFO).

Seekability is the fault-tolerance contract: ``batch_at(step)`` is a pure
function of (seed, step), so resuming from a checkpointed step reproduces
the exact token stream — no iterator state to persist beyond the step."""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator

import numpy as np


class SyntheticLMStream:
    """Language-modeling batches over a Zipf-ish synthetic token process."""

    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, dp_rank: int = 0, dp_size: int = 1):
        assert global_batch % dp_size == 0
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.local_batch = global_batch // dp_size
        self.seed = seed
        self.dp_rank = dp_rank
        self.dp_size = dp_size

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.Generator(np.random.Philox(
            key=self.seed, counter=[step, self.dp_rank, 0, 0]))
        # learnable structure: mixture of a repeated motif and noise so a
        # ~1e8-param model shows a falling loss within a few hundred steps
        B, S = self.local_batch, self.seq_len + 1
        base = rng.zipf(1.5, size=(B, S)).clip(1, self.vocab - 1)
        motif = (np.arange(S)[None] * 7 + rng.integers(0, 13, (B, 1))) \
            % max(self.vocab // 4, 2)
        use_motif = rng.random((B, S)) < 0.7
        toks = np.where(use_motif, motif, base).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class PrefetchLoader:
    """Bounded producer/consumer queue between the data thread and the
    device step — blocking FIFO semantics, depth = ``depth``."""

    _STOP = object()

    def __init__(self, stream: SyntheticLMStream, start_step: int = 0,
                 depth: int = 4):
        self.stream = stream
        self.queue: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _produce(self) -> None:
        step = self._step
        while not self._stop.is_set():
            batch = self.stream.batch_at(step)
            while not self._stop.is_set():
                try:
                    self.queue.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def get(self) -> Dict[str, np.ndarray]:
        step, batch = self.queue.get()
        return batch

    def close(self) -> None:
        self._stop.set()
        self._thread.join(timeout=2.0)
