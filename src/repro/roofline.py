"""Roofline extraction from AOT-compiled artifacts (TPU v5e targets).

Three terms per (arch × shape × mesh) cell, in seconds (DESIGN.md §7):
  compute    = HLO_FLOPs  / (chips · peak_FLOP/s)
  memory     = HLO_bytes  / (chips · HBM_bw)
  collective = coll_bytes / (chips · link_bw · links)

``cost_analysis`` provides FLOPs/bytes of the *partitioned per-device*
module; collective bytes are parsed from the optimized HLO text by summing
the operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction (per device)."""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, Optional

#: TPU v5e, per chip
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link
ICI_LINKS = 4                # 2D torus: 4 links/chip (2 axes x 2 directions)
HBM_BYTES = 16e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_DEF_RE = re.compile(r"%?([\w.\-]+)\s*=\s*(\(?)([a-z0-9]+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*\(?\s*[a-z0-9]+\[[\d,]*\][^=]*?\b"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\b[^(]*\(([^)]*)\)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes per collective kind from (optimized) HLO text."""
    sizes: Dict[str, int] = {}
    for m in _DEF_RE.finditer(hlo_text):
        name, _, dtype, dims = m.groups()
        if dtype in _DTYPE_BYTES:
            sizes[name] = _shape_bytes(dtype, dims)
    out: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        kind, operands = m.groups()
        total = 0
        for op in operands.split(","):
            op = op.strip().lstrip("%")
            # operands appear as "bf16[2,4]{1,0} name" or just "name"
            toks = op.split(" ")
            ref = toks[-1].strip()
            inline = re.match(r"([a-z0-9]+)\[([\d,]*)\]", op)
            if ref in sizes:
                total += sizes[ref]
            elif inline and inline.group(1) in _DTYPE_BYTES:
                total += _shape_bytes(inline.group(1), inline.group(2))
        out[kind] = out.get(kind, 0) + total
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    per_device_flops: float
    per_device_bytes: float
    per_device_coll_bytes: float
    model_flops: float                  # 6·N(active)·D, whole step
    per_device_hbm_peak: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.per_device_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.per_device_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.per_device_coll_bytes / (ICI_BW * ICI_LINKS)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Roofline-optimistic step time: max of the three terms (perfect
        overlap); the dominant term is the floor."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (global HLO FLOPs) — remat/redundancy waste."""
        total = self.per_device_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline-optimistic step time."""
        denom = self.step_time * self.chips * PEAK_FLOPS
        return self.model_flops / denom if denom else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "per_device_flops": self.per_device_flops,
            "per_device_bytes": self.per_device_bytes,
            "per_device_coll_bytes": self.per_device_coll_bytes,
            "model_flops": self.model_flops,
            "per_device_hbm_peak": self.per_device_hbm_peak,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck, "step_time": self.step_time,
            "useful_flops_ratio": self.useful_flops_ratio, "mfu": self.mfu,
        }


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS per step: 6·N_active·D for training (fwd+bwd), 2·N_active·D
    for inference forward; decode processes one token per sequence."""
    n = cfg.n_active_params()
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # decode: 1 token/seq
