"""Gradient compression: int8 quantization with stochastic rounding.

At 1000+ nodes the cross-pod (DCN) gradient all-reduce dominates; int8
halves-to-quarters the payload.  We implement the wire codec exactly
(per-tensor absmax scale, stochastic rounding so the quantizer is unbiased:
E[deq(q(g))] = g); under pjit the all-reduce itself is XLA's, so the codec is
applied around the psum — numerically faithful to a compressed wire."""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def quantize_int8(key: jax.Array, g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    x = g / scale
    lo = jnp.floor(x)
    frac = x - lo
    up = jax.random.uniform(key, g.shape) < frac
    q = (lo + up.astype(lo.dtype)).clip(-127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads(key: jax.Array, grads: Pytree) -> Pytree:
    """Round-trip every gradient leaf through the int8 wire format."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    keys = jax.random.split(key, len(leaves))
    out = [dequantize_int8(*quantize_int8(k, g)).astype(g.dtype)
           for k, g in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)
