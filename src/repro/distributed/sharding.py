"""Logical-axis -> mesh-axis resolution (DP / TP / FSDP / EP / SP).

Every parameter leaf carries logical axis names (see models.layers.ParamSpec);
this module greedily assigns mesh axes by priority with divisibility checks,
so e.g. granite-moe's 40 experts (not divisible by model=16) automatically
fall back to sharding the expert hidden dim instead — no per-arch special
cases (DESIGN.md §6)."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, PartitionSpec as P

from ..config import ModelConfig, RunConfig, ShapeConfig
from ..models.layers import logical_axes_tree
from ..models.model import param_specs

Pytree = Any

#: logical axis -> (priority, mesh-axis candidates).  Lower priority wins the
#: mesh axis when several dims of one leaf could take it.
RULES: Dict[str, Tuple[int, Tuple[str, ...]]] = {
    "vocab": (0, ("model",)),
    "heads": (0, ("model",)),
    "kv_heads": (0, ("model",)),
    "experts": (0, ("model",)),
    "inner": (0, ("model",)),
    "inner2": (0, ("model",)),
    "ff": (1, ("model",)),
    "expert_ff": (1, ("model",)),
    "lora": (2, ("model",)),
    "embed": (5, ("data",)),        # ZeRO-3/FSDP, only when rc.fsdp
}


def _leaf_pspec(shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
                mesh: Mesh, fsdp: bool) -> P:
    taken: set = set()
    assign: list = [None] * len(shape)
    order = sorted(range(len(shape)),
                   key=lambda i: RULES.get(axes[i], (99, ()))[0])
    for i in order:
        name = axes[i]
        if name is None or name not in RULES:
            continue
        if name == "embed" and not fsdp:
            continue
        for cand in RULES[name][1]:
            if cand in taken or cand not in mesh.axis_names:
                continue
            if shape[i] % mesh.shape[cand] == 0 and shape[i] >= mesh.shape[cand]:
                assign[i] = cand
                taken.add(cand)
                break
    while assign and assign[-1] is None:
        assign.pop()
    return P(*assign)


def param_pspecs(cfg: ModelConfig, mesh: Mesh, rc: RunConfig) -> Pytree:
    specs = param_specs(cfg)
    axes_tree = logical_axes_tree(specs)
    from ..models.layers import ParamSpec

    def leaf(spec, axes):
        return _leaf_pspec(spec.shape, axes, mesh, rc.fsdp)

    return jax.tree_util.tree_map(
        leaf, specs, axes_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def _batch_axes(mesh: Mesh, batch: int) -> Optional[Tuple[str, ...]]:
    """Shard the batch over ('pod','data') when divisible, else 'data',
    else replicate (e.g. long_500k's batch of 1)."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    if axes and batch % size == 0 and batch >= size:
        return tuple(axes)
    if "data" in mesh.axis_names and batch % mesh.shape["data"] == 0 \
            and batch >= mesh.shape["data"]:
        return ("data",)
    return None


def _model_axis(mesh: Mesh, dim: int) -> Optional[str]:
    if "model" in mesh.axis_names and dim % mesh.shape["model"] == 0 \
            and dim >= mesh.shape["model"]:
        return "model"
    return None


def input_pspecs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> Pytree:
    """PartitionSpecs matching models.model.input_specs structure."""
    b = _batch_axes(mesh, shape.global_batch)
    base: Dict[str, Any] = {}
    if shape.mode == "decode":
        base["tokens"] = P(b)
        base["cache"] = cache_pspecs(cfg, shape, mesh)
        return base
    if cfg.frontend == "audio":
        base["frames"] = P(b, None, None)
    else:
        base["tokens"] = P(b, None)
        if cfg.frontend == "vision":
            base["patches"] = P(b, None, None)
    if shape.mode == "train":
        base["labels"] = P(b, None)
    return base


def cache_pspecs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> Pytree:
    from ..models.ssm import ssm_dims
    b = _batch_axes(mesh, shape.global_batch)
    out: Dict[str, Any] = {"len": P(b)}   # per-sequence positions: (B,)
    if cfg.family == "ssm":
        d_in, _, _ = ssm_dims(cfg)
        out["ssm"] = P(None, b, _model_axis(mesh, d_in), None)
        out["conv"] = P(None, b, None, _model_axis(mesh, d_in))
        return out
    if cfg.family == "hybrid":
        w = cfg.rglru.lru_width or cfg.d_model
        out["h"] = P(None, b, _model_axis(mesh, w))
        out["conv"] = P(None, b, None, _model_axis(mesh, w))
        out["k"] = _kv_cache_spec(cfg, mesh, b, cfg.rglru.window)
        out["v"] = _kv_cache_spec(cfg, mesh, b, cfg.rglru.window)
        return out
    if cfg.mla:
        # latent cache: shard the sequence dim over 'model' (flash-decode:
        # GSPMD turns the softmax/contraction over the sharded axis into
        # small psums — storage divides TP-ways without gathering)
        t_ax = _model_axis(mesh, shape.seq_len)
        out["latent"] = P(None, b, t_ax, None)
        out["rope"] = P(None, b, t_ax, None)
        return out
    out["k"] = _kv_cache_spec(cfg, mesh, b, shape.seq_len)
    out["v"] = _kv_cache_spec(cfg, mesh, b, shape.seq_len)
    return out


def _kv_cache_spec(cfg: ModelConfig, mesh: Mesh, b, seq_len: int) -> P:
    """(L, B, Hkv, T, hd) cache: shard heads over 'model' when divisible,
    else shard the sequence dim (flash-decode semantics via GSPMD psums) —
    the capacity fix for kv_heads < TP (pixtral 8, nemotron 8, glm4 2)."""
    h_ax = _model_axis(mesh, cfg.n_kv_heads)
    if h_ax is not None:
        return P(None, b, h_ax, None, None)
    return P(None, b, None, _model_axis(mesh, seq_len), None)


def logits_pspec(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> P:
    b = _batch_axes(mesh, shape.global_batch)
    v = _model_axis(mesh, cfg.vocab)
    if shape.mode == "decode":
        return P(b, v)
    return P(b, None, v)
