"""Collective matmul policies — COPIFTv2's queue idea at the mesh level.

Tensor-parallel ``y = x @ W`` with ``x`` gathered across the 'model' axis:

* COPIFT-analogue (``bulk``): ``all_gather(x)`` then one big local matmul —
  batch-granular synchronization: all communication completes before any
  compute starts (one bulk collective, zero overlap).
* COPIFTv2-analogue (``ring``): shards flow around the mesh ring via
  ``collective_permute`` while each in-flight shard is multiplied locally —
  a depth-1 queue of shards, fine-grained producer/consumer overlap.  On a
  real TPU the permute of chunk i+1 overlaps the MXU work on chunk i; the
  collective-bytes term is identical, but it is spread across the step
  instead of serializing at the front (see EXPERIMENTS.md §Perf).

Numerics are identical (same partial sums, same order up to an exact
permutation of chunk concatenation); tests assert exact equality against the
single-device reference.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..core.policy import ExecutionPolicy

if hasattr(jax, "shard_map"):                   # jax >= 0.5
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
else:                                           # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def _bulk_kernel(x, w, axis: str):
    xg = jax.lax.all_gather(x, axis, axis=0, tiled=True)
    return xg @ w


def _ring_kernel(x, w, axis: str):
    """x: (m/n, k) local shard; w: (k, p/n) local shard.  Computes the same
    (m, p/n) result as bulk, one shard-chunk per step, overlapping the
    permute of the next chunk with the matmul of the current one."""
    n = jax.lax.psum(1, axis)
    idx = jax.lax.axis_index(axis)
    perm = [(i, (i + 1) % n) for i in range(n)]

    def step(carry, _):
        buf, out, src = carry
        # issue the permute for the *next* chunk, then compute on the
        # current one: XLA schedules these concurrently (async collective)
        nxt = jax.lax.ppermute(buf, axis, perm)
        part = buf @ w
        out = out.at[src].set(part)
        src = (src - 1) % n
        return (nxt, out, src), None

    m, p = x.shape[0], w.shape[1]
    out0 = jnp.zeros((n, m, p), x.dtype)
    (_, out, _), _ = jax.lax.scan(step, (x, out0, idx), None, length=n)
    return out.reshape(n * m, p)


def tp_matmul(x: jax.Array, w: jax.Array, mesh: Mesh, *,
              policy: ExecutionPolicy = ExecutionPolicy.COPIFTV2,
              axis: str = "model",
              x_spec: Optional[P] = None, w_spec: Optional[P] = None,
              out_spec: Optional[P] = None) -> jax.Array:
    """Sequence-parallel x (sharded on dim 0) times column-parallel W
    (sharded on dim 1) -> y sharded on dim 1.  Policy picks the schedule."""
    x_spec = x_spec or P(axis, None)
    w_spec = w_spec or P(None, axis)
    out_spec = out_spec or P(None, axis)
    kern = _bulk_kernel if policy is not ExecutionPolicy.COPIFTV2 else _ring_kernel
    fn = _shard_map(partial(kern, axis=axis), mesh=mesh,
                    in_specs=(x_spec, w_spec), out_specs=out_spec,
                    **{_CHECK_KW: False})
    return fn(x, w)


def collective_bytes_estimate(m: int, k: int, n_shards: int,
                              dtype_bytes: int = 2) -> dict:
    """Napkin model for §Perf: both policies move the same payload; the ring
    splits it into n chunks that overlap compute."""
    payload = m * k * dtype_bytes * (n_shards - 1) / n_shards
    return {"bulk_front_loaded_bytes": payload,
            "ring_per_step_bytes": payload / max(n_shards - 1, 1),
            "ring_steps": n_shards}
