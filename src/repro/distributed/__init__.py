from .collective_matmul import tp_matmul
from .compression import compress_grads, dequantize_int8, quantize_int8
from .sharding import (cache_pspecs, input_pspecs, logits_pspec, param_pspecs)

__all__ = ["tp_matmul", "compress_grads", "dequantize_int8", "quantize_int8",
           "cache_pspecs", "input_pspecs", "logits_pspec", "param_pspecs"]
