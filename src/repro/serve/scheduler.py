"""Serve-path scheduling: request lifecycle, continuous batching, and
SLO accounting on top of the calibrated machine model.

This module is the bookkeeping half of the serving stack: `engine.ServeEngine`
owns the jitted decode step and the KV cache, while the scheduler owns the
arrival queue, admission control, slot assignment, per-request timestamps and
the cost model that converts engine steps into cycles-equivalent latency and
joules-per-token.  `simulate_serve` drives the same scheduler in virtual time
(no model, just the calibrated step costs) — that is what the trace-driven
`benchmarks/serve_slo.py` load generator runs, so the benchmark's batching
comparison and the real engine share one lifecycle implementation.

SLO objective semantics
-----------------------
A serve SLO is stated per request, in cycles-equivalent of the machine model
(the simulated RISC-V cluster has no wall clock):

* every request's *work* is ``max_new + prefill_weight * prompt_len`` tokens
  (prompt tokens are cheaper than decode tokens — chunked prefill amortizes
  the per-step overhead — so they count at a discount);
* a request *meets its SLO* iff its end-to-end latency (finish − arrival,
  queueing included) is at most ``p99_cycles_per_token × work + base_cycles``;
* the fleet *meets the SLO* iff the p99 over per-request normalized latencies
  (latency / work) is ≤ ``p99_cycles_per_token``, and, when a joules bound is
  set, measured energy-per-token is ≤ ``energy_per_token``.

**Throughput-at-SLO** — the headline serving metric, and what the
``serve-slo`` calibration objective maximizes — counts only the output tokens
of requests that met their SLO, divided by total cycles: tokens delivered
late are real work but worthless to the operator, so a configuration that
drains faster while blowing tail latency does not win.  The calibration-side
selection (``core.calibrate``, objective ``"serve-slo"``) applies the same
semantics analytically: for each Pareto-front point it estimates the p99
sojourn under the traffic level's offered load with an M/D/1-flavoured
queueing bound and picks the highest-throughput point whose estimate fits the
latency and energy budgets (see ``_select`` there).

Why continuous batching wins here: one engine step costs the *full* decode
batch width in both cycles and energy regardless of how many slots hold live
requests — the batch is a fixed-shape jitted program, padded rows burn PE
cycles like real ones.  Static (wave) batching drains every slot before
admitting the next wave, so short requests finish early and their slots idle
until the longest request in the wave completes; continuous batching refills
each slot the step after it frees.  Same cost per step, more live tokens per
step — higher throughput-at-SLO and lower J/token.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional, Tuple

from ..core.policy import TRAFFIC_LEVELS, OperatingPoint
from ..runtime.straggler import Heartbeat, StragglerMonitor


class AdmissionError(RuntimeError):
    """Raised by :meth:`ContinuousScheduler.submit` when admission control
    rejects a request (backpressure or an unservable shape)."""

    def __init__(self, reason: str):
        super().__init__(reason)
        self.reason = reason


@dataclass(frozen=True)
class AdmissionControl:
    """Admission policy: bound the arrival queue and refuse unservable shapes.

    ``max_pending`` bounds the number of queued (admitted-but-unscheduled)
    requests — beyond it the engine sheds load instead of growing an
    unbounded backlog whose tail latency is unbounded too.  ``max_total_len``
    (the engine's KV capacity) rejects requests whose ``prompt + max_new``
    could never fit a slot: admitting one would either overflow the cache or
    silently truncate, both worse than an upfront refusal.
    """
    max_pending: int = 64
    max_total_len: Optional[int] = None

    def reject_reason(self, prompt_len: int, max_new: int,
                      n_pending: int) -> Optional[str]:
        if prompt_len < 1 or max_new < 1:
            return f"empty request (prompt_len={prompt_len}, max_new={max_new})"
        if self.max_total_len is not None and \
                prompt_len + max_new > self.max_total_len:
            return (f"request needs {prompt_len + max_new} cache rows, "
                    f"slot capacity is {self.max_total_len}")
        if n_pending >= self.max_pending:
            return f"queue full ({n_pending}/{self.max_pending} pending)"
        return None


@dataclass
class ServeRequest:
    """Scheduler-side view of one request: shape plus lifecycle timestamps.

    Times are in whatever unit the caller's clock uses — cycles-equivalent in
    the virtual-time simulation, engine steps in the live engine (converted
    to cycles by the :class:`StepCostModel` when reporting).
    """
    rid: int
    prompt_len: int
    max_new: int
    arrival: float
    admit_time: Optional[float] = None    # entered a slot
    prefill_end: Optional[float] = None
    first_token: Optional[float] = None
    finish: Optional[float] = None
    tokens_out: int = 0
    prefill_cursor: int = 0
    slot: Optional[int] = None

    @property
    def phase(self) -> str:
        if self.finish is not None:
            return "done"
        if self.slot is None:
            return "queued"
        return "decode" if self.prefill_cursor >= self.prompt_len else "prefill"


class TrafficEstimator:
    """EWMA arrival-rate estimator mapping the *measured* request stream
    onto the calibration's :data:`~repro.core.policy.TRAFFIC_LEVELS`.

    The schema-v5 ``serve-slo`` calibration selects one operating point per
    offered-load level; this estimator closes that loop against live
    traffic so the serve engine can re-resolve its per-traffic point from
    what actually arrives instead of a static launch flag.

    Offered load is estimated as ``rate x work / capacity``:

    * ``rate`` — reciprocal of an EWMA over inter-arrival gaps (same-clock
      bursts drive the gap toward zero, saturating the estimate — the
      right answer for a thundering herd);
    * ``work`` — EWMA of per-request work tokens
      (``max_new + PREFILL_FRACTION * prompt_len``, the same discount the
      step cost model charges chunked prompt tokens);
    * ``capacity`` — the engine's full-width decode token rate
      (tokens/cycle), supplied by the owner and updated when the operating
      point (and so the cost model) changes.

    :meth:`level` maps the clamped load fraction to the *nearest*
    :data:`TRAFFIC_LEVELS` entry, or ``None`` until ``min_arrivals``
    arrivals have been observed — a cold estimator must not trigger a
    re-selection on no evidence.  Every arrival is observed, shed ones
    included: admission rejections are offered load too.
    """

    def __init__(self, capacity_tokens_per_cycle: float,
                 alpha: float = 0.25, min_arrivals: int = 4):
        assert 0.0 < alpha <= 1.0, alpha
        self.capacity = capacity_tokens_per_cycle
        self.alpha = alpha
        self.min_arrivals = min_arrivals
        self.n_arrivals = 0
        self._gap: Optional[float] = None      # EWMA inter-arrival gap
        self._work: Optional[float] = None     # EWMA work tokens / request
        self._last: Optional[float] = None     # previous arrival timestamp

    def observe(self, now: float, prompt_len: int, max_new: int) -> None:
        work = max_new + PREFILL_FRACTION * prompt_len
        self._work = work if self._work is None else \
            (1.0 - self.alpha) * self._work + self.alpha * work
        if self._last is not None:
            gap = max(now - self._last, 0.0)
            self._gap = gap if self._gap is None else \
                (1.0 - self.alpha) * self._gap + self.alpha * gap
        self._last = now
        self.n_arrivals += 1

    def offered_load(self) -> Optional[float]:
        """Estimated offered load as a fraction of service capacity in
        [0, 1], or None while cold (fewer than ``min_arrivals`` seen)."""
        if self.n_arrivals < self.min_arrivals or self._gap is None \
                or self._work is None:
            return None
        rate = 1.0 / max(self._gap, 1e-12)
        rho = rate * self._work / max(self.capacity, 1e-12)
        return min(max(rho, 0.0), 1.0)

    def level(self) -> Optional[str]:
        """The nearest :data:`TRAFFIC_LEVELS` name, or None while cold."""
        rho = self.offered_load()
        if rho is None:
            return None
        return min(TRAFFIC_LEVELS,
                   key=lambda name: abs(TRAFFIC_LEVELS[name] - rho))


class ContinuousScheduler:
    """Arrival queue + slot assignment for a fixed-width decode batch.

    ``mode="continuous"`` refills any free slot the moment the queue is
    non-empty; ``mode="static"`` reproduces wave batching (refill only once
    *every* slot has drained) and exists as the baseline the serve-SLO
    benchmark measures continuous batching against.  An attached
    :class:`TrafficEstimator` observes every arrival timestamp (admitted or
    shed) so the owner can map measured load onto the calibrated traffic
    levels.
    """

    MODES = ("continuous", "static")

    def __init__(self, n_slots: int, mode: str = "continuous",
                 admission: Optional[AdmissionControl] = None,
                 estimator: Optional[TrafficEstimator] = None):
        if mode not in self.MODES:
            raise ValueError(f"mode must be one of {self.MODES}, got {mode!r}")
        self.n_slots = n_slots
        self.mode = mode
        self.admission = admission or AdmissionControl()
        self.estimator = estimator
        self.queue: Deque[ServeRequest] = deque()
        self.slots: List[Optional[ServeRequest]] = [None] * n_slots
        self.requests: Dict[int, ServeRequest] = {}
        self.n_rejected = 0
        self.n_completed = 0

    # -- lifecycle ---------------------------------------------------------
    def submit(self, rid: int, prompt_len: int, max_new: int,
               now: float) -> ServeRequest:
        if self.estimator is not None:
            self.estimator.observe(now, prompt_len, max_new)
        reason = self.admission.reject_reason(prompt_len, max_new,
                                              len(self.queue))
        if reason is not None:
            self.n_rejected += 1
            raise AdmissionError(reason)
        req = ServeRequest(rid, prompt_len, max_new, arrival=now)
        self.requests[rid] = req
        self.queue.append(req)
        return req

    def refill(self, now: float) -> List[Tuple[int, ServeRequest]]:
        """Move queued requests into free slots; returns the new
        ``(slot, request)`` assignments so the engine can reset cache rows."""
        if self.mode == "static" and any(s is not None for s in self.slots):
            return []
        placed: List[Tuple[int, ServeRequest]] = []
        for i in range(self.n_slots):
            if self.slots[i] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            req.slot, req.admit_time = i, now
            self.slots[i] = req
            placed.append((i, req))
        return placed

    def advance_prefill(self, rid: int, tokens: int, now: float) -> None:
        req = self.requests[rid]
        req.prefill_cursor += tokens
        if req.prefill_cursor >= req.prompt_len and req.prefill_end is None:
            req.prefill_end = now

    def record_token(self, rid: int, now: float) -> bool:
        """One decoded token for ``rid``; returns True when it finished
        (the slot is freed — the engine must not reuse it before resetting
        the slot's cache rows via the next :meth:`refill`)."""
        req = self.requests[rid]
        if req.first_token is None:
            req.first_token = now
        req.tokens_out += 1
        if req.tokens_out >= req.max_new:
            req.finish = now
            if req.slot is not None:
                self.slots[req.slot] = None
            req.slot = None
            self.n_completed += 1
            return True
        return False

    # -- queries -----------------------------------------------------------
    def active(self) -> List[Tuple[int, ServeRequest]]:
        return [(i, r) for i, r in enumerate(self.slots) if r is not None]

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)


# ---------------------------------------------------------------------------
# step-cost model: engine steps -> cycles & joules at the calibrated point
# ---------------------------------------------------------------------------

#: one machine-model proxy sample ~ one decode token's activation math
#: (the ``expf`` kernel is the ``serve`` workload's instruction-mix analogue,
#: see core.policy.WORKLOAD_PROXIES)
_SAMPLES_PER_TOKEN = 1.0
#: chunked-prefill marginal cost per prompt token, as a fraction of a decode
#: token: prefill batches prompt tokens through one jitted chunk call
#: (``models.model.prefill_step``), amortizing the per-step dispatch/sync
#: overhead the decode path pays every token.  Both the live engine and the
#: virtual-time simulation charge prompt tokens at this fraction.
PREFILL_FRACTION = 0.25
#: fixed per-step dispatch overhead (cycles): queue maintenance + batch
#: launch, independent of width
_STEP_OVERHEAD_CYCLES = 16.0


@dataclass(frozen=True)
class StepCostModel:
    """Cycles & joules per engine step, derived from a calibrated
    :class:`~repro.core.policy.OperatingPoint` by simulating the serve
    workload's proxy kernel at that point's full geometry.

    One decode step over a batch of width ``W`` costs
    ``overhead + W * cycles_decode_token`` cycles and
    ``W * energy_decode_token`` joules *regardless of how many slots are
    live* — the jitted batch is fixed-shape, padded rows execute.  Chunked
    prefill adds a discounted marginal cost per prompt token ingested.
    """
    cycles_decode_token: float
    energy_decode_token: float
    cycles_prefill_token: float
    energy_prefill_token: float
    overhead_cycles: float = _STEP_OVERHEAD_CYCLES
    source: str = "default"

    @classmethod
    def from_operating_point(cls, op: Optional[OperatingPoint] = None,
                             workload: str = "serve",
                             n_samples: int = 32) -> "StepCostModel":
        """Simulate the workload's proxy kernel at ``op``'s geometry and
        derive per-token costs.  Falls back to the paper-default operating
        point if ``op``'s geometry is rejected by the machine model, and to
        flat constants if even that fails (never raises)."""
        from ..core.policy import WORKLOAD_PROXIES
        from ..core.sweep import SweepPoint, run_point
        kernel = WORKLOAD_PROXIES.get(workload, "expf")
        candidates = [] if op is None else [(op, op.source)]
        candidates.append((OperatingPoint(), "default"))
        for candidate, src in candidates:
            rec = run_point(SweepPoint(
                kernel=kernel, policy=candidate.policy.value,
                queue_depth=candidate.queue_depth,
                queue_latency=candidate.queue_latency,
                unroll=candidate.unroll, unroll_int=candidate.unroll_int,
                queue_depth_i2f=candidate.queue_depth_i2f,
                queue_depth_f2i=candidate.queue_depth_f2i,
                n_cores=candidate.n_cores, tcdm_banks=candidate.tcdm_banks,
                pipeline=candidate.pipeline, cq_depth=candidate.cq_depth,
                dma_buffers=candidate.dma_buffers, n_samples=n_samples))
            if rec.status == "ok" and rec.cycles > 0 and rec.n_samples > 0:
                cpt = rec.cycles / rec.n_samples * _SAMPLES_PER_TOKEN
                ept = rec.energy / rec.n_samples * _SAMPLES_PER_TOKEN
                return cls(cycles_decode_token=cpt, energy_decode_token=ept,
                           cycles_prefill_token=cpt * PREFILL_FRACTION,
                           energy_prefill_token=ept * PREFILL_FRACTION,
                           source=src)
        return cls(cycles_decode_token=64.0, energy_decode_token=64.0,
                   cycles_prefill_token=16.0, energy_prefill_token=16.0,
                   source="flat-fallback")

    def step_cost(self, width: int, prefill_tokens: int = 0
                  ) -> Tuple[float, float]:
        """(cycles, joules) for one engine step: a full-width decode pass
        plus ``prefill_tokens`` chunked prompt tokens."""
        cycles = (self.overhead_cycles + width * self.cycles_decode_token
                  + prefill_tokens * self.cycles_prefill_token)
        energy = (width * self.energy_decode_token
                  + prefill_tokens * self.energy_prefill_token)
        return cycles, energy


# ---------------------------------------------------------------------------
# straggler-aware dispatch
# ---------------------------------------------------------------------------

class HostDispatch:
    """Straggler-aware work dispatch over ``n_hosts`` data-parallel hosts.

    Each step's batch is split by per-host weights; a host's step time is its
    share of the work stretched by its (unknown to the dispatcher) slowdown
    factor, and the step completes at the barrier — the slowest host.  Every
    per-host time feeds one shared :class:`StragglerMonitor`; a flagged host
    has its dispatch weight halved, shifting work to healthy hosts until its
    step times re-enter the robust band (self-stabilizing — no oscillation,
    because flagged samples never pollute the baseline window).  A
    :class:`Heartbeat` seeded at the dispatcher's start time tracks liveness
    without declaring slow-but-beating hosts dead.

    The monitor is fed each host's time *relative to the step's median host
    time*, not the raw time: reweighting deliberately shifts every host's
    absolute step time, and raw times against a zero-noise baseline window
    (MAD degenerates to the epsilon floor) would flag healthy hosts for the
    shift the mitigation itself caused.  Relative to the median, a healthy
    host is exactly 1.0 every step no matter how the weights move.
    """

    def __init__(self, n_hosts: int, window: int = 32, threshold: float = 4.0,
                 min_samples: int = 8, heartbeat_timeout: float = 1e9,
                 start: float = 0.0):
        self.n_hosts = n_hosts
        self.hosts = [f"host{i}" for i in range(n_hosts)]
        self.weights = [1.0] * n_hosts
        self.speeds = [1.0] * n_hosts     # slowdown factors (tests inject)
        self.monitor = StragglerMonitor(window=window, threshold=threshold,
                                        min_samples=min_samples)
        self.heartbeat = Heartbeat(self.hosts, timeout=heartbeat_timeout,
                                   start=start)
        self.flag_counts: Dict[int, int] = {}
        self._step_no = 0

    def set_speed(self, host: int, slowdown: float) -> None:
        self.speeds[host] = slowdown

    def step(self, cycles: float, now: float) -> float:
        """Dispatch one step of ``cycles`` total work at virtual time
        ``now``; returns the barrier (slowest-host) completion time."""
        if self.n_hosts <= 1:
            self.heartbeat.beat(self.hosts[0], now + cycles)
            return cycles
        total_w = sum(self.weights)
        times = [cycles * self.n_hosts * (w / total_w) * s
                 for w, s in zip(self.weights, self.speeds)]
        med = StragglerMonitor._median(times)
        ratios = [t / max(med, 1e-9) for t in times]
        flagged = [self.monitor.record(self._step_no, r) for r in ratios]
        self._step_no += 1
        for i, (t, f) in enumerate(zip(times, flagged)):
            self.heartbeat.beat(self.hosts[i], now + t)
            if f:
                self.flag_counts[i] = self.flag_counts.get(i, 0) + 1
                # halve the flagged host's share (floored — a mis-flagged
                # host must never be starved to zero)
                self.weights[i] = max(self.weights[i] * 0.5, 2.0 ** -6)
        return max(times)

    @property
    def flagged_hosts(self) -> List[int]:
        return sorted(self.flag_counts)

    def dead(self, now: float) -> List[str]:
        return self.heartbeat.dead(now)


# ---------------------------------------------------------------------------
# SLO definition + report
# ---------------------------------------------------------------------------

def percentile(xs: List[float], q: float) -> float:
    """Linear-interpolation percentile (deterministic, no numpy dependency
    in the hot reporting path).  ``q`` in [0, 100]."""
    if not xs:
        return 0.0
    s = sorted(xs)
    if len(s) == 1:
        return s[0]
    pos = (len(s) - 1) * q / 100.0
    lo = int(math.floor(pos))
    hi = min(lo + 1, len(s) - 1)
    frac = pos - lo
    return s[lo] * (1.0 - frac) + s[hi] * frac


@dataclass(frozen=True)
class ServeSLO:
    """Service-level objective in cycles-equivalent (module docstring has
    the full semantics)."""
    p99_cycles_per_token: float
    energy_per_token: Optional[float] = None
    prefill_weight: float = 0.25
    base_cycles: float = 0.0

    def work_tokens(self, prompt_len: int, max_new: int) -> float:
        return max_new + self.prefill_weight * prompt_len

    def budget(self, prompt_len: int, max_new: int) -> float:
        return (self.p99_cycles_per_token
                * self.work_tokens(prompt_len, max_new) + self.base_cycles)


@dataclass
class ServeReport:
    """Per-run serving metrics: request outcomes, latency percentiles,
    energy accounting and SLO attainment."""
    mode: str
    n_completed: int
    n_rejected: int
    n_unfinished: int
    total_cycles: float
    total_energy: float
    tokens_out: int
    throughput: float                 # tokens / cycle, all completions
    energy_per_token: float           # joules / token, all tokens
    p50_latency: float                # normalized: cycles per work-token
    p99_latency: float
    p50_ttft: float                   # time to first token, cycles
    p99_ttft: float
    slo: Optional[Dict[str, Any]] = None
    straggler: Optional[Dict[str, Any]] = None
    cost_source: str = "default"

    def to_dict(self) -> Dict[str, Any]:
        d = dict(self.__dict__)
        return d


def build_report(sched: ContinuousScheduler, total_cycles: float,
                 total_energy: float, slo: Optional[ServeSLO] = None,
                 dispatch: Optional[HostDispatch] = None,
                 cost_source: str = "default") -> ServeReport:
    done = [r for r in sched.requests.values() if r.finish is not None]
    norm_lat = [(r.finish - r.arrival)
                / max(slo.work_tokens(r.prompt_len, r.max_new) if slo
                      else float(r.max_new), 1e-9) for r in done]
    ttft = [r.first_token - r.arrival for r in done
            if r.first_token is not None]
    tokens = sum(r.tokens_out for r in sched.requests.values())
    cyc = max(total_cycles, 1e-9)
    report = ServeReport(
        mode=sched.mode, n_completed=len(done), n_rejected=sched.n_rejected,
        n_unfinished=len(sched.requests) - len(done),
        total_cycles=total_cycles, total_energy=total_energy,
        tokens_out=tokens, throughput=tokens / cyc,
        energy_per_token=total_energy / max(tokens, 1),
        p50_latency=percentile(norm_lat, 50), p99_latency=percentile(norm_lat, 99),
        p50_ttft=percentile(ttft, 50), p99_ttft=percentile(ttft, 99),
        cost_source=cost_source)
    if slo is not None:
        met = [r for r in done
               if r.finish - r.arrival <= slo.budget(r.prompt_len, r.max_new)]
        met_tokens = sum(r.tokens_out for r in met)
        energy_ok = (slo.energy_per_token is None
                     or report.energy_per_token <= slo.energy_per_token)
        report.slo = {
            "p99_cycles_per_token": slo.p99_cycles_per_token,
            "energy_budget_per_token": slo.energy_per_token,
            "attainment": len(met) / max(len(done), 1),
            "throughput_at_slo": met_tokens / cyc,
            "p99_met": report.p99_latency <= slo.p99_cycles_per_token,
            "energy_met": energy_ok,
        }
    if dispatch is not None:
        report.straggler = {
            "n_hosts": dispatch.n_hosts,
            "flagged_hosts": dispatch.flagged_hosts,
            "flag_events": len(dispatch.monitor.events),
            "weights": list(dispatch.weights),
            "dead_hosts": dispatch.dead(total_cycles),
        }
    return report


# ---------------------------------------------------------------------------
# virtual-time serve simulation (trace-driven, deterministic)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TraceRequest:
    """One request in an arrival trace (times in cycles-equivalent)."""
    rid: int
    arrival: float
    prompt_len: int
    max_new: int


def simulate_serve(trace: List[TraceRequest], n_slots: int,
                   cost: StepCostModel, mode: str = "continuous",
                   slo: Optional[ServeSLO] = None,
                   admission: Optional[AdmissionControl] = None,
                   prefill_chunk: int = 8,
                   dispatch: Optional[HostDispatch] = None,
                   max_steps: int = 200_000) -> ServeReport:
    """Run an arrival trace through the scheduler in virtual time.

    Pure bookkeeping over the calibrated :class:`StepCostModel` — no model,
    no jax — so it is exactly deterministic for a fixed trace, which is what
    lets ``benchmarks/serve_slo.py`` gate on exact numbers in CI.  Each step
    ingests up to ``prefill_chunk`` prompt tokens per prefilling slot and
    decodes one token per decoding slot; a slot whose prefill completes this
    step emits its first token the next step (matching the live engine).
    Step time is stretched by the :class:`HostDispatch` barrier when hosts
    are attached; energy is not stretched (a slow host takes longer at the
    same power draw modelled per useful token).
    """
    sched = ContinuousScheduler(n_slots, mode=mode, admission=admission)
    trace = sorted(trace, key=lambda t: (t.arrival, t.rid))
    clock = 0.0
    ai = 0
    steps = 0
    total_energy = 0.0
    while steps < max_steps:
        while ai < len(trace) and trace[ai].arrival <= clock:
            t = trace[ai]
            ai += 1
            try:
                sched.submit(t.rid, t.prompt_len, t.max_new, now=t.arrival)
            except AdmissionError:
                pass                       # shed load; counted by scheduler
        sched.refill(clock)
        active = sched.active()
        if not active:
            if ai < len(trace):
                clock = max(clock, trace[ai].arrival)
                continue
            if sched.queue:                # static mode drains between waves
                sched.refill(clock)
                if not sched.active():
                    break                  # unservable leftovers
                continue
            break
        prefill_tokens = 0
        decoding = [r for _, r in active if r.phase == "decode"]
        for _, r in active:
            if r.phase == "prefill":
                chunk = min(prefill_chunk, r.prompt_len - r.prefill_cursor)
                prefill_tokens += chunk
                sched.advance_prefill(r.rid, chunk, clock)
        cycles, energy = cost.step_cost(n_slots, prefill_tokens)
        if dispatch is not None:
            cycles = dispatch.step(cycles, clock)
        clock += cycles
        total_energy += energy
        for r in decoding:
            sched.record_token(r.rid, clock)
        steps += 1
    return build_report(sched, clock, total_energy, slo=slo,
                        dispatch=dispatch,
                        cost_source=cost.source)
