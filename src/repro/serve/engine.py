"""Batched serving engine: continuous batching over fixed decode slots,
with real chunked prefill on the live path.

Requests are admitted through the scheduler's arrival queue (bounded —
admission control sheds load past ``max_pending`` and refuses shapes that
cannot fit a slot); every engine step advances all active slots with a
single jitted call.  Slots refill *mid-run* the step after they drain — the
cache tracks a per-sequence position vector (``cache["len"]`` is ``(B,)``),
so one slot's readmission never disturbs its neighbours and never
resurrects stale KV rows (the freed slot's cache rows are zeroed before
reuse).  ``mode="static"`` keeps the old wave-batching behaviour as a
measurable baseline.

Prompt ingestion is chunked: any step with a prefilling slot runs the
jitted :func:`~repro.models.model.prefill_step`, feeding up to
``prefill_chunk`` prompt tokens per prefilling slot per call while
neighbouring slots mid-decode ride along in the same batch with a one-token
chunk — bit-exact with the token-by-token path by construction (the chunk
kernel scans the same ``decode_step`` body over its columns).  Chunk widths
are bucketed to powers of two so the jit cache holds at most
``log2(prefill_chunk) + 1`` programs (``prefill_compiles`` counts them);
``prefill="token"`` keeps the old one-token-per-step ingestion as the
measurable TTFT baseline.  Step accounting matches the virtual-time
``scheduler.simulate_serve``: every step charges the full batch width plus
the ingested prompt tokens at ``PREFILL_FRACTION`` through the
:class:`StepCostModel`, so the engine clock is in cycles-equivalent always.

When neither an explicit ``operating_point`` nor a ``traffic`` level is
given, the engine runs in *measured-traffic* mode: a
:class:`~repro.serve.scheduler.TrafficEstimator` watches the arrival
stream, and at refill boundaries the engine re-resolves the schema-v5
per-traffic ``serve-slo`` operating point for the measured level
(``traffic_history`` records every retarget).  An explicit ``traffic``
flag or operating point disables the estimator and pins the point.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..config import (ModelConfig, RunConfig, _DEFAULT_RC_POLICY,
                      resolve_run_config)
from ..core.policy import OperatingPoint, PolicyTable, default_table
from ..models.model import decode_step, init_cache, prefill_step
from .scheduler import (AdmissionControl, ContinuousScheduler, HostDispatch,
                        ServeReport, ServeSLO, StepCostModel,
                        TrafficEstimator, build_report)

Pytree = Any


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    generated: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Continuous-batching engine.

    The execution policy is resolved per workload at startup through
    :func:`repro.config.resolve_run_config`: an explicit ``operating_point``
    wins, a caller-pinned (non-default) ``rc.policy`` stays authoritative,
    and otherwise the calibration-backed
    :class:`~repro.core.policy.PolicyTable` (``policy_table`` or the
    process-wide default honouring ``REPRO_CALIBRATION_DIR``) supplies the
    ``"serve"`` workload's point, falling back to the paper's defaults when
    no artifact exists.  A ``traffic`` level ("low"/"medium"/"high") pins
    the artifact's per-traffic ``serve-slo`` point when the calibration
    carries one (schema v5); with no pin the engine *measures* the level
    from the arrival stream and re-resolves at refill boundaries (the
    retarget swaps the operating point and its cost model — the executed
    numeric program is untouched, so generated tokens never depend on
    traffic).  The resolved policy is threaded into the engine's
    :class:`RunConfig` so every kernel the decode path reaches sees it; the
    resolution itself never touches the per-step hot path.

    Batch sizing is cluster-aware: with ``batch_slots=None`` the engine
    sizes its decode batch as ``SLOTS_PER_CORE * n_cores`` from the
    resolved operating point — an N-PE cluster sustains N concurrent
    per-core token streams, so the continuous batch scales with the
    calibrated cluster width instead of implicitly assuming one PE.  An
    explicit ``batch_slots`` always wins.

    Request lifecycle and accounting live in
    :class:`~repro.serve.scheduler.ContinuousScheduler`; :meth:`metrics`
    turns the recorded timestamps (cycles-equivalent — the engine clock is
    driven by the operating point's :class:`StepCostModel`) into p50/p99
    latency and energy-per-token.
    """

    #: decode slots the batch allocates per cluster core (one PE's worth of
    #: concurrent streams at the paper's operating point)
    SLOTS_PER_CORE = 4

    PREFILL_MODES = ("chunked", "token")

    def __init__(self, params: Pytree, cfg: ModelConfig, rc: RunConfig,
                 batch_slots: Optional[int] = None, max_len: int = 256,
                 greedy: bool = True,
                 operating_point: Optional[OperatingPoint] = None,
                 policy_table: Optional[PolicyTable] = None,
                 mode: str = "continuous", max_pending: int = 64,
                 traffic: Optional[str] = None,
                 cost_model: Optional[StepCostModel] = None,
                 dispatch: Optional[HostDispatch] = None,
                 prefill: str = "chunked", prefill_chunk: int = 8):
        assert cfg.causal, "serving requires an autoregressive model"
        if prefill not in self.PREFILL_MODES:
            raise ValueError(f"prefill must be one of {self.PREFILL_MODES}, "
                             f"got {prefill!r}")
        assert prefill_chunk >= 1, prefill_chunk
        self.params = params
        pinned = rc.policy if rc.policy is not _DEFAULT_RC_POLICY else None
        rc, self.operating_point = resolve_run_config(
            rc, "serve", operating_point, policy_table, traffic=traffic)
        if batch_slots is None:
            batch_slots = self.SLOTS_PER_CORE * max(
                1, self.operating_point.n_cores)
        self.cfg, self.rc = cfg, rc
        self.traffic = traffic
        self.max_len = max_len
        self.greedy = greedy
        self.prefill = prefill
        self.prefill_chunk = prefill_chunk
        self._cost = cost_model or StepCostModel.from_operating_point(
            self.operating_point)
        self._explicit_cost = cost_model is not None
        # measured-traffic mode: no pinned point, no pinned level — estimate
        # offered load from arrivals and re-resolve at refill boundaries
        self._measured = operating_point is None and traffic is None
        self._pinned_policy = pinned
        self._table = (policy_table if policy_table is not None
                       else default_table())
        self.traffic_level: Optional[str] = traffic
        self.traffic_history: List[Dict[str, Any]] = []
        estimator = None
        if self._measured:
            step_cyc, _ = self._cost.step_cost(batch_slots, 0)
            estimator = TrafficEstimator(
                capacity_tokens_per_cycle=batch_slots / max(step_cyc, 1e-9))
        self.sched = ContinuousScheduler(
            batch_slots, mode=mode,
            admission=AdmissionControl(max_pending=max_pending,
                                       max_total_len=max_len),
            estimator=estimator)
        self.requests: Dict[int, Request] = {}
        self.cache = init_cache(cfg, batch_slots, max_len, jnp.dtype(rc.dtype))
        self._step = jax.jit(partial(decode_step, cfg=cfg, rc=rc))
        #: bucketed chunk-width jit cache: chunk width -> jitted prefill_step.
        #: Widths are powers of two, so at most log2(prefill_chunk)+1 programs
        #: ever compile; ``prefill_compiles`` counts them.
        self._prefill_jit: Dict[int, Any] = {}
        self.prefill_compiles = 0
        self._next_rid = 0
        self.finished: Dict[int, Request] = {}
        self._dispatch = dispatch
        self._n_steps = 0
        self._clock = 0.0       # cycles-equivalent (StepCostModel-driven)
        self._energy = 0.0

    @property
    def slots(self) -> List[Optional[Request]]:
        """Engine-side view of the decode batch: the live :class:`Request`
        per slot (``None`` for free slots)."""
        return [self.requests[s.rid] if s is not None else None
                for s in self.sched.slots]

    def submit(self, prompt: List[int], max_new: int = 16) -> int:
        """Queue a request; raises
        :class:`~repro.serve.scheduler.AdmissionError` when admission
        control sheds it (backpressure — the caller retries later).  The
        scheduler's admission control refuses empty prompts up front, so a
        ``[]`` prompt never reaches the batch-assembly hot path."""
        rid = self._next_rid
        self.sched.submit(rid, len(prompt), max_new, now=self._clock)
        self._next_rid += 1
        self.requests[rid] = Request(rid, list(prompt), max_new)
        return rid

    def _reset_slot_cache(self, i: int) -> None:
        """Zero slot ``i``'s rows in every cache leaf before reuse: batch is
        axis 1 of every stacked leaf, axis 0 of the per-sequence ``len``
        vector.  This is what makes mid-run refill safe — the readmitted
        slot restarts at position 0 over zeroed KV/state rows while its
        neighbours keep decoding at their own positions."""
        self.cache = {k: (v if v.ndim == 0 else
                          v.at[i].set(0) if v.ndim == 1 else
                          v.at[:, i].set(0))
                      for k, v in self.cache.items()}

    # -- chunked prefill machinery ----------------------------------------
    @staticmethod
    def _bucket(n: int) -> int:
        """Smallest power of two >= n: chunk widths quantize to buckets so
        the number of compiled prefill programs stays logarithmic."""
        return 1 << max(n - 1, 0).bit_length()

    def _prefill_fn(self, width: int):
        fn = self._prefill_jit.get(width)
        if fn is None:
            fn = self._prefill_jit[width] = jax.jit(
                partial(prefill_step, cfg=self.cfg, rc=self.rc))
            self.prefill_compiles += 1
        return fn

    def _chunk_forward(self, active) -> Tuple[np.ndarray, np.ndarray, int]:
        """One mixed-phase chunk call: prefilling slots ingest up to
        ``prefill_chunk`` prompt tokens, decoding slots ride along with a
        one-token chunk, free slots stay masked out.  Returns the per-slot
        argmax tokens, the per-slot chunk counts, and the total prompt
        tokens ingested (the prefill component of this step's cost)."""
        n = self.sched.n_slots
        need = 1
        for _, sreq in active:
            if sreq.phase == "prefill":
                need = max(need, min(self.prefill_chunk,
                                     sreq.prompt_len - sreq.prefill_cursor))
        width = self._bucket(need)
        tokens = np.zeros((n, width), np.int32)
        counts = np.zeros((n,), np.int32)
        prefill_tokens = 0
        for i, sreq in active:
            req = self.requests[sreq.rid]
            cur = sreq.prefill_cursor
            if cur < len(req.prompt):
                k = min(self.prefill_chunk, len(req.prompt) - cur)
                tokens[i, :k] = req.prompt[cur:cur + k]
                counts[i] = k
                prefill_tokens += k
            else:
                tokens[i, 0] = (req.generated[-1] if req.generated
                                else req.prompt[-1])
                counts[i] = 1
        logits, self.cache = self._prefill_fn(width)(
            self.params, self.cache,
            {"tokens": jnp.asarray(tokens), "n_tokens": jnp.asarray(counts)})
        return np.asarray(jnp.argmax(logits, axis=-1)), counts, prefill_tokens

    def _token_forward(self, active) -> Tuple[np.ndarray, np.ndarray, int]:
        """One token-by-token step (pure-decode steps, and the whole run
        when ``prefill="token"``): every active slot advances one token
        through the plain jitted decode step."""
        tokens = np.zeros((self.sched.n_slots, 1), np.int32)
        counts = np.zeros((self.sched.n_slots,), np.int32)
        for i, sreq in active:
            req = self.requests[sreq.rid]
            cur = sreq.prefill_cursor
            if cur < len(req.prompt):
                tokens[i, 0] = req.prompt[cur]
            elif req.generated:
                tokens[i, 0] = req.generated[-1]
            else:
                tokens[i, 0] = req.prompt[-1]
            counts[i] = 1
        logits, self.cache = self._step(self.params, self.cache,
                                        {"tokens": jnp.asarray(tokens)})
        return np.asarray(jnp.argmax(logits, axis=-1)), counts, 0

    # -- measured-traffic retargeting --------------------------------------
    def _maybe_retarget_traffic(self) -> None:
        """In measured-traffic mode, re-resolve the per-traffic operating
        point when the estimator's level moved.  Called at refill
        boundaries only — never on the per-token hot path — and only swaps
        the accounting (operating point, cost model, estimator capacity):
        the compiled decode/prefill programs are left alone, so retargeting
        can never change which tokens get generated."""
        est = self.sched.estimator
        if not self._measured or est is None:
            return
        level = est.level()
        if level is None or level == self.traffic_level:
            return
        kw = ({"policy": self._pinned_policy}
              if self._pinned_policy is not None else {})
        op = self._table.resolve("serve", traffic=level, **kw)
        self.traffic_level = level
        self.operating_point = op
        if not self._explicit_cost:
            self._cost = StepCostModel.from_operating_point(op)
        step_cyc, _ = self._cost.step_cost(self.sched.n_slots, 0)
        est.capacity = self.sched.n_slots / max(step_cyc, 1e-9)
        self.traffic_history.append({
            "clock": self._clock, "level": level,
            "offered_load": est.offered_load(),
            "policy": op.policy.value, "source": op.source})

    def step(self) -> None:
        """Advance every active slot — one chunk of prompt tokens for
        prefilling slots, one decoded token for the rest — refilling freed
        slots from the arrival queue first (continuous batching)."""
        placed = self.sched.refill(self._clock)
        for i, _ in placed:
            self._reset_slot_cache(i)
        if placed:
            self._maybe_retarget_traffic()
        active = self.sched.active()
        if not active:
            return
        if self.prefill == "chunked" and any(
                sreq.phase == "prefill" for _, sreq in active):
            nxt, counts, prefill_tokens = self._chunk_forward(active)
        else:
            nxt, counts, prefill_tokens = self._token_forward(active)
        cycles, joules = self._cost.step_cost(self.sched.n_slots,
                                              prefill_tokens)
        if self._dispatch is not None:
            cycles = self._dispatch.step(cycles, self._clock)
        end = self._clock + cycles
        self._energy += joules
        for i, sreq in active:
            req = self.requests[sreq.rid]
            cur = sreq.prefill_cursor
            if cur < len(req.prompt):
                k = int(counts[i])
                self.sched.advance_prefill(sreq.rid, k, end)
                if cur + k < len(req.prompt):
                    continue               # still ingesting the prompt
                # the call that ingested the last prompt token emitted the
                # first generated token — fall through to record it
            req.generated.append(int(nxt[i]))
            if self.sched.record_token(sreq.rid, end):
                req.done = True
                self.finished[req.rid] = req
        self._clock = end
        self._n_steps += 1

    def run(self, max_steps: int = 1000) -> Dict[int, Request]:
        steps = 0
        while self.sched.busy and steps < max_steps:
            self.step()
            steps += 1
        return self.finished

    def metrics(self, slo: Optional[ServeSLO] = None) -> ServeReport:
        """Per-request serving report (p50/p99 latency, TTFT, J/token,
        SLO attainment).  Timestamps are already in cycles-equivalent —
        every step is charged through the operating point's
        :class:`StepCostModel` as it executes (full batch width plus the
        step's prompt tokens at ``PREFILL_FRACTION``), the same accounting
        ``simulate_serve`` applies in virtual time."""
        return build_report(self.sched, self._clock, self._energy,
                            slo=slo, dispatch=self._dispatch,
                            cost_source=self._cost.source)
