"""Batched serving engine: continuous batching over fixed decode slots.

Requests are admitted into free slots of a fixed-size batch; every engine
step decodes one token for all active slots (a single jitted decode_step).
Prompt ingestion reuses the decode path token-by-token (teacher-forcing the
prompt) — exact and cache-consistent; a production deployment would fuse a
chunked prefill, which exists as the lowered ``prefill`` cell of the
dry-run."""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..config import ModelConfig, RunConfig, resolve_run_config
from ..core.policy import OperatingPoint, PolicyTable
from ..models.model import decode_step, init_cache

Pytree = Any


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    generated: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Continuous-batching engine.

    The execution policy is resolved per workload at startup through
    :func:`repro.config.resolve_run_config`: an explicit ``operating_point``
    wins, a caller-pinned (non-default) ``rc.policy`` stays authoritative,
    and otherwise the calibration-backed
    :class:`~repro.core.policy.PolicyTable` (``policy_table`` or the
    process-wide default honouring ``REPRO_CALIBRATION_DIR``) supplies the
    ``"serve"`` workload's point, falling back to the paper's defaults when
    no artifact exists.  The resolved policy is threaded into the engine's
    :class:`RunConfig` so every kernel the decode path reaches sees it; the
    resolution itself never touches the per-step hot path.

    Batch sizing is cluster-aware: with ``batch_slots=None`` the engine
    sizes its decode batch as ``SLOTS_PER_CORE * n_cores`` from the
    resolved operating point — an N-PE cluster sustains N concurrent
    per-core token streams, so the continuous batch scales with the
    calibrated cluster width instead of implicitly assuming one PE.  An
    explicit ``batch_slots`` always wins.
    """

    #: decode slots the batch allocates per cluster core (one PE's worth of
    #: concurrent streams at the paper's operating point)
    SLOTS_PER_CORE = 4

    def __init__(self, params: Pytree, cfg: ModelConfig, rc: RunConfig,
                 batch_slots: Optional[int] = None, max_len: int = 256,
                 greedy: bool = True,
                 operating_point: Optional[OperatingPoint] = None,
                 policy_table: Optional[PolicyTable] = None):
        assert cfg.causal, "serving requires an autoregressive model"
        self.params = params
        rc, self.operating_point = resolve_run_config(
            rc, "serve", operating_point, policy_table)
        if batch_slots is None:
            batch_slots = self.SLOTS_PER_CORE * max(
                1, self.operating_point.n_cores)
        self.cfg, self.rc = cfg, rc
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.pending: List[Request] = []
        self.max_len = max_len
        self.greedy = greedy
        self.cache = init_cache(cfg, batch_slots, max_len, jnp.dtype(rc.dtype))
        self._prompt_cursor: Dict[int, int] = {}      # slot -> prompt index
        self._step = jax.jit(partial(decode_step, cfg=cfg, rc=rc))
        self._next_rid = 0
        self.finished: Dict[int, Request] = {}

    def submit(self, prompt: List[int], max_new: int = 16) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.pending.append(Request(rid, list(prompt), max_new))
        return rid

    def _reset_slot_cache(self, i: int) -> None:
        """Zero slot ``i``'s rows in every cache leaf (batch is axis 1 of
        every non-scalar leaf; the joint ``len`` scalar is left alone)."""
        self.cache = {k: (v if v.ndim == 0 else v.at[:, i].set(0))
                      for k, v in self.cache.items()}

    # Slots are length-tracked jointly (one ``cache["len"]`` scalar), so
    # this simple engine admits requests in waves: a new wave only starts
    # once every slot has drained.  At that boundary the whole cache is
    # re-zeroed (len back to 0) — without it a second wave would attend
    # over the first wave's stale KV rows at an advanced length and
    # diverge from a fresh engine.  The per-slot zeroing on admission is
    # defense in depth for the mid-wave case; per-slot lengths are the
    # straightforward extension.
    def _admit(self) -> None:
        if self.pending and not self._active():
            self.cache = init_cache(self.cfg, len(self.slots), self.max_len,
                                    jnp.dtype(self.rc.dtype))
            self._prompt_cursor.clear()
        for i, slot in enumerate(self.slots):
            if slot is None and self.pending:
                req = self.pending.pop(0)
                self.slots[i] = req
                self._reset_slot_cache(i)
                self._prompt_cursor[i] = 0

    def _active(self) -> bool:
        return any(s is not None for s in self.slots)

    def step(self) -> None:
        """Advance every active slot by one token."""
        self._admit()
        if not self._active():
            return
        tokens = np.zeros((len(self.slots), 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            cur = self._prompt_cursor[i]
            if cur < len(req.prompt):
                tokens[i, 0] = req.prompt[cur]
            elif req.generated:
                tokens[i, 0] = req.generated[-1]
            else:
                tokens[i, 0] = req.prompt[-1]
        logits, self.cache = self._step(self.params, self.cache,
                                        {"tokens": jnp.asarray(tokens)})
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            cur = self._prompt_cursor[i]
            if cur < len(req.prompt) - 1:
                self._prompt_cursor[i] = cur + 1       # still ingesting
                continue
            if cur == len(req.prompt) - 1:
                self._prompt_cursor[i] = cur + 1       # prompt done
            req.generated.append(int(nxt[i]))
            if len(req.generated) >= req.max_new:
                req.done = True
                self.finished[req.rid] = req
                self.slots[i] = None

    def run(self, max_steps: int = 1000) -> Dict[int, Request]:
        steps = 0
        while (self.pending or self._active()) and steps < max_steps:
            self.step()
            steps += 1
        return self.finished
