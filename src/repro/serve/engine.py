"""Batched serving engine: continuous batching over fixed decode slots.

Requests are admitted through the scheduler's arrival queue (bounded —
admission control sheds load past ``max_pending`` and refuses shapes that
cannot fit a slot); every engine step decodes one token for all active slots
(a single jitted decode_step).  Slots refill *mid-run* the step after they
drain — the cache tracks a per-sequence position vector (``cache["len"]`` is
``(B,)``), so one slot's readmission never disturbs its neighbours and never
resurrects stale KV rows (the freed slot's cache rows are zeroed before
reuse).  ``mode="static"`` keeps the old wave-batching behaviour as a
measurable baseline.  Prompt ingestion reuses the decode path token-by-token
(teacher-forcing the prompt) — exact and cache-consistent; the virtual-time
``scheduler.simulate_serve`` models the fused chunked prefill a production
deployment would run.
"""
from __future__ import annotations

import copy
import dataclasses
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..config import ModelConfig, RunConfig, resolve_run_config
from ..core.policy import OperatingPoint, PolicyTable
from ..models.model import decode_step, init_cache
from .scheduler import (AdmissionControl, ContinuousScheduler, HostDispatch,
                        ServeReport, ServeSLO, StepCostModel, build_report)

Pytree = Any


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    generated: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Continuous-batching engine.

    The execution policy is resolved per workload at startup through
    :func:`repro.config.resolve_run_config`: an explicit ``operating_point``
    wins, a caller-pinned (non-default) ``rc.policy`` stays authoritative,
    and otherwise the calibration-backed
    :class:`~repro.core.policy.PolicyTable` (``policy_table`` or the
    process-wide default honouring ``REPRO_CALIBRATION_DIR``) supplies the
    ``"serve"`` workload's point, falling back to the paper's defaults when
    no artifact exists.  A ``traffic`` level ("low"/"medium"/"high") selects
    the artifact's per-traffic ``serve-slo`` point when the calibration
    carries one (schema v5).  The resolved policy is threaded into the
    engine's :class:`RunConfig` so every kernel the decode path reaches sees
    it; the resolution itself never touches the per-step hot path.

    Batch sizing is cluster-aware: with ``batch_slots=None`` the engine
    sizes its decode batch as ``SLOTS_PER_CORE * n_cores`` from the
    resolved operating point — an N-PE cluster sustains N concurrent
    per-core token streams, so the continuous batch scales with the
    calibrated cluster width instead of implicitly assuming one PE.  An
    explicit ``batch_slots`` always wins.

    Request lifecycle and accounting live in
    :class:`~repro.serve.scheduler.ContinuousScheduler`; :meth:`metrics`
    turns the recorded timestamps into p50/p99 latency and energy-per-token
    through the operating point's :class:`StepCostModel`.
    """

    #: decode slots the batch allocates per cluster core (one PE's worth of
    #: concurrent streams at the paper's operating point)
    SLOTS_PER_CORE = 4

    def __init__(self, params: Pytree, cfg: ModelConfig, rc: RunConfig,
                 batch_slots: Optional[int] = None, max_len: int = 256,
                 greedy: bool = True,
                 operating_point: Optional[OperatingPoint] = None,
                 policy_table: Optional[PolicyTable] = None,
                 mode: str = "continuous", max_pending: int = 64,
                 traffic: Optional[str] = None,
                 cost_model: Optional[StepCostModel] = None,
                 dispatch: Optional[HostDispatch] = None):
        assert cfg.causal, "serving requires an autoregressive model"
        self.params = params
        rc, self.operating_point = resolve_run_config(
            rc, "serve", operating_point, policy_table, traffic=traffic)
        if batch_slots is None:
            batch_slots = self.SLOTS_PER_CORE * max(
                1, self.operating_point.n_cores)
        self.cfg, self.rc = cfg, rc
        self.traffic = traffic
        self.max_len = max_len
        self.greedy = greedy
        self.sched = ContinuousScheduler(
            batch_slots, mode=mode,
            admission=AdmissionControl(max_pending=max_pending,
                                       max_total_len=max_len))
        self.requests: Dict[int, Request] = {}
        self.cache = init_cache(cfg, batch_slots, max_len, jnp.dtype(rc.dtype))
        self._step = jax.jit(partial(decode_step, cfg=cfg, rc=rc))
        self._next_rid = 0
        self.finished: Dict[int, Request] = {}
        self._cost = cost_model
        self._dispatch = dispatch
        self._n_steps = 0
        self._clock = 0.0       # cycles when a cost model drives it, else steps
        self._energy = 0.0

    @property
    def slots(self) -> List[Optional[Request]]:
        """Engine-side view of the decode batch: the live :class:`Request`
        per slot (``None`` for free slots)."""
        return [self.requests[s.rid] if s is not None else None
                for s in self.sched.slots]

    def submit(self, prompt: List[int], max_new: int = 16) -> int:
        """Queue a request; raises
        :class:`~repro.serve.scheduler.AdmissionError` when admission
        control sheds it (backpressure — the caller retries later)."""
        rid = self._next_rid
        self.sched.submit(rid, len(prompt), max_new, now=self._clock)
        self._next_rid += 1
        self.requests[rid] = Request(rid, list(prompt), max_new)
        return rid

    def _reset_slot_cache(self, i: int) -> None:
        """Zero slot ``i``'s rows in every cache leaf before reuse: batch is
        axis 1 of every stacked leaf, axis 0 of the per-sequence ``len``
        vector.  This is what makes mid-run refill safe — the readmitted
        slot restarts at position 0 over zeroed KV/state rows while its
        neighbours keep decoding at their own positions."""
        self.cache = {k: (v if v.ndim == 0 else
                          v.at[i].set(0) if v.ndim == 1 else
                          v.at[:, i].set(0))
                      for k, v in self.cache.items()}

    def step(self) -> None:
        """Advance every active slot by one token, refilling freed slots
        from the arrival queue first (continuous batching)."""
        for i, _ in self.sched.refill(self._clock):
            self._reset_slot_cache(i)
        active = self.sched.active()
        if not active:
            return
        tokens = np.zeros((self.sched.n_slots, 1), np.int32)
        for i, sreq in active:
            req = self.requests[sreq.rid]
            cur = sreq.prefill_cursor
            if cur < len(req.prompt):
                tokens[i, 0] = req.prompt[cur]
            elif req.generated:
                tokens[i, 0] = req.generated[-1]
            else:
                tokens[i, 0] = req.prompt[-1]
        logits, self.cache = self._step(self.params, self.cache,
                                        {"tokens": jnp.asarray(tokens)})
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        if self._cost is not None:
            cycles, joules = self._cost.step_cost(self.sched.n_slots, 0)
            if self._dispatch is not None:
                cycles = self._dispatch.step(cycles, self._clock)
            dt, self._energy = cycles, self._energy + joules
        else:
            dt = 1.0                       # steps domain; metrics() converts
        end = self._clock + dt
        for i, sreq in active:
            req = self.requests[sreq.rid]
            cur = sreq.prefill_cursor
            if cur < len(req.prompt):
                self.sched.advance_prefill(sreq.rid, 1, end)
                if cur < len(req.prompt) - 1:
                    continue               # still ingesting the prompt
                # the step that fed the last prompt token emitted the first
                # generated token — fall through to record it
            req.generated.append(int(nxt[i]))
            if self.sched.record_token(sreq.rid, end):
                req.done = True
                self.finished[req.rid] = req
        self._clock = end
        self._n_steps += 1

    def run(self, max_steps: int = 1000) -> Dict[int, Request]:
        steps = 0
        while self.sched.busy and steps < max_steps:
            self.step()
            steps += 1
        return self.finished

    def metrics(self, slo: Optional[ServeSLO] = None) -> ServeReport:
        """Per-request serving report (p50/p99 latency, TTFT, J/token,
        SLO attainment) in cycles-equivalent of the resolved operating
        point.  Without an explicit ``cost_model`` the conversion builds one
        lazily from the operating point (timestamps were tracked in engine
        steps; every step costs the full batch width)."""
        if self._cost is not None:
            return build_report(self.sched, self._clock, self._energy,
                                slo=slo, dispatch=self._dispatch,
                                cost_source=self._cost.source)
        cost = StepCostModel.from_operating_point(self.operating_point)
        cps, eps = cost.step_cost(self.sched.n_slots, 0)

        def conv(t: Optional[float]) -> Optional[float]:
            return None if t is None else t * cps

        sched = copy.copy(self.sched)
        sched.requests = {
            rid: dataclasses.replace(
                r, arrival=conv(r.arrival), admit_time=conv(r.admit_time),
                prefill_end=conv(r.prefill_end),
                first_token=conv(r.first_token), finish=conv(r.finish))
            for rid, r in self.sched.requests.items()}
        return build_report(sched, self._n_steps * cps, self._n_steps * eps,
                            slo=slo, dispatch=self._dispatch,
                            cost_source=cost.source)
