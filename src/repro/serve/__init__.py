from .engine import Request, ServeEngine
from .scheduler import (AdmissionControl, AdmissionError,
                        ContinuousScheduler, HostDispatch, ServeReport,
                        ServeSLO, StepCostModel, TraceRequest,
                        simulate_serve)

__all__ = [
    "AdmissionControl", "AdmissionError", "ContinuousScheduler",
    "HostDispatch", "Request", "ServeEngine", "ServeReport", "ServeSLO",
    "StepCostModel", "TraceRequest", "simulate_serve",
]
