from .engine import Request, ServeEngine
from .scheduler import (PREFILL_FRACTION, AdmissionControl, AdmissionError,
                        ContinuousScheduler, HostDispatch, ServeReport,
                        ServeSLO, StepCostModel, TraceRequest,
                        TrafficEstimator, simulate_serve)

__all__ = [
    "AdmissionControl", "AdmissionError", "ContinuousScheduler",
    "HostDispatch", "PREFILL_FRACTION", "Request", "ServeEngine",
    "ServeReport", "ServeSLO", "StepCostModel", "TraceRequest",
    "TrafficEstimator", "simulate_serve",
]
