"""Configuration system: model / shape / run configs for every assigned
architecture (see DESIGN.md §6) plus reduced smoke variants."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from .core.policy import (ExecutionPolicy, OperatingPoint, PolicyTable,
                          default_table)


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int            # hidden dim of each expert


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class SSMConfig:                 # Mamba-1
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None    # defaults to ceil(d_model/16)


@dataclass(frozen=True)
class RGLRUConfig:               # RecurrentGemma recurrent block
    lru_width: Optional[int] = None  # defaults to d_model
    conv_width: int = 4
    window: int = 2048               # local-attention window of attn layers
    pattern: Tuple[str, ...] = ("rec", "rec", "attn")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None       # defaults to d_model // n_heads
    ffn_act: str = "swiglu"              # swiglu | relu2 | gelu
    causal: bool = True                  # encoder-only archs set False
    rope: bool = True
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    frontend: Optional[str] = None       # None | "vision" | "audio" (stubs)
    n_frontend_tokens: int = 0           # patches/frames replacing prefix ids
    max_seq_len: int = 524_288

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks), for roofline
        MODEL_FLOPS accounting."""
        d, L = self.d_model, self.n_layers
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        hd = self.resolved_head_dim
        if self.ssm is not None:
            d_in = self.ssm.expand * d
            dtr = self.ssm.dt_rank or -(-d // 16)
            per_layer = (d * d_in * 2          # in_proj (x and z)
                         + d_in * self.ssm.d_conv
                         + d_in * (dtr + 2 * self.ssm.d_state)
                         + dtr * d_in
                         + d_in * self.ssm.d_state   # A
                         + d_in * d)           # out_proj
        else:
            if self.mla is not None:
                m = self.mla
                qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
                per_layer += (d * m.q_lora_rank
                              + m.q_lora_rank * self.n_heads * qk_head
                              + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                              + m.kv_lora_rank * self.n_heads
                              * (m.qk_nope_head_dim + m.v_head_dim)
                              + self.n_heads * m.v_head_dim * d)
            else:
                per_layer += (d * self.n_heads * hd
                              + 2 * d * self.n_kv_heads * hd
                              + self.n_heads * hd * d)
            if self.moe is not None:
                e = self.moe
                per_layer += d * e.num_experts            # router
                mult = 3 if self.ffn_act == "swiglu" else 2
                per_layer += e.num_experts * mult * d * e.d_ff_expert
            else:
                mult = 3 if self.ffn_act == "swiglu" else 2
                per_layer += mult * d * self.d_ff
        if self.rglru is not None:
            # mixture of recurrent and local-attention layers
            r = self.rglru
            w = r.lru_width or d
            n_attn = sum(1 for i in range(L)
                         if r.pattern[i % len(r.pattern)] == "attn")
            n_rec = L - n_attn
            rec_layer = d * w * 2 + w * r.conv_width + 2 * w + w * d
            attn_layer = (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                          + self.n_heads * hd * d)
            mult = 3 if self.ffn_act == "swiglu" else 2
            ffn = mult * d * self.d_ff
            return total + n_rec * (rec_layer + ffn) + n_attn * (attn_layer + ffn)
        return total + L * per_layer

    def n_active_params(self) -> int:
        """Active params per token (= n_params for dense; top-k experts for
        MoE) — used for MODEL_FLOPS = 6·N_active·D."""
        if self.moe is None:
            return self.n_params()
        e = self.moe
        mult = 3 if self.ffn_act == "swiglu" else 2
        expert_p = mult * self.d_model * e.d_ff_expert
        inactive = self.n_layers * (e.num_experts - e.top_k) * expert_p
        return self.n_params() - inactive


@dataclass(frozen=True)
class ShapeConfig:
    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    mode: str                    # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class RunConfig:
    policy: ExecutionPolicy = ExecutionPolicy.COPIFTV2
    dtype: str = "bfloat16"          # activation/computation dtype
    param_dtype: str = "float32"
    remat: bool = True               # activation checkpointing per block
    fsdp: bool = False               # shard params/opt-state over 'data'
    microbatch: int = 0              # >0: gradient accumulation steps
    grad_compression: bool = False   # int8 stochastic-rounded grad allreduce
    attn_batch_shard: bool = False   # shard attention activations' batch dim
    #   over (data, model) jointly: when heads %% TP != 0 (granite 24H,
    #   minicpm 40H) the S^2 score tensors are otherwise UNSHARDED on the
    #   model axis (EXPERIMENTS.md §Perf hillclimb)
    moe_dispatch: str = "dense"      # "dense" (exact reference: every token
    #   through every expert, masked) | "grouped" (capacity-bounded dispatch,
    #   the deployable path matching kernels/moe_gemm)
    analysis_mode: bool = False      # dry-run roofline accounting: unroll all
    #   loops (layers, seq chunks, attention blocks) so XLA cost_analysis —
    #   which counts while-loop bodies ONCE — reports true totals
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    seed: int = 0


#: the RunConfig.policy field default — used to detect caller-pinned policies
_DEFAULT_RC_POLICY = RunConfig.__dataclass_fields__["policy"].default


def resolve_run_config(rc: RunConfig, workload: str,
                       operating_point: Optional[OperatingPoint] = None,
                       policy_table: Optional[PolicyTable] = None,
                       queue_latency: Optional[int] = None,
                       traffic: Optional[str] = None
                       ) -> Tuple[RunConfig, OperatingPoint]:
    """Resolve ``workload``'s operating point once, at startup, and thread
    its policy into the run config.

    Precedence: an explicit ``operating_point`` wins verbatim; a
    caller-pinned ``rc.policy`` (any value other than the RunConfig field
    default) stays authoritative while the calibrated queue geometry still
    applies; otherwise the calibration-backed table (``policy_table`` or
    the process default honouring ``REPRO_CALIBRATION_DIR``) supplies the
    whole point, falling back to the paper's hard-coded defaults when no
    artifact exists.  ``queue_latency`` pins the machine's queue-visibility
    latency class for schema-v4 per-class selections (defaulting to the
    workload's ``WORKLOAD_QUEUE_LATENCIES`` entry, the global selection for
    classes the calibration never swept).  ``traffic`` pins an offered-load
    level (:data:`repro.core.policy.TRAFFIC_LEVELS`) for schema-v5
    per-traffic ``serve-slo`` selections — it wins over the latency class
    when the artifact carries one for that level."""
    table = policy_table if policy_table is not None else default_table()
    if operating_point is not None:
        op = table.resolve(workload, override=operating_point)
    elif rc.policy is not _DEFAULT_RC_POLICY:
        op = table.resolve(workload, queue_latency=queue_latency,
                           traffic=traffic, policy=rc.policy)
    else:
        op = table.resolve(workload, queue_latency=queue_latency,
                           traffic=traffic)
    return dataclasses.replace(rc, policy=op.policy), op


def supported_shapes(cfg: ModelConfig) -> List[str]:
    """Which of the four canonical shapes an architecture runs (DESIGN.md §6
    skip rules): long_500k needs sub-quadratic attention; encoder-only archs
    have no autoregressive decode."""
    out = ["train_4k", "prefill_32k"]
    if cfg.causal:
        out.append("decode_32k")
        if cfg.family in ("ssm", "hybrid"):
            out.append("long_500k")
    return out
