"""Front-guided adaptive search over DSE grids (successive halving).

The exhaustive sweep (``core.sweep.run_sweep``) is the ground truth, but at
million-point scale even the batch engine spends most of its time on points
that are nowhere near the Pareto front.  :func:`adaptive_sweep` runs the
grid through a *fidelity ladder*: every point is first simulated at a
reduced sample count (``n_samples // divisor``), the running per-kernel
Pareto fronts are extracted from those coarse records, and only points
within a **dominance tolerance** of their kernel's front advance to the
next rung — the final rung re-simulates the survivors at full fidelity, so
every returned record is exact.  Low-fidelity IPC/energy are biased
estimates of their full-fidelity values; the tolerance is the slack that
absorbs that bias, and the exhaustive sweep stays available as a
differential oracle (``benchmarks/sweep_scale.py`` gates the recovered
front against it on a slice of the grid).

Pruning is *sound-by-construction* for everything the coarse rung cannot
rank: points that come back ``rejected`` or ``deadlock`` at reduced
fidelity advance automatically (a small ``n_samples`` can break lowering
preconditions that hold at full size), so adaptive search only ever drops
points it has positively measured as eps-dominated.

:func:`run_search` is the strategy dispatcher used by ``calibrate`` and
``examples/explore.py``; it returns ``(records, meta)`` where ``meta``
records the strategy and fidelity provenance that calibration artifacts
embed.
"""
from __future__ import annotations

import dataclasses
import operator
from typing import Dict, List, Optional, Sequence, Tuple

from .metrics import group_by
from .pareto import pareto_front
from .sweep import STRATEGIES, SweepPoint, SweepRecord, run_sweep

#: default relative dominance slack for pruning (10% on both axes)
DEFAULT_TOLERANCE = 0.10
#: default fidelity ladder: ``n_samples`` divisors per rung, last must be 1
DEFAULT_LADDER: Tuple[int, ...] = (8, 1)


def eps_dominated(rec: SweepRecord, front: Sequence[SweepRecord],
                  tolerance: float, maximize: str = "ipc",
                  minimize: str = "energy") -> bool:
    """True if some front member still dominates ``rec`` after its own
    advantage is shrunk by ``tolerance`` on both (relative) axes.

    With ``tolerance=0`` this is plain Pareto dominance; larger tolerances
    keep a band of near-front points alive (front members themselves are
    never eps-dominated, since shrinking makes the comparison strict)."""
    g, c = getattr(rec, maximize), getattr(rec, minimize)
    for f in front:
        fg = getattr(f, maximize) * (1.0 - tolerance)
        fc = getattr(f, minimize) * (1.0 + tolerance)
        if fg >= g and fc <= c and (fg > g or fc < c):
            return True
    return False


def front_matches(candidate: Sequence[SweepRecord],
                  reference: Sequence[SweepRecord],
                  tolerance: float = DEFAULT_TOLERANCE,
                  maximize: str = "ipc",
                  minimize: str = "energy") -> Tuple[bool, float]:
    """Does ``candidate`` cover ``reference`` within ``tolerance``?

    For every reference-front member there must be a candidate member whose
    gain is within ``tolerance`` (relative) below it and whose cost is
    within ``tolerance`` above it.  Returns ``(ok, worst_slack)`` where
    ``worst_slack`` is the largest relative shortfall over the reference
    members (0.0 = exact cover; ``inf`` when ``candidate`` is empty but
    ``reference`` is not).  Fronts are per-kernel objects — compare slices
    of the same kernel (e.g. via ``pareto.pareto_by_kernel``)."""
    worst = 0.0
    for r in reference:
        rg, rc = getattr(r, maximize), getattr(r, minimize)
        best: Optional[float] = None
        for cand in candidate:
            cg, cc = getattr(cand, maximize), getattr(cand, minimize)
            sg = 0.0 if cg >= rg else ((rg - cg) / rg if rg else float("inf"))
            sc = 0.0 if cc <= rc else ((cc - rc) / rc if rc else float("inf"))
            s = max(sg, sc)
            best = s if best is None else min(best, s)
        worst = max(worst, best if best is not None else float("inf"))
    return worst <= tolerance, worst


def scale_fidelity(pt: SweepPoint, divisor: int) -> SweepPoint:
    """``pt`` at reduced fidelity: ``n_samples`` divided by ``divisor`` and
    rounded up to a lowering-feasible multiple (unroll x cores), so coarse
    rungs reject only what full fidelity would also reject."""
    if divisor <= 1:
        return pt
    step = max(pt.unroll, pt.unroll_int or 1) * max(1, pt.n_cores)
    n = max(1, pt.n_samples // divisor)
    n = -(-n // step) * step                    # ceil to a feasible multiple
    if n >= pt.n_samples:
        return pt
    return dataclasses.replace(pt, n_samples=n)


def _validate_ladder(ladder: Sequence[int]) -> Tuple[int, ...]:
    lad = tuple(int(d) for d in ladder)
    if (not lad or lad[-1] != 1 or any(d < 1 for d in lad)
            or any(a <= b for a, b in zip(lad, lad[1:]))):
        raise ValueError(
            f"fidelity_ladder must be strictly decreasing divisors ending "
            f"at 1 (full fidelity), got {tuple(ladder)}")
    return lad


def adaptive_sweep(points: Sequence[SweepPoint], *,
                   tolerance: float = DEFAULT_TOLERANCE,
                   fidelity_ladder: Sequence[int] = DEFAULT_LADDER,
                   workers: Optional[int] = None,
                   maximize: str = "ipc",
                   minimize: str = "energy"
                   ) -> Tuple[List[SweepRecord], Dict]:
    """Front-guided successive halving over ``points``.

    Returns ``(records, meta)``: full-fidelity records for the points that
    survived every pruning rung (in input order — a subsequence of what the
    exhaustive sweep would return), and a provenance dict (strategy,
    tolerance, ladder, per-rung evaluated/survivor counts) for calibration
    artifacts.  The per-kernel Pareto fronts over ``records`` match the
    exhaustive fronts whenever the coarse-fidelity bias stays within
    ``tolerance`` (gated against the exhaustive oracle in
    ``benchmarks/sweep_scale.py``)."""
    if not 0.0 <= tolerance < 1.0:
        raise ValueError(f"tolerance must be in [0, 1), got {tolerance}")
    ladder = _validate_ladder(fidelity_ladder)
    points = list(points)
    survivors = list(range(len(points)))
    rungs: List[Dict] = []
    records: List[SweepRecord] = []
    for divisor in ladder:
        scaled = [scale_fidelity(points[i], divisor) for i in survivors]
        recs = run_sweep(scaled, workers=workers)
        if divisor == 1:
            records = recs
            rungs.append({"divisor": 1, "evaluated": len(survivors),
                          "survivors": len(survivors)})
            break
        fronts = {k: pareto_front(rs, maximize, minimize)
                  for k, rs in group_by(
                      (r for r in recs if r.ok),
                      operator.attrgetter("kernel")).items()}
        keep = [i for i, rec in zip(survivors, recs)
                if not rec.ok               # unrankable at this fidelity
                or not eps_dominated(rec, fronts[rec.kernel], tolerance,
                                     maximize, minimize)]
        rungs.append({"divisor": divisor, "evaluated": len(survivors),
                      "survivors": len(keep)})
        survivors = keep
    meta = {
        "strategy": "adaptive",
        "tolerance": tolerance,
        "fidelity_ladder": list(ladder),
        "maximize": maximize,
        "minimize": minimize,
        "n_points": len(points),
        "n_full_fidelity": len(survivors),
        "rungs": rungs,
    }
    return records, meta


def run_search(points: Sequence[SweepPoint], *,
               strategy: str = "exhaustive",
               workers: Optional[int] = None,
               **search_kw) -> Tuple[List[SweepRecord], Dict]:
    """Strategy dispatcher: evaluate ``points`` and return
    ``(records, meta)``.  ``"exhaustive"`` runs every point (the
    differential oracle); ``"adaptive"`` prunes via
    :func:`adaptive_sweep` (keyword arguments ``tolerance`` /
    ``fidelity_ladder`` / ``maximize`` / ``minimize`` pass through)."""
    if strategy not in STRATEGIES:
        raise ValueError(
            f"unknown strategy {strategy!r} (have {STRATEGIES})")
    if strategy == "adaptive":
        return adaptive_sweep(points, workers=workers, **search_kw)
    if search_kw:
        raise TypeError(
            f"unexpected arguments for exhaustive search: "
            f"{sorted(search_kw)}")
    records = run_sweep(points, workers=workers)
    return records, {"strategy": "exhaustive", "n_points": len(records)}
