"""Mixed integer/FP benchmark kernels (the suite of COPIFT [1], Fig. 3).

The paper evaluates COPIFTv2 on "a set of mixed integer and FP codes
presented in [1]"; the figure names ``exp`` and ``poly lcg`` explicitly.  We
reconstruct a representative suite (see DESIGN.md §3.1): each kernel is a
LoopDFG whose integer/FP instruction mix matches the workload class —
math-library range reduction (exp/log), LCG-fed polynomial evaluation,
int8 dequantization dot products, Box–Muller sampling and FP histogramming.

Every node carries a concrete ``fn`` so the machine model doubles as an
interpreter: tests assert that COPIFT/COPIFTv2 lowerings compute exactly the
same outputs as the sequential baseline.
"""
from __future__ import annotations

import math
import struct
from typing import Dict

from .dfg import LoopDFG, Node, s
from .isa import OpKind, Unit

LN2 = 0.6931471805599453
INV_LN2 = 1.4426950408889634
_M52 = (1 << 52) - 1


def _f2b(x: float) -> int:
    return struct.unpack("<Q", struct.pack("<d", x))[0]


def _b2f(b: int) -> float:
    return struct.unpack("<d", struct.pack("<Q", b & ((1 << 64) - 1)))[0]


# ---------------------------------------------------------------------------
_MAGIC = 6755399441055744.0          # 1.5 * 2^52: round-to-nearest-int trick
_INF_BITS = 0x7FF0000000000000


def make_expf() -> LoopDFG:
    """exp(x) by range reduction: x = k·ln2 + r; e^r by a polynomial; 2^k
    built by integer bit manipulation with full underflow/overflow handling
    (the "integer phase" of Fig. 1b).  kf is recovered on the FPSS via the
    magic-number rounding trick, so only k crosses F2I and only the final
    2^k bit pattern crosses I2F — production Snitch expf avoids round trips
    exactly this way."""
    nodes = [
        Node("t", OpKind.FMUL, (s("x"),), fn=lambda x: x * INV_LN2),
        Node("tm", OpKind.FADD, (s("t"),), fn=lambda t: t + _MAGIC),
        Node("kf", OpKind.FADD, (s("tm"),), fn=lambda tm: tm - _MAGIC),
        Node("k", OpKind.CVT_F2I, (s("tm"),),
             fn=lambda tm: int(tm - _MAGIC)),
        # --- integer thread: clamp, under/overflow guards, 2^k pattern -----
        Node("klo", OpKind.IALU, (s("k"),), fn=lambda k: max(k, -1022)),
        Node("kcl", OpKind.IALU, (s("klo"),), fn=lambda k: min(k, 1024)),
        Node("e", OpKind.IALU, (s("kcl"),), fn=lambda k: k + 1023),
        Node("b0", OpKind.IALU, (s("e"),), fn=lambda e: (e << 52) & ((1 << 63) - 1)),
        Node("gz", OpKind.IALU, (s("kcl"),),
             fn=lambda k: -1 if k > -1022 else 0),          # underflow mask
        Node("ovf", OpKind.IALU, (s("kcl"),),
             fn=lambda k: _INF_BITS if k >= 1024 else 0),   # overflow -> inf
        Node("b1", OpKind.IALU, (s("b0"), s("gz")), fn=lambda b, g: b & g),
        Node("bits", OpKind.IALU, (s("b1"), s("ovf")), fn=lambda b, o: b | o),
        # --- FP thread ------------------------------------------------------
        Node("r", OpKind.FMA, (s("kf"), s("x")), fn=lambda kf, x: x - kf * LN2),
        Node("p1", OpKind.FMA, (s("r"),), fn=lambda r: r / 24.0 + 1.0 / 6.0),
        Node("p2", OpKind.FMA, (s("p1"), s("r")), fn=lambda p, r: p * r + 0.5),
        Node("p3", OpKind.FMA, (s("p2"), s("r")), fn=lambda p, r: p * r + 1.0),
        Node("p4", OpKind.FMA, (s("p3"), s("r")), fn=lambda p, r: p * r + 1.0),
        Node("sc", OpKind.CVT_I2F, (s("bits"),),
             fn=lambda b: math.inf if b == _INF_BITS
             else (0.0 if b == 0 else 2.0 ** ((b >> 52) - 1023))),
        Node("y", OpKind.FMUL, (s("p4"), s("sc")), fn=lambda p, sc: p * sc,
             out=True),
    ]
    return LoopDFG("expf", nodes,
                   inputs={"x": lambda i: -8.0 + (i % 41) * 0.4},
                   input_homes={"x": Unit.FP})


# ---------------------------------------------------------------------------
def make_logf() -> LoopDFG:
    """log(x): integer thread loads raw IEEE-754 bits and extracts
    exponent/mantissa; FP thread evaluates log1p on the mantissa."""
    def data(i: int) -> float:
        return 0.5 + (i % 97) * 0.37

    nodes = [
        Node("addr", OpKind.IALU, (s("addr", 1),), fn=lambda a: a + 8),
        Node("xb", OpKind.LW, (s("addr"),), fn=lambda a: _f2b(data(a // 8))),
        Node("eraw", OpKind.IALU, (s("xb"),), fn=lambda b: (b >> 52) & 0x7FF),
        Node("eunb", OpKind.IALU, (s("eraw"),), fn=lambda e: e - 1023),
        Node("mbits", OpKind.IALU, (s("xb"),),
             fn=lambda b: (b & _M52) | (1023 << 52)),
        Node("mf", OpKind.CVT_I2F, (s("mbits"),), fn=lambda b: _b2f(b)),
        Node("u", OpKind.FADD, (s("mf"),), fn=lambda m: m - 1.0),
        Node("q1", OpKind.FMA, (s("u"),), fn=lambda u: 0.2 * u - 0.25),
        Node("q2", OpKind.FMA, (s("q1"), s("u")), fn=lambda q, u: q * u + 1.0 / 3.0),
        Node("q3", OpKind.FMA, (s("q2"), s("u")), fn=lambda q, u: q * u - 0.5),
        Node("q4", OpKind.FMA, (s("q3"), s("u")), fn=lambda q, u: q * u + 1.0),
        Node("q5", OpKind.FMUL, (s("q4"), s("u")), fn=lambda q, u: q * u),
        Node("ef", OpKind.CVT_I2F, (s("eunb"),), fn=float),
        Node("y", OpKind.FMA, (s("ef"), s("q5")),
             fn=lambda ef, q: ef * LN2 + q, out=True),
    ]
    return LoopDFG("logf", nodes, init={"addr": -8})


# ---------------------------------------------------------------------------
def make_poly_lcg() -> LoopDFG:
    """Polynomial over LCG-generated pseudo-random inputs ("poly lcg").
    The LCG is a *serial* integer dependency chain — the kernel where
    COPIFT's spill loads/stores help balance the threads (paper §III)."""
    nodes = [
        Node("st1", OpKind.IMUL, (s("st", 1),),
             fn=lambda v: (v * 1103515245) & 0xFFFFFFFF),
        Node("st", OpKind.IALU, (s("st1"),),
             fn=lambda v: (v + 12345) & 0x7FFFFFFF),
        Node("u", OpKind.IALU, (s("st"),), fn=lambda v: v >> 7),
        Node("xf", OpKind.CVT_I2F, (s("u"),), fn=lambda u: u * 2.0 ** -24),
        Node("h1", OpKind.FMA, (s("xf"),), fn=lambda x: -0.1187 * x + 0.4312),
        Node("h2", OpKind.FMA, (s("h1"), s("xf")), fn=lambda h, x: h * x - 0.8901),
        Node("h3", OpKind.FMA, (s("h2"), s("xf")), fn=lambda h, x: h * x + 1.4142),
        Node("h4", OpKind.FMA, (s("h3"), s("xf")), fn=lambda h, x: h * x - 0.5772),
        Node("h5", OpKind.FMA, (s("h4"), s("xf")),
             fn=lambda h, x: h * x + 0.9159, out=True),
    ]
    return LoopDFG("poly_lcg", nodes, init={"st": 42})


# ---------------------------------------------------------------------------
def make_dequant_dot() -> LoopDFG:
    """int16-packed dequantization feeding a two-lane FP accumulator — the
    Turing-style INT/FP co-execution pattern; near-balanced threads."""
    def packed(i: int) -> int:
        return (((i * 37) % 1024) << 16) | ((i * 53) % 1024)

    nodes = [
        Node("addr", OpKind.IALU, (s("addr", 1),), fn=lambda a: a + 4),
        Node("pk", OpKind.LW, (s("addr"),), fn=lambda a: packed(a // 4)),
        Node("a0", OpKind.IALU, (s("pk"),), fn=lambda p: (p >> 16) & 0xFFFF),
        Node("a1", OpKind.IALU, (s("pk"),), fn=lambda p: p & 0xFFFF),
        Node("z0", OpKind.IALU, (s("a0"),), fn=lambda v: v - 512),
        Node("z1", OpKind.IALU, (s("a1"),), fn=lambda v: v - 512),
        Node("f0", OpKind.CVT_I2F, (s("z0"),), fn=float),
        Node("f1", OpKind.CVT_I2F, (s("z1"),), fn=float),
        Node("s0", OpKind.FMUL, (s("f0"),), fn=lambda x: x * 0.0078125),
        Node("s1", OpKind.FMUL, (s("f1"),), fn=lambda x: x * 0.0078125),
        Node("acc0", OpKind.FMA, (s("s0"), s("acc0", 1)),
             fn=lambda x, a: a + x, out=True),
        Node("acc1", OpKind.FMA, (s("s1"), s("acc1", 1)),
             fn=lambda x, a: a + x, out=True),
    ]
    return LoopDFG("dequant_dot", nodes, init={"addr": -4, "acc0": 0.0, "acc1": 0.0})


# ---------------------------------------------------------------------------
def make_box_muller() -> LoopDFG:
    """Box–Muller-style sampling: LCG + polynomial -2·ln(u) approximation +
    a *blocking* fsqrt — the low-ILP case (dual-issue gains are small)."""
    nodes = [
        Node("st1", OpKind.IMUL, (s("st", 1),),
             fn=lambda v: (v * 1103515245) & 0xFFFFFFFF),
        Node("st", OpKind.IALU, (s("st1"),),
             fn=lambda v: (v + 12345) & 0x7FFFFFFF),
        Node("u1", OpKind.IALU, (s("st"),), fn=lambda v: (v >> 8) | 1),
        Node("uf", OpKind.CVT_I2F, (s("u1"),), fn=lambda u: u * 2.0 ** -23),
        Node("l1", OpKind.FMA, (s("uf"),), fn=lambda u: -0.8 * u + 2.1),
        Node("l2", OpKind.FMA, (s("l1"), s("uf")), fn=lambda l, u: l * u - 3.4),
        Node("l3", OpKind.FMA, (s("l2"), s("uf")), fn=lambda l, u: l * u + 3.9),
        Node("rt", OpKind.FSQRT, (s("l3"),), fn=math.sqrt),
        Node("ang", OpKind.FMUL, (s("uf"),), fn=lambda u: 6.283185307 * u),
        Node("w1", OpKind.FMA, (s("ang"),), fn=lambda a: -0.4967 * a + 0.03705),
        Node("w2", OpKind.FMA, (s("w1"), s("ang")), fn=lambda w, a: w * a + 1.0),
        Node("z", OpKind.FMUL, (s("rt"), s("w2")),
             fn=lambda r, w: r * w, out=True),
    ]
    return LoopDFG("box_muller", nodes, init={"st": 7777})


# ---------------------------------------------------------------------------
def make_cluster_matmul() -> LoopDFG:
    """int8-quantized matmul micro-tile: two packed operand loads, integer
    unpack, FP dequantize (zero-point + scale folded into the FP thread) and
    a two-lane accumulator.  Strictly one-directional (int -> fp, four I2F
    crossings per sample) with an integer half (~11 instrs) balancing the FP
    half (12 instrs) — the cluster *pipeline* target: a producer core
    streams unpacked operands through an inter-core channel to a consumer
    core running the FP stream (``transform.partition_pipeline``)."""
    def packed_a(i: int) -> int:
        return (((i * 37) % 256) << 16) | ((i * 59) % 256)

    def packed_b(i: int) -> int:
        return (((i * 41) % 256) << 16) | ((i * 67) % 256)

    nodes = [
        Node("addr", OpKind.IALU, (s("addr", 1),), fn=lambda a: a + 4),
        Node("pa", OpKind.LW, (s("addr"),), fn=lambda a: packed_a(a // 4)),
        Node("pb", OpKind.LW, (s("addr"),), fn=lambda a: packed_b(a // 4)),
        Node("a0", OpKind.IALU, (s("pa"),), fn=lambda p: (p >> 16) & 0xFFFF),
        Node("a1", OpKind.IALU, (s("pa"),), fn=lambda p: p & 0xFFFF),
        Node("b0", OpKind.IALU, (s("pb"),), fn=lambda p: (p >> 16) & 0xFFFF),
        Node("b1", OpKind.IALU, (s("pb"),), fn=lambda p: p & 0xFFFF),
        Node("fa0", OpKind.CVT_I2F, (s("a0"),), fn=float),
        Node("fa1", OpKind.CVT_I2F, (s("a1"),), fn=float),
        Node("fb0", OpKind.CVT_I2F, (s("b0"),), fn=float),
        Node("fb1", OpKind.CVT_I2F, (s("b1"),), fn=float),
        Node("za0", OpKind.FADD, (s("fa0"),), fn=lambda x: x - 128.0),
        Node("za1", OpKind.FADD, (s("fa1"),), fn=lambda x: x - 128.0),
        Node("zb0", OpKind.FADD, (s("fb0"),), fn=lambda x: x - 128.0),
        Node("zb1", OpKind.FADD, (s("fb1"),), fn=lambda x: x - 128.0),
        Node("p0", OpKind.FMUL, (s("za0"), s("zb0")), fn=lambda x, y: x * y),
        Node("p1", OpKind.FMUL, (s("za1"), s("zb1")), fn=lambda x, y: x * y),
        Node("acc0", OpKind.FMA, (s("p0"), s("acc0", 1)),
             fn=lambda x, a: a + x * 0.0078125, out=True),
        Node("acc1", OpKind.FMA, (s("p1"), s("acc1", 1)),
             fn=lambda x, a: a + x * 0.0078125, out=True),
    ]
    return LoopDFG("cluster_matmul", nodes,
                   init={"addr": -4, "acc0": 0.0, "acc1": 0.0})


# ---------------------------------------------------------------------------
def make_histf() -> LoopDFG:
    """FP histogramming: FP thread scales/converts, integer thread updates
    bins — the F2I-dominant direction."""
    nodes = [
        Node("t", OpKind.FMUL, (s("x"),), fn=lambda x: x * 64.0),
        Node("k", OpKind.CVT_F2I, (s("t"),),
             fn=lambda t: min(63, max(0, int(t)))),
        Node("sh", OpKind.IALU, (s("k"),), fn=lambda k: k << 3),
        Node("ad", OpKind.IALU, (s("sh"),), fn=lambda v: 4096 + v),
        Node("cnt", OpKind.LW, (s("ad"),), fn=lambda a: 0),
        Node("inc", OpKind.IALU, (s("cnt"),), fn=lambda c: c + 1),
        Node("upd", OpKind.SW, (s("ad"), s("inc")),
             fn=lambda a, v: (a, v), out=True),
    ]
    return LoopDFG("histf", nodes,
                   inputs={"x": lambda i: ((i * 7) % 64) / 64.0 + 1e-4},
                   input_homes={"x": Unit.FP})


KERNELS: Dict[str, LoopDFG] = {}
for _mk in (make_expf, make_logf, make_poly_lcg, make_dequant_dot,
            make_cluster_matmul, make_box_muller, make_histf):
    _k = _mk()
    KERNELS[_k.name] = _k
