"""Cycle-approximate model of Snitch + FPSS + COPIFTv2 queues.

Two in-order issue units (the integer core and the FPSS), each issuing at
most one instruction per cycle.  In ``single`` mode (the Snitch baseline) a
single shared issue port models the integer core fetching *all* instructions
and offloading FP ones to the FPSS; in ``dual`` mode (COPIFT / COPIFTv2) the
FPSS replays its FREP loop buffer independently, so both units issue
concurrently — IPC is bounded by 2.

Queues have finite depth with blocking push/pop semantics: a pop stalls the
consuming unit until the head entry is visible; a push stalls the producer
while the queue is full.  Stalls, overlap and IPC *emerge* from the model;
nothing is hard-coded per policy.

The simulator doubles as a functional interpreter: when instructions carry
``fn``, values flow through registers, queues and memory channels, letting
tests assert that every transform preserves the kernel's semantics.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from .isa import E_STATIC_PER_CYCLE, Instr, Queue, Unit
from .policy import ExecutionPolicy


@dataclass
class MachineConfig:
    queue_depth: int = 4
    queue_latency: int = 1          # cycles from producer completion to visibility
    evaluate: bool = True           # run the functional interpreter too
    deadlock_limit: int = 20_000    # cycles without progress => deadlock


@dataclass
class Program:
    name: str
    policy: ExecutionPolicy
    mode: str                        # "single" | "dual"
    streams: Dict[Unit, List[Instr]]
    n_samples: int
    init_env: Dict[str, Any] = field(default_factory=dict)
    output_values: List[str] = field(default_factory=list)  # SSA ids
    frep: bool = False               # FP stream replayed from the loop buffer

    def total_instrs(self) -> int:
        return sum(len(v) for v in self.streams.values())


@dataclass
class SimResult:
    name: str
    policy: ExecutionPolicy
    cycles: int
    n_samples: int
    instrs: Dict[str, int]
    energy: float
    env: Dict[str, Any]
    push_seq: Dict[Queue, List[str]]
    pop_seq: Dict[Queue, List[str]]
    max_queue_occupancy: Dict[Queue, int]
    fifo_violations: List[Tuple[str, str, str, str]] = field(default_factory=list)

    @property
    def total_instrs(self) -> int:
        return sum(self.instrs.values())

    @property
    def ipc(self) -> float:
        return self.total_instrs / self.cycles

    @property
    def throughput(self) -> float:          # samples / cycle
        return self.n_samples / self.cycles

    @property
    def power(self) -> float:               # energy / cycle (relative units)
        return self.energy / self.cycles

    @property
    def efficiency(self) -> float:          # samples / energy
        return self.n_samples / self.energy

    def outputs(self, output_values: List[str]) -> Dict[str, Any]:
        return {v: self.env.get(v) for v in output_values}


class DeadlockError(RuntimeError):
    pass


def simulate(prog: Program, cfg: Optional[MachineConfig] = None) -> SimResult:
    cfg = cfg or MachineConfig()
    ready: Dict[str, int] = {k: 0 for k in prog.init_env}
    env: Dict[str, Any] = dict(prog.init_env)

    queues: Dict[Queue, deque] = {q: deque() for q in Queue}
    occupancy: Dict[Queue, int] = {q: 0 for q in Queue}       # incl. in-flight
    max_occ: Dict[Queue, int] = {q: 0 for q in Queue}
    push_seq: Dict[Queue, List[str]] = {q: [] for q in Queue}
    pop_seq: Dict[Queue, List[str]] = {q: [] for q in Queue}
    fifo_violations: List[Tuple[str, str, str, str]] = []

    if prog.mode == "single":
        # the lowering merges everything into one stream (the integer core
        # fetches all instructions, offloading FP ones to the FPSS)
        assert len(prog.streams) == 1, "single mode expects one merged stream"
        order: List[Tuple[Unit, List[Instr]]] = list(prog.streams.items())
    else:
        # INT first: gives the integer core priority on shared resources.
        order = [(u, prog.streams[u]) for u in (Unit.INT, Unit.FP) if u in prog.streams]

    pcs = {u: 0 for u, _ in order}
    unit_busy = {Unit.INT: 0, Unit.FP: 0}
    instr_count = {"int": 0, "fp": 0}
    energy = 0.0
    cycle = 0
    last_progress = 0
    finish = 0

    def can_issue(ins: Instr, now: int) -> bool:
        if unit_busy[ins.unit] > now:
            return False
        need: Dict[Queue, int] = {}
        for src in ins.srcs:
            if isinstance(src, Queue):
                k = need.get(src, 0)
                q = queues[src]
                if len(q) <= k or q[k][0] > now:
                    return False
                need[src] = k + 1
            else:
                t = ready.get(src)
                if t is None or t > now:
                    return False
        room: Dict[Queue, int] = {}
        for q in ins.pushes:
            room[q] = room.get(q, 0) + 1
            if occupancy[q] + room[q] > cfg.queue_depth:
                return False
        return True

    def do_issue(ins: Instr, now: int) -> int:
        nonlocal energy
        t_done = now + ins.spec.latency
        opvals = []
        n_pop = 0
        for src in ins.srcs:
            if isinstance(src, Queue):
                _, vname, val = queues[src].popleft()
                occupancy[src] -= 1
                pop_seq[src].append(vname)
                if ins.expects and ins.expects[n_pop] != vname:
                    fifo_violations.append(
                        (ins.label, src.value, ins.expects[n_pop], vname))
                n_pop += 1
                opvals.append(val)
            else:
                opvals.append(env.get(src))
        result = None
        if cfg.evaluate and ins.fn is not None:
            result = ins.fn(*opvals)
        if ins.dst is not None:
            ready[ins.dst] = t_done
            env[ins.dst] = result
        for q in ins.pushes:
            queues[q].append((t_done + cfg.queue_latency, ins.push_val or ins.label, result))
            occupancy[q] += 1
            max_occ[q] = max(max_occ[q], occupancy[q])
            push_seq[q].append(ins.push_val or ins.label)
        if ins.spec.blocking:
            unit_busy[ins.unit] = t_done
        energy += ins.energy(frep=prog.frep and ins.unit is Unit.FP)
        instr_count[ins.unit.value] += 1
        return t_done

    while any(pcs[u] < len(lst) for u, lst in order):
        issued = False
        for u, lst in order:
            pc = pcs[u]
            if pc >= len(lst):
                continue
            ins = lst[pc]
            if can_issue(ins, cycle):
                t_done = do_issue(ins, cycle)
                finish = max(finish, t_done)
                pcs[u] = pc + 1
                issued = True
        if issued:
            last_progress = cycle
        if cycle - last_progress > cfg.deadlock_limit:
            stuck = {u.value: (pcs[u], len(lst), str(lst[pcs[u]]) if pcs[u] < len(lst) else "-")
                     for u, lst in order}
            raise DeadlockError(f"{prog.name}/{prog.policy.value}: no progress; {stuck}")
        cycle += 1

    cycles = max(finish, cycle)
    energy += E_STATIC_PER_CYCLE * cycles
    return SimResult(
        name=prog.name,
        policy=prog.policy,
        cycles=cycles,
        n_samples=prog.n_samples,
        instrs=instr_count,
        energy=energy,
        env=env,
        push_seq=push_seq,
        pop_seq=pop_seq,
        max_queue_occupancy=max_occ,
        fifo_violations=fifo_violations,
    )
