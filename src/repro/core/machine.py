"""Cycle-approximate model of Snitch + FPSS + COPIFTv2 queues.

Two in-order issue units (the integer core and the FPSS), each issuing at
most one instruction per cycle.  In ``single`` mode (the Snitch baseline) a
single shared issue port models the integer core fetching *all* instructions
and offloading FP ones to the FPSS; in ``dual`` mode (COPIFT / COPIFTv2) the
FPSS replays its FREP loop buffer independently, so both units issue
concurrently — IPC is bounded by 2.

Queues have finite depth with blocking push/pop semantics: a pop stalls the
consuming unit until the head entry is visible; a push stalls the producer
while the queue is full.  Stalls, overlap and IPC *emerge* from the model;
nothing is hard-coded per policy.  Every cycle a unit fails to issue is
attributed to one cause (``busy`` / ``dep`` / ``queue_empty`` /
``queue_full``), giving the stall breakdown the DSE sweep reports.

The simulator doubles as a functional interpreter: when instructions carry
``fn``, values flow through registers, queues and memory channels, letting
tests assert that every transform preserves the kernel's semantics.

The whole simulation state lives in :class:`Stepper` — re-entrant, cheap to
instantiate, and independent of any module-level state — so design-space
sweeps (``core.sweep``) can run many simulations concurrently in process-pool
workers.  :func:`simulate` remains the one-shot convenience entry point.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from .isa import E_STATIC_PER_CYCLE, Instr, Queue, Unit
from .policy import ExecutionPolicy


@dataclass
class MachineConfig:
    queue_depth: int = 4
    queue_latency: int = 1          # cycles from producer completion to visibility
    evaluate: bool = True           # run the functional interpreter too
    deadlock_limit: int = 20_000    # cycles without progress => deadlock


@dataclass
class Program:
    name: str
    policy: ExecutionPolicy
    mode: str                        # "single" | "dual"
    streams: Dict[Unit, List[Instr]]
    n_samples: int
    init_env: Dict[str, Any] = field(default_factory=dict)
    output_values: List[str] = field(default_factory=list)  # SSA ids
    frep: bool = False               # FP stream replayed from the loop buffer

    def total_instrs(self) -> int:
        return sum(len(v) for v in self.streams.values())


@dataclass
class SimResult:
    """Simulation outcome.  Everything here is plain data (strings, numbers,
    enums, containers thereof) so a result pickles cleanly across process
    boundaries; ``summary()`` flattens it further into primitives for CSV /
    JSON emission when the (possibly large) ``env`` is not wanted."""
    name: str
    policy: ExecutionPolicy
    cycles: int
    n_samples: int
    instrs: Dict[str, int]
    energy: float
    env: Dict[str, Any]
    push_seq: Dict[Queue, List[str]]
    pop_seq: Dict[Queue, List[str]]
    max_queue_occupancy: Dict[Queue, int]
    fifo_violations: List[Tuple[str, str, str, str]] = field(default_factory=list)
    stalls: Dict[str, int] = field(default_factory=dict)

    @property
    def total_instrs(self) -> int:
        return sum(self.instrs.values())

    @property
    def ipc(self) -> float:
        return self.total_instrs / self.cycles

    @property
    def throughput(self) -> float:          # samples / cycle
        return self.n_samples / self.cycles

    @property
    def power(self) -> float:               # energy / cycle (relative units)
        return self.energy / self.cycles

    @property
    def efficiency(self) -> float:          # samples / energy
        return self.n_samples / self.energy

    def outputs(self, output_values: List[str]) -> Dict[str, Any]:
        return {v: self.env.get(v) for v in output_values}

    def summary(self) -> Dict[str, Any]:
        """Primitive-typed record (no env, no enum keys) for aggregation."""
        return {
            "name": self.name,
            "policy": self.policy.value,
            "cycles": self.cycles,
            "n_samples": self.n_samples,
            "instrs_int": self.instrs.get("int", 0),
            "instrs_fp": self.instrs.get("fp", 0),
            "ipc": self.ipc,
            "energy": self.energy,
            "power": self.power,
            "throughput": self.throughput,
            "efficiency": self.efficiency,
            "max_occ_i2f": self.max_queue_occupancy.get(Queue.I2F, 0),
            "max_occ_f2i": self.max_queue_occupancy.get(Queue.F2I, 0),
            "fifo_violations": len(self.fifo_violations),
            "stalls": dict(self.stalls),
        }


class DeadlockError(RuntimeError):
    pass


#: stall-cause keys recorded by the stepper (per unit: ``f"{unit}_{cause}"``)
STALL_CAUSES = ("busy", "dep", "queue_empty", "queue_full")


class Stepper:
    """Re-entrant cycle stepper for one :class:`Program`.

    All simulation state is instance state; ``step()`` advances one cycle and
    ``run()`` drives the program to completion.  Construction is cheap (a few
    dicts over the program's streams), which is what lets ``core.sweep`` spin
    one up per configuration inside process-pool workers.
    """

    def __init__(self, prog: Program, cfg: Optional[MachineConfig] = None):
        self.prog = prog
        self.cfg = cfg or MachineConfig()
        self.ready: Dict[str, int] = {k: 0 for k in prog.init_env}
        self.env: Dict[str, Any] = dict(prog.init_env)

        self.queues: Dict[Queue, deque] = {q: deque() for q in Queue}
        self.occupancy: Dict[Queue, int] = {q: 0 for q in Queue}  # incl. in-flight
        self.max_occ: Dict[Queue, int] = {q: 0 for q in Queue}
        self.push_seq: Dict[Queue, List[str]] = {q: [] for q in Queue}
        self.pop_seq: Dict[Queue, List[str]] = {q: [] for q in Queue}
        self.fifo_violations: List[Tuple[str, str, str, str]] = []

        if prog.mode == "single":
            # the lowering merges everything into one stream (the integer core
            # fetches all instructions, offloading FP ones to the FPSS)
            assert len(prog.streams) == 1, "single mode expects one merged stream"
            self.order: List[Tuple[Unit, List[Instr]]] = list(prog.streams.items())
        else:
            # INT first: gives the integer core priority on shared resources.
            self.order = [(u, prog.streams[u])
                          for u in (Unit.INT, Unit.FP) if u in prog.streams]

        self.pcs = {u: 0 for u, _ in self.order}
        self.unit_busy = {Unit.INT: 0, Unit.FP: 0}
        self.instr_count = {"int": 0, "fp": 0}
        self.energy = 0.0
        self.cycle = 0
        self.last_progress = 0
        self.finish = 0
        self.stalls: Dict[str, int] = {}

    # -- issue logic --------------------------------------------------------

    def _block_reason(self, ins: Instr, now: int) -> Optional[str]:
        """None if ``ins`` can issue at ``now``; else the first stall cause."""
        if self.unit_busy[ins.unit] > now:
            return "busy"
        need: Dict[Queue, int] = {}
        for src in ins.srcs:
            if isinstance(src, Queue):
                k = need.get(src, 0)
                q = self.queues[src]
                if len(q) <= k or q[k][0] > now:
                    return "queue_empty"
                need[src] = k + 1
            else:
                t = self.ready.get(src)
                if t is None or t > now:
                    return "dep"
        room: Dict[Queue, int] = {}
        for q in ins.pushes:
            room[q] = room.get(q, 0) + 1
            if self.occupancy[q] + room[q] > self.cfg.queue_depth:
                return "queue_full"
        return None

    def _do_issue(self, ins: Instr, now: int) -> int:
        cfg = self.cfg
        t_done = now + ins.spec.latency
        opvals = []
        n_pop = 0
        for src in ins.srcs:
            if isinstance(src, Queue):
                _, vname, val = self.queues[src].popleft()
                self.occupancy[src] -= 1
                self.pop_seq[src].append(vname)
                if ins.expects and ins.expects[n_pop] != vname:
                    self.fifo_violations.append(
                        (ins.label, src.value, ins.expects[n_pop], vname))
                n_pop += 1
                opvals.append(val)
            else:
                opvals.append(self.env.get(src))
        result = None
        if cfg.evaluate and ins.fn is not None:
            result = ins.fn(*opvals)
        if ins.dst is not None:
            self.ready[ins.dst] = t_done
            self.env[ins.dst] = result
        for q in ins.pushes:
            self.queues[q].append(
                (t_done + cfg.queue_latency, ins.push_val or ins.label, result))
            self.occupancy[q] += 1
            self.max_occ[q] = max(self.max_occ[q], self.occupancy[q])
            self.push_seq[q].append(ins.push_val or ins.label)
        if ins.spec.blocking:
            self.unit_busy[ins.unit] = t_done
        self.energy += ins.energy(frep=self.prog.frep and ins.unit is Unit.FP)
        self.instr_count[ins.unit.value] += 1
        return t_done

    # -- stepping -----------------------------------------------------------

    @property
    def done(self) -> bool:
        return all(self.pcs[u] >= len(lst) for u, lst in self.order)

    def step(self) -> bool:
        """Advance one cycle; returns False once the program has retired."""
        if self.done:
            return False
        issued = False
        for u, lst in self.order:
            pc = self.pcs[u]
            if pc >= len(lst):
                continue
            ins = lst[pc]
            reason = self._block_reason(ins, self.cycle)
            if reason is None:
                t_done = self._do_issue(ins, self.cycle)
                self.finish = max(self.finish, t_done)
                self.pcs[u] = pc + 1
                issued = True
            else:
                key = f"{ins.unit.value}_{reason}"
                self.stalls[key] = self.stalls.get(key, 0) + 1
        if issued:
            self.last_progress = self.cycle
        if self.cycle - self.last_progress > self.cfg.deadlock_limit:
            stuck = {u.value: (self.pcs[u], len(lst),
                               str(lst[self.pcs[u]]) if self.pcs[u] < len(lst) else "-")
                     for u, lst in self.order}
            raise DeadlockError(
                f"{self.prog.name}/{self.prog.policy.value}: no progress; {stuck}")
        self.cycle += 1
        return True

    def run(self) -> SimResult:
        while self.step():
            pass
        return self.result()

    def result(self) -> SimResult:
        cycles = max(self.finish, self.cycle)
        return SimResult(
            name=self.prog.name,
            policy=self.prog.policy,
            cycles=cycles,
            n_samples=self.prog.n_samples,
            instrs=dict(self.instr_count),
            energy=self.energy + E_STATIC_PER_CYCLE * cycles,
            env=self.env,
            push_seq=self.push_seq,
            pop_seq=self.pop_seq,
            max_queue_occupancy=self.max_occ,
            fifo_violations=self.fifo_violations,
            stalls=dict(self.stalls),
        )


def simulate(prog: Program, cfg: Optional[MachineConfig] = None) -> SimResult:
    return Stepper(prog, cfg).run()
