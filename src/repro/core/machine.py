"""Cycle-approximate model of Snitch + FPSS + COPIFTv2 queues.

Two in-order issue units (the integer core and the FPSS), each issuing at
most one instruction per cycle.  In ``single`` mode (the Snitch baseline) a
single shared issue port models the integer core fetching *all* instructions
and offloading FP ones to the FPSS; in ``dual`` mode (COPIFT / COPIFTv2) the
FPSS replays its FREP loop buffer independently, so both units issue
concurrently — IPC is bounded by 2.

Queues have finite depth with blocking push/pop semantics: a pop stalls the
consuming unit until the head entry is visible; a push stalls the producer
while the queue is full.  Stalls, overlap and IPC *emerge* from the model;
nothing is hard-coded per policy.  Every cycle a unit fails to issue is
attributed to one cause (``busy`` / ``dep`` / ``queue_empty`` /
``queue_full``), giving the stall breakdown the DSE sweep reports.

The simulator doubles as a functional interpreter: when instructions carry
``fn``, values flow through registers, queues and memory channels, letting
tests assert that every transform preserves the kernel's semantics.

The whole simulation state lives in a stepper class — re-entrant, cheap to
instantiate, and independent of any module-level state — so design-space
sweeps (``core.sweep``) can run many simulations concurrently in process-pool
workers.  :func:`simulate` remains the one-shot convenience entry point.

Two engines share that state and are required to agree bit-for-bit:

* :class:`ReferenceStepper` — the naive cycle stepper: one Python iteration
  per simulated cycle, attributing each unit's stall cause cycle by cycle.
  Trusted because it is obvious; O(cycles) host work.
* :class:`Stepper` — the event-driven time-skip engine (the default).  A
  cycle in which at least one unit issues runs exactly like the reference.
  When *every* runnable unit is blocked, nothing in the machine state can
  change until a pending timestamp expires, so the stepper computes each
  blocked unit's clear-times — ``unit_busy`` expiry, register ``ready``
  times, queue-head visibility timestamps (push completion + queue latency)
  — takes the earliest cycle any unit can issue, attributes the skipped
  cycles to the same per-unit stall causes in bulk (the blocking cause of a
  waiting unit changes at known breakpoints: the check order of
  ``_block_reason`` is monotone in time while state is frozen), and jumps
  ``self.cycle`` straight there.  ``queue_full`` (and a missing queue entry
  or an unproduced register value) never clears by time alone; if no unit
  has a finite issue time the machine is deadlocked and the engine fails at
  the exact cycle the reference would.  Host work is O(instructions), not
  O(cycles): deep stalls (high queue latency, long FP latencies, depth-1
  back-pressure) cost one jump instead of thousands of idle iterations.

The invariant, enforced by differential tests (tests/test_machine_event.py)
and by every swept point in ``core.sweep``: cycles, energy, stall breakdown,
push/pop sequences, occupancy highwater and the functional environment are
identical between the two engines on every program.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from .isa import (E_STATIC_PER_CYCLE, QUEUE_INDEX, UNIT_INDEX, Instr, Queue,
                  Unit)
from .policy import ExecutionPolicy


@dataclass
class MachineConfig:
    queue_depth: int = 4
    queue_latency: int = 1          # cycles from producer completion to visibility
    evaluate: bool = True           # run the functional interpreter too
    deadlock_limit: int = 20_000    # cycles without progress => deadlock
    #: optional per-queue depth overrides (asymmetric I2F vs F2I FIFOs);
    #: queues absent from the map fall back to ``queue_depth``
    queue_depths: Optional[Dict[Queue, int]] = None

    def depth_of(self, q: Queue) -> int:
        if self.queue_depths is not None:
            return self.queue_depths.get(q, self.queue_depth)
        return self.queue_depth


@dataclass
class Program:
    name: str
    policy: ExecutionPolicy
    mode: str                        # "single" | "dual"
    streams: Dict[Unit, List[Instr]]
    n_samples: int
    init_env: Dict[str, Any] = field(default_factory=dict)
    output_values: List[str] = field(default_factory=list)  # SSA ids
    frep: bool = False               # FP stream replayed from the loop buffer
    #: kernel name before any per-core decoration (``transform`` partitioning
    #: names per-core programs ``f"{base}@core{c}/{n}"``); ``None`` means the
    #: program was never partitioned and ``name`` *is* the base name.  Kept
    #: explicit so cluster results never have to parse user-given names.
    base_name: Optional[str] = None

    def total_instrs(self) -> int:
        return sum(len(v) for v in self.streams.values())

    @property
    def kernel_name(self) -> str:
        """The undecorated kernel name this program was lowered from."""
        return self.base_name if self.base_name is not None else self.name


@dataclass
class SimResult:
    """Simulation outcome.  Everything here is plain data (strings, numbers,
    enums, containers thereof) so a result pickles cleanly across process
    boundaries; ``summary()`` flattens it further into primitives for CSV /
    JSON emission when the (possibly large) ``env`` is not wanted."""
    name: str
    policy: ExecutionPolicy
    cycles: int
    n_samples: int
    instrs: Dict[str, int]
    energy: float
    env: Dict[str, Any]
    push_seq: Dict[Queue, List[str]]
    pop_seq: Dict[Queue, List[str]]
    max_queue_occupancy: Dict[Queue, int]
    fifo_violations: List[Tuple[str, str, str, str]] = field(default_factory=list)
    stalls: Dict[str, int] = field(default_factory=dict)

    @property
    def total_instrs(self) -> int:
        return sum(self.instrs.values())

    # Degenerate programs (empty streams => cycles == 0, energy == 0) must
    # come back as zero-rate records, not ZeroDivisionErrors killing a pool
    # worker mid-sweep.

    @property
    def ipc(self) -> float:
        return self.total_instrs / self.cycles if self.cycles else 0.0

    @property
    def throughput(self) -> float:          # samples / cycle
        return self.n_samples / self.cycles if self.cycles else 0.0

    @property
    def power(self) -> float:               # energy / cycle (relative units)
        return self.energy / self.cycles if self.cycles else 0.0

    @property
    def efficiency(self) -> float:          # samples / energy
        return self.n_samples / self.energy if self.energy else 0.0

    def outputs(self, output_values: List[str]) -> Dict[str, Any]:
        return {v: self.env.get(v) for v in output_values}

    def summary(self) -> Dict[str, Any]:
        """Primitive-typed record (no env, no enum keys) for aggregation."""
        return {
            "name": self.name,
            "policy": self.policy.value,
            "cycles": self.cycles,
            "n_samples": self.n_samples,
            "instrs_int": self.instrs.get("int", 0),
            "instrs_fp": self.instrs.get("fp", 0),
            "ipc": self.ipc,
            "energy": self.energy,
            "power": self.power,
            "throughput": self.throughput,
            "efficiency": self.efficiency,
            "max_occ_i2f": self.max_queue_occupancy.get(Queue.I2F, 0),
            "max_occ_f2i": self.max_queue_occupancy.get(Queue.F2I, 0),
            "fifo_violations": len(self.fifo_violations),
            "stalls": dict(self.stalls),
        }


class DeadlockError(RuntimeError):
    pass


#: stall-cause keys recorded by the stepper (per unit: ``f"{unit}_{cause}"``)
STALL_CAUSES = ("busy", "dep", "queue_empty", "queue_full")


class ReferenceStepper:
    """Re-entrant naive cycle stepper for one :class:`Program`.

    All simulation state is instance state; ``step()`` advances exactly one
    cycle and ``run()`` drives the program to completion.  Construction is
    cheap (a few dicts over the program's streams), which is what lets
    ``core.sweep`` spin one up per configuration inside process-pool workers.

    This is the trusted O(cycles) engine: every simulated cycle costs one
    Python iteration, including cycles where both units idle.  The default
    :class:`Stepper` subclass skips those dead stretches; this class is kept
    verbatim as the differential-testing oracle.
    """

    def __init__(self, prog: Program, cfg: Optional[MachineConfig] = None):
        self.prog = prog
        self.cfg = cfg or MachineConfig()
        self.ready: Dict[str, int] = {k: 0 for k in prog.init_env}
        self.env: Dict[str, Any] = dict(prog.init_env)

        self.queues: Dict[Queue, deque] = {q: deque() for q in Queue}
        self.depths: Dict[Queue, int] = {q: self.cfg.depth_of(q)
                                         for q in Queue}
        self.occupancy: Dict[Queue, int] = {q: 0 for q in Queue}  # incl. in-flight
        self.max_occ: Dict[Queue, int] = {q: 0 for q in Queue}
        self.push_seq: Dict[Queue, List[str]] = {q: [] for q in Queue}
        self.pop_seq: Dict[Queue, List[str]] = {q: [] for q in Queue}
        self.fifo_violations: List[Tuple[str, str, str, str]] = []

        if prog.mode == "single":
            # the lowering merges everything into one stream (the integer core
            # fetches all instructions, offloading FP ones to the FPSS)
            assert len(prog.streams) == 1, "single mode expects one merged stream"
            self.order: List[Tuple[Unit, List[Instr]]] = list(prog.streams.items())
        else:
            # INT first: gives the integer core priority on shared resources.
            self.order = [(u, prog.streams[u])
                          for u in (Unit.INT, Unit.FP) if u in prog.streams]

        self.pcs = {u: 0 for u, _ in self.order}
        self.unit_busy = {Unit.INT: 0, Unit.FP: 0}
        self.instr_count = {"int": 0, "fp": 0}
        self.energy = 0.0
        self.cycle = 0
        self.last_progress = 0
        self.finish = 0
        self.stalls: Dict[str, int] = {}

    # -- issue logic --------------------------------------------------------

    def _block_reason(self, ins: Instr, now: int) -> Optional[str]:
        """None if ``ins`` can issue at ``now``; else the first stall cause."""
        if self.unit_busy[ins.unit] > now:
            return "busy"
        need: Dict[Queue, int] = {}
        for src in ins.srcs:
            if isinstance(src, Queue):
                k = need.get(src, 0)
                q = self.queues[src]
                if len(q) <= k or q[k][0] > now:
                    return "queue_empty"
                need[src] = k + 1
            else:
                t = self.ready.get(src)
                if t is None or t > now:
                    return "dep"
        room: Dict[Queue, int] = {}
        for q in ins.pushes:
            room[q] = room.get(q, 0) + 1
            if self.occupancy[q] + room[q] > self.depths[q]:
                return "queue_full"
        return None

    def _do_issue(self, ins: Instr, now: int) -> int:
        cfg = self.cfg
        t_done = now + ins.spec.latency
        opvals = []
        n_pop = 0
        for src in ins.srcs:
            if isinstance(src, Queue):
                _, vname, val = self.queues[src].popleft()
                self.occupancy[src] -= 1
                self.pop_seq[src].append(vname)
                if ins.expects and ins.expects[n_pop] != vname:
                    self.fifo_violations.append(
                        (ins.label, src.value, ins.expects[n_pop], vname))
                n_pop += 1
                opvals.append(val)
            else:
                opvals.append(self.env.get(src))
        result = None
        if cfg.evaluate and ins.fn is not None:
            result = ins.fn(*opvals)
        if ins.dst is not None:
            self.ready[ins.dst] = t_done
            self.env[ins.dst] = result
        for q in ins.pushes:
            self.queues[q].append(
                (t_done + cfg.queue_latency, ins.push_val or ins.label, result))
            self.occupancy[q] += 1
            self.max_occ[q] = max(self.max_occ[q], self.occupancy[q])
            self.push_seq[q].append(ins.push_val or ins.label)
        if ins.spec.blocking:
            self.unit_busy[ins.unit] = t_done
        self.energy += ins.energy(frep=self.prog.frep and ins.unit is Unit.FP)
        self.instr_count[ins.unit.value] += 1
        return t_done

    # -- stepping -----------------------------------------------------------

    @property
    def done(self) -> bool:
        return all(self.pcs[u] >= len(lst) for u, lst in self.order)

    def step(self) -> bool:
        """Advance one cycle; returns False once the program has retired."""
        if self.done:
            return False
        issued = False
        for u, lst in self.order:
            pc = self.pcs[u]
            if pc >= len(lst):
                continue
            ins = lst[pc]
            reason = self._block_reason(ins, self.cycle)
            if reason is None:
                t_done = self._do_issue(ins, self.cycle)
                self.finish = max(self.finish, t_done)
                self.pcs[u] = pc + 1
                issued = True
            else:
                key = f"{ins.unit.value}_{reason}"
                self.stalls[key] = self.stalls.get(key, 0) + 1
        if issued:
            self.last_progress = self.cycle
        if self.cycle - self.last_progress > self.cfg.deadlock_limit:
            raise self._deadlock()
        self.cycle += 1
        return True

    def _deadlock(self) -> DeadlockError:
        stuck = {u.value: (self.pcs[u], len(lst),
                           str(lst[self.pcs[u]]) if self.pcs[u] < len(lst) else "-")
                 for u, lst in self.order}
        return DeadlockError(
            f"{self.prog.name}/{self.prog.policy.value}: no progress; {stuck}")

    def run(self) -> SimResult:
        while self.step():
            pass
        return self.result()

    def result(self) -> SimResult:
        cycles = max(self.finish, self.cycle)
        return SimResult(
            name=self.prog.name,
            policy=self.prog.policy,
            cycles=cycles,
            n_samples=self.prog.n_samples,
            instrs=dict(self.instr_count),
            energy=self.energy + E_STATIC_PER_CYCLE * cycles,
            env=self.env,
            push_seq=self.push_seq,
            pop_seq=self.pop_seq,
            max_queue_occupancy=self.max_occ,
            fifo_violations=self.fifo_violations,
            stalls=dict(self.stalls),
        )


#: a clear-time meaning "never clears by the passage of time alone"
_NEVER = float("inf")


class Stepper(ReferenceStepper):
    """Event-driven time-skip stepper — the default simulation engine.

    Identical to :class:`ReferenceStepper` on every cycle in which some unit
    issues.  On a fully-blocked cycle it computes, per blocked unit, the
    ordered clear-times of its issue conditions (operand order per
    ``Instr.issue_plan``, with the unit-busy check prepended), jumps
    ``self.cycle`` to the earliest cycle any unit becomes issuable, and
    attributes every skipped cycle to the stall cause the reference would
    have recorded: while no instruction issues the machine state is frozen,
    so a unit's blocking cause is a pure function of time — the first
    condition (in check order) whose clear-time is still in the future — and
    changes only at those clear-times.

    If no blocked unit has a finite issue time, no timestamp expiry can ever
    unblock the machine: the engine attributes stalls up to the reference's
    deadlock horizon (``last_progress + deadlock_limit + 1``) and raises the
    same :class:`DeadlockError` at the same cycle.
    """

    def __init__(self, prog: Program, cfg: Optional[MachineConfig] = None):
        super().__init__(prog, cfg)
        self._eval = self.cfg.evaluate
        self._frep = prog.frep
        cached = getattr(prog, "_event_engine_cache", None)
        if cached is None or cached[0] != prog.mode:
            cached = (prog.mode, self._build_program_facts())
            prog._event_engine_cache = cached
        facts_order, skip_ok = cached[1]
        # Hot state is list-indexed (enum-keyed dict lookups hash the member
        # on every access); the canonical ReferenceStepper dicts are synced
        # back at every exit point (_sync_canonical).  Queue deques and the
        # push/pop logs are shared objects, so only scalars need syncing.
        # Unit row layout: [unit, facts, skip_ok, pc, next_try].  next_try
        # starts at -1 (never a valid cycle) so ``next_try == cycle`` holds
        # only at a skip-granted exact wake.
        self._rows = [[u, facts, skip_ok[u], 0, -1]
                      for u, facts in facts_order]
        self._busy = [0] * len(Unit)             # by UNIT_INDEX
        self._qs = [self.queues[q] for q in Queue]        # by QUEUE_INDEX
        self._poplog = [self.pop_seq[q] for q in Queue]
        self._pushlog = [self.push_seq[q] for q in Queue]
        self._depths = [self.depths[q] for q in Queue]
        self._occ = [0] * len(Queue)
        self._mx = [0] * len(Queue)

    def _build_program_facts(self):
        """Program-static tables, cached on the Program object so memoized
        programs re-simulated across machine configs build them once:

        * per-unit lists of ``Instr.exec_facts`` in stream order;
        * per-instruction *skip soundness*: whether a blocked instruction
          with an all-finite issue time can be left unchecked until that
          cycle.  Sound iff no **other** unit can perturb its clear-times —
          every register source is written exactly once program-wide (SSA;
          ``ready`` entries are then final once present), no other unit pops
          the queues it pops (FIFO heads can't shift under it), and no other
          unit pushes the queues it pushes (a passing depth check can't
          start failing while it waits).  Busy is always own-unit state.
        """
        facts_order = [(u, [ins.exec_facts for ins in lst])
                       for u, lst in self.order]
        # init_env entries count as a first write: a seeded register that one
        # instruction overwrites has two effective writes, so its ready time
        # is NOT final once present and must disqualify the skip
        written: Dict[str, int] = {k: 1 for k in self.prog.init_env}
        poppers: Dict[Queue, set] = {}
        pushers: Dict[Queue, set] = {}
        for u, facts in facts_order:
            for f in facts:
                if f[7] is not None:
                    written[f[7]] = written.get(f[7], 0) + 1
                for op in f[12]:
                    if op[0]:
                        poppers.setdefault(op[1], set()).add(u)
                for push in f[13]:
                    pushers.setdefault(push[0], set()).add(u)
        multi = frozenset(d for d, c in written.items() if c > 1)

        def sound(u, f):
            for op in f[12]:
                if op[0]:
                    if poppers.get(op[1], set()) - {u}:
                        return False
                elif op[1] in multi:
                    return False
            return not any(pushers.get(push[0], set()) - {u}
                           for push in f[13])

        skip_ok = {u: [sound(u, f) for f in facts]
                   for u, facts in facts_order}
        return facts_order, skip_ok

    # -- hot-path twins of _block_reason / _do_issue ------------------------
    # ``f`` is an Instr.exec_facts tuple; see isa.Instr.exec_facts for the
    # layout.  Queue deques and push/pop logs are the canonical shared
    # objects; scalar state (pcs, busy, occupancy highwater) lives in the
    # list-indexed overlay and is synced back at exit points.

    def _reason_key(self, f, now: int) -> Optional[str]:
        """None if issuable at ``now``; else the ready-made stall key."""
        if self._busy[f[14]] > now:
            return f[6]
        qs = self._qs
        ready = self.ready
        for is_q, src, k, key, _qv, qi in f[12]:
            if is_q:
                dq = qs[qi]
                if len(dq) <= k or dq[k][0] > now:
                    return key
            else:
                t = ready.get(src)
                if t is None or t > now:
                    return key
        pushes = f[13]
        if pushes:
            depths = self._depths
            occ = self._occ
            for _q, k, key, qi in pushes:
                if occ[qi] + k + 1 > depths[qi]:
                    return key
        return None

    def _issue(self, f, now: int) -> int:
        (unit, unit_val, latency, blocking, e_plain, e_frep, _busy_key, dst,
         fn, expects, label, pushv, ops, pushes, uidx) = f
        t_done = now + latency
        opvals = []
        n_pop = 0
        for is_q, src, _k, _key, qv, qi in ops:
            if is_q:
                _, vname, val = self._qs[qi].popleft()
                self._occ[qi] -= 1
                self._poplog[qi].append(vname)
                if expects and expects[n_pop] != vname:
                    self.fifo_violations.append((label, qv,
                                                 expects[n_pop], vname))
                n_pop += 1
                opvals.append(val)
            else:
                opvals.append(self.env.get(src))
        result = None
        if self._eval and fn is not None:
            result = fn(*opvals)
        if dst is not None:
            self.ready[dst] = t_done
            self.env[dst] = result
        if pushes:
            t_vis = t_done + self.cfg.queue_latency
            occ, mx = self._occ, self._mx
            for _q, _k, _key, qi in pushes:
                self._qs[qi].append((t_vis, pushv, result))
                occ[qi] += 1
                if occ[qi] > mx[qi]:
                    mx[qi] = occ[qi]
                self._pushlog[qi].append(pushv)
        if blocking:
            self._busy[uidx] = t_done
        self.energy += e_frep if (self._frep and unit is Unit.FP) else e_plain
        self.instr_count[unit_val] += 1
        return t_done

    # -- time-skip stepping -------------------------------------------------

    def step(self) -> bool:
        """Advance to the next cycle in which the machine state changes.

        Two time-skips compose here, both bit-exact against the reference:

        * **per-unit**: a blocked unit whose issue conditions all clear at
          known times — and are *skip-sound* (no other unit can perturb
          them, see ``_build_program_facts``) — gets its stalls attributed
          in bulk through its exact wake cycle and is not re-checked until
          then, even while the other unit keeps issuing;
        * **whole-machine**: when nothing issued this cycle, the state is
          frozen until the earliest pending wake or clear-time, so the
          clock jumps there directly (or to the deadlock horizon).
        """
        cycle = self.cycle
        issued = False
        exhausted = 0
        wake = _NEVER                        # earliest per-unit skip expiry
        blocked: List[tuple] = []            # ev lists for this cycle's blocks
        stalls = self.stalls
        horizon = self.last_progress + self.cfg.deadlock_limit + 1
        for row in self._rows:
            lst = row[1]
            pc = row[3]
            if pc >= len(lst):
                exhausted += 1
                continue
            nt = row[4]
            if nt > cycle:                   # skip-pending: pre-attributed
                if nt < wake:
                    wake = nt
                continue
            f = lst[pc]
            # at the exact wake cycle of a sound skip the instruction is
            # guaranteed issuable — skip the redundant re-check
            key = None if nt == cycle else self._reason_key(f, cycle)
            if key is None:
                t_done = self._issue(f, cycle)
                if t_done > self.finish:
                    self.finish = t_done
                row[3] = pc + 1
                issued = True
                continue
            ev, t_issue = self._clear_times(f)
            if t_issue <= horizon and row[2][pc]:
                # exact wake time: attribute now, ignore the unit until then
                self._attribute_stalls(ev, cycle, int(t_issue) - 1)
                row[4] = int(t_issue)
                if t_issue < wake:
                    wake = int(t_issue)
            else:
                stalls[key] = stalls.get(key, 0) + 1
                blocked.append((ev, t_issue))
        if issued:
            self.last_progress = cycle
            self.cycle = cycle + 1
            return True
        if exhausted == len(self._rows):
            self._sync_canonical()
            return False                     # program retired
        # Nothing issued: the state is frozen until a pending timestamp
        # expires — the earliest skip wake or blocked clear-time.
        t_next = min([wake] + [t for _ev, t in blocked])
        if t_next > horizon:                 # deadlock (or past the limit)
            for ev, _t in blocked:
                self._attribute_stalls(ev, cycle + 1, horizon)
            self.cycle = horizon
            self._sync_canonical()
            raise self._deadlock()
        for ev, _t in blocked:
            self._attribute_stalls(ev, cycle + 1, int(t_next) - 1)
        self.cycle = int(t_next)
        return True

    @property
    def done(self) -> bool:
        return all(row[3] >= len(row[1]) for row in self._rows)

    def _sync_canonical(self) -> None:
        """Copy the list-indexed overlay back onto the canonical
        ReferenceStepper dicts (pcs / unit_busy / occupancy / max_occ) so
        result(), the deadlock message and external inspection see the same
        state the reference engine would leave behind."""
        for row in self._rows:
            self.pcs[row[0]] = row[3]
        for u, ui in UNIT_INDEX.items():
            self.unit_busy[u] = self._busy[ui]
        for q, qi in QUEUE_INDEX.items():
            self.occupancy[q] = self._occ[qi]
            self.max_occ[q] = self._mx[qi]

    def result(self) -> SimResult:
        self._sync_canonical()
        return super().result()

    def _clear_times(self, f) -> Tuple[List[Tuple[str, float]], float]:
        """((stall key, clear-time) per issue condition in check order,
        max clear-time).

        A condition blocks issue at cycle ``c`` iff its clear-time exceeds
        ``c``; conditions that depend on state rather than time (missing
        queue entry, unproduced register value, full queue) get ``inf``
        because only another unit's issue — impossible while all units are
        blocked — could satisfy them.
        """
        t_max = self._busy[f[14]]
        ev: List[Tuple[str, float]] = [(f[6], t_max)]
        for is_q, src, k, key, _qv, qi in f[12]:
            if is_q:
                dq = self._qs[qi]
                t = dq[k][0] if len(dq) > k else _NEVER
            else:
                t = self.ready.get(src)
                t = t if t is not None else _NEVER
            ev.append((key, t))
            if t > t_max:
                t_max = t
        pushes = f[13]
        if pushes:
            depths = self._depths
            for _q, k, key, qi in pushes:
                if self._occ[qi] + k + 1 > depths[qi]:
                    ev.append((key, _NEVER))
                    t_max = _NEVER
        return ev, t_max

    def _attribute_stalls(self, ev: List[Tuple[str, float]],
                          a: int, b: int) -> None:
        """Record the stalls the reference would for cycles ``a..b`` incl.

        At cycle ``c`` the recorded cause is the first condition with
        clear-time > ``c``; that index is non-decreasing in ``c`` (earlier
        conditions, once cleared, stay cleared while the state is frozen), so
        one ordered walk over ``ev`` yields the per-cause segment lengths.
        """
        if a > b:
            return
        c = a
        stalls = self.stalls
        for key, t in ev:
            if t <= c:
                continue
            end = b if t - 1 > b else int(t) - 1
            stalls[key] = stalls.get(key, 0) + (end - c + 1)
            if t - 1 >= b:
                return
            c = int(t)


#: available simulation engines, default first
ENGINES: Tuple[str, ...] = ("event", "cycle")


def stepper_for(prog: Program, cfg: Optional[MachineConfig] = None,
                engine: str = "event") -> ReferenceStepper:
    """Instantiate the requested engine: ``event`` (time-skip, default) or
    ``cycle`` (the naive reference)."""
    if engine == "event":
        return Stepper(prog, cfg)
    if engine == "cycle":
        return ReferenceStepper(prog, cfg)
    raise ValueError(f"unknown engine {engine!r} (have {ENGINES})")


def simulate(prog: Program, cfg: Optional[MachineConfig] = None,
             engine: str = "event") -> SimResult:
    return stepper_for(prog, cfg, engine).run()
