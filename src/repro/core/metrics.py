"""Result aggregation for the reproduction experiments (paper Fig. 3)."""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import (Callable, Dict, Hashable, Iterable, List, Optional,
                    TypeVar)

from .bench_kernels import KERNELS
from .machine import MachineConfig, SimResult, simulate
from .policy import ExecutionPolicy
from .transform import TransformConfig, lower

T = TypeVar("T")


def geomean(xs: Iterable[float]) -> float:
    xs = list(xs)
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def group_by(items: Iterable[T],
             key: Callable[[T], Hashable]) -> Dict[Hashable, List[T]]:
    """Bucket ``items`` by ``key(item)``, preserving input order per bucket."""
    out: Dict[Hashable, List[T]] = {}
    for it in items:
        out.setdefault(key(it), []).append(it)
    return out


def best(items: Iterable[T], attr: str, maximize: bool = True) -> T:
    """The item with the extreme value of ``attr`` (works on records too)."""
    pick = max if maximize else min
    return pick(items, key=lambda it: getattr(it, attr))


@dataclass
class KernelComparison:
    kernel: str
    results: Dict[ExecutionPolicy, SimResult]

    def ipc(self, p: ExecutionPolicy) -> float:
        return self.results[p].ipc

    def speedup(self, a: ExecutionPolicy, b: ExecutionPolicy) -> float:
        """Throughput (samples/cycle) of ``a`` relative to ``b``."""
        return self.results[a].throughput / self.results[b].throughput

    def energy_gain(self, a: ExecutionPolicy, b: ExecutionPolicy) -> float:
        """Energy-efficiency (samples/J) of ``a`` relative to ``b``."""
        return self.results[a].efficiency / self.results[b].efficiency

    def power_ratio(self, a: ExecutionPolicy, b: ExecutionPolicy) -> float:
        return self.results[a].power / self.results[b].power


def run_suite(n_samples: int = 128,
              tcfg: Optional[TransformConfig] = None,
              mcfg: Optional[MachineConfig] = None,
              kernels: Optional[List[str]] = None) -> Dict[str, KernelComparison]:
    tcfg = tcfg or TransformConfig(n_samples=n_samples)
    mcfg = mcfg or MachineConfig()
    out: Dict[str, KernelComparison] = {}
    for name in (kernels or list(KERNELS)):
        dfg = KERNELS[name]
        res = {p: simulate(lower(dfg, p, tcfg), mcfg) for p in ExecutionPolicy}
        out[name] = KernelComparison(name, res)
    return out


def summarize(suite: Dict[str, KernelComparison]) -> Dict[str, float]:
    V2, CP, BL = (ExecutionPolicy.COPIFTV2, ExecutionPolicy.COPIFT,
                  ExecutionPolicy.BASELINE)
    sp = {k: c.speedup(V2, CP) for k, c in suite.items()}
    eg = {k: c.energy_gain(V2, CP) for k, c in suite.items()}
    sb = {k: c.speedup(V2, BL) for k, c in suite.items()}
    eb = {k: c.energy_gain(V2, BL) for k, c in suite.items()}
    return {
        "peak_ipc_v2": max(c.ipc(V2) for c in suite.values()),
        "max_speedup_vs_copift": max(sp.values()),
        "geomean_speedup_vs_copift": geomean(sp.values()),
        "max_energy_vs_copift": max(eg.values()),
        "geomean_energy_vs_copift": geomean(eg.values()),
        "max_speedup_vs_baseline": max(sb.values()),
        "max_energy_vs_baseline": max(eb.values()),
        "geomean_ipc_copift_vs_baseline": geomean(
            c.ipc(CP) / c.ipc(BL) for c in suite.values()),
        "geomean_energy_copift_vs_baseline": geomean(
            c.energy_gain(CP, BL) for c in suite.values()),
    }


#: Published claims (paper §III / abstract, plus [1] for COPIFT-vs-baseline).
PAPER_CLAIMS = {
    "peak_ipc_v2": 1.81,
    "max_speedup_vs_copift": 1.49,
    "geomean_speedup_vs_copift": 1.19,
    "max_energy_vs_copift": 1.47,
    "geomean_energy_vs_copift": 1.21,
    "max_speedup_vs_baseline": 1.96,
    "max_energy_vs_baseline": 1.75,
    "geomean_ipc_copift_vs_baseline": 1.6,
    "geomean_energy_copift_vs_baseline": 1.3,
}
