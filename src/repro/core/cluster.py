"""Cluster-scale machine model: N dual-issue PEs sharing a banked TCDM.

The single-PE model (``core.machine``) reproduces the paper's dual-issue
core; large-scale ML accelerators deploy *many* of them — a Snitch cluster
couples N cores to a word-interleaved multi-bank TCDM through a single-cycle
logarithmic interconnect (Zaruba et al., TC'21; Colagrande et al.,
"Towards Zero-Stall Matrix Multiplication on Energy-Efficient RISC-V
Clusters").  This module scales the machine model to that shape:

* :class:`ClusterConfig` — cluster geometry: core count, TCDM bank count
  (``None`` = conflict-free, the ∞-bank idealization), the bank service
  window (``bank_conflict_penalty``: cycles a bank stays busy per access, 1
  = fully pipelined single-port SRAM), the per-access interconnect energy,
  and the per-core :class:`~.machine.MachineConfig`.
* :class:`ClusterStepper` — advances N per-core steppers (the event-driven
  :class:`~.machine.Stepper` by default, the naive
  :class:`~.machine.ReferenceStepper` under ``engine="cycle"``) under a
  shared bank arbiter.  Host work stays O(total instructions): each core
  keeps its own event-driven time-skip machinery, and the scheduler always
  advances the core with the smallest local cycle (ties broken by core
  index — the deterministic interconnect priority), so every arbiter
  decision at cycle ``t`` happens after all accesses at cycles ``< t`` and
  after lower-indexed cores' accesses at ``t``.
* :class:`ClusterResult` — per-core :class:`~.machine.SimResult` plus the
  cluster aggregates: makespan cycles, aggregate IPC / throughput, summed
  energy *including interconnect energy*, merged stall breakdown (with the
  cluster-only ``*_bank`` causes), and per-core IPC.

Contention model: every TCDM access (``isa.MEM_KINDS``: loads, stores, SSR
stores) maps to ``crc32(label) % banks`` — a deterministic stand-in for
address-interleaved bank mapping — and occupies its bank for
``bank_conflict_penalty`` cycles.  An access finding its bank busy stalls
its unit with the ``bank`` cause until the bank frees.  Banks only ever get
*busier* over time, which is what makes the per-core time-skip sound: a
blocked core that jumped to its computed wake cycle re-checks every issue
condition there, and no bank can have become free earlier than the core
assumed.  (The per-unit *exact-wake* skip is disabled under finite banks —
another core can extend a bank window while a unit waits — so those
configurations pay a few more host steps; the whole-machine jump, which
re-checks on wake, is kept.)

The hard contract, enforced by ``tests/test_cluster.py`` differentially
against :class:`~.machine.Stepper` across the default sweep grid:
``n_cores=1, tcdm_banks=None`` is **bit-identical** to the single-core
engine — cycles, energy, stall breakdown, FIFO push/pop sequences,
occupancy highwater and the functional environment.  A single PE owns its
scratchpad port (no interconnect energy, no arbiter), so the degenerate
cluster runs the exact single-core code path.  Contention-free N-core
clusters additionally equal N independent single-core runs per core.

Engine parity under contention: issue timing, energy, FIFO sequences and
the environment are identical between ``event`` and ``cycle`` cluster runs
(bank windows only move later, so a jump target is never early).  The
*attribution* of bank-blocked cycles can differ when another core extends a
bank window inside a stretch the event engine already attributed — per-unit
stall totals still agree, only the cause split within the window may shift.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .isa import BANK_STALL_KEYS, E_TCDM_INTERCONNECT, MEM_KINDS, Queue
from .machine import (ENGINES, MachineConfig, Program, ReferenceStepper,
                      SimResult, Stepper)
from .policy import ExecutionPolicy


@dataclass
class ClusterConfig:
    """Cluster geometry.  The defaults (one core, conflict-free TCDM) are
    the degenerate cluster that must match ``core.machine`` bit-for-bit."""
    n_cores: int = 1
    #: TCDM bank count; ``None`` models an infinitely-banked (conflict-free)
    #: scratchpad — the idealization the bit-identity contract pins
    tcdm_banks: Optional[int] = None
    #: cycles a bank stays busy per access (1 = pipelined single-port SRAM);
    #: a conflicting access waits out the remainder of the window
    bank_conflict_penalty: int = 1
    #: energy per TCDM access through the shared interconnect; charged only
    #: when ``n_cores > 1`` (a single PE owns its scratchpad port)
    interconnect_energy: float = E_TCDM_INTERCONNECT
    #: per-core machine configuration (queue geometry, latency, ...)
    machine: MachineConfig = field(default_factory=MachineConfig)

    def __post_init__(self) -> None:
        if self.n_cores <= 0:
            raise ValueError(f"n_cores must be positive, got {self.n_cores}")
        if self.tcdm_banks is not None and self.tcdm_banks <= 0:
            raise ValueError(
                f"tcdm_banks must be positive or None, got {self.tcdm_banks}")
        if self.bank_conflict_penalty < 1:
            raise ValueError("bank_conflict_penalty must be >= 1")


class _Interconnect:
    """Shared TCDM bank arbiter: per-bank busy-until timestamps.

    ``banks=None`` disables arbitration entirely (conflict-free);
    ``e_access`` is the per-access interconnect energy (0 for one core).
    Timestamps only move forward — an acquired window never shrinks — which
    the per-core time-skip relies on (see the module docstring).
    """
    __slots__ = ("banks", "penalty", "e_access", "busy_until")

    def __init__(self, banks: Optional[int], penalty: int, e_access: float):
        self.banks = banks
        self.penalty = penalty
        self.e_access = e_access
        self.busy_until: Dict[int, int] = {}

    def bank_of(self, label: str) -> int:
        # deterministic address-hash proxy for word-interleaved bank mapping
        return zlib.crc32(label.encode()) % self.banks

    def free_at(self, bank: int) -> int:
        return self.busy_until.get(bank, 0)

    def acquire(self, bank: int, now: int) -> None:
        self.busy_until[bank] = now + self.penalty


class _CoreStepper(Stepper):
    """One cluster core: the event-driven engine + the shared bank gate.

    With no interconnect pressure (one core, infinite banks) every override
    below is a no-op pass-through — the degenerate cluster core runs the
    exact single-core code path, which is the bit-identity contract.
    """

    def __init__(self, prog: Program, cfg: MachineConfig, ic: _Interconnect):
        super().__init__(prog, cfg)
        self._ic = ic
        #: id(exec_facts) -> bank, for TCDM-touching instructions only
        self._bank: Dict[int, int] = {}
        self._mem_ids: set = set()
        for _u, lst in self.order:
            for ins in lst:
                if ins.kind in MEM_KINDS:
                    self._mem_ids.add(id(ins.exec_facts))
                    if ic.banks is not None:
                        self._bank[id(ins.exec_facts)] = ic.bank_of(ins.label)
        if self._bank:
            # another core can extend a bank window while a unit waits, so
            # the per-unit exact-wake skip is unsound here; replace (never
            # mutate: the skip table is cached on the Program) each row's
            # skip flags with all-False.  The whole-machine jump re-checks
            # conditions on wake and stays sound.
            for row in self._rows:
                row[2] = [False] * len(row[2])

    # -- bank gate: checked after every single-core issue condition ---------

    def _reason_key(self, f, now: int) -> Optional[str]:
        key = super()._reason_key(f, now)
        if key is None and self._bank:
            b = self._bank.get(id(f))
            if b is not None and self._ic.free_at(b) > now:
                return BANK_STALL_KEYS[f[0]]
        return key

    def _clear_times(self, f) -> Tuple[List[Tuple[str, float]], float]:
        ev, t_max = super()._clear_times(f)
        if self._bank:
            b = self._bank.get(id(f))
            if b is not None:
                t = self._ic.free_at(b)
                ev.append((BANK_STALL_KEYS[f[0]], t))
                if t > t_max:
                    t_max = t
        return ev, t_max

    def _issue(self, f, now: int) -> int:
        fid = id(f)
        if fid in self._mem_ids:
            if self._bank:
                self._ic.acquire(self._bank[fid], now)
            self.energy += self._ic.e_access
        return super()._issue(f, now)


class _RefCoreStepper(ReferenceStepper):
    """Naive per-cycle cluster core — the differential oracle for
    :class:`_CoreStepper` (``engine="cycle"``), with the same bank gate."""

    def __init__(self, prog: Program, cfg: MachineConfig, ic: _Interconnect):
        super().__init__(prog, cfg)
        self._ic = ic
        self._bank: Dict[int, int] = {}
        self._mem_ids: set = set()
        for _u, lst in self.order:
            for ins in lst:
                if ins.kind in MEM_KINDS:
                    self._mem_ids.add(id(ins))
                    if ic.banks is not None:
                        self._bank[id(ins)] = ic.bank_of(ins.label)

    def _block_reason(self, ins, now: int) -> Optional[str]:
        reason = super()._block_reason(ins, now)
        if reason is None and self._bank:
            b = self._bank.get(id(ins))
            if b is not None and self._ic.free_at(b) > now:
                return "bank"
        return reason

    def _do_issue(self, ins, now: int) -> int:
        iid = id(ins)
        if iid in self._mem_ids:
            if self._bank:
                self._ic.acquire(self._bank[iid], now)
            self.energy += self._ic.e_access
        return super()._do_issue(ins, now)


@dataclass
class ClusterResult:
    """Aggregate outcome of one cluster run.  ``cycles`` is the makespan
    (slowest core); energy is the sum over cores *including* interconnect
    energy; the per-core :class:`~.machine.SimResult`\\ s keep full detail
    (env, FIFO sequences) for equivalence checking."""
    name: str
    policy: ExecutionPolicy
    n_cores: int
    tcdm_banks: Optional[int]
    cycles: int
    n_samples: int
    energy: float
    core_results: List[SimResult]

    @property
    def total_instrs(self) -> int:
        return sum(r.total_instrs for r in self.core_results)

    @property
    def ipc(self) -> float:
        """Aggregate IPC over the makespan — up to ``2 * n_cores``."""
        return self.total_instrs / self.cycles if self.cycles else 0.0

    @property
    def ipc_per_core(self) -> float:
        """Mean per-core IPC (each core over its own busy cycles)."""
        if not self.core_results:
            return 0.0
        return sum(r.ipc for r in self.core_results) / len(self.core_results)

    @property
    def throughput(self) -> float:          # samples / cycle, aggregate
        return self.n_samples / self.cycles if self.cycles else 0.0

    @property
    def power(self) -> float:
        return self.energy / self.cycles if self.cycles else 0.0

    @property
    def efficiency(self) -> float:          # samples / energy
        return self.n_samples / self.energy if self.energy else 0.0

    @property
    def instrs(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for r in self.core_results:
            for k, v in r.instrs.items():
                out[k] = out.get(k, 0) + v
        return out

    @property
    def stalls(self) -> Dict[str, int]:
        """Merged stall breakdown; ``*_bank`` keys are the contention."""
        out: Dict[str, int] = {}
        for r in self.core_results:
            for k, v in r.stalls.items():
                out[k] = out.get(k, 0) + v
        return out

    @property
    def bank_stalls(self) -> int:
        return sum(v for k, v in self.stalls.items() if k.endswith("_bank"))

    @property
    def max_queue_occupancy(self) -> Dict[Queue, int]:
        out = {q: 0 for q in Queue}
        for r in self.core_results:
            for q, v in r.max_queue_occupancy.items():
                if v > out[q]:
                    out[q] = v
        return out

    @property
    def fifo_violations(self) -> int:
        return sum(len(r.fifo_violations) for r in self.core_results)

    def summary(self) -> Dict[str, object]:
        """Primitive-typed record mirroring ``SimResult.summary`` with the
        cluster aggregates added."""
        return {
            "name": self.name,
            "policy": self.policy.value,
            "n_cores": self.n_cores,
            "tcdm_banks": self.tcdm_banks,
            "cycles": self.cycles,
            "n_samples": self.n_samples,
            "instrs_int": self.instrs.get("int", 0),
            "instrs_fp": self.instrs.get("fp", 0),
            "ipc": self.ipc,
            "ipc_per_core": self.ipc_per_core,
            "energy": self.energy,
            "power": self.power,
            "throughput": self.throughput,
            "efficiency": self.efficiency,
            "max_occ_i2f": self.max_queue_occupancy.get(Queue.I2F, 0),
            "max_occ_f2i": self.max_queue_occupancy.get(Queue.F2I, 0),
            "fifo_violations": self.fifo_violations,
            "bank_stalls": self.bank_stalls,
            "stalls": dict(self.stalls),
        }


class ClusterStepper:
    """Advance N per-core steppers under the shared TCDM arbiter.

    ``progs`` are the per-core programs (``transform.partition_kernel``
    output, or any list of independent Programs — one per core).  The
    scheduler always steps the core with the smallest local cycle, ties
    broken by core index (core 0 has interconnect priority), which makes
    the contention semantics deterministic and engine-independent.
    """

    def __init__(self, progs: Sequence[Program],
                 cfg: Optional[ClusterConfig] = None,
                 engine: str = "event"):
        progs = list(progs)
        cfg = cfg or ClusterConfig(n_cores=len(progs))
        if len(progs) != cfg.n_cores:
            raise ValueError(
                f"got {len(progs)} per-core programs for n_cores={cfg.n_cores}")
        if engine not in ENGINES:
            raise ValueError(f"unknown engine {engine!r} (have {ENGINES})")
        self.cfg = cfg
        self.interconnect = _Interconnect(
            banks=cfg.tcdm_banks, penalty=cfg.bank_conflict_penalty,
            e_access=cfg.interconnect_energy if cfg.n_cores > 1 else 0.0)
        core_cls = _CoreStepper if engine == "event" else _RefCoreStepper
        self.cores = [core_cls(p, cfg.machine, self.interconnect)
                      for p in progs]

    def run(self) -> ClusterResult:
        cores = self.cores
        live = list(range(len(cores)))
        while live:
            # global-time-ordered advance: the min-cycle core acts next, so
            # every arbiter decision at cycle t already saw all accesses at
            # cycles < t and lower-indexed cores' accesses at t
            c = min(live, key=lambda i: (cores[i].cycle, i))
            if not cores[c].step():
                live.remove(c)
        return self.result()

    def result(self) -> ClusterResult:
        results = [c.result() for c in self.cores]
        prog0 = self.cores[0].prog
        return ClusterResult(
            name=prog0.name.split("@core")[0],
            policy=prog0.policy,
            n_cores=self.cfg.n_cores,
            tcdm_banks=self.cfg.tcdm_banks,
            cycles=max((r.cycles for r in results), default=0),
            n_samples=sum(r.n_samples for r in results),
            energy=sum(r.energy for r in results),
            core_results=results,
        )


def simulate_cluster(progs: Sequence[Program],
                     cfg: Optional[ClusterConfig] = None,
                     engine: str = "event") -> ClusterResult:
    """One-shot convenience entry point, mirroring ``machine.simulate``."""
    return ClusterStepper(progs, cfg, engine).run()
