"""Cluster-scale machine model: N dual-issue PEs sharing a banked TCDM.

The single-PE model (``core.machine``) reproduces the paper's dual-issue
core; large-scale ML accelerators deploy *many* of them — a Snitch cluster
couples N cores to a word-interleaved multi-bank TCDM through a single-cycle
logarithmic interconnect (Zaruba et al., TC'21; Colagrande et al.,
"Towards Zero-Stall Matrix Multiplication on Energy-Efficient RISC-V
Clusters").  This module scales the machine model to that shape:

* :class:`ClusterConfig` — cluster geometry: core count, TCDM bank count
  (``None`` = conflict-free, the ∞-bank idealization), the bank service
  window (``bank_conflict_penalty``: cycles a bank stays busy per access, 1
  = fully pipelined single-port SRAM), the per-access interconnect energy,
  and the per-core :class:`~.machine.MachineConfig`.
* :class:`ClusterStepper` — advances N per-core steppers (the event-driven
  :class:`~.machine.Stepper` by default, the naive
  :class:`~.machine.ReferenceStepper` under ``engine="cycle"``) under a
  shared bank arbiter.  Host work stays O(total instructions): each core
  keeps its own event-driven time-skip machinery, and the scheduler always
  advances the core with the smallest local cycle (ties broken by core
  index — the deterministic interconnect priority), so every arbiter
  decision at cycle ``t`` happens after all accesses at cycles ``< t`` and
  after lower-indexed cores' accesses at ``t``.
* :class:`ClusterResult` — per-core :class:`~.machine.SimResult` plus the
  cluster aggregates: makespan cycles, aggregate IPC / throughput, summed
  energy *including interconnect energy*, merged stall breakdown (with the
  cluster-only ``*_bank`` causes), and per-core IPC.

Contention model: every TCDM access (``isa.MEM_KINDS``: loads, stores, SSR
stores) maps to ``crc32(label) % banks`` — a deterministic stand-in for
address-interleaved bank mapping — and occupies its bank for
``bank_conflict_penalty`` cycles.  An access finding its bank busy stalls
its unit with the ``bank`` cause until the bank frees.  Banks only ever get
*busier* over time, which is what makes the per-core time-skip sound: a
blocked core that jumped to its computed wake cycle re-checks every issue
condition there, and no bank can have become free earlier than the core
assumed.  (The per-unit *exact-wake* skip is disabled under finite banks —
another core can extend a bank window while a unit waits — so those
configurations pay a few more host steps; the whole-machine jump, which
re-checks on wake, is kept.)

Inter-core channels and DMA (the pipelined-cluster fabric): programs may
carry ``CQ_PUSH`` / ``CQ_POP`` ops (``Instr.cq`` names the channel) and
``DMA_START`` / ``DMA_WAIT`` descriptors (``Instr.dma_words`` sizes the
transfer).  Channels are bounded FIFOs living in the TCDM, shared by every
core of the cluster:

* **Determinism** — channel order is decided by the same min-(cycle, core)
  scheduler as the bank arbiter: a push at cycle ``t`` is ordered after all
  channel traffic at cycles ``< t`` and after lower-indexed cores' traffic
  at ``t``, so push/pop sequences are bit-reproducible across runs and
  engines.  A pushed entry becomes visible to the consumer ``cq_latency``
  cycles after the push completes (one interconnect traversal), mirroring
  the intra-core ``queue_latency``.
* **Blocking + stall causes** — a ``CQ_PUSH`` into a channel holding
  ``cq_depth`` entries stalls its unit with the ``cq_full`` cause; a
  ``CQ_POP`` of an empty (or not-yet-visible) channel stalls with
  ``cq_empty``; a ``DMA_START`` past ``dma_buffers`` in-flight transfers
  and a ``DMA_WAIT`` for an unfinished transfer stall with ``dma``.
  Because channel state is mutable by *other* cores, a core blocked on a
  channel op abandons time-skipping and re-checks every cycle (the clear
  time is capped at ``cycle + 1``), which keeps the event engine's stall
  attribution bit-identical to the per-cycle reference.  DMA state is
  core-local and final at issue time, so DMA waits keep the full time-skip.
* **Energy + bank occupancy** — each channel op charges the interconnect
  access energy plus ``E_CQ_ACCESS`` (FIFO pointer maintenance) and
  occupies the channel's TCDM bank (``channel % banks``) for one cycle — a
  single-word pipelined access, *not* the full ``bank_conflict_penalty``
  window.  A DMA transfer charges ``E_DMA_WORD`` per word at START; the
  bulk transfer itself is modeled conflict-free (the engine schedules
  around cores — the zero-stall premise of Colagrande et al.), and loads
  marked ``Instr.local`` (reads from a DMA-staged buffer) bypass bank
  arbitration and interconnect energy entirely.
* **Deadlock** — each core keeps its own no-progress detector, so a cyclic
  cross-core wait (A pops what only B pushes while B pops what only A
  pushes) raises :class:`~.machine.DeadlockError` — annotated with the
  cluster-wide channel occupancy — at the first core to exhaust its
  ``deadlock_limit`` horizon instead of hanging.  A ``DMA_START`` blocked
  on a full engine can never unblock (the freeing ``DMA_WAIT`` sits behind
  it on the same in-order unit) and is likewise reported as a deadlock.

The hard contract, enforced by ``tests/test_cluster.py`` differentially
against :class:`~.machine.Stepper` across the default sweep grid:
``n_cores=1, tcdm_banks=None`` is **bit-identical** to the single-core
engine — cycles, energy, stall breakdown, FIFO push/pop sequences,
occupancy highwater and the functional environment.  A single PE owns its
scratchpad port (no interconnect energy, no arbiter), so the degenerate
cluster runs the exact single-core code path.  Contention-free N-core
clusters additionally equal N independent single-core runs per core.

Engine parity under contention: issue timing, energy, FIFO sequences and
the environment are identical between ``event`` and ``cycle`` cluster runs
(bank windows only move later, so a jump target is never early).  The
*attribution* of bank-blocked cycles can differ when another core extends a
bank window inside a stretch the event engine already attributed — per-unit
stall totals still agree, only the cause split within the window may shift.
"""
from __future__ import annotations

import zlib
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .isa import (BANK_STALL_KEYS, CQ_EMPTY_STALL_KEYS, CQ_FULL_STALL_KEYS,
                  DMA_STALL_KEYS, E_CQ_ACCESS, E_DMA_WORD,
                  E_TCDM_INTERCONNECT, MEM_KINDS, OpKind, Queue)
from .machine import (ENGINES, DeadlockError, MachineConfig, Program,
                      ReferenceStepper, SimResult, Stepper)
from .policy import ExecutionPolicy


@dataclass
class ClusterConfig:
    """Cluster geometry.  The defaults (one core, conflict-free TCDM) are
    the degenerate cluster that must match ``core.machine`` bit-for-bit."""
    n_cores: int = 1
    #: TCDM bank count; ``None`` models an infinitely-banked (conflict-free)
    #: scratchpad — the idealization the bit-identity contract pins
    tcdm_banks: Optional[int] = None
    #: cycles a bank stays busy per access (1 = pipelined single-port SRAM);
    #: a conflicting access waits out the remainder of the window
    bank_conflict_penalty: int = 1
    #: energy per TCDM access through the shared interconnect; charged only
    #: when ``n_cores > 1`` (a single PE owns its scratchpad port)
    interconnect_energy: float = E_TCDM_INTERCONNECT
    #: inter-core channel depth (entries per bounded FIFO through the TCDM)
    cq_depth: int = 4
    #: cycles from a channel push's completion to consumer-side visibility
    #: (one interconnect traversal each way, mirroring ``queue_latency``)
    cq_latency: int = 1
    #: in-flight DMA transfers each per-core engine sustains (2 = the
    #: classic double-buffering; a DMA_START past the cap stalls ``dma``)
    dma_buffers: int = 2
    #: DMA descriptor programming + engine start overhead, cycles
    dma_setup: int = 8
    #: DMA streaming bandwidth, cycles per word moved
    dma_cycles_per_word: int = 1
    #: per-core machine configuration (queue geometry, latency, ...)
    machine: MachineConfig = field(default_factory=MachineConfig)

    def __post_init__(self) -> None:
        if self.n_cores <= 0:
            raise ValueError(f"n_cores must be positive, got {self.n_cores}")
        if self.tcdm_banks is not None and self.tcdm_banks <= 0:
            raise ValueError(
                f"tcdm_banks must be positive or None, got {self.tcdm_banks}")
        if self.bank_conflict_penalty < 1:
            raise ValueError("bank_conflict_penalty must be >= 1")
        if self.cq_depth < 1:
            raise ValueError(f"cq_depth must be >= 1, got {self.cq_depth}")
        if self.cq_latency < 0:
            raise ValueError(
                f"cq_latency must be >= 0, got {self.cq_latency}")
        if self.dma_buffers < 1:
            raise ValueError(
                f"dma_buffers must be >= 1, got {self.dma_buffers}")
        if self.dma_setup < 0 or self.dma_cycles_per_word < 1:
            raise ValueError("invalid DMA timing parameters")


class _Interconnect:
    """Shared TCDM bank arbiter: per-bank busy-until timestamps.

    ``banks=None`` disables arbitration entirely (conflict-free);
    ``e_access`` is the per-access interconnect energy (0 for one core).
    Timestamps only move forward — an acquired window never shrinks — which
    the per-core time-skip relies on (see the module docstring).
    """
    __slots__ = ("banks", "penalty", "e_access", "busy_until")

    def __init__(self, banks: Optional[int], penalty: int, e_access: float):
        self.banks = banks
        self.penalty = penalty
        self.e_access = e_access
        self.busy_until: Dict[int, int] = {}

    def bank_of(self, label: str) -> int:
        # deterministic address-hash proxy for word-interleaved bank mapping
        return zlib.crc32(label.encode()) % self.banks

    def free_at(self, bank: int) -> int:
        return self.busy_until.get(bank, 0)

    def acquire(self, bank: int, now: int,
                penalty: Optional[int] = None) -> None:
        """Occupy ``bank`` from ``now``.  ``penalty`` overrides the bulk
        service window — channel ops pass 1 (a single-word pipelined access
        does not hold the bank for the full conflict window)."""
        self.busy_until[bank] = now + (self.penalty if penalty is None
                                       else penalty)


class _ChannelFabric:
    """Cluster-wide inter-core channel state: one bounded FIFO per channel
    index, shared by every core stepper.  Entries are
    ``(visible_at, value_name, value)`` — the same shape as the intra-core
    COPIFT queues — and the cluster-level push/pop logs keep
    ``(channel, value_name)`` tuples for FIFO-order verification."""
    __slots__ = ("depth", "channels", "push_seq", "pop_seq", "violations")

    def __init__(self, depth: int):
        self.depth = depth
        self.channels: Dict[int, deque] = {}
        self.push_seq: List[Tuple[int, str]] = []
        self.pop_seq: List[Tuple[int, str]] = []
        #: (label, channel, expected value name, got value name)
        self.violations: List[Tuple[str, int, str, str]] = []

    def channel(self, c: int) -> deque:
        ch = self.channels.get(c)
        if ch is None:
            ch = self.channels[c] = deque()
        return ch

    def push(self, c: int, visible_at: int, name: str, value) -> None:
        self.channel(c).append((visible_at, name, value))
        self.push_seq.append((c, name))

    def pop(self, c: int) -> Tuple[int, str, object]:
        entry = self.channels[c].popleft()
        self.pop_seq.append((c, entry[1]))
        return entry


class _DmaEngine:
    """Per-core DMA engine: a deque of in-flight transfer completion times.
    A transfer's buffer stays occupied until its ``DMA_WAIT`` retires it —
    that is what bounds the pipeline to ``dma_buffers`` stages."""
    __slots__ = ("buffers", "inflight")

    def __init__(self, buffers: int):
        self.buffers = buffers
        self.inflight: deque = deque()


def _fabric_meta(ins, ccfg: "ClusterConfig") -> Optional[Tuple]:
    """Pre-resolved fabric semantics for one instruction, or ``None`` for
    ordinary ops.  Tag layout (first element):

    * ``(0, chan, src_reg, pushed_name, visibility_delay)`` — CQ_PUSH
    * ``(1, chan, dst_magic, expected_name|None, label)``   — CQ_POP
    * ``(2, completion_delay, transfer_energy)``            — DMA_START
    * ``(3,)``                                              — DMA_WAIT
    """
    if ins.kind is OpKind.CQ_PUSH or ins.kind is OpKind.CQ_POP:
        if ins.cq is None:
            raise ValueError(
                f"{ins.label}: {ins.kind.value} needs a channel (Instr.cq)")
        if ins.kind is OpKind.CQ_PUSH:
            src = ins.srcs[0] if ins.srcs else None
            return (0, ins.cq, src, ins.push_val or ins.label,
                    ins.spec.latency + ccfg.cq_latency)
        expect = ins.expects[0] if ins.expects else None
        return (1, ins.cq, ins.srcs[0], expect, ins.label)
    if ins.kind is OpKind.DMA_START:
        return (2, ins.spec.latency + ccfg.dma_setup
                + ins.dma_words * ccfg.dma_cycles_per_word,
                E_DMA_WORD * ins.dma_words)
    if ins.kind is OpKind.DMA_WAIT:
        return (3,)
    return None


def _fabric_reason(core, m: Tuple, now: int) -> Optional[str]:
    """The fabric stall cause blocking ``m`` at ``now``, or ``None``.
    Shared verbatim by both engines (cause-string level; the event core
    maps causes to pre-formatted keys)."""
    tag = m[0]
    if tag == 0:
        if len(core._fabric.channel(m[1])) >= core._fabric.depth:
            return "cq_full"
    elif tag == 1:
        ch = core._fabric.channel(m[1])
        if not ch or ch[0][0] > now:
            return "cq_empty"
    elif tag == 2:
        if len(core._dma.inflight) >= core._dma.buffers:
            return "dma"
    else:
        infl = core._dma.inflight
        if infl and infl[0] > now:
            return "dma"
    return None


def _fabric_issue(core, m: Tuple, now: int) -> None:
    """Apply ``m``'s fabric side effects at issue time.  Runs *before* the
    base issue path so a CQ_POP's value lands in ``env`` for the base
    machinery (fn / dst / intra-core pushes) to consume."""
    tag = m[0]
    if tag == 0:
        core._fabric.push(m[1], now + m[4], m[3], core.env.get(m[2]))
        core.energy += E_CQ_ACCESS
    elif tag == 1:
        _vis, name, val = core._fabric.pop(m[1])
        core.env[m[2]] = val
        if m[3] is not None and m[3] != name:
            core._fabric.violations.append((m[4], m[1], m[3], name))
        core.energy += E_CQ_ACCESS
    elif tag == 2:
        core._dma.inflight.append(now + m[1])
        core.energy += m[2]
    else:
        if core._dma.inflight:
            core._dma.inflight.popleft()


#: event-engine stall-key maps per fabric cause string
_FAB_KEYS = {"cq_full": CQ_FULL_STALL_KEYS,
             "cq_empty": CQ_EMPTY_STALL_KEYS,
             "dma": DMA_STALL_KEYS}

_NEVER = float("inf")


class _CoreStepper(Stepper):
    """One cluster core: the event-driven engine + the shared bank gate.

    With no interconnect pressure (one core, infinite banks) and no fabric
    ops every override below is a no-op pass-through — the degenerate
    cluster core runs the exact single-core code path, which is the
    bit-identity contract.
    """

    def __init__(self, prog: Program, ccfg: "ClusterConfig",
                 ic: _Interconnect, fabric: _ChannelFabric):
        super().__init__(prog, ccfg.machine)
        self._ic = ic
        self._fabric = fabric
        self._dma = _DmaEngine(ccfg.dma_buffers)
        #: id(exec_facts) -> bank, for TCDM-touching instructions only
        self._bank: Dict[int, int] = {}
        self._mem_ids: set = set()
        #: id(exec_facts) -> fabric meta (see ``_fabric_meta``)
        self._fab: Dict[int, Tuple] = {}
        for _u, lst in self.order:
            for ins in lst:
                m = _fabric_meta(ins, ccfg)
                if m is not None:
                    fid = id(ins.exec_facts)
                    self._fab[fid] = m
                    # channel ops touch the channel's TCDM bank for one
                    # cycle; DMA descriptors and transfers stay bank-free
                    if m[0] <= 1 and ic.banks is not None:
                        self._bank[fid] = m[1] % ic.banks
                elif ins.kind in MEM_KINDS and not ins.local:
                    self._mem_ids.add(id(ins.exec_facts))
                    if ic.banks is not None:
                        self._bank[id(ins.exec_facts)] = ic.bank_of(ins.label)
        if self._bank or self._fab:
            # another core can extend a bank window or mutate a channel
            # while a unit waits, so the per-unit exact-wake skip is unsound
            # here; replace (never mutate: the skip table is cached on the
            # Program) each row's skip flags with all-False.  The
            # whole-machine jump re-checks conditions on wake and stays
            # sound (channel clear-times are additionally capped below).
            for row in self._rows:
                row[2] = [False] * len(row[2])

    # -- fabric + bank gates around the single-core issue conditions --------
    # Check order, identical in both engines: busy -> fabric -> the
    # single-core conditions -> bank.

    def _reason_key(self, f, now: int) -> Optional[str]:
        m = self._fab.get(id(f))
        if m is not None:
            if self._busy[f[14]] > now:
                return f[6]
            cause = _fabric_reason(self, m, now)
            if cause is not None:
                return _FAB_KEYS[cause][f[0]]
        key = super()._reason_key(f, now)
        if key is None and self._bank:
            b = self._bank.get(id(f))
            if b is not None and self._ic.free_at(b) > now:
                return BANK_STALL_KEYS[f[0]]
        return key

    def _clear_times(self, f) -> Tuple[List[Tuple[str, float]], float]:
        m = self._fab.get(id(f))
        if m is not None and m[0] <= 1:
            # channel state is mutable by other cores, so no clear-time a
            # blocked core computes is trustworthy: cap the jump at one
            # cycle (per-cycle re-check; empty bulk-attribution ranges keep
            # the stall split bit-identical to the reference)
            key = _FAB_KEYS["cq_full" if m[0] == 0 else "cq_empty"][f[0]]
            t = self.cycle + 1
            return [(key, t)], t
        ev, t_max = super()._clear_times(f)
        if m is not None:
            # DMA state is core-local and final at issue: exact clear-times.
            # Insert after the busy entry so the bulk-attribution walk sees
            # the same check order as _reason_key / _block_reason.
            if m[0] == 2:
                if len(self._dma.inflight) >= self._dma.buffers:
                    # only a later same-unit DMA_WAIT could free a buffer —
                    # impossible while this op blocks the unit: deadlock
                    ev.insert(1, (DMA_STALL_KEYS[f[0]], _NEVER))
                    t_max = _NEVER
            else:
                infl = self._dma.inflight
                if infl:
                    t = infl[0]
                    ev.insert(1, (DMA_STALL_KEYS[f[0]], t))
                    if t > t_max:
                        t_max = t
        if self._bank:
            b = self._bank.get(id(f))
            if b is not None:
                t = self._ic.free_at(b)
                ev.append((BANK_STALL_KEYS[f[0]], t))
                if t > t_max:
                    t_max = t
        return ev, t_max

    def _issue(self, f, now: int) -> int:
        fid = id(f)
        m = self._fab.get(fid)
        if m is not None:
            _fabric_issue(self, m, now)
            if m[0] <= 1:
                b = self._bank.get(fid)
                if b is not None:
                    self._ic.acquire(b, now, penalty=1)
                self.energy += self._ic.e_access
        elif fid in self._mem_ids:
            if self._bank:
                self._ic.acquire(self._bank[fid], now)
            self.energy += self._ic.e_access
        return super()._issue(f, now)


class _RefCoreStepper(ReferenceStepper):
    """Naive per-cycle cluster core — the differential oracle for
    :class:`_CoreStepper` (``engine="cycle"``), with the same fabric and
    bank gates in the same check order."""

    def __init__(self, prog: Program, ccfg: "ClusterConfig",
                 ic: _Interconnect, fabric: _ChannelFabric):
        super().__init__(prog, ccfg.machine)
        self._ic = ic
        self._fabric = fabric
        self._dma = _DmaEngine(ccfg.dma_buffers)
        self._bank: Dict[int, int] = {}
        self._mem_ids: set = set()
        self._fab: Dict[int, Tuple] = {}
        for _u, lst in self.order:
            for ins in lst:
                m = _fabric_meta(ins, ccfg)
                if m is not None:
                    self._fab[id(ins)] = m
                    if m[0] <= 1 and ic.banks is not None:
                        self._bank[id(ins)] = m[1] % ic.banks
                elif ins.kind in MEM_KINDS and not ins.local:
                    self._mem_ids.add(id(ins))
                    if ic.banks is not None:
                        self._bank[id(ins)] = ic.bank_of(ins.label)

    def _block_reason(self, ins, now: int) -> Optional[str]:
        m = self._fab.get(id(ins))
        if m is not None:
            if self.unit_busy[ins.unit] > now:
                return "busy"
            cause = _fabric_reason(self, m, now)
            if cause is not None:
                return cause
        reason = super()._block_reason(ins, now)
        if reason is None and self._bank:
            b = self._bank.get(id(ins))
            if b is not None and self._ic.free_at(b) > now:
                return "bank"
        return reason

    def _do_issue(self, ins, now: int) -> int:
        iid = id(ins)
        m = self._fab.get(iid)
        if m is not None:
            _fabric_issue(self, m, now)
            if m[0] <= 1:
                b = self._bank.get(iid)
                if b is not None:
                    self._ic.acquire(b, now, penalty=1)
                self.energy += self._ic.e_access
        elif iid in self._mem_ids:
            if self._bank:
                self._ic.acquire(self._bank[iid], now)
            self.energy += self._ic.e_access
        return super()._do_issue(ins, now)


@dataclass
class ClusterResult:
    """Aggregate outcome of one cluster run.  ``cycles`` is the makespan
    (slowest core); energy is the sum over cores *including* interconnect
    energy; the per-core :class:`~.machine.SimResult`\\ s keep full detail
    (env, FIFO sequences) for equivalence checking."""
    name: str
    policy: ExecutionPolicy
    n_cores: int
    tcdm_banks: Optional[int]
    cycles: int
    n_samples: int
    energy: float
    core_results: List[SimResult]
    #: inter-core channel traffic (cluster-wide, ordered by the scheduler)
    cq_pushes: int = 0
    cq_pops: int = 0
    #: channel entries popped out of expected value order
    cq_violations: int = 0

    @property
    def total_instrs(self) -> int:
        return sum(r.total_instrs for r in self.core_results)

    @property
    def ipc(self) -> float:
        """Aggregate IPC over the makespan — up to ``2 * n_cores``."""
        return self.total_instrs / self.cycles if self.cycles else 0.0

    @property
    def ipc_per_core(self) -> float:
        """Mean per-core IPC (each core over its own busy cycles)."""
        if not self.core_results:
            return 0.0
        return sum(r.ipc for r in self.core_results) / len(self.core_results)

    @property
    def throughput(self) -> float:          # samples / cycle, aggregate
        return self.n_samples / self.cycles if self.cycles else 0.0

    @property
    def power(self) -> float:
        return self.energy / self.cycles if self.cycles else 0.0

    @property
    def efficiency(self) -> float:          # samples / energy
        return self.n_samples / self.energy if self.energy else 0.0

    @property
    def instrs(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for r in self.core_results:
            for k, v in r.instrs.items():
                out[k] = out.get(k, 0) + v
        return out

    @property
    def stalls(self) -> Dict[str, int]:
        """Merged stall breakdown; ``*_bank`` keys are the contention."""
        out: Dict[str, int] = {}
        for r in self.core_results:
            for k, v in r.stalls.items():
                out[k] = out.get(k, 0) + v
        return out

    @property
    def bank_stalls(self) -> int:
        return sum(v for k, v in self.stalls.items() if k.endswith("_bank"))

    @property
    def cq_stalls(self) -> int:
        """Cycles lost to inter-core channel back-pressure (full + empty)."""
        return sum(v for k, v in self.stalls.items()
                   if k.endswith("_cq_empty") or k.endswith("_cq_full"))

    @property
    def dma_stalls(self) -> int:
        return sum(v for k, v in self.stalls.items() if k.endswith("_dma"))

    @property
    def max_queue_occupancy(self) -> Dict[Queue, int]:
        out = {q: 0 for q in Queue}
        for r in self.core_results:
            for q, v in r.max_queue_occupancy.items():
                if v > out[q]:
                    out[q] = v
        return out

    @property
    def fifo_violations(self) -> int:
        return (sum(len(r.fifo_violations) for r in self.core_results)
                + self.cq_violations)

    def summary(self) -> Dict[str, object]:
        """Primitive-typed record mirroring ``SimResult.summary`` with the
        cluster aggregates added."""
        return {
            "name": self.name,
            "policy": self.policy.value,
            "n_cores": self.n_cores,
            "tcdm_banks": self.tcdm_banks,
            "cycles": self.cycles,
            "n_samples": self.n_samples,
            "instrs_int": self.instrs.get("int", 0),
            "instrs_fp": self.instrs.get("fp", 0),
            "ipc": self.ipc,
            "ipc_per_core": self.ipc_per_core,
            "energy": self.energy,
            "power": self.power,
            "throughput": self.throughput,
            "efficiency": self.efficiency,
            "max_occ_i2f": self.max_queue_occupancy.get(Queue.I2F, 0),
            "max_occ_f2i": self.max_queue_occupancy.get(Queue.F2I, 0),
            "fifo_violations": self.fifo_violations,
            "bank_stalls": self.bank_stalls,
            "cq_stalls": self.cq_stalls,
            "dma_stalls": self.dma_stalls,
            "cq_pushes": self.cq_pushes,
            "stalls": dict(self.stalls),
        }


class ClusterStepper:
    """Advance N per-core steppers under the shared TCDM arbiter.

    ``progs`` are the per-core programs (``transform.partition_kernel``
    output, or any list of independent Programs — one per core).  The
    scheduler always steps the core with the smallest local cycle, ties
    broken by core index (core 0 has interconnect priority), which makes
    the contention semantics deterministic and engine-independent.
    """

    def __init__(self, progs: Sequence[Program],
                 cfg: Optional[ClusterConfig] = None,
                 engine: str = "event"):
        progs = list(progs)
        cfg = cfg or ClusterConfig(n_cores=len(progs))
        if len(progs) != cfg.n_cores:
            raise ValueError(
                f"got {len(progs)} per-core programs for n_cores={cfg.n_cores}")
        if engine not in ENGINES:
            raise ValueError(f"unknown engine {engine!r} (have {ENGINES})")
        self.cfg = cfg
        self.interconnect = _Interconnect(
            banks=cfg.tcdm_banks, penalty=cfg.bank_conflict_penalty,
            e_access=cfg.interconnect_energy if cfg.n_cores > 1 else 0.0)
        self.fabric = _ChannelFabric(cfg.cq_depth)
        core_cls = _CoreStepper if engine == "event" else _RefCoreStepper
        self.cores = [core_cls(p, cfg, self.interconnect, self.fabric)
                      for p in progs]

    def run(self) -> ClusterResult:
        cores = self.cores
        live = list(range(len(cores)))
        while live:
            # global-time-ordered advance: the min-cycle core acts next, so
            # every arbiter decision at cycle t already saw all accesses at
            # cycles < t and lower-indexed cores' accesses at t
            c = min(live, key=lambda i: (cores[i].cycle, i))
            try:
                if not cores[c].step():
                    live.remove(c)
            except DeadlockError as err:
                raise self._cluster_deadlock(c, err) from err
        return self.result()

    def _cluster_deadlock(self, c: int, err: DeadlockError) -> DeadlockError:
        """Annotate a per-core deadlock with the cluster-wide picture: a
        cyclic cross-core channel wait surfaces here (the first core to
        exhaust its no-progress horizon raises), and the channel occupancy
        plus every core's local cycle make the cycle legible."""
        chans = {ch: len(q) for ch, q in sorted(self.fabric.channels.items())}
        cycles = [core.cycle for core in self.cores]
        return DeadlockError(
            f"cross-core deadlock detected at core {c}: {err}; "
            f"channel occupancy {chans}; per-core cycles {cycles}")

    def result(self) -> ClusterResult:
        results = [c.result() for c in self.cores]
        prog0 = self.cores[0].prog
        return ClusterResult(
            name=prog0.kernel_name,
            policy=prog0.policy,
            n_cores=self.cfg.n_cores,
            tcdm_banks=self.cfg.tcdm_banks,
            cycles=max((r.cycles for r in results), default=0),
            n_samples=sum(r.n_samples for r in results),
            energy=sum(r.energy for r in results),
            core_results=results,
            cq_pushes=len(self.fabric.push_seq),
            cq_pops=len(self.fabric.pop_seq),
            cq_violations=len(self.fabric.violations),
        )


def simulate_cluster(progs: Sequence[Program],
                     cfg: Optional[ClusterConfig] = None,
                     engine: str = "event") -> ClusterResult:
    """One-shot convenience entry point, mirroring ``machine.simulate``."""
    return ClusterStepper(progs, cfg, engine).run()
