"""Pareto-front extraction and CSV emission for DSE sweep records.

The sweep trades two objectives per kernel: IPC (maximize) against energy
(minimize).  A configuration is *dominated* when another configuration is at
least as good on both axes and strictly better on one; the Pareto front is
the set of non-dominated configurations — the only hardware points worth
building.  The helpers are attribute-generic so other trade-offs (e.g.
throughput vs power) reuse the same machinery.
"""
from __future__ import annotations

import csv
import operator
from typing import Dict, Iterable, List, Sequence, TextIO, Union

from .metrics import group_by
from .sweep import (CSV_FIELDS, LEGACY_CSV_FIELDS, PRE_PIPELINE_CSV_FIELDS,
                    SweepRecord, record_to_row)


def dominates(a: SweepRecord, b: SweepRecord,
              maximize: str = "ipc", minimize: str = "energy") -> bool:
    """True if ``a`` is at least as good as ``b`` on both axes and strictly
    better on at least one."""
    ga, gb = getattr(a, maximize), getattr(b, maximize)
    ca, cb = getattr(a, minimize), getattr(b, minimize)
    return ga >= gb and ca <= cb and (ga > gb or ca < cb)


def pareto_front(records: Iterable[SweepRecord],
                 maximize: str = "ipc",
                 minimize: str = "energy") -> List[SweepRecord]:
    """Non-dominated subset of ``records``, sorted by the minimized axis.

    Only ``status == "ok"`` records participate; rejected/deadlocked points
    cannot be on a hardware trade-off curve.
    """
    ok = [r for r in records if r.ok]
    # sort: ascending cost, descending gain — then one monotone pass suffices
    ok.sort(key=lambda r: (getattr(r, minimize), -getattr(r, maximize)))
    front: List[SweepRecord] = []
    best_gain = best_gain_cost = None
    for r in ok:
        g, c = getattr(r, maximize), getattr(r, minimize)
        if best_gain is None or g > best_gain:
            front.append(r)
            best_gain, best_gain_cost = g, c
        elif g == best_gain and c == best_gain_cost:
            front.append(r)          # exact tie on both axes: also non-dominated
    return front


def pareto_by_kernel(records: Iterable[SweepRecord],
                     maximize: str = "ipc",
                     minimize: str = "energy") -> Dict[str, List[SweepRecord]]:
    """Per-kernel Pareto fronts (kernels are not comparable to each other)."""
    return {k: pareto_front(rs, maximize, minimize)
            for k, rs in sorted(group_by(records, operator.attrgetter("kernel")).items())}


def write_csv(records: Sequence[SweepRecord],
              dest: Union[str, TextIO]) -> int:
    """Write sweep records as CSV (``CSV_FIELDS`` order); returns row count."""
    def _emit(fh: TextIO) -> int:
        w = csv.DictWriter(fh, fieldnames=list(CSV_FIELDS))
        w.writeheader()
        for r in records:
            w.writerow(record_to_row(r))
        return len(records)

    if isinstance(dest, str):
        with open(dest, "w", newline="") as fh:
            return _emit(fh)
    return _emit(dest)


def _parse_stalls(packed: str) -> Dict[str, int]:
    if not packed:
        return {}
    out: Dict[str, int] = {}
    for item in packed.split(";"):
        k, _, v = item.partition("=")
        out[k] = int(v)
    return out


#: per-column parsers for :func:`read_csv`; ``None``-able ints map "" back
_OPT_INT = ("unroll_int", "queue_depth_i2f", "queue_depth_f2i", "tcdm_banks")
_INT = ("queue_depth", "queue_latency", "unroll", "n_samples", "cycles",
        "instrs_int", "instrs_fp", "max_occ_i2f", "max_occ_f2i",
        "fifo_violations", "n_cores", "bank_stalls", "cq_depth",
        "dma_buffers", "cq_stalls", "dma_stalls")
_FLOAT = ("ipc", "energy", "power", "throughput", "efficiency",
          "ipc_per_core")


def row_to_record(row: Dict[str, str]) -> SweepRecord:
    """Inverse of ``sweep.record_to_row`` — exact for every field (floats
    survive because ``str(float)`` is repr-round-trippable).

    Rows from older CSVs parse too: PR-2-era rows (no cluster columns)
    default to the single-PE machine (``n_cores=1``, conflict-free TCDM,
    per-core IPC == aggregate IPC), and PR-5-era rows (no pipeline columns)
    default to the work-partitioned cluster (``pipeline=False`` with the
    default channel/DMA geometry)."""
    kw: Dict[str, object] = dict(row)
    kw.setdefault("n_cores", "1")
    kw.setdefault("tcdm_banks", "")
    kw.setdefault("bank_stalls", "0")
    kw.setdefault("ipc_per_core", row.get("ipc", "0.0"))
    kw.setdefault("pipeline", "0")
    kw.setdefault("cq_depth", "4")
    kw.setdefault("dma_buffers", "2")
    kw.setdefault("cq_stalls", "0")
    kw.setdefault("dma_stalls", "0")
    for f in _INT:
        kw[f] = int(kw[f])
    for f in _OPT_INT:
        kw[f] = int(kw[f]) if kw[f] != "" else None
    for f in _FLOAT:
        kw[f] = float(kw[f])
    kw["equivalent"] = bool(int(row["equivalent"]))
    kw["pipeline"] = bool(int(kw["pipeline"]))
    kw["stalls"] = _parse_stalls(row["stalls"])
    return SweepRecord(**kw)     # type: ignore[arg-type]


def read_csv(src: Union[str, TextIO]) -> List[SweepRecord]:
    """Re-parse a :func:`write_csv` emission back into sweep records; the
    round trip is lossless (tested in ``tests/test_calibration.py``).
    Accepts the current header plus the two prior generations: the PR-5-era
    one without the pipeline columns and the PR-2-era one without the
    cluster columns (older records come back with defaulted new fields)."""
    def _load(fh: TextIO) -> List[SweepRecord]:
        reader = csv.DictReader(fh)
        header = tuple(reader.fieldnames or ())
        if header not in (CSV_FIELDS, PRE_PIPELINE_CSV_FIELDS,
                          LEGACY_CSV_FIELDS):
            raise ValueError(
                f"CSV header {reader.fieldnames} != expected {CSV_FIELDS} "
                f"(or the pre-pipeline / pre-cluster legacy layouts)")
        return [row_to_record(row) for row in reader]

    if isinstance(src, str):
        with open(src, newline="") as fh:
            return _load(fh)
    return _load(src)


def format_front(front: Sequence[SweepRecord]) -> str:
    """Human-readable table for one kernel's Pareto front."""
    hdr = (f"{'policy':<10} {'depth':>5} {'lat':>3} {'unroll':>6} "
           f"{'cores':>5} {'banks':>5} "
           f"{'ipc':>6} {'energy':>10} {'cycles':>7} {'eff':>9}")
    lines = [hdr, "-" * len(hdr)]
    for r in front:
        banks = "-" if r.tcdm_banks is None else r.tcdm_banks
        lines.append(f"{r.policy:<10} {r.queue_depth:>5} {r.queue_latency:>3} "
                     f"{r.unroll:>6} {r.n_cores:>5} {banks:>5} "
                     f"{r.ipc:>6.3f} {r.energy:>10.1f} "
                     f"{r.cycles:>7} {r.efficiency:>9.2e}")
    return "\n".join(lines)
