"""COPIFTv2 core: the paper's methodology as executable transforms + a
cycle-approximate Snitch/FPSS machine model, plus the ExecutionPolicy enum
that threads the dual-stream idea through the TPU layers of the framework."""
from .bench_kernels import KERNELS
from .dfg import LoopDFG, Node, s
from .isa import Instr, OpKind, Queue, Unit
from .machine import DeadlockError, MachineConfig, Program, SimResult, simulate
from .metrics import (PAPER_CLAIMS, KernelComparison, geomean, run_suite,
                      summarize)
from .policy import ExecutionPolicy
from .transform import TransformConfig, analyze, lower

__all__ = [
    "KERNELS", "LoopDFG", "Node", "s", "Instr", "OpKind", "Queue", "Unit",
    "DeadlockError", "MachineConfig", "Program", "SimResult", "simulate",
    "PAPER_CLAIMS", "KernelComparison", "geomean", "run_suite", "summarize",
    "ExecutionPolicy", "TransformConfig", "analyze", "lower",
]
