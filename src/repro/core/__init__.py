"""COPIFTv2 core: the paper's methodology as executable transforms + a
cycle-approximate Snitch/FPSS machine model, a design-space exploration
engine sweeping (kernel x policy x queue geometry x unroll) grids with
Pareto-front extraction, plus the ExecutionPolicy enum that threads the
dual-stream idea through the TPU layers of the framework."""
from .batch_cluster import (BatchClusterDeadlock, BatchClusterStepper,
                            BatchClusterUnsupported, batch_cluster_simulate,
                            batch_cluster_supported)
from .batch_machine import (BatchDeadlock, BatchStepper, BatchUnsupported,
                            batch_simulate, batch_supported)
from .bench_kernels import KERNELS
from .cluster import (ClusterConfig, ClusterResult, ClusterStepper,
                      simulate_cluster)
from .dfg import LoopDFG, Node, s
from .isa import Instr, OpKind, Queue, Unit
from .machine import (ENGINES, DeadlockError, MachineConfig, Program,
                      ReferenceStepper, SimResult, Stepper, simulate,
                      stepper_for)
from .metrics import (PAPER_CLAIMS, KernelComparison, best, geomean,
                      group_by, run_suite, summarize)
from .calibrate import (SCHEMA_VERSION, CalibrationError, CalibrationRecord,
                        StaleArtifactError, calibrate, calibration_dir,
                        load_calibration, select_operating_point,
                        validate_artifact, write_artifact)
from .pareto import (dominates, format_front, pareto_by_kernel, pareto_front,
                     read_csv, write_csv)
from .policy import (WORKLOAD_PROXIES, WORKLOAD_QUEUE_LATENCIES,
                     ExecutionPolicy, OperatingPoint, PolicyTable,
                     clear_policy_table_cache, default_table)
from .search import (adaptive_sweep, eps_dominated, front_matches,
                     run_search, scale_fidelity)
from .sweep import (CSV_FIELDS, LEGACY_CSV_FIELDS, PRE_PIPELINE_CSV_FIELDS,
                    STRATEGIES, SWEEP_ENGINES, SweepPoint, SweepRecord,
                    clear_worker_caches, grid, partition_points,
                    resolve_workers, run_point, run_sweep, sweep_summary)
from .transform import (TransformConfig, analyze, lower, partition_kernel,
                        partition_pipeline)

__all__ = [
    "KERNELS", "LoopDFG", "Node", "s", "Instr", "OpKind", "Queue", "Unit",
    "ClusterConfig", "ClusterResult", "ClusterStepper", "simulate_cluster",
    "DeadlockError", "ENGINES", "MachineConfig", "Program",
    "ReferenceStepper", "SimResult", "Stepper", "simulate", "stepper_for",
    "PAPER_CLAIMS", "KernelComparison", "best", "geomean",
    "group_by", "run_suite", "summarize",
    "dominates", "format_front", "pareto_by_kernel", "pareto_front",
    "read_csv", "write_csv",
    "SCHEMA_VERSION", "CalibrationError", "CalibrationRecord",
    "StaleArtifactError", "calibrate", "calibration_dir", "load_calibration",
    "select_operating_point", "validate_artifact", "write_artifact",
    "WORKLOAD_PROXIES", "WORKLOAD_QUEUE_LATENCIES", "ExecutionPolicy",
    "OperatingPoint", "PolicyTable",
    "clear_policy_table_cache", "default_table",
    "TransformConfig", "analyze", "lower", "partition_kernel",
    "partition_pipeline",
    "CSV_FIELDS", "LEGACY_CSV_FIELDS", "PRE_PIPELINE_CSV_FIELDS",
    "STRATEGIES", "SWEEP_ENGINES", "SweepPoint", "SweepRecord",
    "clear_worker_caches", "grid", "partition_points", "resolve_workers",
    "run_point", "run_sweep", "sweep_summary",
    "BatchDeadlock", "BatchStepper", "BatchUnsupported", "batch_simulate",
    "batch_supported",
    "BatchClusterDeadlock", "BatchClusterStepper", "BatchClusterUnsupported",
    "batch_cluster_simulate", "batch_cluster_supported",
    "adaptive_sweep", "eps_dominated", "front_matches", "run_search",
    "scale_fidelity",
]
