"""Lowering of LoopDFGs to stream programs — the paper's methodologies.

``lower(dfg, policy, ...)`` produces a :class:`Program` for the machine model:

* BASELINE — the original loop, unrolled/interleaved like a compiler would,
  all instructions fetched by the single-issue integer core (FP instructions
  are offloaded to the FPSS but still consume the shared issue port).
* COPIFT — DAC'25 [1], Steps 1–6: partition the DFG into alternating
  integer/FP *phases*, batch samples, spill every cross-thread value to
  memory (store + SSR stream readback), software-pipeline the batches in a
  wavefront with double-buffered spill memory and batch-granular semaphore
  synchronization (FREP launches issued by the integer core).
* COPIFTV2 — this paper, Steps 1–5: partition and schedule once; map every
  cross-thread edge onto the I2F/F2I hardware queues (x31 / integer-operand
  CSR semantics); the FP subgraph runs under a single FREP loop.  No loop
  transformations, no spills, no batch semaphores.

Value/typing model (mirrors the ISA):
 - every value is *int-typed* (produced by an integer-core op, or by an
   FP-unit op with an integer rd such as ``fcvt.w.d``) or *fp-typed*;
 - int-typed values live in the integer RF or in a queue — never the FP RF;
 - under COPIFTv2's CSR, an FP-unit instruction with integer rd *pushes* F2I
   instead of writing a register, and an FP-unit instruction with an integer
   rs *pops* I2F;
 - shim instructions are inserted only where the ISA demands them:
   ``MV x31, rs`` re-pushes (multi-consumer or RF-resident values),
   ``MV rd, x31`` pops to the integer RF (multi-consumer receptions),
   ``FMV_PUSH`` moves an fp-typed value to the integer thread.

FIFO discipline: per queue, push order must equal pop order.  The lowering
reorders movable shims to satisfy it and the tests verify it value-by-value.
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, replace
from typing import Any, Dict, List, Optional, Tuple

from .dfg import LoopDFG, Node
from .isa import (E_SSR_STREAM, INT_DST_FP_KINDS, Instr, OpKind,
                  Queue, Unit)
from .machine import Program
from .policy import ExecutionPolicy


@dataclass(frozen=True)
class TransformConfig:
    """Lowering parameters.  Frozen (hashable) on purpose: a TransformConfig
    is the memo key for ``core.sweep``'s per-worker lowering cache, so two
    sweep points whose transform-relevant fields agree share one lowered
    Program.  Note there is no ``queue_latency`` here — visibility latency is
    a :class:`~.machine.MachineConfig` property the schedule never sees."""
    unroll: int = 8          # Step 3: samples interleaved in the schedule
    unroll_int: Optional[int] = None   # COPIFTv2 integer-stream interleave
    #   (defaults to ``unroll``; the int stream is scheduled *against* the
    #   realized FP queue order, see lower_copiftv2)
    batch: int = 32          # COPIFT only: samples per batch
    sync_cost: int = 2       # COPIFT: int-core instrs to config/launch a phase
    queue_depth: int = 8     # hardware FIFO depth the schedule targets
    n_samples: int = 512

    #: policies whose lowering actually reads ``queue_depth`` (the COPIFTv2
    #: cross-stream replay gate).  BASELINE has no queues and COPIFT spills
    #: through memory, so for those the depth axis can be normalized out of
    #: the memo key — one lowering serves every swept depth.
    DEPTH_SENSITIVE_POLICIES = frozenset({ExecutionPolicy.COPIFTV2})

    def lowering_key(self, policy: ExecutionPolicy) -> Tuple:
        """Hashable memo key: every field the ``lower()`` output depends on
        under ``policy``."""
        depth = (self.queue_depth
                 if policy in self.DEPTH_SENSITIVE_POLICIES else None)
        return (policy.value, self.unroll, self.unroll_int, self.batch,
                self.sync_cost, depth, self.n_samples)


def vid(name: str, i: int) -> str:
    return f"{name}@{i}"


class _Builder:
    def __init__(self) -> None:
        self._uid = itertools.count()

    def instr(self, kind: OpKind, label: str, srcs=(), dst=None, pushes=(),
              push_val=None, sample=-1, fn=None, extra_energy=0.0,
              expects=(), cq=None, dma_words=0, local=False) -> Instr:
        return Instr(uid=next(self._uid), kind=kind, label=label,
                     srcs=tuple(srcs), dst=dst, pushes=tuple(pushes),
                     push_val=push_val, sample=sample, fn=fn,
                     extra_energy=extra_energy, expects=tuple(expects),
                     cq=cq, dma_words=dma_words, local=local)


def _identity(x):
    return x


# ---------------------------------------------------------------------------
# Steps 1-2: partition & communication analysis
# ---------------------------------------------------------------------------

@dataclass
class CommPlan:
    dfg: LoopDFG
    int_nodes: List[Node]          # executed on the integer core
    fp_nodes: List[Node]           # executed on the FPSS
    exec_unit: Dict[str, Unit]     # producer execution unit per value
    vtype: Dict[str, Unit]         # INT => int-typed, FP => fp-typed
    # int-typed value -> FP-side consumptions (I2F pops), in FP program order
    i2f_uses: Dict[str, List[Tuple[Node, int]]]
    # value produced on the FPSS -> integer-side consumptions (F2I pops)
    int_receives: Dict[str, List[Tuple[Node, int]]]


def analyze(dfg: LoopDFG) -> CommPlan:
    int_nodes, fp_nodes = [], []
    exec_unit: Dict[str, Unit] = {}
    vtype: Dict[str, Unit] = {}
    for name in dfg.inputs:
        home = dfg.input_homes.get(name, Unit.FP)
        exec_unit[name] = home
        vtype[name] = home
    for n in dfg.nodes:
        u = dfg.node_unit(n)
        (fp_nodes if u is Unit.FP else int_nodes).append(n)
        exec_unit[n.name] = u
        vtype[n.name] = Unit.INT if (u is Unit.INT or n.kind in INT_DST_FP_KINDS) else Unit.FP

    i2f: Dict[str, List[Tuple[Node, int]]] = {}
    recv: Dict[str, List[Tuple[Node, int]]] = {}
    for n in dfg.nodes:
        side = dfg.node_unit(n)
        for idx, (src, lag) in enumerate(n.srcs):
            if lag != 0:
                if vtype[src] is not (Unit.INT if side is Unit.INT else Unit.FP) \
                        or exec_unit[src] is not side:
                    raise ValueError(
                        f"{dfg.name}: loop-carried dep {src}->{n.name} must stay "
                        "within one thread; restructure the kernel")
                continue
            if src in dfg.inputs and exec_unit[src] is not side:
                raise ValueError(
                    f"{dfg.name}: input {src} consumed across the partition; "
                    "route it through an explicit load node")
            if side is Unit.FP and vtype[src] is Unit.INT:
                i2f.setdefault(src, []).append((n, idx))
            elif side is Unit.INT and exec_unit[src] is Unit.FP:
                recv.setdefault(src, []).append((n, idx))
    return CommPlan(dfg, int_nodes, fp_nodes, exec_unit, vtype, i2f, recv)


def _int_rf_uses(plan: CommPlan, name: str) -> int:
    """Integer-RF consumptions of an int-core-produced value (lag 0)."""
    return sum(1 for n in plan.int_nodes
               for (src, lag) in n.srcs if src == name and lag == 0)


def _lagged_uses(dfg: LoopDFG, name: str) -> bool:
    return any(src == name and lag > 0 for n in dfg.nodes for (src, lag) in n.srcs)


# ---------------------------------------------------------------------------
# helpers shared by the lowerings
# ---------------------------------------------------------------------------

def _loop_overhead(b: _Builder, g: int, tag: str = "") -> List[Instr]:
    prev = f"lc{tag}@{g-1}" if g > 0 else "init:lc"
    cnt = b.instr(OpKind.IALU, f"lc{tag}@{g}", (prev,), dst=f"lc{tag}@{g}",
                  fn=lambda c: c + 1)
    br = b.instr(OpKind.BR, f"br{tag}@{g}", (f"lc{tag}@{g}",), fn=_identity)
    return [cnt, br]


def _init_env(dfg: LoopDFG, n: int) -> Tuple[Dict[str, Any], List[str]]:
    env: Dict[str, Any] = {"init:lc": 0}
    for name, gen in dfg.inputs.items():
        for i in range(n):
            env[vid(name, i)] = gen(i)
    for name, val in dfg.init.items():
        env[f"init:{name}"] = val
    outputs = [vid(node.name, i) for node in dfg.outputs() for i in range(n)]
    return env, outputs


@dataclass
class CrossSchedule:
    """Constraints for scheduling one stream against the other, already
    fixed, stream (COPIFTv2).  ``fixed`` is replayed lazily against real
    queue-occupancy counters, so the scheduled stream only emits a queue
    operation when the joint in-order execution can actually reach it —
    the structural no-deadlock condition, *including finite queue depth*.

    ``depth_gate_hit`` records whether the finite-depth comparison ever
    constrained the schedule.  When it stays False the produced schedule is
    provably identical for every larger ``queue_depth`` (raising the depth
    only relaxes the two gate comparisons), which is what lets the sweep
    layer reuse one lowered Program across the saturated tail of a depth
    axis."""
    fixed: List[Instr]
    queue_depth: int
    push_order: Dict[Queue, "deque"]    # values this stream must push, FIFO
    pop_order: Dict[Queue, "deque"]     # values this stream will pop, FIFO
    depth_gate_hit: bool = False


def _interleave(per_sample: List[List[Instr]], U: int, b: _Builder,
                loop_overhead: bool, tag: str = "",
                cross: Optional[CrossSchedule] = None,
                pop_avail=None) -> List[Instr]:
    """Step 3: list-schedule the stream, interleaving up to ``U`` samples
    with latency-aware greedy list scheduling, honoring (a) per-sample
    program order, (b) in-stream value dependencies (incl. loop-carried
    chains), and (c) optionally a :class:`CrossSchedule` so the FIFO law
    (global push order == pop order) holds and no cross-stream circular
    wait can arise."""
    out: List[Instr] = []
    n = len(per_sample)
    produced_here = {ins.dst for lst in per_sample for ins in lst if ins.dst}
    done_at: Dict[str, int] = {}     # estimated completion cycle per value
    clock = 0                        # estimated issue clock of this stream
    sample_pops: Dict[int, int] = {} # pops emitted so far, per sample

    # joint queue-state replay of the fixed stream (COPIFTv2 only)
    my_push = {q: 0 for q in Queue}
    my_pop = {q: 0 for q in Queue}
    fx_push = {q: 0 for q in Queue}
    fx_pop = {q: 0 for q in Queue}
    fx_ptr = 0

    def replay_fixed() -> None:
        """Advance the fixed stream as far as the queue state allows."""
        nonlocal fx_ptr
        if cross is None:
            return
        fixed = cross.fixed
        while fx_ptr < len(fixed):
            ins = fixed[fx_ptr]
            need: Dict[Queue, int] = {}
            for q in ins.pops:
                need[q] = need.get(q, 0) + 1
            if any(my_push[q] - fx_pop[q] < k for q, k in need.items()):
                break
            room: Dict[Queue, int] = {}
            for q in ins.pushes:
                room[q] = room.get(q, 0) + 1
            if any(fx_push[q] - my_pop[q] + k > cross.queue_depth
                   for q, k in room.items()):
                cross.depth_gate_hit = True
                break
            for q in ins.pops:
                fx_pop[q] += 1
            for q in ins.pushes:
                fx_push[q] += 1
            fx_ptr += 1

    def gates_ok(ins: Instr) -> bool:
        if cross is None:
            return True
        replay_fixed()
        for q in ins.pushes:
            seq = cross.push_order.get(q)
            if seq is not None and (not seq or seq[0] != ins.push_val):
                return False
            if my_push[q] - fx_pop[q] >= cross.queue_depth:
                cross.depth_gate_hit = True
                return False
        pop_idx: Dict[Queue, int] = {}
        for idx, q in enumerate(ins.pops):
            k = pop_idx.get(q, 0)
            pop_idx[q] = k + 1
            seq = cross.pop_order.get(q)
            if seq is not None:
                want = ins.expects[idx] if idx < len(ins.expects) else None
                if len(seq) <= k or seq[k] != want:
                    return False
            if fx_push[q] - my_pop[q] < k + 1:
                return False
        return True

    def deps_emitted(ins: Instr) -> bool:
        return all(src not in produced_here or src in done_at
                   for src in ins.reg_srcs)

    def t_ready(ins: Instr) -> int:
        t = max((done_at.get(src, 0) for src in ins.reg_srcs), default=0)
        if ins.pops and pop_avail is not None:
            # estimated arrival of this sample's next queue operand(s),
            # given the cross-thread producer's steady-state rate
            k0 = sample_pops.get(ins.sample, 0)
            t = max([t] + [int(pop_avail(ins.sample, k0 + j))
                           for j in range(len(ins.pops))])
        return t

    def emit(ins: Instr) -> None:
        nonlocal clock
        clock = max(clock + 1, t_ready(ins))
        out.append(ins)
        if ins.dst:
            done_at[ins.dst] = clock + ins.spec.latency
        if ins.pops:
            sample_pops[ins.sample] = sample_pops.get(ins.sample, 0) + len(ins.pops)
        if cross is not None:
            for q in ins.pushes:
                my_push[q] += 1
                seq = cross.push_order.get(q)
                if seq is not None and seq:
                    seq.popleft()
            for q in ins.pops:
                my_pop[q] += 1
                seq = cross.pop_order.get(q)
                if seq is not None and seq:
                    seq.popleft()

    # Sliding-window scheduling: up to ``U`` samples in flight; a finished
    # sample immediately admits the next one, so the cross-thread round-trip
    # tail of sample i overlaps the head of sample i+U (the FPSS's FREP loop
    # has no group barrier — neither should the schedule).
    active = list(range(min(U, n)))
    next_idx = len(active)
    ptr = {i: 0 for i in active}
    completed = 0
    groups_done = 0
    rr = 0
    while active:
        best, best_key = None, None
        for off, i in enumerate([active[(rr + o) % len(active)]
                                 for o in range(len(active))]):
            ins = per_sample[i][ptr[i]]
            if not deps_emitted(ins) or not gates_ok(ins):
                continue
            key = (t_ready(ins), off)
            if best_key is None or key < best_key:
                best, best_key = i, key
        if best is None:
            if cross is not None:
                if next_idx < n:
                    # the fixed stream demands a later sample first: widen
                    # the in-flight window instead of failing
                    active.append(next_idx)
                    ptr[next_idx] = 0
                    next_idx += 1
                    continue
                raise ValueError(
                    "infeasible joint schedule: every in-flight sample is "
                    "queue-blocked (increase queue depth or restructure)")
            # blocked only on cross-stream events: emit the oldest
            # instruction; runtime queue semantics order execution.
            best = min(active)
        emit(per_sample[best][ptr[best]])
        ptr[best] += 1
        rr = (active.index(best) + 1) % len(active)
        if ptr[best] >= len(per_sample[best]):
            active.remove(best)
            completed += 1
            if next_idx < n:
                active.append(next_idx)
                ptr[next_idx] = 0
                next_idx += 1
            if loop_overhead and completed % U == 0:
                out.extend(_loop_overhead(b, groups_done, tag))
                groups_done += 1
    if loop_overhead and completed % U:
        out.extend(_loop_overhead(b, groups_done, tag))
    return out


# ---------------------------------------------------------------------------
# BASELINE
# ---------------------------------------------------------------------------

def lower_baseline(dfg: LoopDFG, cfg: TransformConfig) -> Program:
    b = _Builder()
    n, U = cfg.n_samples, cfg.unroll
    init_env, outputs = _init_env(dfg, n)

    per_sample: List[List[Instr]] = []
    for i in range(n):
        lst = []
        for node in dfg.nodes:
            srcs = tuple(f"init:{s}" if i - l < 0 else vid(s, i - l)
                         for (s, l) in node.srcs)
            extra = E_SSR_STREAM if node.out else 0.0   # result streamed out
            lst.append(b.instr(node.kind, f"{node.name}@{i}", srcs,
                               dst=vid(node.name, i), sample=i, fn=node.fn,
                               extra_energy=extra))
        per_sample.append(lst)

    instrs = _interleave(per_sample, U, b, loop_overhead=True)
    return Program(name=dfg.name, policy=ExecutionPolicy.BASELINE,
                   mode="single", streams={Unit.INT: instrs}, n_samples=n,
                   init_env=init_env, output_values=outputs, frep=False)


# ---------------------------------------------------------------------------
# COPIFTv2  (Steps 1-5 of the paper)
# ---------------------------------------------------------------------------

#: process-local cache of the depth-independent prefix of lower_copiftv2
#: (partition, per-sample builds, the scheduled FP stream and its realized
#: queue sequences).  Only the integer stream's joint schedule reads
#: ``queue_depth`` (the CrossSchedule replay gate), so one prefix serves an
#: entire swept depth axis.  Keyed by kernel name + the prefix-relevant
#: config fields, with the LoopDFG identity checked on hit so ad-hoc test
#: graphs reusing a name can never poison the cache.  Each entry is a
#: mutable ``[dfg, prefix, saturation]`` record; ``saturation`` holds
#: ``(depth, Program)`` for the shallowest depth whose integer schedule was
#: built without the depth gate ever firing — that Program is provably what
#: lowering would produce at *any* deeper queue, so the saturated tail of a
#: depth axis shares one Program (and all its cached simulation facts).
_V2_PREFIX_CACHE: Dict[Tuple, List] = {}
_V2_PREFIX_CAP = 32


def _v2_entry(dfg: LoopDFG, cfg: TransformConfig) -> List:
    key = (dfg.name, cfg.unroll, cfg.unroll_int, cfg.n_samples)
    hit = _V2_PREFIX_CACHE.get(key)
    if hit is not None and hit[0] is dfg:
        return hit
    entry = [dfg, _lower_copiftv2_prefix(dfg, cfg), None]
    if len(_V2_PREFIX_CACHE) >= _V2_PREFIX_CAP:
        _V2_PREFIX_CACHE.pop(next(iter(_V2_PREFIX_CACHE)))
    _V2_PREFIX_CACHE[key] = entry
    return entry


def _lower_copiftv2_prefix(dfg: LoopDFG, cfg: TransformConfig) -> Tuple:
    plan = analyze(dfg)
    b = _Builder()
    n, U = cfg.n_samples, cfg.unroll
    init_env, outputs = _init_env(dfg, n)

    i2f_needed = {v: len(uses) for v, uses in plan.i2f_uses.items()}

    # F2I values in the order the FPSS produces them within one sample
    fp_f2i_vals: List[str] = []
    for node in plan.fp_nodes:
        if node.kind in INT_DST_FP_KINDS:
            if node.name in plan.int_receives or node.name in plan.i2f_uses:
                fp_f2i_vals.append(node.name)
        elif node.name in plan.int_receives:
            fp_f2i_vals.append(node.name)

    # The FPSS per-sample queue-event sequence (its schedule preserves
    # per-sample program order, so this is the FIFO reference).  Mirrored,
    # it prescribes the integer thread's queue-op order: an FP pop of v
    # requires the integer push of v before it; an FP push hands v to the
    # integer pop after it.
    events: List[Tuple[str, str]] = []      # (int role: "push"|"pop", value)
    for node in plan.fp_nodes:
        for (sname, lag) in node.srcs:
            if lag == 0 and plan.vtype[sname] is Unit.INT:
                events.append(("push", sname))
        if node.kind in INT_DST_FP_KINDS:
            if node.name in plan.int_receives or node.name in plan.i2f_uses:
                events.append(("pop", node.name))
        elif node.name in plan.int_receives:
            events.append(("pop", node.name))

    # nodes consuming more than one FPSS value would pop several queue
    # entries in one instruction — globally unorderable; alias those values
    multi_recv: set = set()
    for node in plan.int_nodes:
        rv = [sname for (sname, lag) in node.srcs
              if lag == 0 and sname in plan.int_receives]
        if len(rv) > 1:
            multi_recv.update(rv)

    def make_plan(alias_all: bool):
        """Per-value reception shims.  ``alias_all`` forces every reception
        through an MV pop (always sequenceable).  Values that must be pushed
        back to the FPSS are always aliased: a pop+push combo instruction
        would couple the two queues' global orders and can deadlock."""
        alias: Dict[str, str] = {}
        direct_pop: set = set()
        for v in fp_f2i_vals:
            node_uses = len(plan.int_receives.get(v, []))
            repushes = (len(plan.i2f_uses.get(v, []))
                        if plan.exec_unit[v] is Unit.FP else 0)
            if (not alias_all and node_uses == 1 and repushes == 0
                    and v not in multi_recv):
                direct_pop.add(v)
            else:
                alias[v] = f"{v}__i"
        return alias, direct_pop

    alias, direct_pop = make_plan(False)

    def build_sample(i: int) -> Tuple[List[Instr], List[Instr]]:
        # ---- FP stream (the fixed FIFO reference) -----------------------
        fp_list: List[Instr] = []
        for node in plan.fp_nodes:
            srcs: List[object] = []
            expects: List[str] = []
            for (sname, lag) in node.srcs:
                if lag > 0:
                    srcs.append(f"init:{sname}" if i - lag < 0 else vid(sname, i - lag))
                elif plan.vtype[sname] is Unit.INT:
                    srcs.append(Queue.I2F)
                    expects.append(vid(sname, i))
                else:
                    srcs.append(vid(sname, i))
            pushes, push_val, dst = (), None, vid(node.name, i)
            if node.kind in INT_DST_FP_KINDS:
                if node.name in plan.int_receives or node.name in plan.i2f_uses:
                    pushes, push_val = (Queue.F2I,), vid(node.name, i)
                dst = None            # integer rd never writes a register file
                if node.out:
                    raise ValueError(f"{dfg.name}: output {node.name} has integer rd")
            extra = E_SSR_STREAM if node.out else 0.0
            fp_list.append(b.instr(node.kind, f"{node.name}@{i}", tuple(srcs),
                                   dst=dst, pushes=pushes, push_val=push_val,
                                   sample=i, fn=node.fn, extra_energy=extra,
                                   expects=expects))
            if node.kind not in INT_DST_FP_KINDS and node.name in plan.int_receives:
                fp_list.append(b.instr(OpKind.FMV_PUSH, f"fpush:{node.name}@{i}",
                                       (vid(node.name, i),), pushes=(Queue.F2I,),
                                       push_val=vid(node.name, i), sample=i,
                                       fn=_identity))

        # ---- integer stream ---------------------------------------------
        int_list: List[Instr] = []
        for node in plan.int_nodes:
            srcs = []
            expects = []
            for idx, (sname, lag) in enumerate(node.srcs):
                if lag > 0:
                    srcs.append(f"init:{sname}" if i - lag < 0 else vid(sname, i - lag))
                elif sname in direct_pop and (node, idx) == (
                        plan.int_receives[sname][0][0], plan.int_receives[sname][0][1]):
                    srcs.append(Queue.F2I)
                    expects.append(vid(sname, i))
                elif sname in alias:
                    srcs.append(vid(alias[sname], i))
                else:
                    srcs.append(vid(sname, i))
            v = node.name
            pushes, push_val = (), None
            extra_pushes = 0
            if v in plan.i2f_uses and plan.exec_unit[v] is Unit.INT:
                if (i2f_needed[v] == 1 and _int_rf_uses(plan, v) == 0
                        and not _lagged_uses(dfg, v) and not node.out
                        and not expects):
                    pushes, push_val = (Queue.I2F,), vid(v, i)
                else:
                    extra_pushes = i2f_needed[v]
            extra = E_SSR_STREAM if node.out else 0.0
            int_list.append(b.instr(node.kind, f"{v}@{i}", tuple(srcs),
                                    dst=vid(v, i), pushes=pushes,
                                    push_val=push_val, sample=i, fn=node.fn,
                                    extra_energy=extra, expects=expects))
            for _ in range(extra_pushes):
                int_list.append(b.instr(OpKind.MV, f"push:{v}@{i}", (vid(v, i),),
                                        pushes=(Queue.I2F,), push_val=vid(v, i),
                                        sample=i, fn=_identity))

        # MV pops + re-pushes for aliased receptions
        for v in fp_f2i_vals:
            if v not in alias:
                continue
            a = alias[v]
            int_list.append(b.instr(OpKind.MV, f"pop:{v}@{i}", (Queue.F2I,),
                                    dst=vid(a, i), sample=i, fn=_identity,
                                    expects=(vid(v, i),)))
            if plan.exec_unit[v] is Unit.FP:
                for _ in plan.i2f_uses.get(v, []):
                    int_list.append(b.instr(OpKind.MV, f"push:{v}@{i}",
                                            (vid(a, i),), pushes=(Queue.I2F,),
                                            push_val=vid(v, i), sample=i,
                                            fn=_identity))
        int_list = _sequence_by_events(int_list, events, i)
        return int_list, fp_list

    # trial-build sample 0; if the optimized plan cannot be sequenced
    # against the FIFO mirror, fall back to alias-all receptions
    try:
        build_sample(0)
    except ValueError:
        alias, direct_pop = make_plan(True)
        build_sample(0)

    int_samples, fp_samples = [], []
    for i in range(n):
        il, fl = build_sample(i)
        int_samples.append(il)
        fp_samples.append(fl)

    # Two-phase scheduling: the FP stream is scheduled freely (value deps
    # only); its realized queue order then *constrains* the integer stream so
    # the global push order equals the pop order on both queues, and every
    # integer queue op is deferred until the joint in-order execution can
    # actually reach it (replay gate: no deadlock, finite queue depth).
    int_per_sample = len(int_samples[0]) + 2.0 / max(cfg.unroll_int or U, 1)
    fp_per_sample = float(len(fp_samples[0]))
    pushes_per_sample = sum(len(ins.pushes) for ins in int_samples[0])
    pop_avail = None
    if pushes_per_sample:
        # steady-state: the slower stream paces both; the k-th queue operand
        # of sample i arrives roughly when the integer thread has advanced
        # through sample i up to its (k+1)-th push.  If the integer thread
        # itself waits on an F2I value (bidirectional kernels like expf),
        # its chain only *starts* after the FPSS produced that value.
        S = max(int_per_sample, fp_per_sample)
        per_push = int_per_sample / pushes_per_sample
        lead = 0.0
        if any(ins.pops for ins in int_samples[0]):
            f2i_idx = [k for k, ins in enumerate(fp_samples[0])
                       if Queue.F2I in ins.pushes]
            if f2i_idx:
                lead = f2i_idx[-1] + 4.0        # producer pos + lat + queue

        def pop_avail(i, k, _S=S, _pp=per_push, _l=lead):   # noqa: E731
            return _S * i + _l + (k + 1) * _pp + 2.0
    fp_stream = _interleave(fp_samples, U, b, loop_overhead=False,
                            pop_avail=pop_avail)
    i2f_pop_seq: deque = deque()
    f2i_push_seq: deque = deque()
    for ins in fp_stream:
        for q in ins.pushes:
            if q is Queue.F2I:
                f2i_push_seq.append(ins.push_val)
        i2f_pop_seq.extend(ins.expects)
    ui = cfg.unroll_int or U
    # symmetric availability model for the integer stream's F2I pops: the
    # k-th F2I value of sample i appears once the FPSS reaches its producer
    f2i_pos = [k for k, ins in enumerate(fp_samples[0])
               if Queue.F2I in ins.pushes]
    int_pop_avail = None
    if f2i_pos:
        S2 = max(int_per_sample, fp_per_sample)

        def int_pop_avail(i, k, _S=S2, _pos=f2i_pos):   # noqa: E731
            return _S * i + _pos[min(k, len(_pos) - 1)] + 4.0
    return (b, init_env, outputs, n, int_samples, fp_stream,
            tuple(i2f_pop_seq), tuple(f2i_push_seq), ui, int_pop_avail)


def lower_copiftv2(dfg: LoopDFG, cfg: TransformConfig,
                   use_prefix_cache: bool = True) -> Program:
    """Depth-independent prefix (cached, see :func:`_v2_entry`) + the
    per-depth joint schedule of the integer stream against the fixed FP
    stream.  Programs lowered at different depths share the prefix's
    immutable pieces (FP stream, per-sample instruction lists, init env),
    and depths past the gate's saturation point share one Program outright."""
    entry = _v2_entry(dfg, cfg) if use_prefix_cache else None
    if entry is not None:
        sat = entry[2]
        if sat is not None and cfg.queue_depth >= sat[0]:
            return sat[1]            # schedule provably identical up here
        prefix = entry[1]
    else:
        prefix = _lower_copiftv2_prefix(dfg, cfg)
    (b, init_env, outputs, n, int_samples, fp_stream,
     i2f_pop_seq, f2i_push_seq, ui, int_pop_avail) = prefix
    cross = CrossSchedule(fixed=fp_stream, queue_depth=cfg.queue_depth,
                          push_order={Queue.I2F: deque(i2f_pop_seq)},
                          pop_order={Queue.F2I: deque(f2i_push_seq)})
    int_stream = _interleave(int_samples, ui, b, loop_overhead=True,
                             cross=cross, pop_avail=int_pop_avail)
    prog = Program(
        name=dfg.name, policy=ExecutionPolicy.COPIFTV2, mode="dual",
        streams={Unit.INT: int_stream, Unit.FP: fp_stream},
        n_samples=n, init_env=init_env, output_values=outputs, frep=True)
    if entry is not None and not cross.depth_gate_hit:
        if entry[2] is None or cfg.queue_depth < entry[2][0]:
            entry[2] = (cfg.queue_depth, prog)
    return prog


def _sequence_by_events(int_list: List[Instr], events: List[Tuple[str, str]],
                        i: int) -> List[Instr]:
    """Order one sample's integer instructions so its queue operations occur
    exactly in the mirrored FPSS event order (the FIFO law by construction),
    pulling register dependencies forward as needed."""
    by_push: Dict[str, List[Instr]] = {}
    by_pop: Dict[str, List[Instr]] = {}
    for ins in int_list:
        if ins.pushes and ins.push_val is not None:
            by_push.setdefault(ins.push_val, []).append(ins)
        for e in ins.expects:
            by_pop.setdefault(e, []).append(ins)
    prod = {ins.dst: ins for ins in int_list if ins.dst}
    placed: set = set()
    result: List[Instr] = []

    def place(ins: Instr, via_event: bool) -> None:
        if ins.uid in placed:
            return
        if not via_event and (ins.pushes or ins.pops):
            raise ValueError(
                f"sample {i}: queue op {ins.label} needed out of event order")
        placed.add(ins.uid)
        for srcv in ins.reg_srcs:
            p = prod.get(srcv)
            if p is not None and p.uid not in placed:
                place(p, via_event=False)
        result.append(ins)

    for role, v in events:
        key = vid(v, i)
        cands = (by_push if role == "push" else by_pop).get(key)
        if not cands:
            raise ValueError(f"sample {i}: no instruction for event {role} {v}")
        ins = cands[0]
        if ins.uid not in placed:
            place(ins, via_event=True)
        cands.pop(0)
    for ins in int_list:
        place(ins, via_event=True)       # leftovers carry no queue ops
    return result


# ---------------------------------------------------------------------------
# COPIFT  (Steps 1-6 of [1])
# ---------------------------------------------------------------------------

def _phases(dfg: LoopDFG, plan: CommPlan) -> Dict[str, int]:
    """Phase per node = boundary crossings along the longest path.
    Even phases run on the integer core, odd phases on the FPSS."""
    ph: Dict[str, int] = {}
    for n in dfg.nodes:
        side = dfg.node_unit(n)
        want = 0 if side is Unit.INT else 1
        best = want
        for (src, lag) in n.srcs:
            if lag != 0 or src in dfg.inputs:
                continue
            p = ph[src]
            prod_side = Unit.INT if p % 2 == 0 else Unit.FP
            cand = p + (0 if prod_side is side else 1)
            if cand % 2 != want:
                cand += 1
            best = max(best, cand)
        ph[n.name] = best
    return ph


def lower_copift(dfg: LoopDFG, cfg: TransformConfig) -> Program:
    plan = analyze(dfg)
    b = _Builder()
    n, U, B = cfg.n_samples, cfg.unroll, cfg.batch
    if n % B:
        raise ValueError("n_samples must be a multiple of the batch size")
    nb = n // B
    init_env, outputs = _init_env(dfg, n)
    ph = _phases(dfg, plan)
    n_phases = max(ph.values()) + 1

    phase_nodes: List[List[Node]] = [[] for _ in range(n_phases)]
    for node in dfg.nodes:
        phase_nodes[ph[node.name]].append(node)

    # values communicated between threads => spilled to memory buffers
    crossing = set(plan.i2f_uses) | set(plan.int_receives)

    def mem(v: str, i: int) -> str:
        return f"mem:{v}@{i}"

    def build_segment(batch: int, phase: int) -> List[Instr]:
        nodes = phase_nodes[phase]
        side = Unit.INT if phase % 2 == 0 else Unit.FP
        per_sample: List[List[Instr]] = []
        for i in range(batch * B, (batch + 1) * B):
            lst: List[Instr] = []
            spills: List[Instr] = []
            loads: List[Instr] = []
            needs_addr = False
            for node in nodes:
                srcs: List[str] = []
                extra = E_SSR_STREAM if node.out else 0.0
                for (s, l) in node.srcs:
                    if l > 0:
                        srcs.append(f"init:{s}" if i - l < 0 else vid(s, i - l))
                    elif s in crossing and ph.get(s, phase) != phase:
                        if side is Unit.FP:
                            # arrives through an SSR stream: no instruction,
                            # SRAM read energy charged to the consumer
                            srcs.append(mem(s, i))
                            extra += E_SSR_STREAM
                        else:
                            lv = f"ld:{s}@{i}"
                            if not any(x.dst == lv for x in loads):
                                loads.append(b.instr(OpKind.LW, lv,
                                                     (mem(s, i),), dst=lv,
                                                     sample=i, fn=_identity))
                                needs_addr = True
                            srcs.append(lv)
                    else:
                        srcs.append(vid(s, i))
                lst.append(b.instr(node.kind, f"{node.name}@{i}", tuple(srcs),
                                   dst=vid(node.name, i), sample=i, fn=node.fn,
                                   extra_energy=extra))
                if node.name in crossing and ph[node.name] == phase:
                    if side is Unit.INT:
                        spills.append(b.instr(OpKind.SW, f"sw:{node.name}@{i}",
                                              (vid(node.name, i),),
                                              dst=mem(node.name, i), sample=i,
                                              fn=_identity))
                        needs_addr = True
                    else:
                        spills.append(b.instr(OpKind.FSD_SSR,
                                              f"fsw:{node.name}@{i}",
                                              (vid(node.name, i),),
                                              dst=mem(node.name, i), sample=i,
                                              fn=_identity))
            pre: List[Instr] = []
            if needs_addr and side is Unit.INT:
                pre.append(b.instr(OpKind.IALU, f"addr:p{phase}@{i}", (),
                                   dst=f"addr:p{phase}@{i}", sample=i,
                                   fn=lambda: 0))
            per_sample.append(pre + loads + lst + spills)
        return _interleave(per_sample, U, b,
                           loop_overhead=(side is Unit.INT),
                           tag=f"p{phase}b{batch}")

    int_stream: List[Instr] = []
    fp_stream: List[Instr] = []
    segs = [(batch, phase) for batch in range(nb) for phase in range(n_phases)
            if phase_nodes[phase]]
    # wavefront (the software pipeline of Step 5/6 in [1]): process diagonals
    # d = batch + phase; within a diagonal the integer core first emits the
    # FREP launches (keeping the FPSS busy), then its own segment bodies in
    # phase order (producers before consumers).
    segs.sort(key=lambda bp: (bp[0] + bp[1], bp[1] % 2 == 0, bp[1]))

    sem_of: Dict[Tuple[int, int], str] = {}
    for (batch, phase) in segs:
        side = Unit.INT if phase % 2 == 0 else Unit.FP
        body = build_segment(batch, phase)
        deps = [sem_of[d] for d in ((batch, phase - 1), (batch - 2, phase + 1))
                if d in sem_of]
        if side is Unit.FP:
            # integer core configures SSRs and launches the FREP body
            launch = f"launch:b{batch}p{phase}"
            prev: Tuple[str, ...] = tuple(deps)
            for k in range(cfg.sync_cost):
                name = launch if k == cfg.sync_cost - 1 else f"{launch}.{k}"
                int_stream.append(b.instr(OpKind.IALU, name, prev, dst=name,
                                          fn=lambda *a: 0))
                prev = (name,)
            body[0] = _with_extra_deps(body[0], (launch,))
            fp_stream.extend(body)
        else:
            if deps:
                poll = f"poll:b{batch}p{phase}"
                int_stream.append(b.instr(OpKind.LW, poll, tuple(deps),
                                          dst=poll, fn=lambda *a: 0))
                int_stream.append(b.instr(OpKind.BR, f"{poll}.br", (poll,),
                                          fn=_identity))
            int_stream.extend(body)
        sem = f"sem:b{batch}p{phase}"
        last = next((x.dst for x in reversed(body) if x.dst), None)
        kind = OpKind.SYNC if side is Unit.INT else OpKind.FSD_SSR
        (int_stream if side is Unit.INT else fp_stream).append(
            b.instr(kind, sem, (last,) if last else (), dst=sem, fn=lambda *a: 0))
        sem_of[(batch, phase)] = sem

    return Program(name=dfg.name, policy=ExecutionPolicy.COPIFT, mode="dual",
                   streams={Unit.INT: int_stream, Unit.FP: fp_stream},
                   n_samples=n, init_env=init_env, output_values=outputs,
                   frep=True)


def _with_extra_deps(ins: Instr, extra: Tuple[str, ...]) -> Instr:
    fn = ins.fn
    wrapped = (lambda *a, _f=fn, _k=len(extra): _f(*a[_k:])) if fn else None
    return Instr(uid=ins.uid, kind=ins.kind, label=ins.label,
                 srcs=tuple(extra) + ins.srcs, dst=ins.dst, pushes=ins.pushes,
                 push_val=ins.push_val, sample=ins.sample, fn=wrapped,
                 extra_energy=ins.extra_energy)


# ---------------------------------------------------------------------------
# Work partitioning: one kernel across the cores of a cluster
# ---------------------------------------------------------------------------

def _fast_forward_init(dfg: LoopDFG, offset: int) -> Dict[str, Any]:
    """The lag-carried machine state after ``offset`` sequential samples —
    what a core's registers hold when its sample range starts at ``offset``.

    Kernels with loop-carried chains (LCG state, running accumulators,
    address counters) cannot be split by naive index offsetting: core ``c``
    must start from the state the chain reaches at its range boundary, the
    same way production partitioned loops seed per-chunk state (LCG
    skip-ahead, per-chunk base addresses, partial-sum registers).  Evaluated
    with the sequential reference semantics, so the concatenated per-core
    outputs stay bit-identical to the unpartitioned kernel.
    """
    lags = [lag for n in dfg.nodes for (_s, lag) in n.srcs if lag > 0]
    if not lags or offset == 0:
        return dict(dfg.init)
    if max(lags) > 1:
        raise ValueError(
            f"{dfg.name}: work partitioning supports loop-carried lag 1 only "
            f"(got lag {max(lags)}); restructure the kernel")
    lagged = {s for n in dfg.nodes for (s, lag) in n.srcs if lag > 0}
    state = dict(dfg.init)
    for i in range(offset):
        cur = {name: gen(i) for name, gen in dfg.inputs.items()}
        for node in dfg.nodes:
            args = [cur[s] if lag == 0 else state[s]
                    for (s, lag) in node.srcs]
            cur[node.name] = node.fn(*args)
        for name in lagged | set(state):
            if name in cur:
                state[name] = cur[name]
    return state


def _shifted_dfg(dfg: LoopDFG, offset: int, tag: str) -> LoopDFG:
    """A view of ``dfg`` whose sample ``i`` is the base kernel's sample
    ``i + offset``: streamed inputs are index-shifted and lag-carried init
    values are fast-forwarded to the range start."""
    inputs = {name: (lambda i, _g=gen, _o=offset: _g(i + _o))
              for name, gen in dfg.inputs.items()}
    return LoopDFG(name=f"{dfg.name}{tag}", nodes=list(dfg.nodes),
                   inputs=inputs, input_homes=dict(dfg.input_homes),
                   init=_fast_forward_init(dfg, offset))


#: process-local cache of shifted per-core DFG views, keyed by
#: (kernel name, n_cores, chunk, core index) with the base-DFG identity
#: checked on hit (exactly like _V2_PREFIX_CACHE): repeated cluster sweeps
#: over machine axes then reuse one shifted DFG per core, which is what lets
#: the COPIFTv2 prefix cache hit across queue depths for partitioned runs.
_PARTITION_CACHE: Dict[Tuple, List] = {}
_PARTITION_CAP = 256


def _core_dfg(dfg: LoopDFG, c: int, n_cores: int, chunk: int) -> LoopDFG:
    key = (dfg.name, n_cores, chunk, c)
    hit = _PARTITION_CACHE.get(key)
    if hit is not None and hit[0] is dfg:
        return hit[1]
    sub = _shifted_dfg(dfg, c * chunk, f"@core{c}/{n_cores}")
    if len(_PARTITION_CACHE) >= _PARTITION_CAP:
        _PARTITION_CACHE.pop(next(iter(_PARTITION_CACHE)))
    _PARTITION_CACHE[key] = [dfg, sub]
    return sub


def partition_kernel(dfg: LoopDFG, policy: "ExecutionPolicy",
                     cfg: Optional[TransformConfig] = None,
                     n_cores: int = 1,
                     use_prefix_cache: bool = True) -> List[Program]:
    """Split ``cfg.n_samples`` across ``n_cores`` disjoint contiguous sample
    ranges and lower one per-core :class:`Program` each (same policy, same
    schedule parameters, ``n_samples / n_cores`` samples per core).

    Core ``c`` computes samples ``[c*chunk, (c+1)*chunk)``: inputs are
    index-shifted and loop-carried state is fast-forwarded to the range
    start, so the concatenation of the per-core outputs is bit-identical to
    the sequential reference.  ``n_cores=1`` returns ``[lower(...)]``
    verbatim — the cluster of one *is* the single-core program (the
    ``ClusterStepper`` bit-identity contract rests on this).

    Raises ``ValueError`` when the kernel cannot be partitioned
    (``n_samples`` not divisible by ``n_cores``, or loop-carried lag > 1).
    """
    cfg = cfg or TransformConfig()
    if n_cores <= 0:
        raise ValueError(f"n_cores must be positive, got {n_cores}")
    if n_cores == 1:
        return [lower(dfg, policy, cfg, use_prefix_cache)]
    n = cfg.n_samples
    if n % n_cores:
        raise ValueError(
            f"{dfg.name}: n_samples={n} not divisible by n_cores={n_cores}")
    chunk = n // n_cores
    batch = min(cfg.batch, chunk)
    while chunk % batch:              # COPIFT needs batch | n_samples
        batch -= 1
    sub_cfg = replace(cfg, n_samples=chunk, batch=batch)
    progs = [lower(_core_dfg(dfg, c, n_cores, chunk), policy, sub_cfg,
                   use_prefix_cache)
             for c in range(n_cores)]
    for p in progs:
        # carried explicitly so cluster results never parse user-given
        # names (which may themselves contain "@core")
        p.base_name = dfg.name
    return progs


# ---------------------------------------------------------------------------
# Pipeline partitioning: heterogeneous producer/consumer core pairs
# ---------------------------------------------------------------------------

def _stage_dma_loads(stream: List[Instr], b: _Builder, cfg: TransformConfig,
                     dma_buffers: int, chan: int) -> List[Instr]:
    """Rewrite the producer stream's loads into DMA-staged local reads.

    Consecutive loads are grouped (one unroll window's worth per transfer);
    group ``g`` is brought in by a ``DMA_START`` issued ``dma_buffers``
    groups ahead (the rotating-buffer prologue starts the first
    ``dma_buffers`` transfers), and a ``DMA_WAIT`` in front of the group's
    first load blocks until the data has landed.  The loads themselves are
    marked ``local`` — they read the staged buffer, exempt from bank
    arbitration and interconnect energy — and take the wait's token as an
    extra dependency so the functional interpreter preserves ordering."""
    loads = [k for k, ins in enumerate(stream) if ins.kind is OpKind.LW]
    if not loads:
        return stream
    samples = {stream[k].sample for k in loads if stream[k].sample >= 0}
    per_sample = max(1, len(loads) // max(1, len(samples)))
    group_size = max(1, (cfg.unroll_int or cfg.unroll) * per_sample)
    n_groups = (len(loads) + group_size - 1) // group_size
    group_of = {idx: j // group_size for j, idx in enumerate(loads)}
    words = [min(group_size, len(loads) - g * group_size)
             for g in range(n_groups)]

    def start(g: int) -> Instr:
        return b.instr(OpKind.DMA_START, f"dma:start{chan}:{g}",
                       dma_words=words[g])

    def wait(g: int) -> Instr:
        return b.instr(OpKind.DMA_WAIT, f"dma:wait{chan}:{g}",
                       dst=f"dma{chan}:{g}", fn=lambda: 0)

    out: List[Instr] = [start(g) for g in range(min(dma_buffers, n_groups))]
    seen: set = set()
    for idx, ins in enumerate(stream):
        g = group_of.get(idx)
        if g is None:
            out.append(ins)
            continue
        if g not in seen:
            seen.add(g)
            out.append(wait(g))
            if g + dma_buffers < n_groups:
                out.append(start(g + dma_buffers))
        tok = f"dma{chan}:{g}"
        fn = ins.fn
        wrapped = (lambda *a, _f=fn: _f(*a[1:])) if fn else None
        out.append(replace(ins, srcs=(tok,) + ins.srcs, fn=wrapped,
                           local=True))
    return out


def _pipeline_pair(sub: LoopDFG, cfg: TransformConfig, chan: int,
                   dma_buffers: int, use_prefix_cache: bool,
                   base: str, core0: int, n_cores: int
                   ) -> Tuple[Program, Program]:
    """Split one COPIFTv2 lowering of ``sub`` into a producer/consumer
    program pair communicating over inter-core channel ``chan``.

    The producer core keeps the v2 *integer* stream with every I2F push
    rewritten into a ``CQ_PUSH`` (and its loads DMA-staged); the consumer
    core keeps the v2 *FP* stream verbatim, fed by a ``CQ_POP`` prelude on
    its integer unit that relays channel entries into the local I2F queue in
    exactly the producer's push order — so the FP stream's FIFO ``expects``
    keep verifying value-exact delivery across the cluster."""
    plan = analyze(sub)
    if plan.int_receives:
        raise ValueError(
            f"{sub.name}: pipeline partitioning needs a one-directional "
            f"(int -> fp) kernel; {sorted(plan.int_receives)} flow back "
            "to the integer thread")
    v2 = lower_copiftv2(sub, cfg, use_prefix_cache)
    b = _Builder()

    prod_stream: List[Instr] = []
    push_order: List[str] = []
    for ins in v2.streams[Unit.INT]:
        if Queue.I2F not in ins.pushes:
            prod_stream.append(ins)
            continue
        pv = ins.push_val or ins.label
        if ins.dst is None:
            # MV re-push shim: becomes the channel push itself
            prod_stream.append(replace(ins, kind=OpKind.CQ_PUSH, pushes=(),
                                       cq=chan))
        else:
            # producing instruction: keep the register write, relay the
            # result through the channel with a separate push
            prod_stream.append(replace(ins, pushes=(), push_val=None))
            prod_stream.append(b.instr(OpKind.CQ_PUSH, f"cqpush:{pv}",
                                       (ins.dst,), push_val=pv,
                                       sample=ins.sample, fn=_identity,
                                       cq=chan))
        push_order.append(pv)
    prod_stream = _stage_dma_loads(prod_stream, b, cfg, dma_buffers, chan)

    magic = f"%cq{chan}"
    cons_int = [b.instr(OpKind.CQ_POP, f"cqpop:{pv}", (magic,),
                        pushes=(Queue.I2F,), push_val=pv, expects=(pv,),
                        fn=_identity, cq=chan)
                for pv in push_order]
    cons_env = dict(v2.init_env)
    cons_env[magic] = 0

    prod = Program(
        name=f"{base}@core{core0}/{n_cores}",
        policy=ExecutionPolicy.COPIFTV2, mode="dual",
        streams={Unit.INT: prod_stream}, n_samples=0,
        init_env=dict(v2.init_env), output_values=[], frep=False,
        base_name=base)
    cons = Program(
        name=f"{base}@core{core0 + 1}/{n_cores}",
        policy=ExecutionPolicy.COPIFTV2, mode="dual",
        streams={Unit.INT: cons_int, Unit.FP: v2.streams[Unit.FP]},
        n_samples=v2.n_samples, init_env=cons_env,
        output_values=list(v2.output_values), frep=True, base_name=base)
    return prod, cons


def partition_pipeline(dfg: LoopDFG, cfg: Optional[TransformConfig] = None,
                       n_cores: int = 2, dma_buffers: int = 2,
                       use_prefix_cache: bool = True) -> List[Program]:
    """Split ``dfg`` across ``n_cores`` as producer/consumer *pairs* — the
    heterogeneous counterpart of :func:`partition_kernel`.

    Core ``2p`` runs the integer (producer) half of pair ``p`` — loads
    (DMA-double-buffered), index arithmetic, and ``CQ_PUSH`` relays into
    inter-core channel ``p``; core ``2p + 1`` runs the FP (consumer) half —
    the unmodified COPIFTv2 FP stream fed from the channel.  Pairs divide
    the sample range exactly like :func:`partition_kernel` divides it over
    cores (index-shifted inputs, fast-forwarded loop-carried state), so the
    concatenated consumer outputs stay bit-identical to the sequential
    reference.

    ``dma_buffers`` must match the cluster's ``ClusterConfig.dma_buffers``
    (the lowering pipelines that many transfers; a deeper schedule than the
    engine sustains deadlocks, which the cluster detector reports).

    Raises ``ValueError`` for odd/insufficient ``n_cores``, a sample count
    not divisible by the pair count, or a kernel with FP-to-int feedback
    (pipeline pairs are one-directional by construction).
    """
    cfg = cfg or TransformConfig()
    if n_cores < 2 or n_cores % 2:
        raise ValueError(
            f"pipeline partitioning needs an even n_cores >= 2, "
            f"got {n_cores}")
    pairs = n_cores // 2
    n = cfg.n_samples
    if n % pairs:
        raise ValueError(
            f"{dfg.name}: n_samples={n} not divisible by "
            f"{pairs} pipeline pairs")
    chunk = n // pairs
    batch = min(cfg.batch, chunk)
    while chunk % batch:
        batch -= 1
    sub_cfg = replace(cfg, n_samples=chunk, batch=batch)
    progs: List[Program] = []
    for p in range(pairs):
        sub = dfg if pairs == 1 else _core_dfg(dfg, p, pairs, chunk)
        progs.extend(_pipeline_pair(sub, sub_cfg, p, dma_buffers,
                                    use_prefix_cache, base=dfg.name,
                                    core0=2 * p, n_cores=n_cores))
    return progs


# ---------------------------------------------------------------------------

def lower(dfg: LoopDFG, policy: ExecutionPolicy,
          cfg: Optional[TransformConfig] = None,
          use_prefix_cache: bool = True) -> Program:
    """Lower ``dfg`` under ``policy``.  ``use_prefix_cache=False`` bypasses
    the COPIFTv2 depth-independent prefix memo (benchmark baselines)."""
    cfg = cfg or TransformConfig()
    if policy is ExecutionPolicy.BASELINE:
        return lower_baseline(dfg, cfg)
    if policy is ExecutionPolicy.COPIFT:
        return lower_copift(dfg, cfg)
    if policy is ExecutionPolicy.COPIFTV2:
        return lower_copiftv2(dfg, cfg, use_prefix_cache)
    raise ValueError(policy)
