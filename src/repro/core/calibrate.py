"""DSE calibration: sweep → Pareto front → operating-point selection →
versioned JSON artifacts that the kernel/serve/train layers consume.

The DSE engine (``core.sweep`` + ``core.pareto``) finds the Pareto-optimal
(IPC, energy) configurations per kernel; this module closes the loop the
roadmap names ("feed Pareto fronts back into the TPU-layer policy choices"):

1. :func:`calibrate` runs a sweep grid (exhaustively, or pruned by the
   front-guided adaptive search in ``core.search`` — the artifact provenance
   records which), reduces it to per-kernel fronts, and
   :func:`select_operating_point` picks one front member under a declared
   objective — ``max-ipc``, ``min-energy`` or ``energy-bounded-ipc`` — with
   deterministic tie-breaking and an optional dominance tolerance (points
   within ``tolerance`` of the best primary axis count as ties, resolved on
   the secondary axis: a 0.1% IPC win never buys a 2x energy cost).  Since
   v4 the same objective is also re-applied per queue-latency class
   (``selected_by_latency``), so consumers whose interconnect pins the
   visibility latency read the best point *at that latency*.
2. Each selection is persisted as ``artifacts/calibration/<kernel>.json`` —
   a schema-checked (:func:`validate_artifact`), versioned
   (:data:`SCHEMA_VERSION`) artifact embedding the swept grid, the full
   front, git-describable provenance and the selection rationale.
3. ``core.policy.PolicyTable`` loads the artifacts (honouring the
   ``REPRO_CALIBRATION_DIR`` override) and hands per-workload
   :class:`~.policy.OperatingPoint`\\ s to ``kernels/queue_matmul``,
   ``serve.engine`` and ``train.step`` at startup.  Stale or malformed
   artifacts are skipped with a warning and consumers fall back to the
   paper's hard-coded headline point, so calibration can never brick a run.

Per-kernel selection (not one global setting) is where the win lives — the
COPIFT predecessor (arXiv:2503.20590) reports the 1.49x speedup only when
each kernel picks its own configuration.
"""
from __future__ import annotations

import datetime
import json
import math
import os
import subprocess
import types
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .pareto import dominates, pareto_by_kernel, pareto_front
from .policy import TRAFFIC_LEVELS, ExecutionPolicy, OperatingPoint
from .search import run_search
from .sweep import SweepRecord, grid

#: bump on any incompatible artifact-layout change; loaders treat a mismatch
#: as *stale* and fall back to defaults rather than guessing at old layouts.
#: v2: cluster-aware points (n_cores / tcdm_banks / throughput /
#: ipc_per_core) — PR-1-era single-PE artifacts are stale, consumers fall
#: back to defaults until recalibrated.
#: v3: pipelined-cluster points (pipeline / cq_depth / dma_buffers) — v2
#: artifacts are stale in turn.
#: v4: per (kernel x queue-latency class) operating points
#: (``selected_by_latency``) + search-strategy/fidelity provenance — v3
#: artifacts load as stale (``PolicyTable`` warns and falls back to
#: defaults) until recalibrated.
#: v5: the ``serve-slo`` objective ("max throughput s.t. p99 < X
#: cycles-equivalent and J/token < Y") + per-traffic-level selections
#: (``selected_by_traffic``, one per :data:`~repro.core.policy.TRAFFIC_LEVELS`
#: entry, embedded rationale included) — v4 artifacts load as stale with the
#: usual fallback warning until recalibrated.
SCHEMA_VERSION = 5

OBJECTIVES = ("max-ipc", "min-energy", "energy-bounded-ipc", "serve-slo")

#: the configuration + measured-metric fields persisted per front point
POINT_FIELDS = (
    "policy", "queue_depth", "queue_latency", "unroll", "unroll_int",
    "queue_depth_i2f", "queue_depth_f2i", "n_cores", "tcdm_banks",
    "pipeline", "cq_depth", "dma_buffers",
    "ipc", "ipc_per_core", "energy", "cycles", "throughput", "efficiency",
)

ARTIFACT_FIELDS = ("schema_version", "kernel", "objective", "selected",
                   "selected_by_latency", "selected_by_traffic", "front",
                   "grid", "provenance", "rationale")

#: per latency-class entry layout inside ``selected_by_latency``
LATENCY_CLASS_FIELDS = ("selected", "rationale")

#: per traffic-level entry layout inside ``selected_by_traffic`` (v5):
#: ``traffic`` records the level's offered-load fraction at selection time
TRAFFIC_CLASS_FIELDS = ("selected", "rationale", "traffic")

OBJECTIVE_FIELDS = ("name", "energy_budget", "tolerance", "slo_p99")

_REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", ".."))


class CalibrationError(ValueError):
    """A calibration artifact is malformed (schema violation)."""


class StaleArtifactError(CalibrationError):
    """A calibration artifact was written under a different schema version."""


def calibration_dir() -> str:
    """Artifact directory: ``REPRO_CALIBRATION_DIR`` wins, else the repo's
    ``artifacts/calibration``."""
    env = os.environ.get("REPRO_CALIBRATION_DIR", "").strip()
    return env or os.path.join(_REPO_ROOT, "artifacts", "calibration")


def point_to_dict(rec: SweepRecord) -> Dict[str, Any]:
    return {f: getattr(rec, f) for f in POINT_FIELDS}


def _op_from_point(s: Dict[str, Any]) -> OperatingPoint:
    return OperatingPoint(
        policy=ExecutionPolicy.parse(s["policy"]),
        queue_depth=s["queue_depth"], queue_latency=s["queue_latency"],
        unroll=s["unroll"], unroll_int=s["unroll_int"],
        queue_depth_i2f=s["queue_depth_i2f"],
        queue_depth_f2i=s["queue_depth_f2i"],
        n_cores=s["n_cores"], tcdm_banks=s["tcdm_banks"],
        pipeline=s["pipeline"], cq_depth=s["cq_depth"],
        dma_buffers=s["dma_buffers"],
        source="calibrated")


@dataclass
class CalibrationRecord:
    """One kernel's persisted calibration: the selected operating point, the
    front it was chosen from, per queue-latency-class selections (v4), and
    everything needed to reproduce the choice."""
    kernel: str
    objective: str
    selected: Dict[str, Any]
    front: List[Dict[str, Any]]
    grid: Dict[str, Any]
    provenance: Dict[str, Any]
    rationale: str
    energy_budget: Optional[float] = None
    tolerance: float = 0.0
    #: v5: the ``serve-slo`` p99 bound (cycles-equivalent per work-token);
    #: None for other objectives or when the bound was auto-derived
    slo_p99: Optional[float] = None
    #: v4: ``str(queue_latency) -> {"selected": point, "rationale": str}`` —
    #: the objective re-applied to each latency class's own Pareto front, so
    #: a consumer whose fabric pins the visibility latency gets the best
    #: point *at that latency* instead of the global winner
    selected_by_latency: Dict[str, Dict[str, Any]] = None  # type: ignore
    #: v5: ``traffic level -> {"selected": point, "rationale": str,
    #: "traffic": offered-load fraction}`` — the serve-slo selection applied
    #: per :data:`~repro.core.policy.TRAFFIC_LEVELS` entry, so the serve
    #: path picks the best point *for its offered load* (light traffic
    #: affords the lowest-energy feasible point; near saturation only the
    #: highest-throughput points hold p99)
    selected_by_traffic: Dict[str, Dict[str, Any]] = None  # type: ignore
    schema_version: int = SCHEMA_VERSION

    def __post_init__(self):
        if self.selected_by_latency is None:
            self.selected_by_latency = {}
        if self.selected_by_traffic is None:
            self.selected_by_traffic = {}

    def operating_point(self) -> OperatingPoint:
        return _op_from_point(self.selected)

    def operating_point_for(self,
                            queue_latency: int) -> OperatingPoint:
        """The operating point for a pinned queue-latency class, falling
        back to the global selection when the class was never swept."""
        cls_ = self.selected_by_latency.get(str(queue_latency))
        if cls_ is None:
            return self.operating_point()
        return _op_from_point(cls_["selected"])

    def operating_point_for_traffic(
            self, traffic: str) -> Optional[OperatingPoint]:
        """The serve-slo operating point for a pinned traffic level, or
        None when the level was never analysed (the caller then falls back
        through the latency-class / global selections)."""
        entry = self.selected_by_traffic.get(traffic)
        if entry is None:
            return None
        return _op_from_point(entry["selected"])

    def to_dict(self) -> Dict[str, Any]:
        return {
            "schema_version": self.schema_version,
            "kernel": self.kernel,
            "objective": {"name": self.objective,
                          "energy_budget": self.energy_budget,
                          "tolerance": self.tolerance,
                          "slo_p99": self.slo_p99},
            "selected": dict(self.selected),
            "selected_by_latency": {
                lat: {"selected": dict(e["selected"]),
                      "rationale": e["rationale"]}
                for lat, e in self.selected_by_latency.items()},
            "selected_by_traffic": {
                lvl: {"selected": dict(e["selected"]),
                      "rationale": e["rationale"],
                      "traffic": e["traffic"]}
                for lvl, e in self.selected_by_traffic.items()},
            "front": [dict(p) for p in self.front],
            "grid": dict(self.grid),
            "provenance": dict(self.provenance),
            "rationale": self.rationale,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "CalibrationRecord":
        validate_artifact(d)
        obj = d["objective"]
        return cls(kernel=d["kernel"], objective=obj["name"],
                   energy_budget=obj["energy_budget"],
                   tolerance=obj["tolerance"], slo_p99=obj["slo_p99"],
                   selected=d["selected"],
                   selected_by_latency=d["selected_by_latency"],
                   selected_by_traffic=d["selected_by_traffic"],
                   front=d["front"], grid=d["grid"],
                   provenance=d["provenance"], rationale=d["rationale"],
                   schema_version=d["schema_version"])


def _check_exact_fields(d: Dict[str, Any], expected: Sequence[str],
                        where: str) -> None:
    missing = [f for f in expected if f not in d]
    extra = [f for f in d if f not in expected]
    if missing or extra:
        raise CalibrationError(
            f"{where}: missing fields {missing}, unexpected fields {extra}")


def validate_artifact(d: Dict[str, Any]) -> None:
    """Strict schema check: exact field sets at every level, a known
    objective, and the current :data:`SCHEMA_VERSION` (mismatch raises
    :class:`StaleArtifactError` so loaders can fall back to defaults)."""
    if not isinstance(d, dict):
        raise CalibrationError(f"artifact must be an object, got {type(d)}")
    version = d.get("schema_version")
    if version != SCHEMA_VERSION:
        raise StaleArtifactError(
            f"artifact schema_version {version!r} != current "
            f"{SCHEMA_VERSION} (stale artifact; re-run calibrate)")
    _check_exact_fields(d, ARTIFACT_FIELDS, "artifact")
    _check_exact_fields(d["objective"], OBJECTIVE_FIELDS, "objective")
    name = d["objective"]["name"]
    if name not in OBJECTIVES:
        raise CalibrationError(
            f"unknown objective {name!r} (have {OBJECTIVES})")
    _check_exact_fields(d["selected"], POINT_FIELDS, "selected")
    ExecutionPolicy.parse(d["selected"]["policy"])
    if not isinstance(d["front"], list) or not d["front"]:
        raise CalibrationError("front must be a non-empty list")
    for i, p in enumerate(d["front"]):
        _check_exact_fields(p, POINT_FIELDS, f"front[{i}]")
    if d["selected"] not in d["front"]:
        raise CalibrationError("selected point is not a front member")
    if not isinstance(d["selected_by_latency"], dict):
        raise CalibrationError("selected_by_latency must be an object")
    for lat, entry in d["selected_by_latency"].items():
        where = f"selected_by_latency[{lat!r}]"
        try:
            lat_val = int(lat)
        except (TypeError, ValueError):
            raise CalibrationError(
                f"{where}: key must be an integer queue latency") from None
        _check_exact_fields(entry, LATENCY_CLASS_FIELDS, where)
        _check_exact_fields(entry["selected"], POINT_FIELDS,
                            f"{where}.selected")
        ExecutionPolicy.parse(entry["selected"]["policy"])
        if entry["selected"]["queue_latency"] != lat_val:
            raise CalibrationError(
                f"{where}: selected point has queue_latency "
                f"{entry['selected']['queue_latency']} != class {lat_val}")
    if not isinstance(d["selected_by_traffic"], dict):
        raise CalibrationError("selected_by_traffic must be an object")
    for lvl, entry in d["selected_by_traffic"].items():
        where = f"selected_by_traffic[{lvl!r}]"
        if lvl not in TRAFFIC_LEVELS:
            raise CalibrationError(
                f"{where}: unknown traffic level "
                f"(have {sorted(TRAFFIC_LEVELS)})")
        _check_exact_fields(entry, TRAFFIC_CLASS_FIELDS, where)
        _check_exact_fields(entry["selected"], POINT_FIELDS,
                            f"{where}.selected")
        ExecutionPolicy.parse(entry["selected"]["policy"])
        if not isinstance(entry["traffic"], (int, float)) or \
                not 0.0 < entry["traffic"] < 1.0:
            raise CalibrationError(
                f"{where}: traffic must be an offered-load fraction in "
                f"(0, 1), got {entry['traffic']!r}")


# -- objective-aware selection ----------------------------------------------

def _cheap_hw_key(r: SweepRecord) -> Tuple:
    """Final tie-break: prefer the cheaper hardware/schedule realization —
    fewer cores, a plain work-partitioned cluster over a pipelined one (no
    channel fabric / DMA engine to build), shallower FIFOs (intra-core and
    inter-core), fewer DMA buffers, lower visibility latency, smaller
    unroll."""
    d_i2f = r.queue_depth_i2f or r.queue_depth
    d_f2i = r.queue_depth_f2i or r.queue_depth
    return (r.n_cores, int(r.pipeline), max(d_i2f, d_f2i), r.cq_depth,
            r.dma_buffers, r.queue_latency, r.unroll,
            r.unroll_int or r.unroll, r.policy)


#: exponential-tail multiplier for the queueing estimate:
#: p99 sojourn ~ -ln(0.01) x mean sojourn
_P99_TAIL = -math.log(0.01)
#: auto-derived serve-slo bound when none is declared: this multiple of the
#: best attainable p99 estimate at the traffic level (keeps the per-traffic
#: selections meaningful for artifacts calibrated under other objectives)
_DEFAULT_SLO_HEADROOM = 3.0


def estimated_p99_sojourn(rec: SweepRecord, offered_load: float) -> float:
    """Analytic p99 sojourn estimate (cycles per work-token) for a swept
    point serving a Poisson arrival stream of ``offered_load`` tokens/cycle.

    M/D/1-flavoured: service is near-deterministic (one token's worth of the
    proxy kernel at a fixed configuration, service rate = the point's
    measured ``throughput``), so mean sojourn is ``S + rho*S/(2(1-rho))``
    and the p99 is approximated with an exponential tail
    (:data:`_P99_TAIL` x mean).  Saturated points (``rho >= 1``) return
    ``inf`` — the queue grows without bound, no SLO holds.
    """
    mu = rec.throughput
    if mu <= 0.0:
        return math.inf
    rho = offered_load / mu
    if rho >= 1.0:
        return math.inf
    service = 1.0 / mu
    mean_sojourn = service + rho * service / (2.0 * (1.0 - rho))
    return _P99_TAIL * mean_sojourn


def _select_serve_slo(cands: Sequence[SweepRecord], traffic: float,
                      slo_p99: Optional[float],
                      energy_budget: Optional[float],
                      tolerance: float) -> Tuple[SweepRecord, str]:
    """The ``serve-slo`` discipline: max throughput s.t. the estimated p99
    sojourn fits ``slo_p99`` (cycles-equivalent per work-token) and
    joules-per-token fits ``energy_budget``.  ``traffic`` is the offered
    load as a fraction of the front's best service rate.  An infeasible SLO
    degrades to the closest-to-feasible point (min estimated p99) and the
    rationale says so.
    """
    served = [r for r in cands if r.throughput > 0]
    if not served:
        raise CalibrationError(
            "serve-slo: no front point has positive throughput")
    lam = traffic * max(r.throughput for r in served)
    est = {id(r): estimated_p99_sojourn(r, lam) for r in served}
    auto = ""
    if slo_p99 is None:
        best_est = min(est.values())
        slo_p99 = _DEFAULT_SLO_HEADROOM * best_est
        auto = (f" (auto bound: {_DEFAULT_SLO_HEADROOM:g}x best attainable "
                f"{best_est:.1f})")

    def jpt(r: SweepRecord) -> float:
        return r.energy / max(r.n_samples, 1)

    feasible = [r for r in served if est[id(r)] <= slo_p99
                and (energy_budget is None or jpt(r) <= energy_budget)]
    bounds = f"p99<={slo_p99:g}cyc/tok{auto}"
    if energy_budget is not None:
        bounds += f", J/tok<={energy_budget:g}"
    if feasible:
        best = max(r.throughput for r in feasible)
        tied = [r for r in feasible if r.throughput >= best * (1.0 - tolerance)]
        pick = min(tied, key=lambda r: (est[id(r)], r.energy)
                   + _cheap_hw_key(r))
        how = (f"serve-slo(load={traffic:g}, {bounds}): "
               f"throughput={pick.throughput:.4f} tok/cyc "
               f"(front best {best:.4f}), est p99={est[id(pick)]:.1f}, "
               f"J/tok={jpt(pick):.1f}; {len(feasible)} of {len(served)} "
               f"points feasible ({len(tied)} within tolerance "
               f"{tolerance:g})")
    else:
        pick = min(served, key=lambda r: (est[id(r)], -r.throughput)
                   + _cheap_hw_key(r))
        how = (f"serve-slo(load={traffic:g}, {bounds}): INFEASIBLE — no "
               f"point meets the bounds (best attainable est "
               f"p99={est[id(pick)]:.1f}, J/tok={jpt(pick):.1f}); degraded "
               f"to the closest point, throughput={pick.throughput:.4f}")
    rationale = (f"{how}; picked {pick.policy} depth={pick.queue_depth} "
                 f"lat={pick.queue_latency} unroll={pick.unroll} "
                 f"cores={pick.n_cores}")
    return pick, rationale


def select_operating_point(front: Sequence[SweepRecord], objective: str,
                           energy_budget: Optional[float] = None,
                           tolerance: float = 0.0,
                           slo_p99: Optional[float] = None,
                           traffic: Optional[float] = None
                           ) -> Tuple[SweepRecord, str]:
    """Pick one front member under ``objective``; returns ``(record,
    rationale)``.

    ``tolerance`` is the dominance tolerance: candidates within that relative
    distance of the best primary-axis value are treated as tied and the tie
    is broken on the secondary axis (then on :func:`_cheap_hw_key`).
    ``energy-bounded-ipc`` maximizes IPC subject to ``energy <=
    energy_budget``; an infeasible budget degrades to ``min-energy`` and the
    rationale says so.  ``serve-slo`` maximizes throughput subject to an
    estimated p99 sojourn bound (``slo_p99``, cycles-equivalent per token —
    auto-derived with headroom when omitted) and a joules-per-token bound
    (``energy_budget``) at an offered load of ``traffic`` (fraction of the
    front's best service rate, default the "medium"
    :data:`~repro.core.policy.TRAFFIC_LEVELS` entry).
    """
    if objective not in OBJECTIVES:
        raise ValueError(f"unknown objective {objective!r} "
                         f"(have {OBJECTIVES})")
    cands = [r for r in front if r.ok]
    if not cands:
        raise CalibrationError("cannot select from an empty Pareto front")
    note = ""
    if objective == "serve-slo":
        if traffic is None:
            traffic = TRAFFIC_LEVELS["medium"]
        return _select_serve_slo(cands, traffic, slo_p99, energy_budget,
                                 tolerance)
    if objective == "energy-bounded-ipc":
        if energy_budget is None:
            raise ValueError("energy-bounded-ipc requires energy_budget")
        feasible = [r for r in cands if r.energy <= energy_budget]
        if feasible:
            cands, note = feasible, f" within energy budget {energy_budget:g}"
        else:
            objective_eff = "min-energy"
            note = (f"; budget {energy_budget:g} infeasible "
                    f"(front min energy {min(r.energy for r in cands):g}), "
                    f"degraded to min-energy")
            return _select(cands, objective_eff, tolerance, note)
        return _select(cands, "max-ipc", tolerance, note)
    return _select(cands, objective, tolerance, note)


def _select(cands: Sequence[SweepRecord], objective: str, tolerance: float,
            note: str) -> Tuple[SweepRecord, str]:
    if objective == "max-ipc":
        best = max(r.ipc for r in cands)
        tied = [r for r in cands if r.ipc >= best * (1.0 - tolerance)]
        pick = min(tied, key=lambda r: (r.energy,) + _cheap_hw_key(r))
        how = f"max-ipc{note}: ipc={pick.ipc:.4f} (front best {best:.4f})"
    else:                                   # min-energy
        best = min(r.energy for r in cands)
        tied = [r for r in cands if r.energy <= best * (1.0 + tolerance)]
        pick = min(tied, key=lambda r: (-r.ipc,) + _cheap_hw_key(r))
        how = (f"min-energy{note}: energy={pick.energy:.1f} "
               f"(front best {best:.1f})")
    rationale = (f"{how}; picked {pick.policy} depth={pick.queue_depth} "
                 f"lat={pick.queue_latency} unroll={pick.unroll} from "
                 f"{len(cands)} candidates ({len(tied)} within tolerance "
                 f"{tolerance:g})")
    return pick, rationale


# -- provenance + artifact IO ------------------------------------------------

def git_describe() -> str:
    try:
        out = subprocess.run(
            ["git", "describe", "--always", "--dirty", "--tags"],
            cwd=_REPO_ROOT, capture_output=True, text=True, timeout=10)
        if out.returncode == 0:
            return out.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    return "unknown"


def artifact_path(kernel: str, directory: Optional[str] = None) -> str:
    return os.path.join(directory or calibration_dir(), f"{kernel}.json")


def write_artifact(rec: CalibrationRecord,
                   directory: Optional[str] = None) -> str:
    path = artifact_path(rec.kernel, directory)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as fh:
        json.dump(rec.to_dict(), fh, indent=2, sort_keys=True)
        fh.write("\n")
    return path


def load_artifact(path: str) -> CalibrationRecord:
    """Parse + validate one artifact file; raises :class:`CalibrationError`
    (or :class:`StaleArtifactError`) on any schema violation."""
    try:
        with open(path) as fh:
            d = json.load(fh)
    except OSError as e:
        raise CalibrationError(f"unreadable artifact {path}: {e}") from e
    except json.JSONDecodeError as e:
        raise CalibrationError(f"artifact {path} is not JSON: {e}") from e
    return CalibrationRecord.from_dict(d)


def load_calibration(kernel: str,
                     directory: Optional[str] = None
                     ) -> Optional[CalibrationRecord]:
    """The artifact for ``kernel``, or None (missing artifacts are normal —
    consumers fall back to defaults)."""
    path = artifact_path(kernel, directory)
    if not os.path.exists(path):
        return None
    return load_artifact(path)


# -- the end-to-end calibration run ------------------------------------------

#: the default calibration grid — the same 336-configuration space
#: ``examples/explore.py`` sweeps by default
DEFAULT_GRID = dict(queue_depths=(1, 2, 4, 8), queue_latencies=(1, 2),
                    unrolls=(4, 8), n_samples=32)


def _select_by_latency(records: List[SweepRecord], objective: str,
                       energy_budget: Optional[float], tolerance: float,
                       slo_p99: Optional[float] = None
                       ) -> Dict[str, Dict[str, Any]]:
    """The v4 per-class selections: re-apply the objective to each queue-
    latency class's own Pareto front (a class whose front is empty — every
    point rejected — is simply absent)."""
    classes: Dict[int, List[SweepRecord]] = {}
    for r in records:
        if r.ok:
            classes.setdefault(r.queue_latency, []).append(r)
    out: Dict[str, Dict[str, Any]] = {}
    for lat in sorted(classes):
        front = pareto_front(classes[lat])
        if not front:
            continue
        pick, rationale = select_operating_point(
            front, objective, energy_budget=energy_budget,
            tolerance=tolerance, slo_p99=slo_p99)
        out[str(lat)] = {"selected": point_to_dict(pick),
                         "rationale": f"latency class {lat}: {rationale}"}
    return out


def _select_by_traffic(records: List[SweepRecord],
                       energy_budget: Optional[float], tolerance: float,
                       slo_p99: Optional[float]
                       ) -> Dict[str, Dict[str, Any]]:
    """The v5 per-traffic-level selections: the ``serve-slo`` discipline
    applied to the kernel's front at every :data:`TRAFFIC_LEVELS` offered
    load — computed for *every* calibration (whatever its global objective),
    so the serve path can always resolve a point for its traffic level.  The
    energy budget is treated as a per-token bound here (serve-slo
    semantics), independent of how the global objective interprets it."""
    ok = [r for r in records if r.ok]
    front = pareto_front(ok) if ok else []
    out: Dict[str, Dict[str, Any]] = {}
    if not front:
        return out
    for level, util in TRAFFIC_LEVELS.items():
        try:
            pick, rationale = _select_serve_slo(
                front, util, slo_p99, energy_budget, tolerance)
        except CalibrationError:
            continue
        out[level] = {"selected": point_to_dict(pick),
                      "rationale": f"traffic {level}: {rationale}",
                      "traffic": util}
    return out


def calibrate(kernels: Optional[Sequence[str]] = None,
              objective: str = "max-ipc",
              energy_budget: Optional[float] = None,
              tolerance: float = 0.0,
              slo_p99: Optional[float] = None,
              grid_kw: Optional[Dict[str, Any]] = None,
              workers: Optional[int] = None,
              out_dir: Optional[str] = None,
              write: bool = True,
              strategy: str = "exhaustive",
              search_kw: Optional[Dict[str, Any]] = None
              ) -> Dict[str, CalibrationRecord]:
    """Sweep → per-kernel fronts → objective selection → artifacts.

    Returns kernel → :class:`CalibrationRecord`; with ``write=True`` (the
    default) each record is also persisted under ``out_dir`` (defaulting to
    :func:`calibration_dir`).  Raises if any swept point deadlocks or
    diverges from the baseline interpreter — a calibration produced by a
    broken simulation must never be written.

    ``strategy`` selects the search discipline (``core.search``):
    ``"adaptive"`` prunes the grid by front-guided successive halving
    (``search_kw`` passes ``tolerance`` / ``fidelity_ladder`` through) and
    the artifact's provenance embeds the full search meta — strategy,
    fidelity ladder, per-rung survivor counts — so a consumer can tell a
    pruned calibration from an exhaustive one.  Besides the global
    selection, each artifact carries per queue-latency-class selections
    (``selected_by_latency``, v4): the objective re-applied to each latency
    class's own front; and per-traffic-level ``serve-slo`` selections
    (``selected_by_traffic``, v5) — always computed, whatever the global
    objective, with ``slo_p99`` as the p99 bound (auto-derived with headroom
    when omitted) and ``energy_budget`` read as a joules-per-token bound.
    """
    gk = dict(DEFAULT_GRID)
    gk.update(grid_kw or {})
    points = grid(kernels=kernels, **gk)
    records, search_meta = run_search(points, strategy=strategy,
                                      workers=workers, **(search_kw or {}))
    bad = [r for r in records if r.status == "deadlock"
           or (r.ok and (not r.equivalent or r.fifo_violations))]
    if bad:
        raise CalibrationError(
            f"{len(bad)} swept points deadlocked or diverged from the "
            f"baseline interpreter, e.g. {bad[0]}; refusing to calibrate")
    grid_desc: Dict[str, Any] = {
        "kernels": sorted({p.kernel for p in points}), **{
            k: (list(v) if isinstance(v, (tuple, list)) else v)
            for k, v in gk.items()},
    }
    if "policies" in grid_desc:
        grid_desc["policies"] = [
            ExecutionPolicy.parse(p).value for p in grid_desc["policies"]]
    provenance = {
        "git": git_describe(),
        "created": datetime.datetime.now(datetime.timezone.utc).isoformat(),
        "engine": points[0].engine if points else "event",
        "n_points": len(points),
        "n_ok": sum(r.ok for r in records),
        "search": search_meta,
    }
    by_kernel: Dict[str, List[SweepRecord]] = {}
    for r in records:
        by_kernel.setdefault(r.kernel, []).append(r)
    out: Dict[str, CalibrationRecord] = {}
    for kernel, front in pareto_by_kernel(records).items():
        pick, rationale = select_operating_point(
            front, objective, energy_budget=energy_budget,
            tolerance=tolerance, slo_p99=slo_p99)
        rec = CalibrationRecord(
            kernel=kernel, objective=objective, energy_budget=energy_budget,
            tolerance=tolerance, slo_p99=slo_p99,
            selected=point_to_dict(pick),
            selected_by_latency=_select_by_latency(
                by_kernel.get(kernel, []), objective, energy_budget,
                tolerance, slo_p99=slo_p99),
            selected_by_traffic=_select_by_traffic(
                by_kernel.get(kernel, []), energy_budget, tolerance,
                slo_p99),
            front=[point_to_dict(r) for r in front], grid=grid_desc,
            provenance=provenance, rationale=rationale)
        validate_artifact(rec.to_dict())     # never persist a bad artifact
        if write:
            write_artifact(rec, out_dir)
        out[kernel] = rec
    return out


def never_dominated_by(rec: CalibrationRecord,
                       baseline: SweepRecord) -> bool:
    """True iff ``baseline`` does not dominate the selected point — the
    calibrated choice can never be strictly worse than a hard-coded one."""
    sel = types.SimpleNamespace(ipc=rec.selected["ipc"],
                                energy=rec.selected["energy"])
    return not dominates(baseline, sel)
