"""Loop-body dataflow graphs for mixed integer/FP kernels (COPIFT Step 1).

A :class:`LoopDFG` describes one iteration ("sample") of a kernel loop as a
list of SSA nodes.  Sources may reference values from the same iteration
(lag=0) or carry across iterations (lag>=1, e.g. an LCG state).  Streamed
inputs model SSR-fed operands (no instruction cost; energy is charged to the
consumer, matching Snitch's SSRs).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from .isa import FP_KINDS, INT_DST_FP_KINDS, OpKind, Unit

#: (value name, lag): lag=0 -> this iteration, lag=k -> k iterations ago.
Src = Tuple[str, int]


def s(name: str, lag: int = 0) -> Src:
    return (name, lag)


@dataclass(frozen=True)
class Node:
    name: str                      # produced value (unique within the body)
    kind: OpKind
    srcs: Tuple[Src, ...]
    fn: Optional[Callable[..., Any]] = None
    out: bool = False              # kernel output (must survive transforms)


@dataclass
class LoopDFG:
    """One loop body.  ``inputs`` maps streamed input names to generator
    functions i -> value; ``init`` provides lag-carried initial values.
    """
    name: str
    nodes: List[Node]
    inputs: Dict[str, Callable[[int], Any]] = field(default_factory=dict)
    input_homes: Dict[str, Unit] = field(default_factory=dict)
    init: Dict[str, Any] = field(default_factory=dict)

    def __post_init__(self) -> None:
        names = [n.name for n in self.nodes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate node names in {self.name}")
        defined = set(names) | set(self.inputs)
        for n in self.nodes:
            for (src, lag) in n.srcs:
                if lag == 0 and src not in defined:
                    raise ValueError(f"{self.name}:{n.name} uses undefined {src}")
                if lag > 0 and src not in names and src not in self.init:
                    raise ValueError(f"{self.name}:{n.name} lagged src {src} has no init")

    # --- Step 1/2: classification ------------------------------------------
    def node_unit(self, node: Node) -> Unit:
        return Unit.FP if node.kind in FP_KINDS else Unit.INT

    def value_home(self, name: str) -> Unit:
        """Which register file a value lives in (drives queue direction)."""
        if name in self.inputs:
            return self.input_homes.get(name, Unit.FP)
        node = self.node(name)
        if node.kind in INT_DST_FP_KINDS:        # FP-executed, integer rd
            return Unit.INT
        return self.node_unit(node)

    def node(self, name: str) -> Node:
        for n in self.nodes:
            if n.name == name:
                return n
        raise KeyError(name)

    def consumers(self, name: str, unit: Optional[Unit] = None) -> List[Node]:
        out = []
        for n in self.nodes:
            if any(src == name and lag == 0 for (src, lag) in n.srcs):
                if unit is None or self.consumer_side(n) is unit:
                    out.append(n)
        return out

    def consumer_side(self, node: Node) -> Unit:
        """On which side a node *reads* cross-thread operands.

        FP-unit ops read integer operands from the I2F queue; integer ops
        read FP-homed values from the F2I queue.
        """
        return self.node_unit(node)

    def comm_edges(self) -> List[Tuple[str, Node]]:
        """All (value, consumer) pairs crossing the INT/FP boundary."""
        edges = []
        for n in self.nodes:
            for (src, lag) in n.srcs:
                if lag != 0:
                    continue
                if self.value_home(src) is not self.consumer_side(n):
                    edges.append((src, n))
        return edges

    def outputs(self) -> List[Node]:
        return [n for n in self.nodes if n.out]

    def eval_reference(self, n_samples: int) -> Dict[str, List[Any]]:
        """Pure-Python oracle: evaluate the loop body sequentially."""
        env: Dict[Tuple[str, int], Any] = {}
        outs: Dict[str, List[Any]] = {n.name: [] for n in self.outputs()}
        for i in range(n_samples):
            for name, gen in self.inputs.items():
                env[(name, i)] = gen(i)
            for node in self.nodes:
                args = []
                for (src, lag) in node.srcs:
                    j = i - lag
                    if j < 0:
                        args.append(self.init[src])
                    else:
                        args.append(env[(src, j)])
                if node.fn is None:
                    raise ValueError(f"node {node.name} has no fn")
                env[(node.name, i)] = node.fn(*args)
            for node in self.outputs():
                outs[node.name].append(env[(node.name, i)])
        return outs
