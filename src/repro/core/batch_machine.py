"""Batched simulation: B machine configs of one program at once.

The DSE sweep (``core.sweep``) spends almost all of its time stepping the
*same lowered program* under many machine configurations — the lowering
memos already collapse the grid to a handful of distinct programs, but each
(depth x latency) point still pays a full Python interpreter loop in
:class:`~.machine.Stepper`.  This module vectorizes that work **across
points**: issue times, stall accumulators and energy counters become
``(B,)`` numpy arrays, per-point config (queue depths, queue latency,
deadlock limit) becomes array parameters, and the whole batch is advanced
with a handful of numpy operations per *instruction* instead of a Python
loop iteration per *cycle*.  Points that deadlock are delegated to the
scalar engine (they are the slow exception, not the common case), never
looped over in the hot path.

Bit-identity contract (the PR-2 contract, extended):
:class:`BatchStepper` must match :class:`~.machine.Stepper` *exactly* —
cycles, energy (same float operations in the same order), per-cause stall
breakdown, push/pop sequences, occupancy highwater, FIFO violations, the
functional environment, and deadlock cycle/message — for every point.
``tests/test_batch_machine.py`` fuzzes this differentially and CI gates it.

How the batch engine gets away with one functional pass
-------------------------------------------------------
Timing never feeds back into *values* for the programs the sweep lowers:

* every register is written at most once program-wide (SSA; ``init_env``
  counts as a first write), so a consumer always reads the unique value;
* each queue is pushed by at most one stream and popped by at most one
  stream, so push order and pop order are the streams' program order —
  independent of machine timing — and the k-th pop always observes the
  k-th push.

Under those restrictions the environment, push/pop sequences, FIFO
violations, instruction counts and per-instruction energies are computed
once per program by a greedy dataflow pass (:func:`_compile`), shared by
all B points; only *when* things happen differs per point.  Programs that
violate the restrictions raise :class:`BatchUnsupported` — callers
(``core.sweep``) fall back to the scalar event engine, keeping the batch
path an optimization, never a semantics fork.

Why issue times are a max-recurrence
------------------------------------
The same restrictions make every blocking condition a *statically linked*
timestamp.  In-order issue means instruction ``i`` of a stream is first
attempted the cycle after its predecessor issues; each condition in the
scalar engine's check order then clears at a time that is a pure function
of other instructions' issue/completion times:

* ``busy``        — completion of the nearest prior blocking instruction
  of the same unit in the same stream (issue order = program order);
* ``dep``         — completion of the register's unique producer;
* ``queue_empty`` — the matching push (k-th pop reads k-th push, both
  serials static) becomes visible at producer completion + queue latency;
* ``queue_full``  — room for push serial ``p`` at depth ``d`` appears when
  pop serial ``p - d`` issues (+1 cycle when the popper's unit is checked
  after the pusher's in the same machine cycle).

So ``t[i] = max(t[prev]+1, busy, deps…, visibility…, room…)`` — and with
the dependence edges (including the depth-dependent capacity edges) forming
a DAG, one pass over the instructions in topological order evaluates the
whole batch with ~a dozen numpy ops per instruction.  The capacity edges
only get *looser* as depths grow, so a topological order computed at the
batch's componentwise-minimum depths is valid for every point; capacity
cycles (push that can never make room) and incomplete dataflow are
guaranteed deadlocks and are delegated to the scalar engine, as are points
whose issue-time gaps exceed their deadlock limit (detected post-hoc from
the computed schedule, which is exact up to the deadlock horizon).

Stall attribution reuses the event engine's bulk walk: while ``i`` is
blocked, every clear-time above is a constant, so the per-cycle "first
failing condition" decomposes into interval sums (:func:`_attribute`).
Energy is bit-exact, not just close: per point, the shared per-instruction
energies are permuted into issue order (cycle, then unit order) and summed
left-to-right with ``np.cumsum`` — the same IEEE additions the scalar
engines perform — and the static term is applied once at result time
exactly like ``ReferenceStepper.result``.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .isa import E_STATIC_PER_CYCLE, QUEUE_INDEX, Queue, Unit
from .machine import (STALL_CAUSES, DeadlockError, MachineConfig, Program,
                      SimResult, Stepper)

#: flat stall-counter layout: ``unit_index * len(STALL_CAUSES) + cause_index``
_STALL_KEY_STRINGS: Tuple[str, ...] = tuple(
    f"{u.value}_{c}" for u in Unit for c in STALL_CAUSES)
_STALL_KEY_ID: Dict[str, int] = {k: i for i, k in enumerate(_STALL_KEY_STRINGS)}
_NKEYS = len(_STALL_KEY_STRINGS)

_I8 = np.int64


class BatchUnsupported(ValueError):
    """The program (or config batch) falls outside the restrictions that
    make one shared functional pass sound; run the scalar engine instead."""


@dataclass
class BatchDeadlock:
    """Per-point deadlock outcome, carrying exactly what
    :class:`~.machine.Stepper` raises: the reference-identical message, the
    cycle at the deadlock horizon, and the stall breakdown at raise time."""
    name: str
    policy: Any
    message: str
    cycle: int
    stalls: Dict[str, int] = field(default_factory=dict)

    def error(self) -> DeadlockError:
        return DeadlockError(self.message)


#: one entry of ``BatchStepper.run()``'s output
BatchOutcome = Union[SimResult, BatchDeadlock]


class _ProgramTables:
    """Everything config-independent about one program: the shared
    functional-pass outputs plus the static dependence linkage that turns
    per-point issue times into a max-recurrence (see module docstring).

    Per-instruction records (``self.instrs``, global issue order of the
    streams = INT then FP):

    ``(prev, busyprev, busykey, lat, srcs, pushes)`` where ``srcs`` is a
    tuple of ``(clear_gid, is_queue, key)`` in the scalar engine's semantic
    check order (entries whose clear time is identically 0 — init-env
    registers — are dropped; a zero clear time can never block or own a
    stall) and ``pushes`` is a tuple of ``(queue_index, push_serial, key)``.
    """

    def __init__(self, prog: Program, evaluate: bool):
        if prog.mode == "single":
            assert len(prog.streams) == 1, "single mode expects one merged stream"
            order = list(prog.streams.items())
        else:
            order = [(u, prog.streams[u])
                     for u in (Unit.INT, Unit.FP) if u in prog.streams]
        self.order: List[Tuple[Unit, List[Any]]] = order
        facts = [[ins.exec_facts for ins in lst] for _u, lst in order]
        S = len(order)
        self.S = max(1, S)

        # -- supported-program restrictions (see module docstring) ----------
        written: Dict[str, int] = {k: 1 for k in prog.init_env}
        pushers: Dict[int, set] = {}
        poppers: Dict[int, set] = {}
        for s, ((u, _lst), fs) in enumerate(zip(order, facts)):
            for f in fs:
                if f[2] < 1:
                    raise BatchUnsupported(
                        f"{prog.name}: zero-latency instruction "
                        f"(completion-time identities need latency >= 1)")
                if prog.mode != "single" and f[0] is not u:
                    raise BatchUnsupported(
                        f"{prog.name}: {f[0].value} instruction scheduled on "
                        f"the {u.value} stream (cross-stream busy coupling "
                        f"would be timing-dependent)")
                if f[7] is not None:
                    written[f[7]] = written.get(f[7], 0) + 1
                for op in f[12]:
                    if op[0]:
                        poppers.setdefault(op[5], set()).add(s)
                for push in f[13]:
                    pushers.setdefault(push[3], set()).add(s)
        multi = [d for d, c in written.items() if c > 1]
        if multi:
            raise BatchUnsupported(
                f"{prog.name}: registers written more than once "
                f"(timing could select the value): {sorted(multi)[:4]}")
        shared = [qi for m in (pushers, poppers)
                  for qi, ss in m.items() if len(ss) > 1]
        if shared:
            raise BatchUnsupported(
                f"{prog.name}: queue pushed/popped by more than one stream "
                f"(FIFO order would depend on timing)")

        # -- shared functional pass (greedy dataflow execution) -------------
        # Executes every instruction whose register sources are produced and
        # whose queue pops have matching pushes, ignoring capacity and
        # latency: with the restrictions above, any machine-feasible issue
        # order yields these exact values/sequences.  A greedy fixpoint over
        # in-order streams reaches the maximal executable prefix of each
        # stream; if that leaves instructions stranded, the dataflow itself
        # is circular and *every* machine config deadlocks before needing
        # the missing values.
        env: Dict[str, Any] = dict(prog.init_env)
        produced = set(prog.init_env)
        push_log: Dict[Queue, List[str]] = {q: [] for q in Queue}
        pop_log: Dict[Queue, List[str]] = {q: [] for q in Queue}
        push_vals: List[List[Any]] = [[] for _ in Queue]
        popped = [0 for _ in Queue]
        violations: Dict[int, List[Tuple[str, str, str, str]]] = {}
        pcs = [0] * len(order)
        progress = True
        while progress:
            progress = False
            for s, fs in enumerate(facts):
                while pcs[s] < len(fs):
                    f = fs[pcs[s]]
                    ops = f[12]
                    ok = True
                    for is_q, src, k, _key, _qv, qi in ops:
                        if is_q:
                            if len(push_vals[qi]) < popped[qi] + k + 1:
                                ok = False
                                break
                        elif src not in produced:
                            ok = False
                            break
                    if not ok:
                        break
                    opvals = []
                    expects = f[9]
                    n_pop = 0
                    for is_q, src, k, _key, qv, qi in ops:
                        if is_q:
                            vname, val = push_vals[qi][popped[qi]]
                            popped[qi] += 1
                            pop_log[list(Queue)[qi]].append(vname)
                            if expects and expects[n_pop] != vname:
                                gid = self._gid(s, pcs[s], facts)
                                violations.setdefault(gid, []).append(
                                    (f[10], qv, expects[n_pop], vname))
                            n_pop += 1
                            opvals.append(val)
                        else:
                            opvals.append(env.get(src))
                    result = None
                    if evaluate and f[8] is not None:
                        result = f[8](*opvals)
                    if f[7] is not None:
                        env[f[7]] = result
                        produced.add(f[7])
                    for _q, _k, _key, qi in f[13]:
                        push_vals[qi].append((f[11], result))
                        push_log[list(Queue)[qi]].append(f[11])
                    pcs[s] += 1
                    progress = True
        self.value_complete = all(pcs[s] == len(fs)
                                  for s, fs in enumerate(facts))
        self.env = env
        self.push_seq = push_log
        self.pop_seq = pop_log
        self.instr_count = {"int": 0, "fp": 0}
        for _u, lst in order:
            for ins in lst:
                self.instr_count[ins.unit.value] += 1

        # -- FIFO-violation interleaving bookkeeping ------------------------
        # Violating instructions are "tracked": the engine records their
        # per-point issue cycles and the result builder re-merges the global
        # violation list by (issue cycle, stream order) — the exact append
        # order of the scalar engines.
        tracked_gids = sorted(violations)
        self.n_tracked = len(tracked_gids)
        self.tracked_gid = np.array(tracked_gids, dtype=_I8)
        self.tracked_sorder = np.array(
            [self._stream_of(gid, facts) for gid in tracked_gids],
            dtype=_I8)
        self.tracked_tuples: List[List[Tuple[str, str, str, str]]] = [
            violations[gid] for gid in tracked_gids]

        # -- static dependence linkage --------------------------------------
        offsets: List[int] = []
        off = 0
        for fs in facts:
            offsets.append(off)
            off += len(fs)
        L = off
        self.L = L
        NQ = len(Queue)
        self.g_e = np.zeros(L, np.float64)
        self.g_sidx = np.zeros(L, _I8)
        producer: Dict[str, int] = {}
        pushg: List[List[int]] = [[] for _ in range(NQ)]  # push serial -> gid
        popg: List[List[int]] = [[] for _ in range(NQ)]   # pop serial -> gid
        pop_ev: List[List[Tuple[int, int, int]]] = [[] for _ in range(NQ)]
        push_ev: List[List[Tuple[int, int, int]]] = [[] for _ in range(NQ)]
        km = 1
        raw: List[Tuple] = []  # (prev, busyprev, busykey, lat, raw_srcs, raw_pushes)
        for s, fs in enumerate(facts):
            last_blocking: Dict[int, int] = {}
            for i, f in enumerate(fs):
                gid = offsets[s] + i
                (unit, _uval, latency, blocking, e_plain, e_frep, busy_key,
                 dst, _fn, _expects, _label, _pushv, ops, pushes, uidx) = f
                self.g_sidx[gid] = s
                self.g_e[gid] = (e_frep if (prog.frep and unit is Unit.FP)
                                 else e_plain)
                prev = gid - 1 if i > 0 else -1
                busyprev = last_blocking.get(uidx, -1)
                if blocking:
                    last_blocking[uidx] = gid
                if dst is not None:
                    producer[dst] = gid
                km = max(km, len(ops) + 1, len(pushes) + 1)
                # visibility serials use the pre-instruction pop counts
                raw_srcs = []
                pre = [len(popg[qi]) for qi in range(NQ)]
                for is_q, src, k, key, _qv, qi in ops:
                    if is_q:
                        raw_srcs.append((True, qi, pre[qi] + k,
                                         _STALL_KEY_ID[key]))
                    else:
                        raw_srcs.append((False, src, -1, _STALL_KEY_ID[key]))
                for j, (is_q, _src, _k, _key, _qv, qi) in enumerate(ops):
                    if is_q:
                        popg[qi].append(gid)
                        pop_ev[qi].append((gid, s * 2 + 0, j))
                raw_pushes = []
                # room serials use the scalar check's k (relative to the
                # pre-instruction occupancy), FIFO serials the append order
                pre_push = [len(pushg[qi]) for qi in range(NQ)]
                for j, (_q, k, key, qi) in enumerate(pushes):
                    raw_pushes.append((qi, pre_push[qi] + k,
                                       _STALL_KEY_ID[key]))
                    pushg[qi].append(gid)
                    push_ev[qi].append((gid, s * 2 + 1, j))
                raw.append((prev, busyprev, _STALL_KEY_ID[busy_key],
                            int(latency), tuple(raw_srcs), tuple(raw_pushes)))

        init = set(prog.init_env)
        instrs: List[Tuple] = []
        preds: List[List[int]] = []
        cap_slots: List[Tuple[int, int, int]] = []
        for gid, (prev, busyprev, busykey, lat, raw_srcs, raw_pushes) \
                in enumerate(raw):
            srcs = []
            p: List[int] = [prev] if prev >= 0 else []
            for is_q, a, serial, key in raw_srcs:
                if is_q:
                    pg = pushg[a]
                    g = pg[serial] if serial < len(pg) else -1
                else:
                    g = -1 if a in init else producer.get(a, -1)
                if g >= 0:
                    srcs.append((g, is_q, key))
                    p.append(g)
            for qi, ps, _key in raw_pushes:
                cap_slots.append((gid, qi, ps))
            instrs.append((prev, busyprev, busykey, lat,
                           tuple(srcs), raw_pushes))
            preds.append(p)
        self.instrs = instrs
        self._preds = preds
        self._cap_slots = cap_slots
        self._topo_cache: Dict[Tuple[int, ...], Optional[List[int]]] = {}

        self.popg = [np.array(g, dtype=_I8) for g in popg]
        self.npop = [len(g) for g in popg]
        #: depth below which some push needs a pop that never happens —
        #: guaranteed deadlock, delegated to the scalar engine
        req = [0] * NQ
        for _gid, qi, serial in cap_slots:
            req[qi] = max(req[qi], serial - len(popg[qi]) + 1)
        self.min_depth_req = np.array(req, dtype=_I8)
        self.adj = []
        for qi in range(NQ):
            pu = next(iter(pushers.get(qi, {0})))
            po = next(iter(poppers.get(qi, {0})))
            self.adj.append(0 if po < pu else 1)
        #: occupancy events per queue: gid / static tiebreak / +-1 delta.
        #: Within a machine cycle the scalar engine applies units in stream
        #: order and, within an instruction, pops before pushes — encoded in
        #: the tiebreak so a per-point argsort replays the exact interleave.
        self.occ_tie_mod = self.S * 2 * km
        self.occ_ev = []
        for qi in range(NQ):
            evs = pop_ev[qi] + push_ev[qi]
            gids = np.array([g for g, _ph, _j in evs], dtype=_I8)
            tie = np.array([ph * km + j for _g, ph, j in evs], dtype=_I8)
            delta = np.array([-1] * len(pop_ev[qi]) + [1] * len(push_ev[qi]),
                             dtype=_I8)
            self.occ_ev.append((gids, tie, delta, len(push_ev[qi]) > 0))

    def topo(self, dvec: Tuple[int, ...]) -> Optional[List[int]]:
        """Topological order of the dependence DAG at queue depths ``dvec``
        (``None`` if the capacity edges create a cycle — a guaranteed
        deadlock for every point at those depths).  Cached per program; a
        capacity edge at depth ``d`` is implied by the edge at any tighter
        depth plus stream order, so the order for the componentwise-minimum
        depths of a batch is valid for the entire batch."""
        cached = self._topo_cache.get(dvec, False)
        if cached is not False:
            return cached
        L = self.L
        indeg = [0] * L
        succ: List[List[int]] = [[] for _ in range(L)]
        for i, ps in enumerate(self._preds):
            for p in ps:
                succ[p].append(i)
                indeg[i] += 1
        for gid, qi, serial in self._cap_slots:
            j = serial - dvec[qi]
            if j >= 0:
                # feasibility (min_depth_req) guarantees j < npop here
                p = int(self.popg[qi][j])
                succ[p].append(gid)
                indeg[gid] += 1
        dq = deque(i for i in range(L) if indeg[i] == 0)
        out: List[int] = []
        while dq:
            i = dq.popleft()
            out.append(i)
            for nxt in succ[i]:
                indeg[nxt] -= 1
                if indeg[nxt] == 0:
                    dq.append(nxt)
        res: Optional[List[int]] = out if len(out) == L else None
        self._topo_cache[dvec] = res
        return res

    @staticmethod
    def _gid(s: int, i: int, facts: List[List[Tuple]]) -> int:
        return sum(len(facts[t]) for t in range(s)) + i

    @staticmethod
    def _stream_of(gid: int, facts: List[List[Tuple]]) -> int:
        for s, fs in enumerate(facts):
            if gid < len(fs):
                return s
            gid -= len(fs)
        raise AssertionError("tracked instruction out of range")


def _compile(prog: Program, evaluate: bool) -> _ProgramTables:
    """Build (or fetch) the program's batch tables.  Cached on the Program
    object — mirroring ``Stepper``'s ``_event_engine_cache`` — so memoized
    programs re-simulated across config batches compile once per
    ``(mode, evaluate)``."""
    key = (prog.mode, bool(evaluate))
    cached = getattr(prog, "_batch_engine_cache", None)
    if cached is not None and cached[0] == key:
        return cached[1]
    tables = _ProgramTables(prog, evaluate)
    prog._batch_engine_cache = (key, tables)
    return tables


class BatchStepper:
    """Advance B machine configurations of one program at once.

    ``run()`` returns one outcome per config, in input order: a
    :class:`~.machine.SimResult` bit-identical to what
    ``Stepper(prog, cfg).run()`` would produce, or a :class:`BatchDeadlock`
    carrying the identical :class:`DeadlockError` message/cycle/stalls
    (deadlocking points are delegated to the scalar engine — exactness by
    construction; completing points never are).

    Shared, config-independent pieces (``env``, push/pop sequences) are
    *shared objects* across the returned results — treat them as read-only,
    exactly like the memoized Programs the sweep already shares.

    Raises :class:`BatchUnsupported` (at construction) for programs outside
    the one-writer/one-pusher/one-popper restrictions or for mixed
    ``evaluate`` flags across the batch.
    """

    def __init__(self, prog: Program, cfgs: Sequence[MachineConfig]):
        self.prog = prog
        self.cfgs = [c if c is not None else MachineConfig() for c in cfgs]
        evals = {bool(c.evaluate) for c in self.cfgs}
        if len(evals) > 1:
            raise BatchUnsupported(
                "mixed cfg.evaluate across a batch (env would differ)")
        self._evaluate = evals.pop() if evals else True
        self._t = _compile(prog, self._evaluate)

    def run(self) -> List[BatchOutcome]:
        t = self._t
        B = len(self.cfgs)
        if B == 0:
            return []
        qlist = list(Queue)
        depths = np.array([[c.depth_of(q) for q in qlist]
                           for c in self.cfgs], _I8)

        out: List[Optional[BatchOutcome]] = [None] * B
        if t.L == 0:
            zero = np.zeros(_NKEYS, _I8)
            for b in range(B):
                out[b] = self._result(0, 0.0, zero, [0] * len(qlist), None)
            return out  # type: ignore[return-value]
        if not t.value_complete:
            # circular dataflow: every config deadlocks before the missing
            # values are needed — the scalar engine is exact and cheap here.
            return [self._scalar(b) for b in range(B)]

        feasible = ~(depths < t.min_depth_req[None, :]).any(axis=1)
        for b in np.nonzero(~feasible)[0]:
            out[int(b)] = self._scalar(int(b))
        rows = np.nonzero(feasible)[0].astype(_I8)
        groups: List[Tuple[np.ndarray, List[int]]] = []
        if rows.size:
            dmin = tuple(int(x) for x in depths[rows].min(axis=0))
            order = t.topo(dmin)
            if order is not None:
                groups.append((rows, order))
            else:
                # the batch's min-depth envelope is capacity-cyclic but
                # individual depth classes may not be: split per class.
                classes: Dict[Tuple[int, ...], List[int]] = {}
                for b in rows:
                    classes.setdefault(
                        tuple(int(x) for x in depths[b]), []).append(int(b))
                for dvec, bs in classes.items():
                    o = t.topo(dvec)
                    if o is None:
                        for b in bs:
                            out[b] = self._scalar(b)
                    else:
                        groups.append((np.array(bs, _I8), o))

        stalls = np.zeros((B, _NKEYS), _I8)
        for rows_g, order in groups:
            self._run_group(rows_g, order, depths, stalls, out)
        return out  # type: ignore[return-value]

    # -- the max-recurrence over one topologically-ordered group -------------

    def _run_group(self, rows: np.ndarray, order: List[int],
                   depths: np.ndarray, stalls: np.ndarray,
                   out: List[Optional[BatchOutcome]]) -> None:
        t = self._t
        L = t.L
        R = rows.size
        cfgs = self.cfgs
        dR = depths[rows]
        qR = np.array([cfgs[int(b)].queue_latency for b in rows], _I8)
        limR = np.array([cfgs[int(b)].deadlock_limit for b in rows], _I8)
        ar = np.arange(R)
        zeros = np.zeros(R, _I8)
        ti = np.zeros((L, R), _I8)
        td = np.zeros((L, R), _I8)
        instrs = t.instrs
        popg = t.popg
        npop = t.npop
        adj = t.adj
        for i in order:
            prev, busyprev, busykey, lat, srcs, pushes = instrs[i]
            base = ti[prev] + 1 if prev >= 0 else zeros
            acc = base
            clears: List[Tuple[np.ndarray, int]] = []
            if busyprev >= 0:
                c = td[busyprev]
                clears.append((c, busykey))
                acc = np.maximum(acc, c)
            for g, is_q, key in srcs:
                c = td[g] + qR if is_q else td[g]
                clears.append((c, key))
                acc = np.maximum(acc, c)
            for qi, ps, key in pushes:
                jv = ps - dR[:, qi]
                if npop[qi] == 0:
                    # feasibility guarantees jv < 0 for every surviving
                    # point: depth >= total pushes, so room always exists
                    continue
                jc = np.clip(jv, 0, npop[qi] - 1)
                c = ti[popg[qi][jc], ar] + adj[qi]
                c = np.where(jv < 0, 0, c)
                clears.append((c, key))
                acc = np.maximum(acc, c)
            ti[i] = acc
            td[i] = acc + lat
            if clears and acc is not base:
                m = acc > base
                if m.any():
                    sub = np.nonzero(m)[0]
                    ct = np.stack([c[sub] for c, _k in clears], axis=1)
                    keys = np.broadcast_to(
                        np.array([k for _c, k in clears], _I8),
                        (sub.size, len(clears)))
                    _attribute(stalls, rows[sub], ct, keys,
                               base[sub], acc[sub] - 1)

        # deadlock-limit detection: the schedule above is the no-horizon
        # machine's exact schedule, so the reference deadlocks iff the wait
        # for the first/next issue exceeds limit+1 cycles.
        lim1 = limR + 1
        ts = np.sort(ti, axis=0)
        dead = ts[0] > lim1
        if L > 1:
            dead |= (np.diff(ts, axis=0) > lim1[None, :]).any(axis=0)

        cycles = td.max(axis=0)
        # energy in exact issue order: cumsum is sequential left-to-right
        # addition (unlike np.sum's pairwise reduction), matching the scalar
        # engines' accumulate-at-issue float ops bit for bit.
        perm = np.argsort(ti * t.S + t.g_sidx[:, None], axis=0, kind="stable")
        energy = np.cumsum(t.g_e[perm], axis=0)[-1]
        NQ = len(t.occ_ev)
        mx = np.zeros((NQ, R), _I8)
        for qi in range(NQ):
            gids, tie, delta, has_push = t.occ_ev[qi]
            if not has_push:
                continue
            key = ti[gids] * t.occ_tie_mod + tie[:, None]
            p = np.argsort(key, axis=0, kind="stable")
            d = delta[p]
            cs = np.cumsum(d, axis=0)
            mx[qi] = np.max(np.where(d > 0, cs, 0), axis=0)
        issue = ti[t.tracked_gid] if t.n_tracked else None

        for r in range(R):
            b = int(rows[r])
            if dead[r]:
                out[b] = self._scalar(b)
                continue
            out[b] = self._result(
                int(cycles[r]), float(energy[r]), stalls[b], mx[:, r],
                issue[:, r] if issue is not None else None)

    # -- result assembly / scalar delegation ---------------------------------

    def _result(self, cycles: int, dyn_energy: float, stall_row, mx_row,
                issue_row) -> SimResult:
        t = self._t
        prog = self.prog
        sd = {_STALL_KEY_STRINGS[k]: int(stall_row[k])
              for k in range(_NKEYS) if stall_row[k]}
        viol: List[Tuple[str, str, str, str]] = []
        if t.n_tracked and issue_row is not None:
            merged = sorted(
                range(t.n_tracked),
                key=lambda tid: (int(issue_row[tid]),
                                 int(t.tracked_sorder[tid])))
            for tid in merged:
                viol.extend(t.tracked_tuples[tid])
        return SimResult(
            name=prog.name,
            policy=prog.policy,
            cycles=cycles,
            n_samples=prog.n_samples,
            instrs=dict(t.instr_count),
            energy=dyn_energy + E_STATIC_PER_CYCLE * cycles,
            env=t.env,
            push_seq=t.push_seq,
            pop_seq=t.pop_seq,
            max_queue_occupancy={q: int(mx_row[qi])
                                 for q, qi in QUEUE_INDEX.items()},
            fifo_violations=viol,
            stalls=sd,
        )

    def _scalar(self, b: int) -> BatchOutcome:
        """Run one point on the scalar event engine — used for points the
        recurrence predicts (or cannot rule out) to deadlock.  Delegation is
        always sound: if the prediction were ever wrong, the scalar result
        is returned as-is, so mispredictions cost speed, never identity."""
        st = Stepper(self.prog, self.cfgs[b])
        try:
            return st.run()
        except DeadlockError as e:
            return BatchDeadlock(
                name=self.prog.name, policy=self.prog.policy,
                message=str(e), cycle=int(st.cycle), stalls=dict(st.stalls))


def _attribute(stalls: np.ndarray, rows: np.ndarray, ct: np.ndarray,
               keys: np.ndarray, a: np.ndarray, b: np.ndarray) -> None:
    """Vectorized twin of ``Stepper._attribute_stalls`` over many points.

    For each row, walk the clear-time columns in check order: while the
    cursor ``c`` is within ``[a, b]``, the first column with clear-time
    ``t > c`` owns the stall cycles ``[c, min(b, t-1)]``.  Columns whose
    clear-time is already past (including absent conditions encoded as 0)
    are skipped, exactly like the scalar walk.
    """
    c = a.astype(np.int64, copy=True)
    for j in range(ct.shape[1]):
        tj = ct[:, j]
        m = (tj > c) & (c <= b)
        if not m.any():
            continue
        end = np.minimum(b, tj - 1)
        amt = np.where(m, end - c + 1, 0)
        np.add.at(stalls, (rows, keys[:, j]), amt)
        c = np.where(m, np.minimum(tj, b + 1), c)


def batch_simulate(prog: Program,
                   cfgs: Sequence[MachineConfig]) -> List[BatchOutcome]:
    """One-shot convenience twin of :func:`~.machine.simulate` for a batch."""
    return BatchStepper(prog, cfgs).run()


def batch_supported(prog: Program,
                    evaluate: bool = True) -> Optional[str]:
    """``None`` if ``prog`` can run on the batch engine, else the reason."""
    try:
        _compile(prog, evaluate)
        return None
    except BatchUnsupported as e:
        return str(e)
