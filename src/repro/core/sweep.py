"""Design-space exploration over the machine model (the DSE engine).

The paper's headline numbers come from one hardware point (queue depth 4,
latency 1, unroll 8).  This module sweeps the whole configuration grid —
(kernel x policy x queue_depth x queue_latency x unroll x unroll_int) — through
the simulator and reduces each run to a flat :class:`SweepRecord` with IPC,
energy, throughput and the stall breakdown, ready for Pareto extraction
(``core.pareto``) and CSV emission.

Every sweep point doubles as a correctness test: the simulated program's
outputs are compared bit-for-bit against the sequential baseline interpreter
(``LoopDFG.eval_reference``), so a large sweep is also the repo's largest
semantics fuzzer for the COPIFT/COPIFTv2 lowerings.

Sweep points are plain primitives (no lambdas, no Programs), so they pickle
across process boundaries; :func:`run_sweep` fans the grid out over a process
pool (the stepper is pure Python — processes, not threads, buy parallelism)
and falls back to in-process execution when a pool is unavailable.
"""
from __future__ import annotations

import itertools
import os
from dataclasses import asdict, dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .bench_kernels import KERNELS
from .machine import DeadlockError, MachineConfig, Stepper
from .metrics import best, geomean, group_by
from .policy import ExecutionPolicy
from .transform import TransformConfig, lower


@dataclass(frozen=True)
class SweepPoint:
    """One configuration in the design space.  All fields are primitives so
    points (and lists of them) pickle cleanly into pool workers."""
    kernel: str
    policy: str                      # ExecutionPolicy value
    queue_depth: int = 4
    queue_latency: int = 1
    unroll: int = 8
    unroll_int: Optional[int] = None
    n_samples: int = 64


@dataclass
class SweepRecord:
    """Flat, serializable result for one sweep point."""
    kernel: str
    policy: str
    queue_depth: int
    queue_latency: int
    unroll: int
    unroll_int: Optional[int]
    n_samples: int
    status: str                      # "ok" | "rejected" | "deadlock"
    detail: str = ""
    cycles: int = 0
    ipc: float = 0.0
    energy: float = 0.0
    power: float = 0.0
    throughput: float = 0.0
    efficiency: float = 0.0
    instrs_int: int = 0
    instrs_fp: int = 0
    max_occ_i2f: int = 0
    max_occ_f2i: int = 0
    fifo_violations: int = 0
    equivalent: bool = False         # outputs bit-identical to the interpreter
    stalls: Dict[str, int] = field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return self.status == "ok"


#: column order for CSV emission (see ``core.pareto.write_csv``)
CSV_FIELDS: Tuple[str, ...] = (
    "kernel", "policy", "queue_depth", "queue_latency", "unroll", "unroll_int",
    "n_samples", "status", "cycles", "ipc", "energy", "power", "throughput",
    "efficiency", "instrs_int", "instrs_fp", "max_occ_i2f", "max_occ_f2i",
    "fifo_violations", "equivalent", "stalls", "detail",
)


def grid(kernels: Optional[Sequence[str]] = None,
         policies: Optional[Sequence[ExecutionPolicy]] = None,
         queue_depths: Sequence[int] = (1, 2, 4, 8),
         queue_latencies: Sequence[int] = (1,),
         unrolls: Sequence[int] = (8,),
         unroll_ints: Sequence[Optional[int]] = (None,),
         n_samples: int = 64) -> List[SweepPoint]:
    """Enumerate the cartesian configuration grid as sweep points."""
    ks = list(kernels) if kernels else sorted(KERNELS)
    ps = list(policies) if policies else list(ExecutionPolicy)
    unknown = [k for k in ks if k not in KERNELS]
    if unknown:
        raise KeyError(f"unknown kernels: {unknown} (have {sorted(KERNELS)})")
    return [
        SweepPoint(kernel=k, policy=ExecutionPolicy.parse(p).value,
                   queue_depth=d, queue_latency=lat, unroll=u, unroll_int=ui,
                   n_samples=n_samples)
        for k, p, d, lat, u, ui in itertools.product(
            ks, ps, queue_depths, queue_latencies, unrolls, unroll_ints)
    ]


def run_point(pt: SweepPoint) -> SweepRecord:
    """Lower + simulate one configuration and check baseline equivalence.

    Never raises for model-level outcomes: infeasible schedules come back as
    ``status="rejected"`` and runtime deadlocks as ``status="deadlock"`` so a
    sweep always yields one record per point.
    """
    dfg = KERNELS[pt.kernel]
    policy = ExecutionPolicy.parse(pt.policy)
    base = dict(kernel=pt.kernel, policy=policy.value,
                queue_depth=pt.queue_depth, queue_latency=pt.queue_latency,
                unroll=pt.unroll, unroll_int=pt.unroll_int,
                n_samples=pt.n_samples)
    tcfg = TransformConfig(unroll=pt.unroll, unroll_int=pt.unroll_int,
                           batch=min(32, pt.n_samples),
                           queue_depth=pt.queue_depth, n_samples=pt.n_samples)
    mcfg = MachineConfig(queue_depth=pt.queue_depth,
                         queue_latency=pt.queue_latency)
    try:
        prog = lower(dfg, policy, tcfg)
    except ValueError as e:
        return SweepRecord(**base, status="rejected", detail=str(e))
    try:
        res = Stepper(prog, mcfg).run()
    except DeadlockError as e:
        return SweepRecord(**base, status="deadlock", detail=str(e))
    ref = dfg.eval_reference(pt.n_samples)
    equivalent = all(
        [res.env.get(f"{node.name}@{i}") for i in range(pt.n_samples)]
        == ref[node.name]
        for node in dfg.outputs())
    s = res.summary()
    return SweepRecord(
        **base, status="ok", cycles=s["cycles"], ipc=s["ipc"],
        energy=s["energy"], power=s["power"], throughput=s["throughput"],
        efficiency=s["efficiency"], instrs_int=s["instrs_int"],
        instrs_fp=s["instrs_fp"], max_occ_i2f=s["max_occ_i2f"],
        max_occ_f2i=s["max_occ_f2i"], fifo_violations=s["fifo_violations"],
        equivalent=equivalent, stalls=s["stalls"])


def run_sweep(points: Sequence[SweepPoint],
              workers: Optional[int] = None) -> List[SweepRecord]:
    """Run every point, in input order.  ``workers=None`` auto-sizes a
    process pool to the machine; ``workers<=1`` forces in-process execution.
    Pool startup failures (restricted sandboxes) degrade to serial."""
    points = list(points)
    if workers is None:
        workers = min(os.cpu_count() or 1, max(1, len(points) // 8))
    if workers > 1 and len(points) > 1:
        try:
            from concurrent.futures import ProcessPoolExecutor
            from concurrent.futures.process import BrokenProcessPool
            chunk = max(1, len(points) // (workers * 4))
            with ProcessPoolExecutor(max_workers=workers) as pool:
                return list(pool.map(run_point, points, chunksize=chunk))
        except (ImportError, OSError, PermissionError, BrokenProcessPool):
            pass                     # no usable pool: run in-process below
    return [run_point(pt) for pt in points]


def sweep_summary(records: Iterable[SweepRecord]) -> Dict[str, float]:
    """Aggregate a sweep into headline scalars (geomeans over ok points)."""
    recs = [r for r in records]
    ok = [r for r in recs if r.ok]
    out: Dict[str, float] = {
        "n_points": float(len(recs)),
        "n_ok": float(len(ok)),
        "n_rejected": float(sum(r.status == "rejected" for r in recs)),
        "n_equivalent": float(sum(r.equivalent for r in ok)),
        "n_fifo_violations": float(sum(r.fifo_violations for r in ok)),
    }
    if ok:
        out["peak_ipc"] = best(ok, "ipc").ipc
        out["best_efficiency"] = best(ok, "efficiency").efficiency
        for pol, rs in sorted(group_by(ok, lambda r: r.policy).items()):
            out[f"geomean_ipc_{pol}"] = geomean(r.ipc for r in rs)
            out[f"geomean_efficiency_{pol}"] = geomean(r.efficiency for r in rs)
    return out


def record_to_row(rec: SweepRecord) -> Dict[str, object]:
    """A CSV-ready dict in :data:`CSV_FIELDS` order (stalls packed)."""
    d = asdict(rec)
    d["stalls"] = ";".join(f"{k}={v}" for k, v in sorted(rec.stalls.items()))
    d["equivalent"] = int(rec.equivalent)
    return {k: d[k] for k in CSV_FIELDS}
