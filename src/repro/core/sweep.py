"""Design-space exploration over the machine model (the DSE engine).

The paper's headline numbers come from one hardware point (queue depth 4,
latency 1, unroll 8).  This module sweeps the whole configuration grid —
(kernel x policy x queue_depth x queue_latency x unroll x unroll_int) — through
the simulator and reduces each run to a flat :class:`SweepRecord` with IPC,
energy, throughput and the stall breakdown, ready for Pareto extraction
(``core.pareto``) and CSV emission.

Every sweep point doubles as a correctness test: the simulated program's
outputs are compared bit-for-bit against the sequential baseline interpreter
(``LoopDFG.eval_reference``), so a large sweep is also the repo's largest
semantics fuzzer for the COPIFT/COPIFTv2 lowerings.

Sweep points are plain primitives (no lambdas, no Programs), so they pickle
across process boundaries; :func:`run_sweep` fans the grid out over a process
pool (the stepper is pure Python — processes, not threads, buy parallelism)
and falls back to in-process execution when a pool is unavailable.

Per-worker caching: a sweep redoes a lot of shared work if every point is
treated as independent — ``lower()`` does not depend on ``queue_latency``
(nor on ``queue_depth`` for queue-free policies), and the interpreter oracle
``dfg.eval_reference`` depends only on ``(kernel, n_samples)``.  Both are
memoized per process (:func:`_lower_cached` / :func:`_reference_cached`),
and :func:`partition_points` hands each pool worker a contiguous, presized
run of points sorted by lowering key so those memos actually hit.  Workers
are sized by ``min(cpu, len(points))`` and can be pinned with the
``REPRO_SWEEP_WORKERS`` environment variable (CI sets it to 1).

Engines: every point carries an ``engine`` field.  ``"cycle"`` and
``"event"`` are the per-point steppers from ``core.machine``;
``engine="batch"`` (PR 7) routes non-clustered points through
``core.batch_machine.BatchStepper``, which advances *all points sharing a
lowered program* in one vectorized pass — each worker groups its partition
by program identity (:func:`_batch_records`), so the whole
``queue_depth x queue_latency x i2f x f2i`` machine axis of a
depth-insensitive policy collapses into a single numpy evaluation.
Clustered and pipelined points batch the same way (PR 8): grouped by
*partitioned-program-set* identity and advanced through
``core.batch_cluster.BatchClusterStepper`` (:func:`_batch_cluster_records`),
collapsing the ``banks x cq_depth x machine`` axes of one partitioning
into a single pass.  Both batch engines are bit-identical to the event
engine (enforced by ``tests/test_batch_machine.py`` /
``tests/test_batch_cluster.py``); program sets they cannot express fall
back to the per-point event stepper.

Strategies: :func:`run_sweep` evaluates every point exhaustively by
default; ``strategy="adaptive"`` dispatches to
``core.search.adaptive_sweep`` (front-guided successive halving), which
returns records only for points that survive to full fidelity.
"""
from __future__ import annotations

import functools
import itertools
import os
from dataclasses import asdict, dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .batch_cluster import (BatchClusterDeadlock, BatchClusterStepper,
                            BatchClusterUnsupported)
from .batch_machine import BatchDeadlock, BatchStepper, BatchUnsupported
from .bench_kernels import KERNELS
from .cluster import ClusterConfig, ClusterStepper
from .isa import Queue
from .machine import DeadlockError, ENGINES, MachineConfig, stepper_for
from .metrics import best, geomean, group_by
from .policy import ExecutionPolicy
from .transform import (TransformConfig, lower, partition_kernel,
                        partition_pipeline)

#: engines accepted by sweep points: the per-point steppers from
#: ``core.machine`` plus the vectorized batch engine (``core.batch_machine``)
SWEEP_ENGINES: Tuple[str, ...] = tuple(ENGINES) + ("batch",)

#: search strategies accepted by :func:`run_sweep`
STRATEGIES: Tuple[str, ...] = ("exhaustive", "adaptive")


@dataclass(frozen=True)
class SweepPoint:
    """One configuration in the design space.  All fields are primitives so
    points (and lists of them) pickle cleanly into pool workers."""
    kernel: str
    policy: str                      # ExecutionPolicy value
    queue_depth: int = 4
    queue_latency: int = 1
    unroll: int = 8
    unroll_int: Optional[int] = None
    n_samples: int = 64
    engine: str = "event"            # SWEEP_ENGINES: "event"|"cycle"|"batch"
    #: asymmetric FIFO geometry: per-queue depth overrides (None => the
    #: symmetric ``queue_depth``).  The lowering targets the tighter queue
    #: (min effective depth), which keeps the no-deadlock schedule guarantee
    #: on the looser one.
    queue_depth_i2f: Optional[int] = None
    queue_depth_f2i: Optional[int] = None
    #: cluster geometry (``core.cluster``): cores sharing the TCDM, and the
    #: bank count (None = conflict-free).  ``n_cores=1, tcdm_banks=None`` is
    #: the single-PE machine, bit-identical to the plain stepper.
    n_cores: int = 1
    tcdm_banks: Optional[int] = None
    #: pipelined-cluster axes (PR-6, ``transform.partition_pipeline``):
    #: ``pipeline=True`` splits each core pair into an INT producer streaming
    #: operands over inter-core channels to an FP-heavy consumer.
    #: ``cq_depth`` bounds the channel FIFOs (runtime property, like
    #: ``tcdm_banks``); ``dma_buffers`` is the producer's double-buffering
    #: degree (a schedule property — it shapes the lowered program).
    pipeline: bool = False
    cq_depth: int = 4
    dma_buffers: int = 2

    def effective_depths(self) -> Tuple[int, int]:
        return (self.queue_depth_i2f or self.queue_depth,
                self.queue_depth_f2i or self.queue_depth)

    @property
    def clustered(self) -> bool:
        return self.n_cores > 1 or self.tcdm_banks is not None or self.pipeline


@dataclass
class SweepRecord:
    """Flat, serializable result for one sweep point."""
    kernel: str
    policy: str
    queue_depth: int
    queue_latency: int
    unroll: int
    unroll_int: Optional[int]
    n_samples: int
    status: str                      # "ok" | "rejected" | "deadlock"
    detail: str = ""
    cycles: int = 0
    ipc: float = 0.0
    energy: float = 0.0
    power: float = 0.0
    throughput: float = 0.0
    efficiency: float = 0.0
    instrs_int: int = 0
    instrs_fp: int = 0
    max_occ_i2f: int = 0
    max_occ_f2i: int = 0
    fifo_violations: int = 0
    equivalent: bool = False         # outputs bit-identical to the interpreter
    engine: str = "event"
    queue_depth_i2f: Optional[int] = None
    queue_depth_f2i: Optional[int] = None
    #: cluster columns (PR-5): core count, TCDM banks (None = conflict-free),
    #: mean per-core IPC (== ipc on one core; ``ipc`` itself is the cluster
    #: aggregate over the makespan, up to 2*n_cores), and the total cycles
    #: lost to bank conflicts
    n_cores: int = 1
    tcdm_banks: Optional[int] = None
    ipc_per_core: float = 0.0
    bank_stalls: int = 0
    #: pipelined-cluster columns (PR-6): the pipeline/channel/DMA geometry
    #: plus the cycles lost to channel back-pressure (``*_cq_empty`` +
    #: ``*_cq_full``) and to DMA waits (``*_dma``)
    pipeline: bool = False
    cq_depth: int = 4
    dma_buffers: int = 2
    cq_stalls: int = 0
    dma_stalls: int = 0
    stalls: Dict[str, int] = field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return self.status == "ok"


#: column order for CSV emission (see ``core.pareto.write_csv``)
CSV_FIELDS: Tuple[str, ...] = (
    "kernel", "policy", "queue_depth", "queue_latency", "unroll", "unroll_int",
    "n_samples", "status", "cycles", "ipc", "energy", "power", "throughput",
    "efficiency", "instrs_int", "instrs_fp", "max_occ_i2f", "max_occ_f2i",
    "fifo_violations", "equivalent", "engine", "queue_depth_i2f",
    "queue_depth_f2i", "n_cores", "tcdm_banks", "ipc_per_core", "bank_stalls",
    "pipeline", "cq_depth", "dma_buffers", "cq_stalls", "dma_stalls",
    "stalls", "detail",
)

#: the PR-5-era column set (cluster axes but no pipeline/channel/DMA ones);
#: ``core.pareto.read_csv`` still accepts it, defaulting the pipeline columns
PRE_PIPELINE_CSV_FIELDS: Tuple[str, ...] = tuple(
    f for f in CSV_FIELDS
    if f not in ("pipeline", "cq_depth", "dma_buffers", "cq_stalls",
                 "dma_stalls"))

#: the PR-2/PR-3-era column set (no cluster axes); ``core.pareto.read_csv``
#: still accepts it, defaulting the cluster columns (n_cores=1)
LEGACY_CSV_FIELDS: Tuple[str, ...] = tuple(
    f for f in PRE_PIPELINE_CSV_FIELDS
    if f not in ("n_cores", "tcdm_banks", "ipc_per_core", "bank_stalls"))


def grid(kernels: Optional[Sequence[str]] = None,
         policies: Optional[Sequence[ExecutionPolicy]] = None,
         queue_depths: Sequence[int] = (1, 2, 4, 8),
         queue_latencies: Sequence[int] = (1,),
         unrolls: Sequence[int] = (8,),
         unroll_ints: Sequence[Optional[int]] = (None,),
         n_samples: int = 64,
         engine: str = "event",
         i2f_depths: Sequence[Optional[int]] = (None,),
         f2i_depths: Sequence[Optional[int]] = (None,),
         n_cores: Sequence[int] = (1,),
         tcdm_banks: Sequence[Optional[int]] = (None,),
         pipelines: Sequence[bool] = (False,),
         cq_depths: Sequence[int] = (4,),
         dma_buffers: Sequence[int] = (2,)) -> List[SweepPoint]:
    """Enumerate the cartesian configuration grid as sweep points.

    ``i2f_depths``/``f2i_depths`` add asymmetric FIFO geometries: each non-
    None value overrides that queue's depth while ``queue_depths`` keeps
    supplying the symmetric base (and the other queue's depth).

    ``n_cores``/``tcdm_banks`` are the cluster axes (``core.cluster``):
    core counts sharing the TCDM and bank counts (None = conflict-free).
    The defaults keep every existing grid a single-PE grid.

    ``pipelines``/``cq_depths``/``dma_buffers`` are the pipelined-cluster
    axes (PR-6): producer/consumer core pairing over inter-core channels,
    channel FIFO depth, and the producer's DMA double-buffering degree.
    Pipelined points require an even ``n_cores >= 2`` and the COPIFTv2
    policy — other combinations come back as ``status="rejected"``."""
    ks = list(kernels) if kernels else sorted(KERNELS)
    ps = list(policies) if policies else list(ExecutionPolicy)
    unknown = [k for k in ks if k not in KERNELS]
    if unknown:
        raise KeyError(f"unknown kernels: {unknown} (have {sorted(KERNELS)})")
    if engine not in SWEEP_ENGINES:
        raise ValueError(
            f"unknown engine {engine!r} (have {SWEEP_ENGINES})")
    if any(nc < 1 for nc in n_cores):
        raise ValueError(f"n_cores axis must be positive: {tuple(n_cores)}")
    if any(nb is not None and nb < 1 for nb in tcdm_banks):
        raise ValueError(
            f"tcdm_banks axis must be positive or None: {tuple(tcdm_banks)}")
    if any(cd < 1 for cd in cq_depths):
        raise ValueError(f"cq_depths axis must be positive: {tuple(cq_depths)}")
    if any(db < 1 for db in dma_buffers):
        raise ValueError(
            f"dma_buffers axis must be positive: {tuple(dma_buffers)}")
    return [
        SweepPoint(kernel=k, policy=ExecutionPolicy.parse(p).value,
                   queue_depth=d, queue_latency=lat, unroll=u, unroll_int=ui,
                   n_samples=n_samples, engine=engine,
                   queue_depth_i2f=di, queue_depth_f2i=df,
                   n_cores=nc, tcdm_banks=nb,
                   pipeline=pl, cq_depth=cd, dma_buffers=db)
        for k, p, d, lat, u, ui, di, df, nc, nb, pl, cd, db in
        itertools.product(
            ks, ps, queue_depths, queue_latencies, unrolls, unroll_ints,
            i2f_depths, f2i_depths, n_cores, tcdm_banks, pipelines,
            cq_depths, dma_buffers)
    ]


# -- per-worker memos --------------------------------------------------------
# Both caches are process-local (each pool worker owns one) and keyed purely
# on primitives, so cache state never crosses a pickle boundary.  Cached
# values are treated as immutable by every consumer: steppers copy
# ``init_env`` and never touch a Program's streams, and the interpreter
# reference is only compared against, never written.

def _tcfg_for(pt: SweepPoint) -> TransformConfig:
    # the schedule targets the tighter FIFO of an asymmetric pair: the
    # replay gate's no-deadlock guarantee then holds a fortiori on the
    # looser queue
    return TransformConfig(unroll=pt.unroll, unroll_int=pt.unroll_int,
                           batch=min(32, pt.n_samples),
                           queue_depth=min(pt.effective_depths()),
                           n_samples=pt.n_samples)


def _lower_key(pt: SweepPoint) -> Tuple:
    """The transform-relevant fields of a point (see
    ``TransformConfig.lowering_key``): ``queue_latency`` never matters, and
    ``queue_depth`` only matters for depth-sensitive policies.  ``n_cores``
    shapes the partitioned per-core programs; ``tcdm_banks`` is purely a
    runtime (machine) property.  ``pipeline``/``dma_buffers`` shape the
    producer/consumer programs; ``cq_depth`` is runtime-only (like
    ``tcdm_banks``)."""
    policy = ExecutionPolicy.parse(pt.policy)
    pipe = (pt.pipeline, pt.dma_buffers if pt.pipeline else 0)
    return (pt.kernel, pt.n_cores) + pipe + _tcfg_for(pt).lowering_key(policy)


@functools.lru_cache(maxsize=64)
def _lower_cached(kernel: str, policy_value: str, tcfg: TransformConfig):
    """Memoized ``lower()``; raises ValueError exactly like the uncached
    call (lru_cache does not cache exceptions, but rejection is cheap)."""
    return lower(KERNELS[kernel], ExecutionPolicy.parse(policy_value), tcfg)


@functools.lru_cache(maxsize=64)
def _reference_cached(kernel: str, n_samples: int):
    """Memoized sequential-interpreter oracle for equivalence checks."""
    return KERNELS[kernel].eval_reference(n_samples)


@functools.lru_cache(maxsize=64)
def _partition_cached(kernel: str, policy_value: str, tcfg: TransformConfig,
                      n_cores: int) -> Tuple:
    """Memoized ``partition_kernel()`` (the cluster analogue of
    ``_lower_cached``); raises ValueError exactly like the uncached call."""
    return tuple(partition_kernel(KERNELS[kernel],
                                  ExecutionPolicy.parse(policy_value),
                                  tcfg, n_cores))


@functools.lru_cache(maxsize=64)
def _pipeline_cached(kernel: str, tcfg: TransformConfig, n_cores: int,
                     dma_buffers: int) -> Tuple:
    """Memoized ``partition_pipeline()`` (producer/consumer pairing is
    COPIFTv2-only, so no policy key); raises ValueError like the uncached
    call."""
    return tuple(partition_pipeline(KERNELS[kernel], tcfg, n_cores,
                                    dma_buffers=dma_buffers))


def clear_worker_caches() -> None:
    """Drop this process's lowering/reference memos (benchmark hygiene)."""
    from . import transform
    _lower_cached.cache_clear()
    _reference_cached.cache_clear()
    _partition_cached.cache_clear()
    _pipeline_cached.cache_clear()
    transform._V2_PREFIX_CACHE.clear()
    transform._PARTITION_CACHE.clear()


def _geometry_detail(pt: SweepPoint) -> Optional[str]:
    """A rejection message for malformed cluster geometry, else None."""
    if (pt.n_cores < 1 or (pt.tcdm_banks is not None and pt.tcdm_banks < 1)
            or pt.cq_depth < 1 or pt.dma_buffers < 1):
        return (f"invalid cluster geometry: n_cores={pt.n_cores}, "
                f"tcdm_banks={pt.tcdm_banks}, cq_depth={pt.cq_depth}, "
                f"dma_buffers={pt.dma_buffers}")
    return None


def _point_base(pt: SweepPoint, policy: ExecutionPolicy) -> Dict:
    """The identity columns every record for ``pt`` shares."""
    return dict(kernel=pt.kernel, policy=policy.value,
                queue_depth=pt.queue_depth, queue_latency=pt.queue_latency,
                unroll=pt.unroll, unroll_int=pt.unroll_int,
                n_samples=pt.n_samples, engine=pt.engine,
                queue_depth_i2f=pt.queue_depth_i2f,
                queue_depth_f2i=pt.queue_depth_f2i,
                n_cores=pt.n_cores, tcdm_banks=pt.tcdm_banks,
                pipeline=pt.pipeline, cq_depth=pt.cq_depth,
                dma_buffers=pt.dma_buffers)


def _lower_tcfg(pt: SweepPoint, policy: ExecutionPolicy) -> TransformConfig:
    """The lowering config for ``pt``, normalized so the per-worker memo key
    collapses axes the transform ignores (depth for queue-free policies)."""
    tcfg = _tcfg_for(pt)
    if policy not in TransformConfig.DEPTH_SENSITIVE_POLICIES:
        # depth is not transform-relevant here: normalize it out of the memo
        # key so one lowering serves the whole depth axis
        tcfg = TransformConfig(unroll=tcfg.unroll, unroll_int=tcfg.unroll_int,
                               batch=tcfg.batch, n_samples=tcfg.n_samples)
    return tcfg


def _mcfg_for(pt: SweepPoint) -> MachineConfig:
    d_i2f, d_f2i = pt.effective_depths()
    return MachineConfig(queue_depth=pt.queue_depth,
                         queue_latency=pt.queue_latency,
                         queue_depths=({Queue.I2F: d_i2f, Queue.F2I: d_f2i}
                                       if (pt.queue_depth_i2f is not None or
                                           pt.queue_depth_f2i is not None)
                                       else None))


def _check_equivalent(dfg, env: Dict, n_samples: int, ref: Dict) -> bool:
    """Outputs in ``env`` bit-identical to the interpreter oracle ``ref``?"""
    return all(
        [env.get(f"{node.name}@{i}") for i in range(n_samples)]
        == ref[node.name]
        for node in dfg.outputs())


def _ok_record(base: Dict, res, equivalent: bool) -> SweepRecord:
    """Flatten a single-PE :class:`SimResult` into an ok record."""
    s = res.summary()
    return SweepRecord(
        **base, status="ok", cycles=s["cycles"], ipc=s["ipc"],
        energy=s["energy"], power=s["power"], throughput=s["throughput"],
        efficiency=s["efficiency"], instrs_int=s["instrs_int"],
        instrs_fp=s["instrs_fp"], max_occ_i2f=s["max_occ_i2f"],
        max_occ_f2i=s["max_occ_f2i"], fifo_violations=s["fifo_violations"],
        equivalent=equivalent, ipc_per_core=s["ipc"], stalls=s["stalls"])


def run_point(pt: SweepPoint, *, use_caches: bool = True) -> SweepRecord:
    """Lower + simulate one configuration and check baseline equivalence.

    Never raises for model-level outcomes: infeasible schedules come back as
    ``status="rejected"`` and runtime deadlocks as ``status="deadlock"`` so a
    sweep always yields one record per point.  ``use_caches=False`` bypasses
    the per-worker memos (the pre-caching pipeline, kept for benchmarking).

    ``engine="batch"`` on a single point runs a width-1 batch — single-PE
    points through :class:`~.batch_machine.BatchStepper`, clustered and
    pipelined points through
    :class:`~.batch_cluster.BatchClusterStepper` (the grouped fast paths
    live in :func:`_batch_records` / :func:`_batch_cluster_records`,
    reached via :func:`run_sweep`); batch-inexpressible programs fall back
    to the per-point event stepper.
    """
    dfg = KERNELS[pt.kernel]
    policy = ExecutionPolicy.parse(pt.policy)
    detail = _geometry_detail(pt)
    if detail is not None:
        # a malformed cluster geometry must yield one rejected record, not a
        # raw traceback killing a pool worker (and an n_cores=0 point must
        # never masquerade as a cheap single-PE run in a calibration sweep)
        return SweepRecord(**_point_base(pt, policy), status="rejected",
                           detail=detail)
    base = _point_base(pt, policy)
    tcfg = _lower_tcfg(pt, policy)
    mcfg = _mcfg_for(pt)
    if pt.clustered:
        return _run_cluster_point(pt, dfg, policy, base, tcfg, mcfg,
                                  use_caches)
    try:
        if use_caches:
            prog = _lower_cached(pt.kernel, policy.value, tcfg)
        else:
            prog = lower(dfg, policy, tcfg, use_prefix_cache=False)
    except ValueError as e:
        return SweepRecord(**base, status="rejected", detail=str(e))
    if pt.engine == "batch":
        try:
            out = BatchStepper(prog, [mcfg]).run()[0]
        except BatchUnsupported:
            out = None               # inexpressible: event-stepper fallback
        if isinstance(out, BatchDeadlock):
            return SweepRecord(**base, status="deadlock", detail=out.message)
        res = out
    else:
        res = None
    if res is None:
        try:
            sim_engine = "event" if pt.engine == "batch" else pt.engine
            res = stepper_for(prog, mcfg, sim_engine).run()
        except DeadlockError as e:
            return SweepRecord(**base, status="deadlock", detail=str(e))
    ref = (_reference_cached(pt.kernel, pt.n_samples) if use_caches
           else dfg.eval_reference(pt.n_samples))
    equivalent = _check_equivalent(dfg, res.env, pt.n_samples, ref)
    return _ok_record(base, res, equivalent)


def _pipeline_policy_detail(pt: SweepPoint,
                            policy: ExecutionPolicy) -> Optional[str]:
    """A rejection message for pipelined points on the wrong policy."""
    if pt.pipeline and policy is not ExecutionPolicy.COPIFTV2:
        return (f"pipeline partitioning is COPIFTv2-only "
                f"(got policy {policy.value!r})")
    return None


def _cluster_progs(pt: SweepPoint, dfg, policy: ExecutionPolicy,
                   tcfg: TransformConfig, use_caches: bool) -> Tuple:
    """The per-core program set for a clustered point.  Raises ValueError
    for infeasible partitionings, exactly like the uncached transforms.
    The memoized variants return one tuple object per distinct
    partitioning, so ``id(progs)`` doubles as the batch grouping key."""
    if pt.pipeline:
        if use_caches:
            return _pipeline_cached(pt.kernel, tcfg, pt.n_cores,
                                    pt.dma_buffers)
        return tuple(partition_pipeline(dfg, tcfg, pt.n_cores,
                                        dma_buffers=pt.dma_buffers,
                                        use_prefix_cache=False))
    if use_caches:
        return _partition_cached(pt.kernel, policy.value, tcfg, pt.n_cores)
    return tuple(partition_kernel(dfg, policy, tcfg, pt.n_cores,
                                  use_prefix_cache=False))


def _ccfg_for(pt: SweepPoint, mcfg: MachineConfig) -> ClusterConfig:
    return ClusterConfig(n_cores=pt.n_cores, tcdm_banks=pt.tcdm_banks,
                         machine=mcfg, cq_depth=pt.cq_depth,
                         dma_buffers=pt.dma_buffers)


def _cluster_ok_record(pt: SweepPoint, base: Dict, dfg, res, ref,
                       equiv_memo: Optional[Dict] = None) -> SweepRecord:
    """Flatten a :class:`~.cluster.ClusterResult` into an ok record, checking
    the *concatenated* per-core outputs against the sequential interpreter.
    Work-partitioned points assign disjoint sample ranges per core (core
    ``c`` owns ``[c*chunk, (c+1)*chunk)``); pipelined points assign them per
    producer/consumer *pair* — only the odd-indexed (consumer) cores hold
    outputs.  ``equiv_memo`` (grouped batch path) caches the check per
    distinct env tuple: lockstep points of one group share env objects."""
    if pt.pipeline:
        # outputs live on the consumer cores (odd indices), one per pair
        owners = res.core_results[1::2]
    else:
        owners = res.core_results
    chunk = pt.n_samples // len(owners)
    key = tuple(id(core.env) for core in owners)
    equivalent = equiv_memo.get(key) if equiv_memo is not None else None
    if equivalent is None:
        equivalent = all(
            [core.env.get(f"{node.name}@{i}") for i in range(chunk)]
            == ref[node.name][c * chunk:(c + 1) * chunk]
            for node in dfg.outputs()
            for c, core in enumerate(owners))
        if equiv_memo is not None:
            equiv_memo[key] = equivalent
    s = res.summary()
    return SweepRecord(
        **base, status="ok", cycles=s["cycles"], ipc=s["ipc"],
        energy=s["energy"], power=s["power"], throughput=s["throughput"],
        efficiency=s["efficiency"], instrs_int=s["instrs_int"],
        instrs_fp=s["instrs_fp"], max_occ_i2f=s["max_occ_i2f"],
        max_occ_f2i=s["max_occ_f2i"], fifo_violations=s["fifo_violations"],
        equivalent=equivalent, ipc_per_core=s["ipc_per_core"],
        bank_stalls=s["bank_stalls"], cq_stalls=s["cq_stalls"],
        dma_stalls=s["dma_stalls"], stalls=s["stalls"])


def _run_cluster_point(pt: SweepPoint, dfg, policy: ExecutionPolicy,
                       base: Dict, tcfg: TransformConfig,
                       mcfg: MachineConfig,
                       use_caches: bool) -> SweepRecord:
    """The cluster leg of :func:`run_point`: partition the kernel across
    ``pt.n_cores`` and run the per-core programs under the shared bank
    arbiter.  ``engine="batch"`` runs a width-1
    :class:`~.batch_cluster.BatchClusterStepper` (the grouped fast path
    lives in :func:`_batch_cluster_records`); inexpressible program sets
    fall back to the per-point event engine."""
    detail = _pipeline_policy_detail(pt, policy)
    if detail is not None:
        return SweepRecord(**base, status="rejected", detail=detail)
    try:
        progs = _cluster_progs(pt, dfg, policy, tcfg, use_caches)
    except ValueError as e:
        return SweepRecord(**base, status="rejected", detail=str(e))
    ccfg = _ccfg_for(pt, mcfg)
    res = None
    if pt.engine == "batch":
        try:
            out = BatchClusterStepper(progs, [ccfg]).run()[0]
        except BatchClusterUnsupported:
            out = None               # inexpressible: event-stepper fallback
        if isinstance(out, BatchClusterDeadlock):
            return SweepRecord(**base, status="deadlock", detail=out.message)
        res = out
    if res is None:
        try:
            sim_engine = "event" if pt.engine == "batch" else pt.engine
            res = ClusterStepper(progs, ccfg, engine=sim_engine).run()
        except DeadlockError as e:
            return SweepRecord(**base, status="deadlock", detail=str(e))
    ref = (_reference_cached(pt.kernel, pt.n_samples) if use_caches
           else dfg.eval_reference(pt.n_samples))
    return _cluster_ok_record(pt, base, dfg, res, ref)


def partition_points(points: Sequence[SweepPoint],
                     workers: int) -> List[List[int]]:
    """Presized, cache-friendly partition of ``points`` for a worker pool.

    Returns at most ``workers`` lists of *input indices*.  Points sharing a
    lowering key stay on one worker and adjacent keys stay adjacent (the
    partition walks key groups in sorted order, cutting only at group
    boundaries once a worker reaches its presized target), so each worker's
    lowering/reference memos see runs of hits instead of a random shuffle.
    """
    if workers <= 0:
        raise ValueError("workers must be positive")
    groups: Dict[Tuple, List[int]] = {}
    for i, pt in enumerate(points):
        groups.setdefault(_lower_key(pt), []).append(i)
    target = -(-len(points) // workers)          # ceil division
    parts: List[List[int]] = [[]]

    def sortable(kv):                # lowering keys mix None with ints
        return tuple((v is None, 0 if v is None else v) for v in kv[0])

    for _key, idxs in sorted(groups.items(), key=sortable):
        if len(parts[-1]) >= target and len(parts) < workers:
            parts.append([])
        parts[-1].extend(idxs)
    return parts


def _batch_eligible(pt: SweepPoint) -> bool:
    """Points the grouped batch paths handle: batch-engine with well-formed
    geometry — single-PE points go through :func:`_batch_records`, clustered
    and pipelined ones through :func:`_batch_cluster_records` (everything
    else goes through :func:`run_point`)."""
    return pt.engine == "batch" and _geometry_detail(pt) is None


def _batch_records(pairs: List[Tuple[int, SweepPoint]]
                   ) -> List[Tuple[int, SweepRecord]]:
    """The grouped fast path for batch-eligible points.

    Lowers every point through the per-worker memo, groups by *lowered
    program identity* — ``id(prog)`` merges the whole machine axis of a
    depth-insensitive policy (and depth-saturated COPIFTv2 classes that
    reuse a Program) into one group — and advances each group through a
    single :class:`~.batch_machine.BatchStepper` pass.  Per group, the
    equivalence oracle is checked once per distinct result env (lockstep
    points share one env object; only scalar-delegated outliers re-check).
    Groups the batch engine cannot express fall back to per-point event
    simulation via :func:`run_point`; deadlocked points become
    ``status="deadlock"`` records exactly like the scalar path."""
    out: List[Tuple[int, SweepRecord]] = []
    groups: Dict[int, List[Tuple[int, SweepPoint, MachineConfig]]] = {}
    progs: Dict[int, object] = {}
    for i, pt in pairs:
        policy = ExecutionPolicy.parse(pt.policy)
        try:
            prog = _lower_cached(pt.kernel, policy.value,
                                 _lower_tcfg(pt, policy))
        except ValueError as e:
            out.append((i, SweepRecord(**_point_base(pt, policy),
                                       status="rejected", detail=str(e))))
            continue
        gid = id(prog)
        progs[gid] = prog
        groups.setdefault(gid, []).append((i, pt, _mcfg_for(pt)))
    for gid, items in groups.items():
        prog = progs[gid]
        try:
            results = BatchStepper(prog, [m for _, _, m in items]).run()
        except BatchUnsupported:
            out.extend((i, run_point(pt)) for i, pt, _ in items)
            continue
        equiv_by_env: Dict[int, bool] = {}
        for (i, pt, _mcfg), res in zip(items, results):
            policy = ExecutionPolicy.parse(pt.policy)
            base = _point_base(pt, policy)
            if isinstance(res, BatchDeadlock):
                out.append((i, SweepRecord(**base, status="deadlock",
                                           detail=res.message)))
                continue
            eq = equiv_by_env.get(id(res.env))
            if eq is None:
                eq = _check_equivalent(
                    KERNELS[pt.kernel], res.env, pt.n_samples,
                    _reference_cached(pt.kernel, pt.n_samples))
                equiv_by_env[id(res.env)] = eq
            out.append((i, _ok_record(base, res, eq)))
    return out


def _batch_cluster_records(pairs: List[Tuple[int, SweepPoint]]
                           ) -> List[Tuple[int, SweepRecord]]:
    """The grouped fast path for batch-eligible *clustered* points.

    Partitions every point through the per-worker memos, groups by
    *partitioned-program-set identity* — the memoized transforms return one
    tuple per distinct partitioning, so ``id(progs)`` merges the whole
    ``tcdm_banks x cq_depth x machine`` axis of one partitioning (bank
    count, channel depth and per-core MachineConfig are runtime properties)
    into one group — and advances each group through a single
    :class:`~.batch_cluster.BatchClusterStepper` pass.  The equivalence
    oracle runs once per distinct env tuple (lockstep points share the
    per-core env objects; only scalar-delegated outliers re-check).
    Program sets the batch engine cannot express fall back to per-point
    event simulation via :func:`run_point`; deadlocked points become
    ``status="deadlock"`` records carrying the scalar engine's message."""
    out: List[Tuple[int, SweepRecord]] = []
    groups: Dict[int, List[Tuple[int, SweepPoint, ClusterConfig]]] = {}
    progsets: Dict[int, Tuple] = {}
    for i, pt in pairs:
        policy = ExecutionPolicy.parse(pt.policy)
        base = _point_base(pt, policy)
        detail = _pipeline_policy_detail(pt, policy)
        if detail is not None:
            out.append((i, SweepRecord(**base, status="rejected",
                                       detail=detail)))
            continue
        try:
            progs = _cluster_progs(pt, KERNELS[pt.kernel], policy,
                                   _lower_tcfg(pt, policy), use_caches=True)
        except ValueError as e:
            out.append((i, SweepRecord(**base, status="rejected",
                                       detail=str(e))))
            continue
        gid = id(progs)
        progsets[gid] = progs
        groups.setdefault(gid, []).append((i, pt, _ccfg_for(pt,
                                                            _mcfg_for(pt))))
    for gid, items in groups.items():
        progs = progsets[gid]
        try:
            results = BatchClusterStepper(
                progs, [c for _, _, c in items]).run()
        except BatchClusterUnsupported:
            out.extend((i, run_point(pt)) for i, pt, _ in items)
            continue
        equiv_memo: Dict[Tuple[int, ...], bool] = {}
        for (i, pt, _ccfg), res in zip(items, results):
            policy = ExecutionPolicy.parse(pt.policy)
            base = _point_base(pt, policy)
            if isinstance(res, BatchClusterDeadlock):
                out.append((i, SweepRecord(**base, status="deadlock",
                                           detail=res.message)))
                continue
            dfg = KERNELS[pt.kernel]
            ref = _reference_cached(pt.kernel, pt.n_samples)
            out.append((i, _cluster_ok_record(pt, base, dfg, res, ref,
                                              equiv_memo)))
    return out


def _run_indexed(pairs: List[Tuple[int, SweepPoint]]
                 ) -> List[Tuple[int, SweepRecord]]:
    """Pool-worker entry: run a batch in partition order, tagging each record
    with its input index so the caller can restore input order.  Batch-
    eligible points peel off into the grouped fast paths (single-PE and
    cluster); the rest run one at a time."""
    batched = [(i, pt) for i, pt in pairs
               if _batch_eligible(pt) and not pt.clustered]
    clustered = [(i, pt) for i, pt in pairs
                 if _batch_eligible(pt) and pt.clustered]
    rest = [(i, pt) for i, pt in pairs if not _batch_eligible(pt)]
    out = [(i, run_point(pt)) for i, pt in rest]
    if batched:
        out.extend(_batch_records(batched))
    if clustered:
        out.extend(_batch_cluster_records(clustered))
    return out


def resolve_workers(n_points: int, workers: Optional[int] = None) -> int:
    """Pool width: explicit ``workers`` wins, then the ``REPRO_SWEEP_WORKERS``
    environment override (CI pins it to 1), then ``min(cpu, n_points)`` —
    small sweeps no longer degrade to serial on many-core hosts."""
    if workers is None:
        env = os.environ.get("REPRO_SWEEP_WORKERS", "").strip()
        if env:
            workers = int(env)
    if workers is None:
        workers = min(os.cpu_count() or 1, n_points)
    return max(1, workers)


def run_sweep(points: Sequence[SweepPoint],
              workers: Optional[int] = None,
              strategy: str = "exhaustive",
              **search_kw) -> List[SweepRecord]:
    """Run a sweep, returning records in input order.  ``workers=None``
    auto-sizes a process pool (see :func:`resolve_workers`); ``workers<=1``
    forces in-process execution.  Pool startup failures (restricted
    sandboxes) degrade to serial.  Points are fanned out with
    :func:`partition_points` — one partition per worker, so batch grouping
    happens inside each worker and never double-partitions.

    ``strategy`` selects the search discipline (:data:`STRATEGIES`):
    ``"exhaustive"`` evaluates every point; ``"adaptive"`` dispatches to
    ``core.search.adaptive_sweep`` (front-guided successive halving) and
    returns *only* the full-fidelity survivor records — extra keyword
    arguments (``tolerance``, ``fidelity_ladder``, ...) pass through."""
    if strategy not in STRATEGIES:
        raise ValueError(
            f"unknown strategy {strategy!r} (have {STRATEGIES})")
    if strategy == "adaptive":
        from .search import adaptive_sweep   # local: search imports sweep
        records, _meta = adaptive_sweep(points, workers=workers, **search_kw)
        return records
    if search_kw:
        raise TypeError(
            f"unexpected arguments for exhaustive sweep: {sorted(search_kw)}")
    points = list(points)
    workers = resolve_workers(len(points), workers)
    if workers > 1 and len(points) > 1:
        try:
            from concurrent.futures import ProcessPoolExecutor
            from concurrent.futures.process import BrokenProcessPool
            parts = [p for p in partition_points(points, workers) if p]
            out: List[Optional[SweepRecord]] = [None] * len(points)
            with ProcessPoolExecutor(max_workers=len(parts)) as pool:
                futs = [pool.submit(_run_indexed,
                                    [(i, points[i]) for i in part])
                        for part in parts]
                for fut in futs:
                    for i, rec in fut.result():
                        out[i] = rec
                return list(out)     # type: ignore[arg-type]
        except (ImportError, OSError, PermissionError, BrokenProcessPool):
            pass                     # no usable pool: run in-process below
    serial: List[Optional[SweepRecord]] = [None] * len(points)
    for i, rec in _run_indexed(list(enumerate(points))):
        serial[i] = rec
    return list(serial)              # type: ignore[arg-type]


def sweep_summary(records: Iterable[SweepRecord]) -> Dict[str, float]:
    """Aggregate a sweep into headline scalars (geomeans over ok points)."""
    recs = [r for r in records]
    ok = [r for r in recs if r.ok]
    out: Dict[str, float] = {
        "n_points": float(len(recs)),
        "n_ok": float(len(ok)),
        "n_rejected": float(sum(r.status == "rejected" for r in recs)),
        "n_equivalent": float(sum(r.equivalent for r in ok)),
        "n_fifo_violations": float(sum(r.fifo_violations for r in ok)),
    }
    if ok:
        out["peak_ipc"] = best(ok, "ipc").ipc
        out["best_efficiency"] = best(ok, "efficiency").efficiency
        for pol, rs in sorted(group_by(ok, lambda r: r.policy).items()):
            out[f"geomean_ipc_{pol}"] = geomean(r.ipc for r in rs)
            out[f"geomean_efficiency_{pol}"] = geomean(r.efficiency for r in rs)
    return out


def record_to_row(rec: SweepRecord) -> Dict[str, object]:
    """A CSV-ready dict in :data:`CSV_FIELDS` order (stalls packed)."""
    d = asdict(rec)
    d["stalls"] = ";".join(f"{k}={v}" for k, v in sorted(rec.stalls.items()))
    d["equivalent"] = int(rec.equivalent)
    d["pipeline"] = int(rec.pipeline)
    return {k: d[k] for k in CSV_FIELDS}
