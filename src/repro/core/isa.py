"""Abstract ISA for the Snitch + FPSS machine model.

The paper's platform is a Snitch cluster core [Zaruba et al., TC'21]: a
single-issue in-order integer core ("INT" unit) with a decoupled FP
coprocessor ("FP" unit, the FPSS) that supports FREP hardware loops and SSR
streaming registers.  COPIFTv2 adds two blocking FIFO queues (I2F, F2I)
between the units.

We model instructions abstractly: each OpKind carries the executing unit, a
result latency (cycles until the destination value is usable), an energy
weight (relative units — we only ever report *ratios*, see DESIGN.md §3.1),
and whether it blocks its unit (non-pipelined, e.g. fdiv/fsqrt).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Tuple


class Unit(enum.Enum):
    INT = "int"
    FP = "fp"


@dataclass(frozen=True)
class OpSpec:
    unit: Unit
    latency: int
    energy: float
    blocking: bool = False


class OpKind(enum.Enum):
    # Integer core
    IALU = "ialu"          # add/sub/shift/and/or/lui...
    IMUL = "imul"
    LW = "lw"              # integer load (TCDM hit)
    SW = "sw"              # integer store
    MV = "mv"              # register move; also queue push/pop shim
    BR = "br"              # branch / loop bookkeeping
    SYNC = "sync"          # COPIFT batch-semaphore bookkeeping (flag store)
    # FPSS
    FLD = "fld"
    FSD = "fsd"
    FSD_SSR = "fsd_ssr"    # store through an SSR stream (COPIFT F2I spill)
    FADD = "fadd"
    FMUL = "fmul"
    FMA = "fma"
    FDIV = "fdiv"
    FSQRT = "fsqrt"
    CVT_I2F = "cvt_i2f"    # fcvt.d.w / fmv.d.x : int operand -> FP result
    CVT_F2I = "cvt_f2i"    # fcvt.w.d / fmv.x.d : FP operand -> int result
    FMV_PUSH = "fmv_push"  # fmv.x.d used purely to push an FP value to F2I


#: Latency / energy table, loosely calibrated to Snitch (GF12, 1 GHz).
#: Energies are relative units; see DESIGN.md §3.1 for the calibration stance.
OP_TABLE: dict[OpKind, OpSpec] = {
    OpKind.IALU:     OpSpec(Unit.INT, 1, 1.0),
    OpKind.IMUL:     OpSpec(Unit.INT, 3, 1.8),
    OpKind.LW:       OpSpec(Unit.INT, 3, 4.5),
    OpKind.SW:       OpSpec(Unit.INT, 1, 4.0),
    OpKind.MV:       OpSpec(Unit.INT, 1, 0.8),
    OpKind.BR:       OpSpec(Unit.INT, 1, 0.9),
    OpKind.SYNC:     OpSpec(Unit.INT, 1, 1.1),
    OpKind.FLD:      OpSpec(Unit.FP, 3, 5.0),
    OpKind.FSD:      OpSpec(Unit.FP, 1, 4.5),
    OpKind.FSD_SSR:  OpSpec(Unit.FP, 1, 4.2),
    OpKind.FADD:     OpSpec(Unit.FP, 3, 2.2),
    OpKind.FMUL:     OpSpec(Unit.FP, 3, 2.4),
    OpKind.FMA:      OpSpec(Unit.FP, 4, 3.4),
    OpKind.FDIV:     OpSpec(Unit.FP, 11, 7.0, blocking=True),
    OpKind.FSQRT:    OpSpec(Unit.FP, 13, 7.5, blocking=True),
    OpKind.CVT_I2F:  OpSpec(Unit.FP, 2, 1.6),
    OpKind.CVT_F2I:  OpSpec(Unit.FP, 2, 1.6),
    OpKind.FMV_PUSH: OpSpec(Unit.FP, 1, 0.9),
}

#: Kinds executed on the FPSS whose *destination* is integer-homed.
INT_DST_FP_KINDS = frozenset({OpKind.CVT_F2I, OpKind.FMV_PUSH})
#: Kinds executed on the FPSS.
FP_KINDS = frozenset(k for k, s in OP_TABLE.items() if s.unit is Unit.FP)

# --- Energy model knobs (relative units) -----------------------------------
#: extra energy for a queue push or pop (lightweight FIFO access)
E_QUEUE_ACCESS = 0.4
#: extra energy when a value arrives through an SSR memory stream (COPIFT
#: spill readback): an SRAM read the hardware performs on the FPSS's behalf.
E_SSR_STREAM = 3.8
#: fetch/decode overhead for an instruction issued by the integer core
E_FETCH_INT = 0.6
#: re-issue overhead for an instruction replayed from the FREP loop buffer
E_FETCH_FREP = 0.2
#: background (clock tree, icache, idle datapath, leakage) energy per cycle
#: for the core pair.  Dominant for a tiny in-order core at 1 GHz; calibrated
#: so the published COPIFT/COPIFTv2 energy-efficiency ratios are reproduced
#: (DESIGN.md §3.1 — we report energy *ratios* only).
E_STATIC_PER_CYCLE = 22.0


class Queue(enum.Enum):
    I2F = "i2f"
    F2I = "f2i"


@dataclass(frozen=True)
class Instr:
    """One concrete instruction instance in a lowered stream program.

    ``srcs`` holds operands *in semantic order*: each element is either an
    SSA value name ("t@3" = value t of sample 3) or a :class:`Queue`, which
    means "pop the head of that queue as this operand" (the x31 / integer-rs
    semantics of the EnCopiftQueues CSR).  ``pushes`` enqueues the computed
    result; ``push_val`` records the semantic value name pushed, used to
    verify FIFO order correctness.  ``fn`` (optional) gives concrete
    semantics so the simulator doubles as a functional interpreter for
    transform-correctness checks.
    """
    uid: int
    kind: OpKind
    label: str
    srcs: Tuple[object, ...] = ()
    dst: Optional[str] = None
    pushes: Tuple[Queue, ...] = ()
    push_val: Optional[str] = None
    expects: Tuple[str, ...] = ()         # value names expected by pops, in order
    sample: int = -1                      # -1 => overhead instruction
    fn: Optional[Callable[..., Any]] = None
    extra_energy: float = 0.0             # e.g. SSR stream read on behalf

    @property
    def spec(self) -> OpSpec:
        return OP_TABLE[self.kind]

    @property
    def unit(self) -> Unit:
        return self.spec.unit

    @property
    def pops(self) -> Tuple[Queue, ...]:
        return tuple(s for s in self.srcs if isinstance(s, Queue))

    @property
    def reg_srcs(self) -> Tuple[str, ...]:
        return tuple(s for s in self.srcs if isinstance(s, str))

    def energy(self, *, frep: bool) -> float:
        e = self.spec.energy + self.extra_energy
        e += E_QUEUE_ACCESS * (len(self.pops) + len(self.pushes))
        if self.unit is Unit.INT or not frep:
            e += E_FETCH_INT
        else:
            e += E_FETCH_FREP
        return e
