"""Abstract ISA for the Snitch + FPSS machine model.

The paper's platform is a Snitch cluster core [Zaruba et al., TC'21]: a
single-issue in-order integer core ("INT" unit) with a decoupled FP
coprocessor ("FP" unit, the FPSS) that supports FREP hardware loops and SSR
streaming registers.  COPIFTv2 adds two blocking FIFO queues (I2F, F2I)
between the units.

We model instructions abstractly: each OpKind carries the executing unit, a
result latency (cycles until the destination value is usable), an energy
weight (relative units — we only ever report *ratios*, see DESIGN.md §3.1),
and whether it blocks its unit (non-pipelined, e.g. fdiv/fsqrt).
"""
from __future__ import annotations

import enum
import functools
from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple


class Unit(enum.Enum):
    INT = "int"
    FP = "fp"


@dataclass(frozen=True)
class OpSpec:
    unit: Unit
    latency: int
    energy: float
    blocking: bool = False


class OpKind(enum.Enum):
    # Integer core
    IALU = "ialu"          # add/sub/shift/and/or/lui...
    IMUL = "imul"
    LW = "lw"              # integer load (TCDM hit)
    SW = "sw"              # integer store
    MV = "mv"              # register move; also queue push/pop shim
    BR = "br"              # branch / loop bookkeeping
    SYNC = "sync"          # COPIFT batch-semaphore bookkeeping (flag store)
    # FPSS
    FLD = "fld"
    FSD = "fsd"
    FSD_SSR = "fsd_ssr"    # store through an SSR stream (COPIFT F2I spill)
    FADD = "fadd"
    FMUL = "fmul"
    FMA = "fma"
    FDIV = "fdiv"
    FSQRT = "fsqrt"
    CVT_I2F = "cvt_i2f"    # fcvt.d.w / fmv.d.x : int operand -> FP result
    CVT_F2I = "cvt_f2i"    # fcvt.w.d / fmv.x.d : FP operand -> int result
    FMV_PUSH = "fmv_push"  # fmv.x.d used purely to push an FP value to F2I
    # Cluster-level communication (``core.cluster``): inter-core queue ops
    # and DMA transfer descriptors, all issued by the integer core.  Outside
    # a cluster these degrade to plain register moves — the cluster core
    # steppers attach the channel / engine semantics (see ``Instr.cq`` /
    # ``Instr.dma_words``).
    CQ_PUSH = "cq_push"    # push a register value into an inter-core channel
    CQ_POP = "cq_pop"      # pop an inter-core channel head into a register
    DMA_START = "dma_start"  # program a bulk TCDM transfer descriptor
    DMA_WAIT = "dma_wait"    # retire the oldest in-flight DMA transfer


#: Latency / energy table, loosely calibrated to Snitch (GF12, 1 GHz).
#: Energies are relative units; see DESIGN.md §3.1 for the calibration stance.
OP_TABLE: dict[OpKind, OpSpec] = {
    OpKind.IALU:     OpSpec(Unit.INT, 1, 1.0),
    OpKind.IMUL:     OpSpec(Unit.INT, 3, 1.8),
    OpKind.LW:       OpSpec(Unit.INT, 3, 4.5),
    OpKind.SW:       OpSpec(Unit.INT, 1, 4.0),
    OpKind.MV:       OpSpec(Unit.INT, 1, 0.8),
    OpKind.BR:       OpSpec(Unit.INT, 1, 0.9),
    OpKind.SYNC:     OpSpec(Unit.INT, 1, 1.1),
    OpKind.FLD:      OpSpec(Unit.FP, 3, 5.0),
    OpKind.FSD:      OpSpec(Unit.FP, 1, 4.5),
    OpKind.FSD_SSR:  OpSpec(Unit.FP, 1, 4.2),
    OpKind.FADD:     OpSpec(Unit.FP, 3, 2.2),
    OpKind.FMUL:     OpSpec(Unit.FP, 3, 2.4),
    OpKind.FMA:      OpSpec(Unit.FP, 4, 3.4),
    OpKind.FDIV:     OpSpec(Unit.FP, 11, 7.0, blocking=True),
    OpKind.FSQRT:    OpSpec(Unit.FP, 13, 7.5, blocking=True),
    OpKind.CVT_I2F:  OpSpec(Unit.FP, 2, 1.6),
    OpKind.CVT_F2I:  OpSpec(Unit.FP, 2, 1.6),
    OpKind.FMV_PUSH: OpSpec(Unit.FP, 1, 0.9),
    OpKind.CQ_PUSH:  OpSpec(Unit.INT, 1, 1.2),
    OpKind.CQ_POP:   OpSpec(Unit.INT, 1, 1.2),
    OpKind.DMA_START: OpSpec(Unit.INT, 1, 1.5),
    OpKind.DMA_WAIT: OpSpec(Unit.INT, 1, 0.8),
}

#: Kinds executed on the FPSS whose *destination* is integer-homed.
INT_DST_FP_KINDS = frozenset({OpKind.CVT_F2I, OpKind.FMV_PUSH})
#: Kinds executed on the FPSS.
FP_KINDS = frozenset(k for k, s in OP_TABLE.items() if s.unit is Unit.FP)
#: Kinds that touch the TCDM (loads/stores, SSR-backed stores) — the accesses
#: a shared-memory cluster arbitrates over banks (``core.cluster``).
MEM_KINDS = frozenset({OpKind.LW, OpKind.SW, OpKind.FLD, OpKind.FSD,
                       OpKind.FSD_SSR})
#: Inter-core channel accesses: the bounded FIFOs live in TCDM, so pushes
#: and pops also occupy a bank and cross the cluster interconnect.
CQ_KINDS = frozenset({OpKind.CQ_PUSH, OpKind.CQ_POP})
#: DMA descriptor management ops (per-core engine, ``core.cluster``).
DMA_KINDS = frozenset({OpKind.DMA_START, OpKind.DMA_WAIT})

# --- Energy model knobs (relative units) -----------------------------------
#: extra energy for a queue push or pop (lightweight FIFO access)
E_QUEUE_ACCESS = 0.4
#: extra energy when a value arrives through an SSR memory stream (COPIFT
#: spill readback): an SRAM read the hardware performs on the FPSS's behalf.
E_SSR_STREAM = 3.8
#: fetch/decode overhead for an instruction issued by the integer core
E_FETCH_INT = 0.6
#: re-issue overhead for an instruction replayed from the FREP loop buffer
E_FETCH_FREP = 0.2
#: background (clock tree, icache, idle datapath, leakage) energy per cycle
#: for the core pair.  Dominant for a tiny in-order core at 1 GHz; calibrated
#: so the published COPIFT/COPIFTv2 energy-efficiency ratios are reproduced
#: (DESIGN.md §3.1 — we report energy *ratios* only).
E_STATIC_PER_CYCLE = 22.0
#: energy per TCDM access crossing the cluster's shared interconnect (the
#: log-depth crossbar between N cores and the banked TCDM).  Charged only in
#: multi-core clusters (``core.cluster``): a single PE owns its scratchpad
#: port, so the ``n_cores=1`` machine stays bit-identical to ``machine``.
E_TCDM_INTERCONNECT = 0.9
#: extra energy for an inter-core channel push or pop on top of the TCDM
#: access itself (head/tail pointer maintenance in the producer/consumer
#: cores — the channels are plain TCDM ring buffers, ``core.cluster``)
E_CQ_ACCESS = 0.5
#: energy per word moved by the cluster DMA engine (SRAM read + interconnect
#: traversal + SRAM write, no core fetch/decode on either side).  A
#: DMA-staged word is then re-read locally without interconnect energy
#: (``Instr.local``), trading one extra copy for conflict-free access.
E_DMA_WORD = 2.0


class Queue(enum.Enum):
    I2F = "i2f"
    F2I = "f2i"


#: pre-interned per-unit stall-counter keys (``"<unit>_<cause>"``), so the
#: simulator hot path never string-formats; causes mirror
#: ``machine.STALL_CAUSES`` plus the unit-busy check.  ``bank`` /
#: ``cq_empty`` / ``cq_full`` / ``dma`` are the cluster-only causes (TCDM
#: bank busy, inter-core channel empty/full, DMA engine busy —
#: ``core.cluster``).
_STALL_KEYS = {
    u.value: {c: f"{u.value}_{c}"
              for c in ("busy", "dep", "queue_empty", "queue_full", "bank",
                        "cq_empty", "cq_full", "dma")}
    for u in Unit
}

#: per-unit stall key for a TCDM bank conflict (``core.cluster``)
BANK_STALL_KEYS = {u: _STALL_KEYS[u.value]["bank"] for u in Unit}
#: per-unit stall keys for the cluster communication causes (``core.cluster``)
CQ_EMPTY_STALL_KEYS = {u: _STALL_KEYS[u.value]["cq_empty"] for u in Unit}
CQ_FULL_STALL_KEYS = {u: _STALL_KEYS[u.value]["cq_full"] for u in Unit}
DMA_STALL_KEYS = {u: _STALL_KEYS[u.value]["dma"] for u in Unit}

#: dense indices for the hot-path list layouts (enum-keyed dicts hash the
#: member on every access; a list index does not)
UNIT_INDEX = {u: i for i, u in enumerate(Unit)}
QUEUE_INDEX = {q: i for i, q in enumerate(Queue)}

#: (busy, dep, queue_empty, queue_full) stall keys per unit, pre-unpacked
#: for the exec_facts builder
_HOT_KEYS = {
    u: (_STALL_KEYS[u.value]["busy"], _STALL_KEYS[u.value]["dep"],
        _STALL_KEYS[u.value]["queue_empty"],
        _STALL_KEYS[u.value]["queue_full"])
    for u in Unit
}


@dataclass(frozen=True)
class Instr:
    """One concrete instruction instance in a lowered stream program.

    ``srcs`` holds operands *in semantic order*: each element is either an
    SSA value name ("t@3" = value t of sample 3) or a :class:`Queue`, which
    means "pop the head of that queue as this operand" (the x31 / integer-rs
    semantics of the EnCopiftQueues CSR).  ``pushes`` enqueues the computed
    result; ``push_val`` records the semantic value name pushed, used to
    verify FIFO order correctness.  ``fn`` (optional) gives concrete
    semantics so the simulator doubles as a functional interpreter for
    transform-correctness checks.
    """
    uid: int
    kind: OpKind
    label: str
    srcs: Tuple[object, ...] = ()
    dst: Optional[str] = None
    pushes: Tuple[Queue, ...] = ()
    push_val: Optional[str] = None
    expects: Tuple[str, ...] = ()         # value names expected by pops, in order
    sample: int = -1                      # -1 => overhead instruction
    fn: Optional[Callable[..., Any]] = None
    extra_energy: float = 0.0             # e.g. SSR stream read on behalf
    #: inter-core channel index for CQ_PUSH / CQ_POP.  The channel gate,
    #: value transport and energy live entirely in the cluster core steppers
    #: (``core.cluster``); the single-core engines treat these ops as plain
    #: register moves, so ``None`` (every non-cluster program) changes
    #: nothing.
    cq: Optional[int] = None
    #: words moved by a DMA_START transfer (0 for every other kind)
    dma_words: int = 0
    #: TCDM access served from a DMA-staged local buffer: exempt from bank
    #: arbitration and interconnect energy in a cluster (the DMA already
    #: paid the interconnect crossing per word, ``E_DMA_WORD``)
    local: bool = False

    # cached: Instr is immutable and these are hammered by both the list
    # schedulers (transform._interleave) and the simulator issue loop
    @functools.cached_property
    def spec(self) -> OpSpec:
        return OP_TABLE[self.kind]

    @functools.cached_property
    def unit(self) -> Unit:
        return self.spec.unit

    @functools.cached_property
    def pops(self) -> Tuple[Queue, ...]:
        return tuple(s for s in self.srcs if isinstance(s, Queue))

    @functools.cached_property
    def reg_srcs(self) -> Tuple[str, ...]:
        return tuple(s for s in self.srcs if isinstance(s, str))

    @functools.cached_property
    def issue_plan(self) -> Tuple[Tuple[str, object, int], ...]:
        """Issue conditions in machine-check order, pre-resolved once.

        Each entry is ``(check, operand, k)``:

        * ``("queue_empty", queue, k)`` — this operand pops the ``k``-th
          pending entry of ``queue`` (k counts this instruction's earlier pops
          of the same queue); blocked until that entry is *visible*, i.e. its
          queue timestamp (producer completion + queue latency) has passed.
        * ``("dep", name, 0)`` — register operand; blocked until the
          producer's result latency has elapsed (``ready[name]``).
        * ``("queue_full", queue, k)`` — this push needs the queue's
          occupancy (in-flight included) to be at most ``depth - k - 1``;
          cleared only by a consumer pop, never by time alone.

        The event-driven stepper turns each entry into a clear-time and
        time-skips to the earliest cycle every condition holds; the order here
        matches ``ReferenceStepper._block_reason`` so bulk stall attribution
        is bit-identical to per-cycle attribution.  (The unit-busy check is
        state-only and is prepended by the stepper.)
        """
        plan = []
        need: dict = {}
        for src in self.srcs:
            if isinstance(src, Queue):
                k = need.get(src, 0)
                plan.append(("queue_empty", src, k))
                need[src] = k + 1
            else:
                plan.append(("dep", src, 0))
        room: dict = {}
        for q in self.pushes:
            k = room.get(q, 0)
            plan.append(("queue_full", q, k))
            room[q] = k + 1
        return tuple(plan)

    @functools.cached_property
    def exec_facts(self) -> Tuple:
        """Hot-path companion of :attr:`issue_plan`: every instruction-static
        fact the simulator needs at issue time, resolved once per ``Instr``
        and cached on the instance — so memoized programs re-simulated across
        machine configs (``core.sweep``) never re-derive latencies, energies
        or stall-counter keys.  Layout::

            (unit, unit_value, latency, blocking,
             energy_no_frep, energy_frep, busy_stall_key,
             dst, fn, expects, label, pushed_value_name,
             ops,    # per source operand, in semantic order:
                     #   (is_queue, operand, k, stall_key, queue_value_str,
                     #    queue_index)           (queue_index -1 for registers)
             pushes, # per push: (queue, k, stall_key, queue_index)
             unit_index)

        ``ops``/``pushes`` are split out of :attr:`issue_plan` (same order,
        same ``k`` bookkeeping), with the stall keys pre-formatted and
        :data:`QUEUE_INDEX`/:data:`UNIT_INDEX` positions resolved for the
        event engine's list-indexed hot state.
        """
        spec = OP_TABLE[self.kind]
        unit = spec.unit
        busy_key, dep_key, qe_key, qf_key = _HOT_KEYS[unit]
        qindex = QUEUE_INDEX
        ops = []
        n_pop = 0
        need: dict = {}
        for src in self.srcs:                   # same walk as issue_plan
            if type(src) is Queue:
                k = need.get(src, 0)
                need[src] = k + 1
                n_pop += 1
                ops.append((True, src, k, qe_key, src.value, qindex[src]))
            else:
                ops.append((False, src, 0, dep_key, None, -1))
        pushes = []
        room: dict = {}
        for q in self.pushes:
            k = room.get(q, 0)
            room[q] = k + 1
            pushes.append((q, k, qf_key, qindex[q]))
        e = spec.energy + self.extra_energy
        e += E_QUEUE_ACCESS * (len(pushes) + n_pop)
        return (unit, unit.value, spec.latency, spec.blocking,
                e + E_FETCH_INT,
                e + (E_FETCH_INT if unit is Unit.INT else E_FETCH_FREP),
                busy_key, self.dst, self.fn, self.expects, self.label,
                self.push_val or self.label, tuple(ops), tuple(pushes),
                UNIT_INDEX[unit])

    def energy(self, *, frep: bool) -> float:
        e = self.spec.energy + self.extra_energy
        e += E_QUEUE_ACCESS * (len(self.pops) + len(self.pushes))
        if self.unit is Unit.INT or not frep:
            e += E_FETCH_INT
        else:
            e += E_FETCH_FREP
        return e
