"""Batched cluster simulation: B cluster configs of one partitioned set at once.

``core.batch_machine`` vectorized the single-PE sweep; clustered and
pipelined points (``n_cores > 1``, finite TCDM banks, inter-core channels,
DMA staging) still fell back to the scalar event :class:`~.cluster.ClusterStepper`
— the slowest path exactly where ROADMAP item 2 explodes the grid.  This
module extends the lockstep max-recurrence to the whole cluster: issue
times of *every core's* instructions become one ``(L_total, B)`` array,
cluster config (cq depth/latency, DMA buffers/setup/bandwidth, bank
penalty, interconnect energy) becomes per-point array parameters, and all
B cluster configurations of one partitioned program set advance together.

Bit-identity contract (the PR-2/PR-7 contract, extended to clusters):
:class:`BatchClusterStepper` must match ``ClusterStepper(progs, cfg).run()``
*exactly* — per-core cycles/energy/stall breakdown/FIFO sequences/env,
cluster aggregates (makespan, summed energy in the same float order,
cq push/pop/violation counts), and the cross-core deadlock message —
for every point.  ``tests/test_batch_cluster.py`` fuzzes this
differentially and CI gates it.

What makes the cluster recurrence static
----------------------------------------
The single-PE restrictions (SSA registers, one pusher/popper stream per
intra-core queue) apply per core; three cluster-specific restrictions make
the fabric edges static too (violations raise
:class:`BatchClusterUnsupported` and the caller falls back to the scalar
engine — an optimization boundary, never a semantics fork):

* each inter-core channel has exactly one pushing (core, stream) and one
  popping (core, stream) cluster-wide, so the k-th pop matches the k-th
  push and both serials are program-static;
* a ``CQ_POP``'s magic destination register is only read by the pops that
  write it (the ``transform.partition_pipeline`` idiom), so values stay
  timing-independent;
* all DMA ops of a core live on one stream with every ``DMA_WAIT`` behind
  its matching ``DMA_START``, so the in-flight deque's head is static.

Each fabric condition then clears at a statically-linked time, derived
from the scalar engines' check semantics under the min-(cycle, core)
scheduler (core index, then stream position, orders same-cycle events):

* ``cq_empty``  — pop serial ``k`` waits for push ``k``'s visibility:
  ``t[push_k] + push_latency + cq_latency``;
* ``cq_full``   — push serial ``p`` at depth ``d`` waits for pop ``p - d``
  to issue (+1 cycle when the popper's (core, stream) is ordered after
  the pusher's within a machine cycle);
* ``dma``       — ``DMA_WAIT`` w waits for START w's completion
  (``t[start] + latency + dma_setup + words * cycles_per_word``); a
  ``DMA_START`` finding all ``dma_buffers`` in flight can never unblock
  (its freeing WAIT sits behind it in program order) — a guaranteed
  deadlock, predicted per point from the static buffer demand.

Banks: the oracle, not a fixpoint
---------------------------------
Finite-bank contention is *not* a monotone recurrence (delaying one access
can make another issue earlier), so the batch path does not model bank
windows.  Instead it computes the bank-free schedule and runs a
*zero-contention oracle*: every TCDM access (mem ops by ``crc32(label) %
banks``, channel ops by ``channel % banks``, windows of
``bank_conflict_penalty`` resp. 1 cycle) is checked, per point, for
overlap with the running busy window of its bank in (time, core, stream)
order.  Conflict-free points provably execute identically with the
arbiter enabled — no access ever finds its bank busy, no stall is ever
attributed to ``bank`` — so their bank-free results are exact; points
with any conflict are delegated to the scalar engine.  Deadlock
prediction reuses the ``batch_machine`` gap criterion per core, and
predicted-deadlock points are delegated too, reproducing the scalar
``cross-core deadlock`` message verbatim.  Delegation is always sound:
the scalar result is returned as-is, so a misprediction costs speed,
never identity.
"""
from __future__ import annotations

import zlib
from collections import deque
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .batch_machine import _I8, _attribute
from .cluster import ClusterConfig, ClusterResult, ClusterStepper
from .isa import (E_CQ_ACCESS, E_DMA_WORD, E_STATIC_PER_CYCLE, MEM_KINDS,
                  OpKind, QUEUE_INDEX, Queue, Unit)
from .machine import STALL_CAUSES, DeadlockError, Program, SimResult

#: flat per-core stall layout over all cluster causes:
#: ``core * _NK + unit_index * len(_CAUSES) + cause_index``
_CAUSES: Tuple[str, ...] = tuple(STALL_CAUSES) + ("bank", "cq_empty",
                                                  "cq_full", "dma")
_KEY_STRINGS: Tuple[str, ...] = tuple(
    f"{u.value}_{c}" for u in Unit for c in _CAUSES)
_KEY_ID: Dict[str, int] = {k: i for i, k in enumerate(_KEY_STRINGS)}
_NK = len(_KEY_STRINGS)


class BatchClusterUnsupported(ValueError):
    """The partitioned program set falls outside the restrictions that make
    the cluster-wide functional pass and static fabric linkage sound; run
    the scalar :class:`~.cluster.ClusterStepper` instead."""


@dataclass
class BatchClusterDeadlock:
    """Per-point deadlock outcome carrying the scalar engine's exact
    cross-core :class:`~.machine.DeadlockError` message (the predicted
    point is re-run on the scalar cluster engine, so the channel-occupancy
    and per-core-cycle annotations are reference-identical)."""
    name: str
    policy: Any
    message: str

    def error(self) -> DeadlockError:
        return DeadlockError(self.message)


#: one entry of ``BatchClusterStepper.run()``'s output
ClusterOutcome = Union[ClusterResult, BatchClusterDeadlock]


class _ClusterTables:
    """Everything config-independent about one partitioned program set:
    the cluster-global functional pass (fabric-aware) plus the static
    linkage that turns per-point issue times into one max-recurrence.

    Global instruction ids enumerate cores in index order, each core's
    streams in scheduler order — the same (cycle, core, stream) priority
    the scalar scheduler uses, so static tiebreaks replay its interleave.

    Per-instruction records (``self.instrs``):
    ``(prev, busyprev, busykey, lat, srcs, pushes, fab)`` — ``srcs`` and
    ``pushes`` as in ``batch_machine._ProgramTables`` (queue indices are
    core-scoped: ``cqi = core * NQ + qi``), ``fab`` the static fabric
    condition: ``None``, ``(0, chan, push_serial, key)`` for CQ_PUSH
    capacity, ``(1, chan, pop_serial, key)`` for CQ_POP visibility, or
    ``(3, start_gid, start_latency, words, key)`` for DMA_WAIT completion
    (DMA_START never carries a runtime clear: a blocked START is a
    guaranteed deadlock, excluded by the per-point buffer feasibility).
    """

    def __init__(self, progs: Sequence[Program], evaluate: bool):
        n_cores = len(progs)
        self.n_cores = n_cores
        NQ = len(Queue)
        self.NQ = NQ
        qlist = list(Queue)

        orders: List[List[Tuple[Unit, List[Any]]]] = []
        for prog in progs:
            if prog.mode == "single":
                assert len(prog.streams) == 1, \
                    "single mode expects one merged stream"
                order = list(prog.streams.items())
            else:
                order = [(u, prog.streams[u])
                         for u in (Unit.INT, Unit.FP) if u in prog.streams]
            orders.append(order)

        # -- per-core single-PE restrictions (mirrors _ProgramTables) -------
        core_pushers: List[Dict[int, set]] = []
        core_poppers: List[Dict[int, set]] = []
        for prog, order in zip(progs, orders):
            written: Dict[str, int] = {k: 1 for k in prog.init_env}
            pushers: Dict[int, set] = {}
            poppers: Dict[int, set] = {}
            for s, (u, lst) in enumerate(order):
                for ins in lst:
                    f = ins.exec_facts
                    if f[2] < 1:
                        raise BatchClusterUnsupported(
                            f"{prog.name}: zero-latency instruction "
                            f"(completion-time identities need latency >= 1)")
                    if prog.mode != "single" and f[0] is not u:
                        raise BatchClusterUnsupported(
                            f"{prog.name}: {f[0].value} instruction on the "
                            f"{u.value} stream (cross-stream busy coupling "
                            f"would be timing-dependent)")
                    if f[7] is not None:
                        written[f[7]] = written.get(f[7], 0) + 1
                    for op in f[12]:
                        if op[0]:
                            poppers.setdefault(op[5], set()).add(s)
                    for push in f[13]:
                        pushers.setdefault(push[3], set()).add(s)
            multi = [d for d, c in written.items() if c > 1]
            if multi:
                raise BatchClusterUnsupported(
                    f"{prog.name}: registers written more than once "
                    f"(timing could select the value): {sorted(multi)[:4]}")
            if any(len(ss) > 1 for m in (pushers, poppers)
                   for ss in m.values()):
                raise BatchClusterUnsupported(
                    f"{prog.name}: queue pushed/popped by more than one "
                    f"stream (FIFO order would depend on timing)")
            core_pushers.append(pushers)
            core_poppers.append(poppers)

        # -- global layout ---------------------------------------------------
        offsets: List[int] = []
        core_L: List[int] = []
        off = 0
        for order in orders:
            offsets.append(off)
            lc = sum(len(lst) for _u, lst in order)
            core_L.append(lc)
            off += lc
        L = off
        self.L = L
        self.core_off = offsets
        self.core_L = core_L
        self.core_S = [max(1, len(order)) for order in orders]
        rank_of: Dict[Tuple[int, int], int] = {}
        for c, order in enumerate(orders):
            for s in range(len(order)):
                rank_of[(c, s)] = len(rank_of)
        self.n_ranks = max(1, len(rank_of))

        # -- fabric registries + cluster-specific restrictions ---------------
        # chan -> [(gid, push_latency, pushed_name, src_reg)] / pops, plus
        # the unique pusher/popper (core, stream) per channel; DMA starts
        # per core with the static buffer demand.
        chan_push: Dict[int, List[Tuple[int, int, str, Any]]] = {}
        chan_pop: Dict[int, List[Tuple[int, Any, Optional[str], str]]] = {}
        chan_pusher: Dict[int, Tuple[int, int]] = {}
        chan_popper: Dict[int, Tuple[int, int]] = {}
        magic_writer: Dict[Tuple[int, str], Tuple[int, int]] = {}
        core_starts: List[List[Tuple[int, int, int]]] = [[] for _ in progs]
        core_waits: List[int] = [0] * n_cores
        dma_stream: Dict[int, int] = {}
        dma_req = [0] * n_cores
        fabmeta: Dict[int, Tuple] = {}      # gid -> static fabric tuple

        def _one_dma_stream(c: int, s: int, prog: Program) -> None:
            if dma_stream.setdefault(c, s) != s:
                raise BatchClusterUnsupported(
                    f"{prog.name}: DMA ops on more than one stream "
                    f"(in-flight order would be timing-dependent)")

        gid = 0
        for c, (prog, order) in enumerate(zip(progs, orders)):
            for s, (u, lst) in enumerate(order):
                for ins in lst:
                    f = ins.exec_facts
                    kind = ins.kind
                    if kind is OpKind.CQ_PUSH or kind is OpKind.CQ_POP:
                        if ins.cq is None:
                            raise ValueError(
                                f"{ins.label}: {kind.value} needs a channel "
                                f"(Instr.cq)")
                        ch = ins.cq
                        if kind is OpKind.CQ_PUSH:
                            if chan_pusher.setdefault(ch, (c, s)) != (c, s):
                                raise BatchClusterUnsupported(
                                    f"{prog.name}: channel {ch} pushed by "
                                    f"more than one (core, stream)")
                            p = len(chan_push.setdefault(ch, []))
                            src = ins.srcs[0] if ins.srcs else None
                            chan_push[ch].append(
                                (gid, int(f[2]), ins.push_val or ins.label,
                                 src))
                            fabmeta[gid] = (
                                0, ch, p,
                                c * _NK + _KEY_ID[f"{f[1]}_cq_full"])
                        else:
                            if chan_popper.setdefault(ch, (c, s)) != (c, s):
                                raise BatchClusterUnsupported(
                                    f"{prog.name}: channel {ch} popped by "
                                    f"more than one (core, stream)")
                            k = len(chan_pop.setdefault(ch, []))
                            magic = ins.srcs[0]
                            if isinstance(magic, str):
                                if magic_writer.setdefault(
                                        (c, magic), (c, s)) != (c, s):
                                    raise BatchClusterUnsupported(
                                        f"{prog.name}: magic register "
                                        f"{magic!r} written by pops of more "
                                        f"than one stream")
                            expect = ins.expects[0] if ins.expects else None
                            chan_pop[ch].append(
                                (gid, magic, expect, ins.label))
                            fabmeta[gid] = (
                                1, ch, k,
                                c * _NK + _KEY_ID[f"{f[1]}_cq_empty"])
                    elif kind is OpKind.DMA_START:
                        _one_dma_stream(c, s, prog)
                        j = len(core_starts[c])
                        dma_req[c] = max(dma_req[c], j - core_waits[c] + 1)
                        core_starts[c].append(
                            (gid, int(f[2]), ins.dma_words))
                        fabmeta[gid] = (2,)
                    elif kind is OpKind.DMA_WAIT:
                        _one_dma_stream(c, s, prog)
                        w = core_waits[c]
                        if w >= len(core_starts[c]):
                            raise BatchClusterUnsupported(
                                f"{prog.name}: DMA_WAIT without a matching "
                                f"in-flight DMA_START (head would be "
                                f"timing-dependent)")
                        sg, slat, words = core_starts[c][w]
                        core_waits[c] = w + 1
                        fabmeta[gid] = (
                            3, sg, slat, words,
                            c * _NK + _KEY_ID[f"{f[1]}_dma"])
                    gid += 1

        # magic registers feed only their own pops: any other reader would
        # observe a timing-dependent snapshot of the rotating value
        for c, prog in enumerate(progs):
            magics = {name for (cc, name) in magic_writer if cc == c}
            if not magics:
                continue
            for _u, lst in orders[c]:
                for ins in lst:
                    for src in ins.reg_srcs:
                        if src in magics and not (
                                ins.kind is OpKind.CQ_POP
                                and ins.srcs and ins.srcs[0] == src):
                            raise BatchClusterUnsupported(
                                f"{prog.name}: magic register {src!r} read "
                                f"outside its CQ_POP")

        self.dma_req_max = max(dma_req) if dma_req else 0
        self.cq_req_max = max(
            (max(0, len(chan_push.get(ch, []))
                 - len(chan_pop.get(ch, [])))
             for ch in set(chan_push) | set(chan_pop)), default=0)
        #: channel linkage for the runtime recurrence
        self.cq_pushg = {ch: np.array([g for g, _l, _n, _s in lst], _I8)
                         for ch, lst in chan_push.items()}
        self.cq_push_lat = {ch: np.array([l for _g, l, _n, _s in lst], _I8)
                            for ch, lst in chan_push.items()}
        self.cq_popg = {ch: np.array([g for g, _m, _e, _l in lst], _I8)
                        for ch, lst in chan_pop.items()}
        self.cq_adj = {}
        for ch, pu in chan_pusher.items():
            po = chan_popper.get(ch)
            # same-cycle ordering under the min-(cycle, core) scheduler:
            # the popper's issue is visible to the pusher's check iff the
            # popper's (core, stream) slot comes first
            self.cq_adj[ch] = 1 if po is None else (0 if po < pu else 1)

        # -- cluster-global functional pass (fabric-aware) -------------------
        # Greedy fixpoint over every core's streams: execute any instruction
        # whose register sources are produced, whose intra-core pops have
        # matching pushes and whose CQ_POP has a pushed channel value —
        # ignoring capacity, banks and latency.  Confluent (executing an
        # enabled instruction never disables another), so any machine
        # schedule yields these exact values and sequences.
        envs: List[Dict[str, Any]] = [dict(p.init_env) for p in progs]
        produced: List[set] = [set(p.init_env) for p in progs]
        push_vals: List[List[List[Tuple[str, Any]]]] = [
            [[] for _ in qlist] for _ in progs]
        popped: List[List[int]] = [[0] * NQ for _ in progs]
        push_logs = [{q: [] for q in qlist} for _ in progs]
        pop_logs = [{q: [] for q in qlist} for _ in progs]
        chan_vals: Dict[int, List[Tuple[str, Any]]] = {}
        chan_taken: Dict[int, int] = {}
        violations: List[Dict[int, List[Tuple[str, str, str, str]]]] = [
            {} for _ in progs]
        n_cq_push = n_cq_pop = n_cq_viol = 0
        pcs = [[0] * len(order) for order in orders]
        flat_facts = [[[ins.exec_facts for ins in lst] for _u, lst in order]
                      for order in orders]
        stream_off: List[List[int]] = []
        for c, order in enumerate(orders):
            offs, o = [], offsets[c]
            for _u, lst in order:
                offs.append(o)
                o += len(lst)
            stream_off.append(offs)

        progress = True
        while progress:
            progress = False
            for c in range(n_cores):
                for s, fs in enumerate(flat_facts[c]):
                    while pcs[c][s] < len(fs):
                        f = fs[pcs[c][s]]
                        g = stream_off[c][s] + pcs[c][s]
                        fab = fabmeta.get(g)
                        ok = True
                        for is_q, src, k, _key, _qv, qi in f[12]:
                            if is_q:
                                if (len(push_vals[c][qi])
                                        < popped[c][qi] + k + 1):
                                    ok = False
                                    break
                            elif src not in produced[c]:
                                ok = False
                                break
                        if ok and fab is not None and fab[0] == 1:
                            ch = fab[1]
                            if (len(chan_vals.get(ch, []))
                                    <= chan_taken.get(ch, 0)):
                                ok = False
                        if not ok:
                            break
                        # fabric side effects first (the scalar order): a
                        # CQ_POP's value lands in env before the base ops
                        # read it
                        if fab is not None:
                            tag = fab[0]
                            if tag == 0:
                                ch = fab[1]
                                _g, _l, name, src = chan_push[ch][fab[2]]
                                chan_vals.setdefault(ch, []).append(
                                    (name, envs[c].get(src)))
                                n_cq_push += 1
                            elif tag == 1:
                                ch = fab[1]
                                _g, magic, expect, _lbl = chan_pop[ch][fab[2]]
                                nm, val = chan_vals[ch][
                                    chan_taken.get(ch, 0)]
                                chan_taken[ch] = chan_taken.get(ch, 0) + 1
                                envs[c][magic] = val
                                produced[c].add(magic)
                                if expect is not None and expect != nm:
                                    n_cq_viol += 1
                                n_cq_pop += 1
                        opvals = []
                        expects = f[9]
                        n_pop = 0
                        for is_q, src, k, _key, qv, qi in f[12]:
                            if is_q:
                                vname, val = push_vals[c][qi][popped[c][qi]]
                                popped[c][qi] += 1
                                pop_logs[c][qlist[qi]].append(vname)
                                if expects and expects[n_pop] != vname:
                                    violations[c].setdefault(g, []).append(
                                        (f[10], qv, expects[n_pop], vname))
                                n_pop += 1
                                opvals.append(val)
                            else:
                                opvals.append(envs[c].get(src))
                        result = None
                        if evaluate and f[8] is not None:
                            result = f[8](*opvals)
                        if f[7] is not None:
                            envs[c][f[7]] = result
                            produced[c].add(f[7])
                        for _q, _k, _key, qi in f[13]:
                            push_vals[c][qi].append((f[11], result))
                            push_logs[c][qlist[qi]].append(f[11])
                        pcs[c][s] += 1
                        progress = True
        self.value_complete = all(
            pcs[c][s] == len(fs)
            for c in range(n_cores)
            for s, fs in enumerate(flat_facts[c]))
        self.env_c = envs
        self.push_seq_c = push_logs
        self.pop_seq_c = pop_logs
        self.n_cq_pushes = n_cq_push
        self.n_cq_pops = n_cq_pop
        self.n_cq_violations = n_cq_viol
        self.instr_count_c = []
        for order in orders:
            cnt = {"int": 0, "fp": 0}
            for _u, lst in order:
                for ins in lst:
                    cnt[ins.unit.value] += 1
            self.instr_count_c.append(cnt)

        # per-core FIFO-violation re-merge bookkeeping (batch_machine idiom)
        self.tracked_gid_c: List[np.ndarray] = []
        self.tracked_sorder_c: List[np.ndarray] = []
        self.tracked_tuples_c: List[List[List[Tuple[str, str, str, str]]]] = []
        for c in range(n_cores):
            gids = sorted(violations[c])
            self.tracked_gid_c.append(np.array(gids, dtype=_I8))
            sorder = []
            for g in gids:
                s = 0
                while (s + 1 < len(stream_off[c])
                       and g >= stream_off[c][s + 1]):
                    s += 1
                sorder.append(s)
            self.tracked_sorder_c.append(np.array(sorder, dtype=_I8))
            self.tracked_tuples_c.append([violations[c][g] for g in gids])

        # -- static dependence linkage --------------------------------------
        self.g_e = np.zeros(L, np.float64)
        self.e0 = np.zeros(L, np.float64)          # fabric energy at issue
        self.e1m = np.zeros(L, bool)               # charges interconnect
        self.g_sidx = np.zeros(L, _I8)             # local stream index
        self.g_rank = np.zeros(L, _I8)             # global (core, stream)
        pushg_cq: List[List[int]] = [[] for _ in range(n_cores * NQ)]
        popg_cq: List[List[int]] = [[] for _ in range(n_cores * NQ)]
        pop_ev: List[List[Tuple[int, int, int]]] = [
            [] for _ in range(n_cores * NQ)]
        push_ev: List[List[Tuple[int, int, int]]] = [
            [] for _ in range(n_cores * NQ)]
        core_km = [1] * n_cores
        producer: List[Dict[str, int]] = [{} for _ in progs]
        acc_gid: List[int] = []
        acc_hash: List[int] = []
        acc_is_mem: List[bool] = []
        raw: List[Tuple] = []
        for c, (prog, order) in enumerate(zip(progs, orders)):
            for s, (u, lst) in enumerate(order):
                last_blocking: Dict[int, int] = {}
                # busy chains span the whole core, not one stream — but a
                # unit's instructions all live on one stream (checked
                # above for dual mode; single mode has one stream), so
                # per-stream tracking is per-unit tracking
                for i, ins in enumerate(lst):
                    f = ins.exec_facts
                    g = stream_off[c][s] + i
                    (unit, _uval, latency, blocking, e_plain, e_frep,
                     busy_key, dst, _fn, _expects, _label, _pushv, ops,
                     pushes, uidx) = f
                    self.g_sidx[g] = s
                    self.g_rank[g] = rank_of[(c, s)]
                    self.g_e[g] = (e_frep if (prog.frep and unit is Unit.FP)
                                   else e_plain)
                    fab = fabmeta.get(g)
                    if fab is not None:
                        if fab[0] <= 1:
                            self.e0[g] = E_CQ_ACCESS
                            self.e1m[g] = True
                            acc_gid.append(g)
                            acc_hash.append(fab[1])
                            acc_is_mem.append(False)
                        elif fab[0] == 2:
                            self.e0[g] = E_DMA_WORD * ins.dma_words
                    elif ins.kind in MEM_KINDS and not ins.local:
                        self.e1m[g] = True
                        acc_gid.append(g)
                        acc_hash.append(zlib.crc32(ins.label.encode()))
                        acc_is_mem.append(True)
                    prev = g - 1 if i > 0 else -1
                    busyprev = last_blocking.get(uidx, -1)
                    if blocking:
                        last_blocking[uidx] = g
                    if dst is not None:
                        producer[c][dst] = g
                    core_km[c] = max(core_km[c], len(ops) + 1,
                                     len(pushes) + 1)
                    raw_srcs = []
                    pre = [len(popg_cq[c * NQ + qi]) for qi in range(NQ)]
                    for is_q, src, k, key, _qv, qi in ops:
                        if is_q:
                            raw_srcs.append((True, qi, pre[qi] + k,
                                             c * _NK + _KEY_ID[key]))
                        else:
                            raw_srcs.append((False, src, -1,
                                             c * _NK + _KEY_ID[key]))
                    for j, (is_q, _src, _k, _key, _qv, qi) in enumerate(ops):
                        if is_q:
                            popg_cq[c * NQ + qi].append(g)
                            pop_ev[c * NQ + qi].append((g, s * 2 + 0, j))
                    raw_pushes = []
                    pre_push = [len(pushg_cq[c * NQ + qi])
                                for qi in range(NQ)]
                    for j, (_q, k, key, qi) in enumerate(pushes):
                        raw_pushes.append((c * NQ + qi, qi, pre_push[qi] + k,
                                           c * _NK + _KEY_ID[key]))
                        pushg_cq[c * NQ + qi].append(g)
                        push_ev[c * NQ + qi].append((g, s * 2 + 1, j))
                    raw.append((c, prev, busyprev,
                                c * _NK + _KEY_ID[busy_key],
                                int(latency), tuple(raw_srcs),
                                tuple(raw_pushes), fab))
        self.acc_gid = np.array(acc_gid, dtype=_I8)
        self.acc_hash = np.array(acc_hash, dtype=_I8)
        self.acc_is_mem = np.array(acc_is_mem, dtype=bool)

        instrs: List[Tuple] = []
        preds: List[List[int]] = []
        cap_slots: List[Tuple[int, int, int, int]] = []
        cq_cap_slots: List[Tuple[int, int, int]] = []
        for g, (c, prev, busyprev, busykey, lat, raw_srcs, raw_pushes,
                fab) in enumerate(raw):
            srcs = []
            p: List[int] = [prev] if prev >= 0 else []
            for is_q, a, serial, key in raw_srcs:
                if is_q:
                    pg = pushg_cq[c * NQ + a]
                    gg = pg[serial] if serial < len(pg) else -1
                else:
                    gg = (-1 if a in progs[c].init_env
                          else producer[c].get(a, -1))
                if gg >= 0:
                    srcs.append((gg, is_q, key))
                    p.append(gg)
            for cqi, _qi, ps, _key in raw_pushes:
                cap_slots.append((g, cqi, cqi % NQ, ps))
            if fab is not None:
                if fab[0] == 0:
                    cq_cap_slots.append((g, fab[1], fab[2]))
                elif fab[0] == 1:
                    pg = self.cq_pushg.get(fab[1])
                    if pg is not None and fab[2] < len(pg):
                        p.append(int(pg[fab[2]]))
                elif fab[0] == 3:
                    p.append(fab[1])
            instrs.append((prev, busyprev, busykey, lat, tuple(srcs),
                           raw_pushes, fab))
            preds.append(p)
        self.instrs = instrs
        self._preds = preds
        self._cap_slots = cap_slots
        self._cq_cap_slots = cq_cap_slots
        self._topo_cache: Dict[Tuple[int, ...], Optional[List[int]]] = {}
        self.popg = [np.array(gids, dtype=_I8) for gids in popg_cq]
        self.npop = [len(gids) for gids in popg_cq]
        # the stall-key vector of each instruction's clear list is static
        # (which conditions participate never depends on the config values,
        # only on compile-time linkage) — precompute it for the hot loop
        self.clear_keys: List[np.ndarray] = []
        for prev, busyprev, busykey, lat, srcs, pushes, fab in instrs:
            ks: List[int] = []
            if busyprev >= 0:
                ks.append(busykey)
            if fab is not None:
                if fab[0] == 0:
                    pg = self.cq_popg.get(fab[1])
                    if pg is not None and len(pg):
                        ks.append(fab[3])
                elif fab[0] == 1:
                    ks.append(fab[3])
                elif fab[0] == 3:
                    ks.append(fab[4])
            ks.extend(key for _g, _q, key in srcs)
            ks.extend(key for cqi, _qi, _ps, key in pushes
                      if self.npop[cqi])
            self.clear_keys.append(np.array(ks, dtype=_I8))
        req = [0] * NQ
        for _g, cqi, qi, serial in cap_slots:
            req[qi] = max(req[qi], serial - len(popg_cq[cqi]) + 1)
        self.min_depth_req = np.array(req, dtype=_I8)
        self.qadj = []
        for c in range(n_cores):
            for qi in range(NQ):
                pu = next(iter(core_pushers[c].get(qi, {0})))
                po = next(iter(core_poppers[c].get(qi, {0})))
                self.qadj.append(0 if po < pu else 1)
        self.occ_tie_mod_c = [self.core_S[c] * 2 * core_km[c]
                              for c in range(n_cores)]
        self.occ_ev_c = []
        for c in range(n_cores):
            per_q = []
            km = core_km[c]
            for qi in range(NQ):
                cqi = c * NQ + qi
                evs = pop_ev[cqi] + push_ev[cqi]
                gids = np.array([g for g, _ph, _j in evs], dtype=_I8)
                tie = np.array([ph * km + j for _g, ph, j in evs], dtype=_I8)
                delta = np.array([-1] * len(pop_ev[cqi])
                                 + [1] * len(push_ev[cqi]), dtype=_I8)
                per_q.append((gids, tie, delta, len(push_ev[cqi]) > 0))
            self.occ_ev_c.append(per_q)

    def topo(self, dvec: Tuple[int, ...]) -> Optional[List[int]]:
        """Topological order of the global dependence DAG at intra-queue
        depths ``dvec[:NQ]`` and channel depth ``dvec[NQ]`` (``None`` if
        the capacity edges create a cycle — guaranteed deadlock at those
        depths).  As in ``batch_machine``, capacity edges only loosen as
        depths grow, so the order at the batch's componentwise minimum is
        valid for every point."""
        cached = self._topo_cache.get(dvec, False)
        if cached is not False:
            return cached
        L = self.L
        NQ = self.NQ
        indeg = [0] * L
        succ: List[List[int]] = [[] for _ in range(L)]
        for i, ps in enumerate(self._preds):
            for p in ps:
                succ[p].append(i)
                indeg[i] += 1
        for g, cqi, qi, serial in self._cap_slots:
            j = serial - dvec[qi]
            if j >= 0:
                p = int(self.popg[cqi][j])
                succ[p].append(g)
                indeg[g] += 1
        for g, ch, serial in self._cq_cap_slots:
            j = serial - dvec[NQ]
            if j >= 0:
                pg = self.cq_popg.get(ch)
                if pg is None or j >= len(pg):
                    # push that can never find room: guaranteed deadlock,
                    # excluded by the per-point cq feasibility check
                    self._topo_cache[dvec] = None
                    return None
                p = int(pg[j])
                succ[p].append(g)
                indeg[g] += 1
        dq = deque(i for i in range(L) if indeg[i] == 0)
        out: List[int] = []
        while dq:
            i = dq.popleft()
            out.append(i)
            for nxt in succ[i]:
                indeg[nxt] -= 1
                if indeg[nxt] == 0:
                    dq.append(nxt)
        res: Optional[List[int]] = out if len(out) == L else None
        self._topo_cache[dvec] = res
        return res


def _compile_cluster(progs: Sequence[Program],
                     evaluate: bool) -> _ClusterTables:
    """Build (or fetch) the program set's batch tables.  Cached on the
    first program — keyed by the identity of the whole set (pinned by the
    cache entry, so ids stay valid) — mirroring ``batch_machine._compile``
    so the memoized partitioned sets the sweep re-simulates across config
    batches compile once."""
    progs = list(progs)
    key = (tuple(id(p) for p in progs), bool(evaluate))
    anchor = progs[0]
    cached = getattr(anchor, "_batch_cluster_cache", None)
    if cached is not None and cached[0] == key:
        return cached[2]
    tables = _ClusterTables(progs, evaluate)
    anchor._batch_cluster_cache = (key, tuple(progs), tables)
    return tables


class BatchClusterStepper:
    """Advance B cluster configurations of one partitioned program set.

    ``run()`` returns one outcome per config, in input order: a
    :class:`~.cluster.ClusterResult` bit-identical to
    ``ClusterStepper(progs, cfg).run()``, or a :class:`BatchClusterDeadlock`
    carrying the identical cross-core :class:`~.machine.DeadlockError`
    message.  Predicted-deadlock, bank-conflicted and infeasible points are
    delegated to the scalar engine (always sound — the scalar result is
    returned as-is); completing conflict-free points never are.

    Shared config-independent pieces (per-core env, push/pop sequences)
    are shared objects across the returned results — treat them as
    read-only, exactly like the memoized Programs the sweep shares.

    Raises :class:`BatchClusterUnsupported` (at construction) for program
    sets outside the restrictions in the module docstring, and
    ``ValueError`` when a config's ``n_cores`` does not match the program
    count (the scalar constructor's contract).
    """

    def __init__(self, progs: Sequence[Program],
                 cfgs: Sequence[Optional[ClusterConfig]]):
        self.progs = list(progs)
        self.cfgs = [c if c is not None
                     else ClusterConfig(n_cores=len(self.progs))
                     for c in cfgs]
        for cfg in self.cfgs:
            if len(self.progs) != cfg.n_cores:
                raise ValueError(
                    f"got {len(self.progs)} per-core programs for "
                    f"n_cores={cfg.n_cores}")
        evals = {bool(c.machine.evaluate) for c in self.cfgs}
        if len(evals) > 1:
            raise BatchClusterUnsupported(
                "mixed cfg.machine.evaluate across a batch "
                "(env would differ)")
        self._evaluate = evals.pop() if evals else True
        if not self.progs:
            raise ValueError("got 0 per-core programs")
        self._t = _compile_cluster(self.progs, self._evaluate)

    def run(self) -> List[ClusterOutcome]:
        t = self._t
        B = len(self.cfgs)
        if B == 0:
            return []
        out: List[Optional[ClusterOutcome]] = [None] * B
        if t.L == 0 or not t.value_complete:
            # empty sets are trivial, circular dataflow deadlocks every
            # config: the scalar engine is exact (and cheap) for both
            for b in range(B):
                out[b] = self._scalar(b)
            return out  # type: ignore[return-value]

        qlist = list(Queue)
        depths = np.array([[c.machine.depth_of(q) for q in qlist]
                           for c in self.cfgs], _I8)
        cqd = np.array([c.cq_depth for c in self.cfgs], _I8)
        bufs = np.array([c.dma_buffers for c in self.cfgs], _I8)
        feasible = ~(depths < t.min_depth_req[None, :]).any(axis=1)
        feasible &= cqd >= t.cq_req_max
        feasible &= bufs >= t.dma_req_max
        for b in np.nonzero(~feasible)[0]:
            out[int(b)] = self._scalar(int(b))
        rows = np.nonzero(feasible)[0].astype(_I8)
        groups: List[Tuple[np.ndarray, List[int]]] = []
        if rows.size:
            dmin = tuple(int(x) for x in depths[rows].min(axis=0)) + (
                int(cqd[rows].min()),)
            order = t.topo(dmin)
            if order is not None:
                groups.append((rows, order))
            else:
                classes: Dict[Tuple[int, ...], List[int]] = {}
                for b in rows:
                    dv = tuple(int(x) for x in depths[b]) + (int(cqd[b]),)
                    classes.setdefault(dv, []).append(int(b))
                for dvec, bs in classes.items():
                    o = t.topo(dvec)
                    if o is None:
                        for b in bs:
                            out[b] = self._scalar(b)
                    else:
                        groups.append((np.array(bs, _I8), o))

        stalls = np.zeros((B, t.n_cores * _NK), _I8)
        for rows_g, order in groups:
            self._run_group(rows_g, order, depths, stalls, out)
        return out  # type: ignore[return-value]

    # -- the max-recurrence over one topologically-ordered group -------------

    def _run_group(self, rows: np.ndarray, order: List[int],
                   depths: np.ndarray, stalls: np.ndarray,
                   out: List[Optional[ClusterOutcome]]) -> None:
        t = self._t
        L = t.L
        R = rows.size
        n_cores = t.n_cores
        NQ = t.NQ
        cl = [self.cfgs[int(b)] for b in rows]
        dR = depths[rows]
        qR = np.array([c.machine.queue_latency for c in cl], _I8)
        limR = np.array([c.machine.deadlock_limit for c in cl], _I8)
        cqdR = np.array([c.cq_depth for c in cl], _I8)
        cqlR = np.array([c.cq_latency for c in cl], _I8)
        setR = np.array([c.dma_setup for c in cl], _I8)
        cpwR = np.array([c.dma_cycles_per_word for c in cl], _I8)
        penR = np.array([c.bank_conflict_penalty for c in cl], _I8)
        eaccR = np.array([c.interconnect_energy if c.n_cores > 1 else 0.0
                          for c in cl], np.float64)
        banksR = np.array([c.tcdm_banks or 0 for c in cl], _I8)
        ar = np.arange(R)
        zeros = np.zeros(R, _I8)
        ti = np.zeros((L, R), _I8)
        td = np.zeros((L, R), _I8)
        instrs = t.instrs
        popg = t.popg
        npop = t.npop
        qadj = t.qadj
        base_buf = np.empty(R, _I8)
        acc = np.empty(R, _I8)
        for i in order:
            prev, busyprev, busykey, lat, srcs, pushes, fab = instrs[i]
            if prev >= 0:
                np.add(ti[prev], 1, out=base_buf)
                base = base_buf
            else:
                base = zeros
            np.copyto(acc, base)
            # scalar check order: busy -> fabric -> sources -> capacity;
            # the bank gate (last) is omitted — the zero-contention oracle
            # guarantees it neither blocks nor owns a stall for surviving
            # points, and conflicted points are delegated.  The key of each
            # clear is static (``t.clear_keys[i]``, same order as appended).
            clears: List[np.ndarray] = []
            if busyprev >= 0:
                c = td[busyprev]
                clears.append(c)
                np.maximum(acc, c, out=acc)
            if fab is not None:
                tag = fab[0]
                if tag == 0:
                    ch = fab[1]
                    pg = t.cq_popg.get(ch)
                    if pg is not None and len(pg):
                        jv = fab[2] - cqdR
                        jc = np.clip(jv, 0, len(pg) - 1)
                        c = ti[pg[jc], ar] + t.cq_adj[ch]
                        c = np.where(jv < 0, 0, c)
                        clears.append(c)
                        np.maximum(acc, c, out=acc)
                    # else: feasibility guarantees depth >= total pushes
                elif tag == 1:
                    ch = fab[1]
                    c = (ti[t.cq_pushg[ch][fab[2]]]
                         + int(t.cq_push_lat[ch][fab[2]]) + cqlR)
                    clears.append(c)
                    np.maximum(acc, c, out=acc)
                elif tag == 3:
                    c = ti[fab[1]] + fab[2] + setR + fab[3] * cpwR
                    clears.append(c)
                    np.maximum(acc, c, out=acc)
            for g, is_q, _key in srcs:
                c = td[g] + qR if is_q else td[g]
                clears.append(c)
                np.maximum(acc, c, out=acc)
            for cqi, qi, ps, _key in pushes:
                if npop[cqi] == 0:
                    continue
                jv = ps - dR[:, qi]
                jc = np.clip(jv, 0, npop[cqi] - 1)
                c = ti[popg[cqi][jc], ar] + qadj[cqi]
                c = np.where(jv < 0, 0, c)
                clears.append(c)
                np.maximum(acc, c, out=acc)
            ti[i] = acc
            np.add(acc, lat, out=td[i])
            if clears:
                m = acc > base
                if m.any():
                    sub = np.nonzero(m)[0]
                    ct = np.empty((sub.size, len(clears)), _I8)
                    for j, c in enumerate(clears):
                        ct[:, j] = c[sub]
                    karr = t.clear_keys[i]
                    keys = np.broadcast_to(karr, (sub.size, karr.size))
                    _attribute(stalls, rows[sub], ct, keys,
                               base[sub], acc[sub] - 1)

        # per-core deadlock prediction (the batch_machine gap criterion:
        # the schedule is the no-horizon machine's exact schedule, and a
        # core's detector fires iff some inter-issue wait exceeds limit+1)
        lim1 = limR + 1
        dead = np.zeros(R, bool)
        for c in range(n_cores):
            off, Lc = t.core_off[c], t.core_L[c]
            if Lc == 0:
                continue
            ts = np.sort(ti[off:off + Lc], axis=0)
            dc = ts[0] > lim1
            if Lc > 1:
                dc |= (np.diff(ts, axis=0) > lim1[None, :]).any(axis=0)
            dead |= dc

        # zero-contention bank oracle: any access overlapping the running
        # busy window of its bank (in (time, core, stream) arbiter order)
        # breaks the bank-free-schedule equivalence -> delegate that point
        confl = np.zeros(R, bool)
        if t.acc_gid.size and (banksR > 0).any():
            acc_t = ti[t.acc_gid]
            acc_rank = t.g_rank[t.acc_gid]
            for nb in np.unique(banksR[banksR > 0]):
                cols = np.nonzero(banksR == nb)[0]
                ids = t.acc_hash % int(nb)
                for bank in np.unique(ids):
                    sel = np.nonzero(ids == bank)[0]
                    if sel.size < 2:
                        continue
                    times = acc_t[np.ix_(sel, cols)]
                    w = np.where(t.acc_is_mem[sel][:, None],
                                 penR[cols][None, :], 1)
                    key = times * t.n_ranks + acc_rank[sel][:, None]
                    p = np.argsort(key, axis=0, kind="stable")
                    tsrt = np.take_along_axis(times, p, 0)
                    wsrt = np.take_along_axis(w, p, 0)
                    endmax = np.maximum.accumulate(tsrt + wsrt, axis=0)
                    cc = (tsrt[1:] < endmax[:-1]).any(axis=0)
                    if cc.any():
                        confl[cols[np.nonzero(cc)[0]]] = True

        delegate = dead | confl
        for r in np.nonzero(delegate)[0]:
            out[int(rows[r])] = self._scalar(int(rows[r]))
        surv = np.nonzero(~delegate)[0]
        if not surv.size:
            return

        # per-core cycles, issue-order energy, occupancy highwaters
        core_cyc = np.zeros((n_cores, R), _I8)
        core_dyn = np.zeros((n_cores, R), np.float64)
        mx_all = np.zeros((n_cores, NQ, R), _I8)
        for c in range(n_cores):
            off, Lc = t.core_off[c], t.core_L[c]
            if Lc == 0:
                continue
            tic = ti[off:off + Lc]
            core_cyc[c] = td[off:off + Lc].max(axis=0)
            sidx = t.g_sidx[off:off + Lc]
            perm = np.argsort(tic * t.core_S[c] + sidx[:, None],
                              axis=0, kind="stable")
            # three energy terms per issue, in the scalar's accumulation
            # order: fabric (E_CQ_ACCESS / DMA words), interconnect access,
            # instruction energy.  Zero terms add +0.0 — IEEE-exact for the
            # non-negative accumulator, so cumsum replays the scalar sums.
            mat = np.empty((Lc, 3, R), np.float64)
            mat[:, 0, :] = t.e0[off:off + Lc, None]
            mat[:, 1, :] = np.where(t.e1m[off:off + Lc, None],
                                    eaccR[None, :], 0.0)
            mat[:, 2, :] = t.g_e[off:off + Lc, None]
            matp = np.take_along_axis(mat, perm[:, None, :], axis=0)
            core_dyn[c] = np.cumsum(matp.reshape(Lc * 3, R), axis=0)[-1]
            for qi in range(NQ):
                gids, tie, delta, has_push = t.occ_ev_c[c][qi]
                if not has_push:
                    continue
                key = ti[gids] * t.occ_tie_mod_c[c] + tie[:, None]
                p = np.argsort(key, axis=0, kind="stable")
                d = delta[p]
                cs = np.cumsum(d, axis=0)
                mx_all[c, qi] = np.max(np.where(d > 0, cs, 0), axis=0)
        issue_c = [ti[t.tracked_gid_c[c]] if len(t.tracked_gid_c[c]) else None
                   for c in range(n_cores)]

        for r in surv:
            b = int(rows[r])
            out[b] = self._assemble(b, r, core_cyc, core_dyn, mx_all,
                                    issue_c, stalls)

    # -- result assembly / scalar delegation ---------------------------------

    def _assemble(self, b: int, r: int, core_cyc, core_dyn, mx_all,
                  issue_c, stalls) -> ClusterResult:
        t = self._t
        cfg = self.cfgs[b]
        results: List[SimResult] = []
        for c, prog in enumerate(self.progs):
            cyc = int(core_cyc[c, r])
            sl = stalls[b, c * _NK:(c + 1) * _NK]
            sd = {_KEY_STRINGS[k]: int(sl[k]) for k in range(_NK) if sl[k]}
            viol: List[Tuple[str, str, str, str]] = []
            if issue_c[c] is not None:
                iss = issue_c[c][:, r]
                merged = sorted(
                    range(len(t.tracked_tuples_c[c])),
                    key=lambda tid: (int(iss[tid]),
                                     int(t.tracked_sorder_c[c][tid])))
                for tid in merged:
                    viol.extend(t.tracked_tuples_c[c][tid])
            results.append(SimResult(
                name=prog.name,
                policy=prog.policy,
                cycles=cyc,
                n_samples=prog.n_samples,
                instrs=dict(t.instr_count_c[c]),
                energy=float(core_dyn[c, r]) + E_STATIC_PER_CYCLE * cyc,
                env=t.env_c[c],
                push_seq=t.push_seq_c[c],
                pop_seq=t.pop_seq_c[c],
                max_queue_occupancy={q: int(mx_all[c, qi, r])
                                     for q, qi in QUEUE_INDEX.items()},
                fifo_violations=viol,
                stalls=sd,
            ))
        prog0 = self.progs[0]
        return ClusterResult(
            name=prog0.kernel_name,
            policy=prog0.policy,
            n_cores=cfg.n_cores,
            tcdm_banks=cfg.tcdm_banks,
            cycles=max((res.cycles for res in results), default=0),
            n_samples=sum(res.n_samples for res in results),
            energy=sum(res.energy for res in results),
            core_results=results,
            cq_pushes=t.n_cq_pushes,
            cq_pops=t.n_cq_pops,
            cq_violations=t.n_cq_violations,
        )

    def _scalar(self, b: int) -> ClusterOutcome:
        """Run one point on the scalar cluster engine — used for predicted
        deadlocks, bank conflicts and infeasible geometries.  Always sound:
        a completing scalar result is returned as-is, so mispredictions
        cost speed, never identity."""
        try:
            return ClusterStepper(self.progs, self.cfgs[b]).run()
        except DeadlockError as e:
            prog0 = self.progs[0]
            return BatchClusterDeadlock(
                name=prog0.kernel_name, policy=prog0.policy, message=str(e))


def batch_cluster_simulate(
        progs: Sequence[Program],
        cfgs: Sequence[Optional[ClusterConfig]]) -> List[ClusterOutcome]:
    """One-shot convenience twin of :func:`~.cluster.simulate_cluster`
    for a batch of cluster configs."""
    return BatchClusterStepper(progs, cfgs).run()


def batch_cluster_supported(progs: Sequence[Program],
                            evaluate: bool = True) -> Optional[str]:
    """``None`` if the program set can run on the batch cluster engine,
    else the reason string.  Compiling here primes the cache the stepper
    uses, so a supported-check followed by a run costs one compile."""
    try:
        _compile_cluster(list(progs), evaluate)
        return None
    except BatchClusterUnsupported as e:
        return str(e)
