"""Execution policies — the paper's methodology ladder, framework-wide.

BASELINE  — original sequential code on the single-issue core (Snitch [6]).
COPIFT    — DAC'25 methodology [1]: DFG partition + batch + software pipeline
            + double buffering; inter-thread communication spilled to memory;
            batch-granular semaphore synchronization.
COPIFTV2  — this paper: DFG partition + schedule; communication and
            synchronization through blocking hardware FIFO queues (I2F/F2I);
            no loop transformations.

The same enum is threaded through the TPU layers (see DESIGN.md §4):
kernels/queue_matmul (bulk staging vs multi-buffered DMA queue) and
distributed/collective_matmul (all-gather-then-compute vs ppermute ring).
"""
from __future__ import annotations

import enum


class ExecutionPolicy(enum.Enum):
    BASELINE = "baseline"
    COPIFT = "copift"
    COPIFTV2 = "copiftv2"

    @classmethod
    def parse(cls, s: "str | ExecutionPolicy") -> "ExecutionPolicy":
        if isinstance(s, ExecutionPolicy):
            return s
        return cls(s.lower())
