"""Execution policies — the paper's methodology ladder, framework-wide.

BASELINE  — original sequential code on the single-issue core (Snitch [6]).
COPIFT    — DAC'25 methodology [1]: DFG partition + batch + software pipeline
            + double buffering; inter-thread communication spilled to memory;
            batch-granular semaphore synchronization.
COPIFTV2  — this paper: DFG partition + schedule; communication and
            synchronization through blocking hardware FIFO queues (I2F/F2I);
            no loop transformations.

The same enum is threaded through the TPU layers (see DESIGN.md §4):
kernels/queue_matmul (bulk staging vs multi-buffered DMA queue) and
distributed/collective_matmul (all-gather-then-compute vs ppermute ring).

Policy *selection* lives here too: an :class:`OperatingPoint` bundles the
policy with the queue geometry / unroll it should run at, and a
:class:`PolicyTable` resolves one per workload.  The table is populated from
DSE calibration artifacts (``core.calibrate``, written by
``examples/explore.py calibrate`` into ``artifacts/calibration/`` or the
``REPRO_CALIBRATION_DIR`` override); consumers fall back to the paper's
hard-coded headline point when no artifact exists, and an explicit override
always wins.  Workloads whose fabric pins a queue-visibility latency class
(:data:`WORKLOAD_QUEUE_LATENCIES`) resolve through the schema-v4 per-class
selections when the artifact carries them, with the global selection as the
fallback.  Resolution happens once at startup — the selection machinery
stays off the hot path (cf. Snitch, arXiv:2002.10143).
"""
from __future__ import annotations

import dataclasses
import enum
import os
import warnings
from dataclasses import dataclass
from typing import Dict, Optional


class ExecutionPolicy(enum.Enum):
    BASELINE = "baseline"
    COPIFT = "copift"
    COPIFTV2 = "copiftv2"

    @classmethod
    def parse(cls, s: "str | ExecutionPolicy") -> "ExecutionPolicy":
        if isinstance(s, ExecutionPolicy):
            return s
        return cls(s.lower())


@dataclass(frozen=True)
class OperatingPoint:
    """One (policy, queue geometry, unroll) choice for a workload.

    The defaults are the paper's headline hardware point (queue depth 4,
    latency 1, unroll 8 under COPIFTv2) — the sane fallback when no
    calibration artifact is available.  ``source`` records how the point was
    chosen: ``"default"`` (fallback), ``"calibrated"`` (loaded from a DSE
    artifact) or ``"override"`` (caller-pinned).
    """
    policy: ExecutionPolicy = ExecutionPolicy.COPIFTV2
    queue_depth: int = 4
    queue_latency: int = 1
    unroll: int = 8
    unroll_int: Optional[int] = None
    queue_depth_i2f: Optional[int] = None
    queue_depth_f2i: Optional[int] = None
    #: cluster geometry (``core.cluster``): PEs sharing the TCDM and the
    #: bank count (None = conflict-free).  The paper's headline point is a
    #: single PE; cluster-level calibration artifacts populate these.
    n_cores: int = 1
    tcdm_banks: Optional[int] = None
    #: pipelined-cluster geometry (``transform.partition_pipeline`` +
    #: ``core.cluster``): producer/consumer core pairing over inter-core
    #: channels, the channel FIFO depth, and the producer's DMA
    #: double-buffering degree.  The paper's headline point is a single
    #: work-partitioned PE, so the defaults leave the fabric unused.
    pipeline: bool = False
    cq_depth: int = 4
    dma_buffers: int = 2
    source: str = "default"

    def effective_depths(self) -> "tuple[int, int]":
        return (self.queue_depth_i2f or self.queue_depth,
                self.queue_depth_f2i or self.queue_depth)


#: Consumer workloads mapped to the machine-model kernel whose instruction
#: mix is the closest analogue (DESIGN.md §4): the per-kernel calibration
#: artifact for the proxy supplies the workload's operating point.
#:  * ``queue_matmul`` / ``moe_gemm`` stream quantized operand tiles through
#:    a blocking FIFO ring — the int8 dequantization dot product is the
#:    matching mixed int/FP kernel;
#:  * ``serve`` decode is dominated by activation math (exp in softmax /
#:    gating) — the range-reduction ``expf`` kernel;
#:  * ``train`` is GEMM-bound (forward + backward matmuls over quantized
#:    comms) — ``dequant_dot`` again.
WORKLOAD_PROXIES: Dict[str, str] = {
    "queue_matmul": "dequant_dot",
    "moe_gemm": "dequant_dot",
    "serve": "expf",
    "train": "dequant_dot",
}

#: Consumer workloads' pinned queue-visibility latency class.  The fabric a
#: workload's machine analogue communicates over fixes how many cycles a
#: pushed value takes to become pop-visible, and the schema-v4 calibration
#: artifacts carry per-latency-class selections (``selected_by_latency``)
#: precisely so these consumers can take the best point *at their latency*
#: instead of the global winner: ``queue_matmul`` / ``moe_gemm`` / ``train``
#: stream operand tiles through the shared-TCDM interconnect (one traversal
#: each way: class 2), while ``serve`` decode's softmax/gating FIFOs are
#: core-local (class 1).  :meth:`PolicyTable.resolve` falls back to the
#: global selection when the class was never swept.
WORKLOAD_QUEUE_LATENCIES: Dict[str, int] = {
    "queue_matmul": 2,
    "moe_gemm": 2,
    "serve": 1,
    "train": 2,
}

#: Serve-path traffic levels: offered load as a fraction of the best
#: sustainable service rate on the calibrated Pareto front.  The schema-v5
#: calibration artifacts carry one ``serve-slo`` selection per level
#: (``selected_by_traffic``): max throughput subject to the estimated-p99
#: and joules-per-token bounds *at that offered load* — queueing delay
#: grows with load, so the feasible set shrinks as traffic rises and the
#: levels select different points on fronts where cheap-but-slow
#: configurations only hold the SLO when the queue stays short.
#: :meth:`PolicyTable.resolve` takes a ``traffic=`` level and falls back to
#: the latency-class/global selection when the artifact predates v5 or
#: never analysed that level.
TRAFFIC_LEVELS: Dict[str, float] = {
    "low": 0.3,
    "medium": 0.6,
    "high": 0.85,
}


class PolicyTable:
    """Workload → :class:`OperatingPoint` resolution, calibration-backed.

    Resolution order for :meth:`resolve`:

    1. an explicit ``override`` point (or keyword field overrides) — wins
       unconditionally, tagged ``source="override"``;
    2. a calibrated entry for the workload itself, then for its
       :data:`WORKLOAD_PROXIES` proxy kernel — tagged ``"calibrated"``.
       When a ``traffic=`` level is pinned and the artifact carries a
       schema-v5 per-traffic ``serve-slo`` selection for it, that level's
       point wins; otherwise, when the workload pins a queue-latency class
       (an explicit ``queue_latency=`` argument, or its
       :data:`WORKLOAD_QUEUE_LATENCIES` entry) and the artifact carries a
       schema-v4 per-class selection for it, that class's point is
       returned; the global selection is the fallback for classes/levels
       the calibration never analysed;
    3. the :class:`OperatingPoint` defaults — tagged ``"default"``.
    """

    def __init__(self, entries: Optional[Dict[str, OperatingPoint]] = None,
                 directory: Optional[str] = None,
                 records: Optional[Dict[str, "object"]] = None):
        self.entries: Dict[str, OperatingPoint] = dict(entries or {})
        #: kernel -> full CalibrationRecord, kept alongside the resolved
        #: global points so latency-class resolution can reach
        #: ``operating_point_for`` (in-memory tables have none)
        self.records: Dict[str, "object"] = dict(records or {})
        self.directory = directory

    @classmethod
    def load(cls, directory: Optional[str] = None) -> "PolicyTable":
        """Build a table from every valid artifact in the calibration
        directory (``REPRO_CALIBRATION_DIR`` or ``artifacts/calibration``).
        Invalid or stale artifacts are skipped with a warning — consumers
        then fall back to defaults rather than failing at startup."""
        # local import: calibrate imports sweep -> policy (cycle otherwise)
        from .calibrate import (CalibrationError, calibration_dir,
                                load_artifact)
        directory = directory or calibration_dir()
        entries: Dict[str, OperatingPoint] = {}
        records: Dict[str, "object"] = {}
        if os.path.isdir(directory):
            for fname in sorted(os.listdir(directory)):
                if not fname.endswith(".json"):
                    continue
                path = os.path.join(directory, fname)
                try:
                    rec = load_artifact(path)
                except CalibrationError as e:
                    warnings.warn(
                        f"ignoring calibration artifact {path}: {e}; "
                        f"affected workloads fall back to defaults",
                        stacklevel=2)
                    continue
                entries[rec.kernel] = rec.operating_point()
                records[rec.kernel] = rec
        return cls(entries, directory=directory, records=records)

    def resolve(self, workload: str,
                override: Optional[OperatingPoint] = None,
                queue_latency: Optional[int] = None,
                traffic: Optional[str] = None,
                **field_overrides) -> OperatingPoint:
        if override is not None:
            return dataclasses.replace(override, source="override")
        key = workload if workload in self.entries else \
            WORKLOAD_PROXIES.get(workload)
        point = self.entries.get(key) if key is not None else None
        if point is not None:
            rec = self.records.get(key)
            traffic_point = None
            if rec is not None and traffic is not None:
                # schema-v5 per-traffic serve-slo selection; getattr keeps
                # pre-v5 CalibrationRecord objects (and stale-fallback
                # loads) working — they simply lack the accessor
                for_traffic = getattr(rec, "operating_point_for_traffic",
                                      None)
                if for_traffic is not None:
                    traffic_point = for_traffic(traffic)
            if traffic_point is not None:
                point = traffic_point
            else:
                if queue_latency is None:
                    queue_latency = WORKLOAD_QUEUE_LATENCIES.get(workload)
                if rec is not None and queue_latency is not None:
                    point = rec.operating_point_for(queue_latency)  # type: ignore[attr-defined]
        if point is None:
            point = OperatingPoint()
        if field_overrides:
            point = dataclasses.replace(point, **field_overrides,
                                        source="override")
        return point

    def __repr__(self) -> str:
        return (f"PolicyTable({sorted(self.entries)} "
                f"from {self.directory or '<memory>'})")


# One table per calibration directory: loading scans the filesystem, and the
# resolved points must stay stable for a process's lifetime (selection is a
# startup decision, never a hot-path one).  Keyed by directory so tests can
# repoint ``REPRO_CALIBRATION_DIR`` at temp dirs without cross-talk.
_TABLE_CACHE: Dict[str, PolicyTable] = {}


def default_table() -> PolicyTable:
    """The process-wide calibration-backed table (cached per directory)."""
    from .calibrate import calibration_dir
    directory = calibration_dir()
    table = _TABLE_CACHE.get(directory)
    if table is None:
        table = _TABLE_CACHE[directory] = PolicyTable.load(directory)
    return table


def clear_policy_table_cache() -> None:
    """Drop cached tables (tests repointing ``REPRO_CALIBRATION_DIR``)."""
    _TABLE_CACHE.clear()
