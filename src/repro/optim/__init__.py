"""Optimizers: AdamW (decoupled weight decay) + Lion, warmup-cosine schedule,
global-norm gradient clipping.  Pure-pytree implementation (no optax)."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..config import RunConfig

Pytree = Any


class OptState(NamedTuple):
    step: jax.Array
    mu: Pytree
    nu: Pytree          # unused (zeros-like scalars) for lion


def init_opt_state(params: Pytree, kind: str = "adamw") -> OptState:
    def zeros():
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    nu = zeros() if kind == "adamw" else jax.tree_util.tree_map(
        lambda p: jnp.zeros((), jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros(), nu=nu)


def opt_state_shapes(param_shapes: Pytree, kind: str = "adamw") -> OptState:
    zeros = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), param_shapes)
    nu = zeros if kind == "adamw" else jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct((), jnp.float32), param_shapes)
    return OptState(step=jax.ShapeDtypeStruct((), jnp.int32), mu=zeros, nu=nu)


def lr_schedule(step: jax.Array, rc: RunConfig) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(rc.warmup_steps, 1), 1.0)
    t = jnp.clip((step - rc.warmup_steps)
                 / jnp.maximum(rc.total_steps - rc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return rc.lr * warm * (0.1 + 0.9 * cos)


def clip_by_global_norm(grads: Pytree, max_norm: float
                        ) -> Tuple[Pytree, jax.Array]:
    leaves = jax.tree_util.tree_leaves(grads)
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype),
                                  grads), norm


def adamw_update(params: Pytree, state: OptState, grads: Pytree,
                 rc: RunConfig, b1=0.9, b2=0.95, eps=1e-8
                 ) -> Tuple[Pytree, OptState, Dict[str, jax.Array]]:
    grads, gnorm = clip_by_global_norm(grads, rc.grad_clip)
    step = state.step + 1
    lr = lr_schedule(step, rc)
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v, g):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        u = (m / c1) / (jnp.sqrt(v / c2) + eps) + rc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    out = jax.tree_util.tree_map(upd, params, state.mu, state.nu, grads)
    new_p = jax.tree_util.tree_map(lambda t: t[0], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return new_p, OptState(step, new_m, new_v), {"lr": lr, "grad_norm": gnorm}


def lion_update(params: Pytree, state: OptState, grads: Pytree, rc: RunConfig,
                b1=0.9, b2=0.99) -> Tuple[Pytree, OptState, Dict[str, jax.Array]]:
    grads, gnorm = clip_by_global_norm(grads, rc.grad_clip)
    step = state.step + 1
    lr = lr_schedule(step, rc) * 0.3

    def upd(p, m, g):
        g32 = g.astype(jnp.float32)
        u = jnp.sign(b1 * m + (1 - b1) * g32) + rc.weight_decay * p.astype(jnp.float32)
        m = b2 * m + (1 - b2) * g32
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m

    out = jax.tree_util.tree_map(upd, params, state.mu, grads)
    new_p = jax.tree_util.tree_map(lambda t: t[0], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return new_p, OptState(step, new_m, state.nu), {"lr": lr, "grad_norm": gnorm}
