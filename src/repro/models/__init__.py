from .model import (cache_spec, decode_step, forward, init_cache,
                    init_model_params, input_specs, param_shapes, param_specs,
                    prefill_step)

__all__ = ["cache_spec", "decode_step", "forward", "init_cache",
           "init_model_params", "input_specs", "param_shapes", "param_specs",
           "prefill_step"]
