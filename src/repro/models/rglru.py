"""RG-LRU recurrent block (RecurrentGemma's temporal mixer).

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t), with a_t = exp(-c*softplus(Λ)*r_t).
Prefill uses a chunked associative scan (like the Mamba block); decode is an
O(1) update, so ``long_500k`` runs for the hybrid family."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from ..config import ModelConfig
from .layers import ParamSpec

_C = 8.0


def rglru_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    w = cfg.rglru.lru_width or d
    conv = cfg.rglru.conv_width
    return {
        "in_proj": ParamSpec((d, w), ("embed", "inner")),
        "gate_proj": ParamSpec((d, w), ("embed", "inner")),
        "conv_w": ParamSpec((conv, w), (None, "inner")),
        "conv_b": ParamSpec((w,), ("inner",), init="zeros"),
        "rg_w": ParamSpec((w, w), ("inner", None)),       # recurrence gate
        "rg_b": ParamSpec((w,), ("inner",), init="zeros"),
        "ig_w": ParamSpec((w, w), ("inner", None)),       # input gate
        "ig_b": ParamSpec((w,), ("inner",), init="zeros"),
        "lam": ParamSpec((w,), ("inner",), init="ones"),  # Λ
        "out_proj": ParamSpec((w, d), ("inner", "embed")),
    }


def _conv1d(p, x, conv_state=None):
    K = p["conv_w"].shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, k:k + x.shape[1]] * p["conv_w"][k] for k in range(K))
    return out + p["conv_b"], xp[:, -(K - 1):]


def _gates(p, u):
    r = jax.nn.sigmoid(u @ p["rg_w"] + p["rg_b"]).astype(jnp.float32)
    i = jax.nn.sigmoid(u @ p["ig_w"] + p["ig_b"]).astype(jnp.float32)
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    return a, beta * i * u.astype(jnp.float32)


def rglru_apply(p, x: jax.Array, cfg: ModelConfig, *, chunk: int = 256,
                unroll: bool = False) -> jax.Array:
    B, S, _ = x.shape
    u = x @ p["in_proj"]
    u, _ = _conv1d(p, u)
    gate = jax.nn.gelu(x @ p["gate_proj"])

    if unroll:
        chunk = min(2048, max(chunk, S))
    pad = (-S) % chunk
    up = jnp.pad(u, ((0, 0), (0, pad), (0, 0))) if pad else u
    n_chunks = up.shape[1] // chunk
    uc = up.reshape(B, n_chunks, chunk, -1).transpose(1, 0, 2, 3)

    def chunk_step(h, uck):
        a, bx = _gates(p, uck)

        def assoc(l, r):
            return (l[0] * r[0], r[0] * l[1] + r[1])

        decay, hseq = jax.lax.associative_scan(assoc, (a, bx), axis=1)
        hseq = hseq + decay * h[:, None]
        return hseq[:, -1], hseq.astype(x.dtype)

    h0 = jnp.zeros((B, up.shape[-1]), jnp.float32)
    if unroll:
        hcur, hlist = h0, []
        for ci in range(n_chunks):
            hcur, hk = chunk_step(hcur, uc[ci])
            hlist.append(hk)
        hs = jnp.stack(hlist)
    else:
        _, hs = jax.lax.scan(chunk_step, h0, uc)
    h = hs.transpose(1, 0, 2, 3).reshape(B, -1, up.shape[-1])[:, :S]
    return (h * gate) @ p["out_proj"]


def rglru_decode(p, x: jax.Array, cfg: ModelConfig, h, conv_state):
    """x: (B,1,d); h: (B,w) fp32; conv_state: (B,K-1,w)."""
    u = x @ p["in_proj"]
    u, conv_state = _conv1d(p, u, conv_state)
    gate = jax.nn.gelu(x @ p["gate_proj"])
    a, bx = _gates(p, u)
    h = a[:, 0] * h + bx[:, 0]
    out = (h[:, None].astype(x.dtype) * gate) @ p["out_proj"]
    return out, h, conv_state
