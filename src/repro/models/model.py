"""Model assembly: parameter trees, forward pass, KV/state caches and decode
steps for every assigned architecture family (dense / moe / ssm / hybrid /
vlm / audio).  Homogeneous layer stacks are scanned (`lax.scan` over stacked
params — compile time stays flat in depth); the hybrid family scans over its
repeating (rec, rec, attn) macro-block with an unrolled tail."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..config import ModelConfig, RunConfig, ShapeConfig
from . import attention as attn
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import ssm as ssm_mod
from .layers import (ParamSpec, ffn_apply, ffn_specs, init_params, rms_norm,
                     shape_tree)

Pytree = Any


# ---------------------------------------------------------------------------
# parameter trees
# ---------------------------------------------------------------------------

def _stack_specs(specs: Pytree, n: int) -> Pytree:
    return jax.tree_util.tree_map(
        lambda s: ParamSpec((n, *s.shape), ("layers", *s.axes), s.init, s.scale),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def _dense_block_specs(cfg: ModelConfig) -> Dict[str, Pytree]:
    d = cfg.d_model
    block: Dict[str, Pytree] = {"ln1": ParamSpec((d,), ("embed",), init="zeros"),
                                "ln2": ParamSpec((d,), ("embed",), init="zeros")}
    block["attn"] = attn.mla_specs(cfg) if cfg.mla else attn.gqa_specs(cfg)
    block["ffn"] = (moe_mod.moe_specs(cfg) if cfg.moe
                    else ffn_specs(d, cfg.d_ff, cfg.ffn_act))
    return block


def _rec_block_specs(cfg: ModelConfig) -> Dict[str, Pytree]:
    d = cfg.d_model
    return {"ln1": ParamSpec((d,), ("embed",), init="zeros"),
            "ln2": ParamSpec((d,), ("embed",), init="zeros"),
            "rglru": rglru_mod.rglru_specs(cfg),
            "ffn": ffn_specs(d, cfg.d_ff, cfg.ffn_act)}


def _hybrid_layout(cfg: ModelConfig) -> Tuple[int, Tuple[str, ...]]:
    pat = cfg.rglru.pattern
    n_full = cfg.n_layers // len(pat)
    tail = tuple(pat[:cfg.n_layers % len(pat)])
    return n_full, tail


def param_specs(cfg: ModelConfig) -> Pytree:
    d = cfg.d_model
    tree: Dict[str, Pytree] = {
        "embed": ParamSpec((cfg.vocab, d), ("vocab", "embed"),
                           init="embed", scale=0.02),
        "final_norm": ParamSpec((d,), ("embed",), init="zeros"),
    }
    if not cfg.tie_embeddings:
        tree["head"] = ParamSpec((cfg.vocab, d), ("vocab", "embed"),
                                 init="embed", scale=0.02)
    if cfg.family == "ssm":
        block = {"ln1": ParamSpec((d,), ("embed",), init="zeros"),
                 "mamba": ssm_mod.mamba_specs(cfg)}
        tree["blocks"] = _stack_specs(block, cfg.n_layers)
    elif cfg.family == "hybrid":
        n_full, tail = _hybrid_layout(cfg)
        macro = {}
        for j, kind in enumerate(cfg.rglru.pattern):
            macro[f"{j}_{kind}"] = (_rec_block_specs(cfg) if kind == "rec"
                                    else _dense_block_specs(cfg))
        tree["macros"] = _stack_specs(macro, n_full)
        for j, kind in enumerate(tail):
            tree[f"tail_{j}_{kind}"] = (_rec_block_specs(cfg) if kind == "rec"
                                        else _dense_block_specs(cfg))
    else:
        tree["blocks"] = _stack_specs(_dense_block_specs(cfg), cfg.n_layers)
    return tree


def init_model_params(key, cfg: ModelConfig, dtype=jnp.float32) -> Pytree:
    return init_params(key, param_specs(cfg), dtype)


def param_shapes(cfg: ModelConfig, dtype) -> Pytree:
    return shape_tree(param_specs(cfg), dtype)


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _dense_block_apply(p, x, cfg: ModelConfig, rc: RunConfig,
                       q_offset: int = 0, window: Optional[int] = None):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.mla:
        h = attn.mla_apply(p["attn"], h, cfg, q_offset=q_offset,
                           analysis=rc.analysis_mode,
                           batch_shard=rc.attn_batch_shard)
    else:
        h = attn.gqa_apply(p["attn"], h, cfg, window=window,
                           q_offset=q_offset, analysis=rc.analysis_mode,
                           batch_shard=rc.attn_batch_shard)
    x = x + h
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.moe:
        h = (moe_mod.moe_apply_grouped(p["ffn"], h, cfg)
             if rc.moe_dispatch == "grouped"
             else moe_mod.moe_apply(p["ffn"], h, cfg))
    else:
        h = ffn_apply(p["ffn"], h, cfg.ffn_act)
    return x + h


def _rec_block_apply(p, x, cfg: ModelConfig, rc: RunConfig):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    x = x + rglru_mod.rglru_apply(p["rglru"], h, cfg,
                                  unroll=rc.analysis_mode)
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + ffn_apply(p["ffn"], h, cfg.ffn_act)


def _ssm_block_apply(p, x, cfg: ModelConfig, rc: RunConfig):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    return x + ssm_mod.mamba_apply(p["mamba"], h, cfg,
                                   unroll=rc.analysis_mode)


def _stack_scan(body, x, xs, rc: RunConfig):
    """lax.scan over stacked layers, or a Python unroll in analysis mode
    (XLA cost_analysis counts while bodies once — unrolling restores true
    FLOP/byte/collective totals for the roofline)."""
    if rc.analysis_mode:
        leaves = jax.tree_util.tree_leaves(xs)
        L = leaves[0].shape[0]
        outs = []
        for i in range(L):
            sl = jax.tree_util.tree_map(lambda a: a[i], xs)
            x, out = body(x, sl)
            outs.append(out)
        if outs and outs[0] is not None:
            stacked = jax.tree_util.tree_map(
                lambda *ys: jnp.stack(ys), *outs)
        else:
            stacked = None
        return x, stacked
    if rc.remat:
        body = jax.checkpoint(body)
    return jax.lax.scan(body, x, xs)


def embed_inputs(params, batch: Dict[str, jax.Array], cfg: ModelConfig,
                 dtype) -> jax.Array:
    if cfg.frontend == "audio":
        return batch["frames"].astype(dtype)
    x = params["embed"][batch["tokens"]].astype(dtype)
    if cfg.frontend == "vision":
        n = cfg.n_frontend_tokens
        patches = batch["patches"].astype(dtype)
        x = jnp.concatenate([patches, x[:, n:]], axis=1)
    return x


def _heads_shard_on_model(cfg: ModelConfig) -> bool:
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or "model" not in mesh.axis_names:
            return True
        return cfg.n_heads % mesh.shape["model"] == 0
    except Exception:
        return True


def forward(params: Pytree, batch: Dict[str, jax.Array], cfg: ModelConfig,
            rc: RunConfig) -> jax.Array:
    """Full-sequence forward -> logits (B, S, vocab)."""
    dtype = jnp.dtype(rc.dtype)
    x = embed_inputs(params, batch, cfg, dtype)
    if rc.attn_batch_shard and not _heads_shard_on_model(cfg):
        # heads cannot shard over the model axis (e.g. 24H or 40H on TP=16):
        # switch the whole residual stream to 2-D batch sharding once, here,
        # instead of bouncing layouts around every attention layer
        from .attention import batch_shard_constraint
        x = batch_shard_constraint(x)
    cast = lambda t: jax.tree_util.tree_map(lambda a: a.astype(dtype)
                                            if a.dtype == jnp.float32 else a, t)

    if cfg.family == "ssm":
        def body(h, bp):
            return _ssm_block_apply(cast(bp), h, cfg, rc), None
        x, _ = _stack_scan(body, x, params["blocks"], rc)
    elif cfg.family == "hybrid":
        window = cfg.rglru.window

        def macro_body(h, mp):
            mp = cast(mp)
            for j, kind in enumerate(cfg.rglru.pattern):
                bp = mp[f"{j}_{kind}"]
                h = (_rec_block_apply(bp, h, cfg, rc) if kind == "rec"
                     else _dense_block_apply(bp, h, cfg, rc, window=window))
            return h, None
        x, _ = _stack_scan(macro_body, x, params["macros"], rc)
        _, tail = _hybrid_layout(cfg)
        for j, kind in enumerate(tail):
            bp = cast(params[f"tail_{j}_{kind}"])
            x = (_rec_block_apply(bp, x, cfg, rc) if kind == "rec"
                 else _dense_block_apply(bp, x, cfg, rc, window=window))
    else:
        def body(h, bp):
            return _dense_block_apply(cast(bp), h, cfg, rc), None
        x, _ = _stack_scan(body, x, params["blocks"], rc)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,vd->bsv", x, head.astype(dtype))
    # logits stay in the compute dtype: upcasting here would drag the entire
    # backward pass (activation-gradient all-reduces included) into fp32 —
    # see EXPERIMENTS.md §Perf (phi3 hillclimb #1)
    return logits


# ---------------------------------------------------------------------------
# decode caches + step
# ---------------------------------------------------------------------------

def cache_spec(cfg: ModelConfig, batch: int, max_len: int,
               dtype) -> Dict[str, jax.ShapeDtypeStruct]:
    """Shape tree of the decode cache (also used to allocate zeros)."""
    L, hd = cfg.n_layers, cfg.resolved_head_dim
    sd = lambda shape, dt=dtype: jax.ShapeDtypeStruct(shape, dt)
    # ``len`` is per-sequence: continuous batching admits a request into a
    # freed slot mid-run, so each batch row carries its own position (RoPE
    # angle, KV write cursor, and attention-mask extent all derive from it)
    out: Dict[str, Any] = {"len": sd((batch,), jnp.int32)}
    if cfg.family == "ssm":
        d_in, _, d_state = ssm_mod.ssm_dims(cfg)
        K = cfg.ssm.d_conv
        out["ssm"] = sd((L, batch, d_in, d_state), jnp.float32)
        out["conv"] = sd((L, batch, K - 1, d_in))
        return out
    if cfg.family == "hybrid":
        n_full, tail = _hybrid_layout(cfg)
        pat = cfg.rglru.pattern
        kinds = list(pat) * n_full + list(tail)
        n_rec = sum(1 for k in kinds if k == "rec")
        n_attn = len(kinds) - n_rec
        w = cfg.rglru.lru_width or cfg.d_model
        W = min(cfg.rglru.window, max_len)
        out["h"] = sd((n_rec, batch, w), jnp.float32)
        out["conv"] = sd((n_rec, batch, cfg.rglru.conv_width - 1, w))
        out["k"] = sd((n_attn, batch, cfg.n_kv_heads, W, hd))
        out["v"] = sd((n_attn, batch, cfg.n_kv_heads, W, hd))
        return out
    if cfg.mla:
        m = cfg.mla
        out["latent"] = sd((L, batch, max_len, m.kv_lora_rank))
        out["rope"] = sd((L, batch, max_len, m.qk_rope_head_dim))
        return out
    out["k"] = sd((L, batch, cfg.n_kv_heads, max_len, hd))
    out["v"] = sd((L, batch, cfg.n_kv_heads, max_len, hd))
    return out


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Pytree:
    return jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype),
                                  cache_spec(cfg, batch, max_len, dtype))


def decode_step(params: Pytree, cache: Pytree, batch: Dict[str, jax.Array],
                cfg: ModelConfig, rc: RunConfig
                ) -> Tuple[jax.Array, Pytree]:
    """One token for every sequence in the batch.
    batch = {"tokens": (B, 1)} -> (logits (B, vocab), new cache).
    ``cache["len"]`` is a per-sequence (B,) position vector, so slots of a
    continuously-batched engine may sit at different sequence lengths."""
    dtype = jnp.dtype(rc.dtype)
    x = params["embed"][batch["tokens"]].astype(dtype)
    length = cache["len"]
    cast = lambda t: jax.tree_util.tree_map(lambda a: a.astype(dtype)
                                            if a.dtype == jnp.float32 else a, t)

    if cfg.family == "ssm":
        def body(h, sl):
            bp, ssm_s, conv_s = sl
            bp = cast(bp)
            hn = rms_norm(h, bp["ln1"], cfg.norm_eps)
            y, ssm_s, conv_s = ssm_mod.mamba_decode(bp["mamba"], hn, cfg,
                                                    ssm_s, conv_s)
            return h + y, (ssm_s, conv_s)
        x, (ssm_s, conv_s) = _stack_scan(
            body, x, (params["blocks"], cache["ssm"], cache["conv"]),
            rc)
        cache = {**cache, "ssm": ssm_s, "conv": conv_s, "len": length + 1}
    elif cfg.family == "hybrid":
        x, cache = _hybrid_decode(params, cache, x, cfg, rc, dtype)
    elif cfg.mla:
        def body(h, sl):
            bp, lat, rp = sl
            bp = cast(bp)
            hn = rms_norm(h, bp["ln1"], cfg.norm_eps)
            y, lat, rp = attn.mla_decode(bp["attn"], hn, cfg, lat, rp, length)
            h = h + y
            hn = rms_norm(h, bp["ln2"], cfg.norm_eps)
            y = (moe_mod.moe_apply(bp["ffn"], hn, cfg) if cfg.moe
                 else ffn_apply(bp["ffn"], hn, cfg.ffn_act))
            return h + y, (lat, rp)
        x, (lat, rp) = _stack_scan(
            body, x, (params["blocks"], cache["latent"], cache["rope"]), rc)
        cache = {**cache, "latent": lat, "rope": rp, "len": length + 1}
    else:
        def body(h, sl):
            bp, kc, vc = sl
            bp = cast(bp)
            hn = rms_norm(h, bp["ln1"], cfg.norm_eps)
            y, kc, vc = attn.gqa_decode(bp["attn"], hn, cfg, kc, vc, length)
            h = h + y
            hn = rms_norm(h, bp["ln2"], cfg.norm_eps)
            y = (moe_mod.moe_apply(bp["ffn"], hn, cfg) if cfg.moe
                 else ffn_apply(bp["ffn"], hn, cfg.ffn_act))
            return h + y, (kc, vc)
        x, (kc, vc) = _stack_scan(
            body, x, (params["blocks"], cache["k"], cache["v"]), rc)
        cache = {**cache, "k": kc, "v": vc, "len": length + 1}

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,vd->bsv", x, head.astype(dtype))[:, 0]
    return logits.astype(jnp.float32), cache


def _hybrid_decode(params, cache, x, cfg: ModelConfig, rc: RunConfig, dtype):
    length = cache["len"]
    window = cfg.rglru.window
    n_full, tail = _hybrid_layout(cfg)
    cast = lambda t: jax.tree_util.tree_map(lambda a: a.astype(dtype)
                                            if a.dtype == jnp.float32 else a, t)
    pat = cfg.rglru.pattern
    rec_per_macro = sum(1 for k in pat if k == "rec")
    attn_per_macro = len(pat) - rec_per_macro
    n_rec_scan = n_full * rec_per_macro
    n_attn_scan = n_full * attn_per_macro

    h_sc = cache["h"][:n_rec_scan].reshape(n_full, rec_per_macro, *cache["h"].shape[1:])
    cv_sc = cache["conv"][:n_rec_scan].reshape(n_full, rec_per_macro, *cache["conv"].shape[1:])
    k_sc = cache["k"][:n_attn_scan].reshape(n_full, attn_per_macro, *cache["k"].shape[1:])
    v_sc = cache["v"][:n_attn_scan].reshape(n_full, attn_per_macro, *cache["v"].shape[1:])

    def macro(hx, sl):
        mp, hs, cs, ks, vs = sl
        mp = cast(mp)
        ri = ai = 0
        hs2, cs2, ks2, vs2 = list(hs), list(cs), list(ks), list(vs)
        for j, kind in enumerate(pat):
            bp = mp[f"{j}_{kind}"]
            hn = rms_norm(hx, bp["ln1"], cfg.norm_eps)
            if kind == "rec":
                y, h_new, c_new = rglru_mod.rglru_decode(bp["rglru"], hn, cfg,
                                                         hs[ri], cs[ri])
                hs2[ri], cs2[ri] = h_new, c_new
                ri += 1
            else:
                y, k_new, v_new = attn.gqa_decode(bp["attn"], hn, cfg,
                                                  ks[ai], vs[ai], length,
                                                  window=window)
                ks2[ai], vs2[ai] = k_new, v_new
                ai += 1
            hx = hx + y
            hn = rms_norm(hx, bp["ln2"], cfg.norm_eps)
            hx = hx + ffn_apply(bp["ffn"], hn, cfg.ffn_act)
        return hx, (jnp.stack(hs2), jnp.stack(cs2), jnp.stack(ks2), jnp.stack(vs2))

    x, (hs, cs, ks, vs) = _stack_scan(
        macro, x, (params["macros"], h_sc, cv_sc, k_sc, v_sc), rc)
    new_h = list(hs.reshape(n_rec_scan, *cache["h"].shape[1:]))
    new_cv = list(cs.reshape(n_rec_scan, *cache["conv"].shape[1:]))
    new_k = list(ks.reshape(n_attn_scan, *cache["k"].shape[1:]))
    new_v = list(vs.reshape(n_attn_scan, *cache["v"].shape[1:]))

    ri, ai = n_rec_scan, n_attn_scan
    for j, kind in enumerate(tail):
        bp = cast(params[f"tail_{j}_{kind}"])
        hn = rms_norm(x, bp["ln1"], cfg.norm_eps)
        if kind == "rec":
            y, h_new, c_new = rglru_mod.rglru_decode(
                bp["rglru"], hn, cfg, cache["h"][ri], cache["conv"][ri])
            new_h.append(h_new)
            new_cv.append(c_new)
            ri += 1
        else:
            y, k_new, v_new = attn.gqa_decode(bp["attn"], hn, cfg,
                                              cache["k"][ai], cache["v"][ai],
                                              length, window=window)
            new_k.append(k_new)
            new_v.append(v_new)
            ai += 1
        x = x + y
        hn = rms_norm(x, bp["ln2"], cfg.norm_eps)
        x = x + ffn_apply(bp["ffn"], hn, cfg.ffn_act)

    cache = {**cache, "h": jnp.stack(new_h), "conv": jnp.stack(new_cv),
             "k": jnp.stack(new_k), "v": jnp.stack(new_v), "len": length + 1}
    return x, cache


# ---------------------------------------------------------------------------
# chunked prefill: C prompt tokens per slot per jitted call
# ---------------------------------------------------------------------------

def _merge_masked(active: jax.Array, new: jax.Array, old: jax.Array
                  ) -> jax.Array:
    """Per-slot select between two cache leaves: batch is axis 0 of the
    per-sequence ``len`` vector and axis 1 of every stacked leaf (same
    convention as the engine's slot-reset)."""
    if new.ndim == 0:
        return new
    if new.ndim == 1:                          # cache["len"]: (B,)
        return jnp.where(active, new, old)
    shape = (1, active.shape[0]) + (1,) * (new.ndim - 2)
    return jnp.where(active.reshape(shape), new, old)


def prefill_step(params: Pytree, cache: Pytree, batch: Dict[str, jax.Array],
                 cfg: ModelConfig, rc: RunConfig
                 ) -> Tuple[jax.Array, Pytree]:
    """Ingest a chunk of up to C prompt tokens per slot in ONE jitted call.

    ``batch = {"tokens": (B, C) int32, "n_tokens": (B,) int32}`` — slot
    ``i`` consumes its first ``n_tokens[i]`` columns starting at its own
    cache position ``cache["len"][i]`` (``0 <= n_tokens[i] <= C``; ``0``
    leaves the slot completely untouched).  Mixed-phase batches are the
    point: a slot mid-prefill (``n_tokens = C``) coexists with a slot
    mid-decode (``n_tokens = 1``, its column 0 holding the last generated
    token) and with free slots (``n_tokens = 0``) in the same fixed-shape
    call.

    Returns ``(logits, cache)`` where ``logits[i]`` is the next-token
    distribution after slot ``i``'s **last valid column** — for a decoding
    slot that is the ordinary decode logits; for a slot whose prefill
    completes inside this chunk it is the first-generated-token logits.

    Bit-exactness with the token-by-token path is by construction: the
    chunk columns are advanced by ``lax.scan`` over the *same*
    :func:`decode_step` body (per-sequence positions, attention/SSM/RG-LRU
    cache writes included), with a per-slot mask selecting whether the
    column's update lands — so one ``(B, C)`` call produces exactly the
    tokens and final cache rows that C single-token calls would, while the
    per-step host dispatch, device sync, and scheduling overhead are paid
    once per chunk instead of once per token (the ``PREFILL_FRACTION``
    discount the serve cost model charges prompt tokens).
    """
    tokens, n_tokens = batch["tokens"], batch["n_tokens"]
    B, C = tokens.shape

    def column(carry, j):
        cache, logits = carry
        tok = jax.lax.dynamic_slice_in_dim(tokens, j, 1, axis=1)   # (B, 1)
        active = j < n_tokens                                      # (B,)
        step_logits, new_cache = decode_step(params, cache,
                                             {"tokens": tok}, cfg, rc)
        cache = {k: _merge_masked(active, new_cache[k], cache[k])
                 for k in cache}
        logits = jnp.where(active[:, None], step_logits, logits)
        return (cache, logits), None

    logits0 = jnp.zeros((B, cfg.vocab), jnp.float32)
    (cache, logits), _ = jax.lax.scan(column, (cache, logits0),
                                      jnp.arange(C))
    return logits, cache


# ---------------------------------------------------------------------------
# canonical input specs per (arch x shape) cell — ShapeDtypeStructs only
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig, rc: RunConfig,
                ) -> Dict[str, Any]:
    """Stand-ins for every model input of this cell (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    dtype = jnp.dtype(rc.dtype)
    i32 = jnp.int32
    sd = jax.ShapeDtypeStruct
    if shape.mode == "decode":
        return {"tokens": sd((B, 1), i32),
                "cache": cache_spec(cfg, B, S, dtype)}
    batch: Dict[str, Any] = {}
    if cfg.frontend == "audio":
        batch["frames"] = sd((B, S, cfg.d_model), dtype)
    else:
        batch["tokens"] = sd((B, S), i32)
        if cfg.frontend == "vision":
            batch["patches"] = sd((B, cfg.n_frontend_tokens, cfg.d_model), dtype)
    if shape.mode == "train":
        batch["labels"] = sd((B, S), i32)
    return batch
