"""Mixture-of-Experts FFN with top-k routing (granite-moe / olmoe).

The routing pipeline is the framework's clearest COPIFTv2 analogue: the
*integer stream* (top-k selection, expert counts, dispatch indices) feeds the
*FP stream* (expert GEMMs) — see ``repro.kernels.moe_gemm`` for the
queue-coupled kernel.  This module is the dense einsum reference: dispatch
via one-hot combine matrices, numerically exact and shardable (experts over
the 'model' mesh axis when divisible; see distributed.sharding)."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..config import ModelConfig
from .layers import ParamSpec


def moe_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, e = cfg.d_model, cfg.moe
    specs = {
        "router": ParamSpec((d, e.num_experts), ("embed", "experts")),
        "wi": ParamSpec((e.num_experts, d, e.d_ff_expert),
                        ("experts", "embed", "expert_ff")),
        "wo": ParamSpec((e.num_experts, e.d_ff_expert, d),
                        ("experts", "expert_ff", "embed")),
    }
    if cfg.ffn_act == "swiglu":
        specs["wg"] = ParamSpec((e.num_experts, d, e.d_ff_expert),
                                ("experts", "embed", "expert_ff"))
    return specs


def router_probs(p, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Top-k routing.  x: (B, S, d) -> (weights (B,S,k), idx (B,S,k))."""
    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    w, idx = jax.lax.top_k(logits, cfg.moe.top_k)
    w = jax.nn.softmax(w, axis=-1)
    return w, idx


def moe_apply(p, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Dense-dispatch MoE: one-hot combine (exact, EP-shardable reference)."""
    e = cfg.moe
    B, S, d = x.shape
    w, idx = router_probs(p, x, cfg)
    # combine[b,s,E] = sum_k w[b,s,k] * (idx[b,s,k] == E)
    combine = jnp.sum(
        jax.nn.one_hot(idx, e.num_experts, dtype=x.dtype)
        * w[..., None].astype(x.dtype), axis=2)               # (B,S,E)
    # dispatch every token to every expert it routes to (dense reference:
    # compute is masked by the combine weights)
    h = jnp.einsum("bsd,edf->besf", x, p["wi"])
    if cfg.ffn_act == "swiglu":
        g = jnp.einsum("bsd,edf->besf", x, p["wg"])
        h = jax.nn.silu(g) * h
    elif cfg.ffn_act == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    y = jnp.einsum("besf,efd->besd", h, p["wo"])
    out = jnp.einsum("besd,bse->bsd", y, combine)
    return out


def _expert_shard_constraint(buf: jax.Array, num_experts: int) -> jax.Array:
    """Pin the expert dim of dispatch buffers to the 'model' axis (EP): the
    scatter feeding it becomes GSPMD's all-to-all and the expert GEMMs run
    expert-parallel instead of token-replicated.  No-op outside a mesh
    context or when experts don't divide (granite's 40 on TP=16)."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or "model" not in mesh.axis_names:
            return buf
        tp = mesh.shape["model"]
        if num_experts % tp or num_experts < tp:
            return buf
        spec = jax.sharding.PartitionSpec("model", None, None)
        return jax.lax.with_sharding_constraint(buf, spec)
    except Exception:
        return buf


def moe_apply_grouped(p, x: jax.Array, cfg: ModelConfig,
                      capacity_factor: float = 1.25,
                      expert_parallel: bool = False) -> jax.Array:
    """Capacity-bounded sort-based dispatch (deployable path, matches
    ``kernels/moe_gemm``): assignments are sorted by expert, scattered into
    (E, C, d) buffers — O(T·k·d) gather/scatter + O(E·C·d·f) GEMMs, never a
    (T, E, C) tensor.  This *is* the paper's structure: the sort/offset
    computation is the integer stream feeding the expert-GEMM FP stream.
    Matches ``moe_apply`` up to dropped over-capacity tokens."""
    e = cfg.moe
    B, S, d = x.shape
    T = B * S
    k = e.top_k
    xt = x.reshape(T, d)
    w, idx = router_probs(p, x, cfg)
    w = w.reshape(T * k)
    eid = idx.reshape(T * k)
    C = max(int(capacity_factor * k * T / e.num_experts), 1)

    # --- integer stream: sort by expert, per-expert slot offsets ----------
    order = jnp.argsort(eid)                       # stable
    eid_s = eid[order]
    tok_s = order // k
    w_s = w[order]
    counts = jnp.bincount(eid, length=e.num_experts)
    starts = jnp.cumsum(counts) - counts
    slot = jnp.arange(T * k) - starts[eid_s]
    keep = slot < C
    slot_c = jnp.where(keep, slot, 0)
    eid_c = jnp.where(keep, eid_s, 0)

    # --- dispatch: scatter kept tokens into per-expert buffers ------------
    vals = xt[tok_s] * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((e.num_experts, C, d), x.dtype)
    buf = buf.at[eid_c, slot_c].add(vals)
    if expert_parallel:
        # measured NET-NEGATIVE on olmoe train_4k at TP=16 (collective term
        # 5.6 s -> 18 s outweighs the halved compute): opt-in only; see
        # EXPERIMENTS.md §Perf "refuted: EP all-to-all dispatch"
        buf = _expert_shard_constraint(buf, e.num_experts)

    # --- FP stream: expert GEMMs (the moe_gemm kernel's computation) ------
    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    if cfg.ffn_act == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", buf, p["wg"])
        h = jax.nn.silu(g) * h
    elif cfg.ffn_act == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    y = jnp.einsum("ecf,efd->ecd", h, p["wo"])                    # (E,C,d)

    # --- combine: gather back, weight, scatter-add over tokens ------------
    y_tok = y[eid_c, slot_c] * (w_s * keep).astype(x.dtype)[:, None]
    out = jnp.zeros((T, d), x.dtype).at[tok_s].add(y_tok)
    return out.reshape(B, S, d)
