"""Shared layers: param-spec trees, norms, embeddings, RoPE, FFN variants.

Parameters are declared as :class:`ParamSpec` trees (shape + logical axis
names + initializer).  The same tree serves three consumers:
 - ``init_params``      — materialize real weights (smoke tests, training)
 - ``shape_tree``       — ShapeDtypeStructs for AOT lowering (dry-run)
 - ``distributed.sharding`` — logical-axis -> mesh-axis resolution
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]      # logical axis names (for sharding)
    init: str = "normal"                 # normal | zeros | ones | embed
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_leaf(key, spec: ParamSpec, dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "embed":
        return (jax.random.normal(key, spec.shape) * spec.scale).astype(dtype)
    fan_in = spec.shape[0] if len(spec.shape) > 1 else 1
    std = spec.scale / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape) * std).astype(dtype)


def init_params(key, tree: Pytree, dtype=jnp.float32) -> Pytree:
    leaves, treedef = jax.tree_util.tree_flatten(
        tree, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def shape_tree(tree: Pytree, dtype) -> Pytree:
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), tree,
        is_leaf=lambda x: isinstance(x, ParamSpec))


def logical_axes_tree(tree: Pytree) -> Pytree:
    return jax.tree_util.tree_map(
        lambda s: s.axes, tree, is_leaf=lambda x: isinstance(x, ParamSpec))


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    x32 = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def rope_angles(positions: jax.Array, dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions: (...,) int32 -> cos/sin of shape (..., dim//2), fp32."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., dim); cos/sin broadcastable to (..., dim//2)."""
    dt = x.dtype
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(dt)


def ffn_specs(d_model: int, d_ff: int, act: str) -> Dict[str, ParamSpec]:
    if act == "swiglu":
        return {
            "wi": ParamSpec((d_model, d_ff), ("embed", "ff")),
            "wg": ParamSpec((d_model, d_ff), ("embed", "ff")),
            "wo": ParamSpec((d_ff, d_model), ("ff", "embed")),
        }
    return {
        "wi": ParamSpec((d_model, d_ff), ("embed", "ff")),
        "wo": ParamSpec((d_ff, d_model), ("ff", "embed")),
    }


def ffn_apply(p: Dict[str, jax.Array], x: jax.Array, act: str) -> jax.Array:
    if act == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    elif act == "relu2":
        h = jnp.square(jax.nn.relu(x @ p["wi"]))
    else:
        h = jax.nn.gelu(x @ p["wi"])
    return h @ p["wo"]
