"""Mamba-1 selective-state-space block (falcon-mamba family).

Prefill/train uses a *chunked* associative scan: materializing the full
(B, S, d_inner, d_state) state sequence at 32k+ context is terabytes, so the
sequence is processed in chunks with the recurrent state carried by
``lax.scan`` and a parallel (associative) scan inside each chunk.  Decode is
the O(1) recurrent update — the reason ``long_500k`` runs for this family.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..config import ModelConfig
from .layers import ParamSpec


def ssm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    dt_rank = s.dt_rank or -(-cfg.d_model // 16)
    return d_in, dt_rank, s.d_state


def mamba_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    d_in, dt_rank, d_state = ssm_dims(cfg)
    conv = cfg.ssm.d_conv
    return {
        "in_proj": ParamSpec((d, 2 * d_in), ("embed", "inner2")),
        "conv_w": ParamSpec((conv, d_in), (None, "inner")),
        "conv_b": ParamSpec((d_in,), ("inner",), init="zeros"),
        "x_proj": ParamSpec((d_in, dt_rank + 2 * d_state), ("inner", None)),
        "dt_proj": ParamSpec((dt_rank, d_in), (None, "inner")),
        "dt_bias": ParamSpec((d_in,), ("inner",), init="zeros"),
        "A_log": ParamSpec((d_in, d_state), ("inner", None), init="ones"),
        "D": ParamSpec((d_in,), ("inner",), init="ones"),
        "out_proj": ParamSpec((d_in, d), ("inner", "embed")),
    }


def _ssm_coeffs(p, x_in: jax.Array, cfg: ModelConfig):
    """x_in: (B, T, d_in) post-conv activations -> (dA, dBx, C).
    dA: (B,T,d_in,d_state) decay; dBx same shape; C: (B,T,d_state)."""
    d_in, dt_rank, d_state = ssm_dims(cfg)
    proj = x_in @ p["x_proj"]
    dt, Bc, C = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"] + p["dt_bias"])     # (B,T,d_in)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))               # (d_in,d_state)
    dA = jnp.exp(dt.astype(jnp.float32)[..., None] * A)        # decay
    dBx = (dt * x_in).astype(jnp.float32)[..., None] \
        * Bc.astype(jnp.float32)[:, :, None, :]
    return dA, dBx, C


def _conv1d(p, x: jax.Array, conv_state=None):
    """Causal depthwise conv.  x: (B, T, d_in).  conv_state: (B, K-1, d_in)."""
    K = p["conv_w"].shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, k:k + x.shape[1]] * p["conv_w"][k] for k in range(K))
    return out + p["conv_b"], xp[:, -(K - 1):]


def mamba_apply(p, x: jax.Array, cfg: ModelConfig, *,
                chunk: int = 256, unroll: bool = False) -> jax.Array:
    """Full-sequence forward.  x: (B, S, d)."""
    B, S, _ = x.shape
    d_in, _, d_state = ssm_dims(cfg)
    xz = x @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)
    xi, _ = _conv1d(p, xi)
    xi = jax.nn.silu(xi)

    if unroll:
        chunk = min(2048, max(chunk, S))
    pad = (-S) % chunk
    if pad:
        xi_p = jnp.pad(xi, ((0, 0), (0, pad), (0, 0)))
    else:
        xi_p = xi
    n_chunks = xi_p.shape[1] // chunk
    xc = xi_p.reshape(B, n_chunks, chunk, d_in).transpose(1, 0, 2, 3)

    def chunk_step(h, xck):
        dA, dBx, C = _ssm_coeffs(p, xck, cfg)

        def assoc(a, b):
            return (a[0] * b[0], b[0] * a[1] + b[1])

        decay, state = jax.lax.associative_scan(assoc, (dA, dBx), axis=1)
        state = state + decay * h[:, None]          # inject carry
        h_next = state[:, -1]
        y = jnp.einsum("btds,bts->btd", state, C.astype(jnp.float32))
        return h_next, y.astype(x.dtype)

    h0 = jnp.zeros((B, d_in, d_state), jnp.float32)
    if unroll:
        hs, ylist = h0, []
        for ci in range(n_chunks):
            hs, yk = chunk_step(hs, xc[ci])
            ylist.append(yk)
        ys = jnp.stack(ylist)
    else:
        _, ys = jax.lax.scan(chunk_step, h0, xc)
    y = ys.transpose(1, 0, 2, 3).reshape(B, n_chunks * chunk, d_in)[:, :S]
    y = y + xi * p["D"]
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"]


def mamba_decode(p, x: jax.Array, cfg: ModelConfig, ssm_state, conv_state):
    """One-token step.  x: (B, 1, d); ssm_state: (B, d_in, d_state) fp32;
    conv_state: (B, K-1, d_in)."""
    xz = x @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)
    xi, conv_state = _conv1d(p, xi, conv_state)
    xi = jax.nn.silu(xi)
    dA, dBx, C = _ssm_coeffs(p, xi, cfg)
    ssm_state = dA[:, 0] * ssm_state + dBx[:, 0]
    y = jnp.einsum("bds,bs->bd", ssm_state, C[:, 0].astype(jnp.float32))
    y = y[:, None].astype(x.dtype) + xi * p["D"]
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"], ssm_state, conv_state
