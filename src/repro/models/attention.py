"""Attention layers: GQA (full/causal/local-window), MLA, and decode paths.

All sequence-level attention goes through :func:`flash_attention_ref` — a
blockwise online-softmax implementation in pure jnp (the oracle for the
Pallas kernel in ``repro.kernels.flash_attention``).  Materializing S² scores
at 32k context would need terabytes; blockwise keeps the working set at
(block_q × block_k) per head.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..config import ModelConfig
from .layers import ParamSpec, apply_rope, rms_norm, rope_angles

NEG_INF = -1e30


def batch_shard_constraint(*arrays):
    """Pin the leading (batch) dim of attention activations to the combined
    (data, model) mesh axes when legal — a no-op outside a mesh context or
    when the batch does not divide.  See RunConfig.attn_batch_shard."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or "model" not in mesh.axis_names:
            return arrays if len(arrays) > 1 else arrays[0]
        axes = tuple(a for a in ("pod", "data", "model")
                     if a in mesh.axis_names)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out = []
        for x in arrays:
            if x.shape[0] % size == 0 and x.shape[0] >= size:
                spec = jax.sharding.PartitionSpec(axes, *([None] * (x.ndim - 1)))
                x = jax.lax.with_sharding_constraint(x, spec)
            out.append(x)
        return tuple(out) if len(out) > 1 else out[0]
    except Exception:
        return arrays if len(arrays) > 1 else arrays[0]


# ---------------------------------------------------------------------------
# blockwise attention reference (flash-style, pure jnp)
# ---------------------------------------------------------------------------

def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: Optional[int] = None,
                        block_q: int = 512, block_k: int = 1024,
                        q_offset: int = 0, unroll: bool = False) -> jax.Array:
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Sk, D[v]); GQA via head grouping.
    ``q_offset`` is the absolute position of q[0] (for decode/chunked use).
    Returns (B, Hq, Sq, Dv)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, Dv = v.shape
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    orig_sq = Sq

    pad_q = (-Sq) % block_q
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        Sq = q.shape[2]
    pad_k = (-Sk) % block_k
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        Sk_p = k.shape[2]
    else:
        Sk_p = Sk

    qb = q.reshape(B, Hkv, G, Sq // block_q, block_q, D)
    kb = k.reshape(B, Hkv, Sk_p // block_k, block_k, D)
    vb = v.reshape(B, Hkv, Sk_p // block_k, block_k, Dv)
    nq, nk = Sq // block_q, Sk_p // block_k

    q_pos = q_offset + jnp.arange(Sq).reshape(nq, block_q)
    k_pos = jnp.arange(Sk_p).reshape(nk, block_k)

    def q_block(qi, q_i):
        # online softmax over k blocks
        def kv_step(carry, ki):
            m, l, acc = carry
            s = jnp.einsum("bhgqd,bhkd->bhgqk", q_i.astype(jnp.float32),
                           kb[:, :, ki].astype(jnp.float32)) * scale
            mask = k_pos[ki][None, :] <= Sk - 1          # strip k padding
            if causal:
                mask = mask & (k_pos[ki][None, :] <= q_pos[qi][:, None])
            if window is not None:
                mask = mask & (k_pos[ki][None, :]
                               > q_pos[qi][:, None] - window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vb[:, :, ki].astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, block_q), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, block_q, Dv), jnp.float32)
        if unroll:
            carry = (m0, l0, a0)
            for ki in range(nk):
                # block skipping: drop blocks that are fully masked (causal
                # future blocks; blocks beyond the sliding window) — on TPU
                # the Pallas kernel skips these via its grid/masking too
                if causal and ki * block_k > q_offset + (qi + 1) * block_q - 1:
                    continue
                if (window is not None and (ki + 1) * block_k - 1
                        <= q_offset + qi * block_q - window):
                    continue
                carry, _ = kv_step(carry, ki)
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                          jnp.arange(nk))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    if unroll:
        out = jnp.stack([q_block(qi, qb[:, :, :, qi]) for qi in range(nq)])
    else:
        out = jax.lax.map(lambda qi: q_block(qi, qb[:, :, :, qi]),
                          jnp.arange(nq))
    # out: (nq, B, Hkv, G, block_q, Dv) -> (B, Hq, Sq, Dv)
    out = jnp.moveaxis(out, 0, 3).reshape(B, Hkv, G, Sq, Dv)
    out = out.reshape(B, Hq, Sq, Dv)[:, :, :orig_sq]
    return out.astype(v.dtype)


def decode_attention_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         length: jax.Array, *, window: Optional[int] = None
                         ) -> jax.Array:
    """Single-token attention: q (B, Hq, 1, D); caches (B, Hkv, T, D).
    ``length`` (scalar int32, or per-sequence (B,) int32 for continuous
    batching) = number of valid cache entries per sequence."""
    B, Hq, _, D = q.shape
    _, Hkv, T, Dv = v_cache.shape
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bhtd->bhgt", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    pos = jnp.arange(T)
    lv = jnp.reshape(jnp.asarray(length), (-1, 1))   # (B, 1) or (1, 1)
    mask = pos[None] < lv
    if window is not None:
        mask = mask & (pos[None] >= lv - window)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgt,bhtd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, Hq, 1, Dv).astype(v_cache.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------

def gqa_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    return {
        "wq": ParamSpec((d, cfg.n_heads, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((cfg.n_heads, hd, d), ("heads", "head_dim", "embed")),
    }


def gqa_apply(p, x: jax.Array, cfg: ModelConfig, *,
              window: Optional[int] = None, q_offset: int = 0,
              analysis: bool = False, batch_shard: bool = False) -> jax.Array:
    """Full-sequence GQA attention.  x: (B, S, d)."""
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bhsk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bhsk", x, p["wv"])
    if cfg.rope:
        pos = q_offset + jnp.arange(S)
        cos, sin = rope_angles(pos, cfg.resolved_head_dim, cfg.rope_theta)
        q = apply_rope(q, cos[None, None], sin[None, None])
        k = apply_rope(k, cos[None, None], sin[None, None])
    if batch_shard:
        q, k, v = batch_shard_constraint(q, k, v)
    if analysis:
        S_ = x.shape[1]
        o = flash_attention_ref(q, k, v, causal=cfg.causal, window=window,
                                q_offset=q_offset, unroll=True,
                                block_q=min(4096, S_), block_k=min(4096, S_))
    else:
        o = flash_attention_ref(q, k, v, causal=cfg.causal, window=window,
                                q_offset=q_offset)
    if batch_shard:
        o = batch_shard_constraint(o)
    return jnp.einsum("bhsk,hkd->bsd", o, p["wo"])


def gqa_prefill_kv(p, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """K/V for the whole prompt (cache fill)."""
    k = jnp.einsum("bsd,dhk->bhsk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bhsk", x, p["wv"])
    if cfg.rope:
        pos = jnp.arange(x.shape[1])
        cos, sin = rope_angles(pos, cfg.resolved_head_dim, cfg.rope_theta)
        k = apply_rope(k, cos[None, None], sin[None, None])
    return k, v


def gqa_decode(p, x: jax.Array, cfg: ModelConfig, k_cache, v_cache,
               length: jax.Array, *, window: Optional[int] = None):
    """One-token step.  x: (B, 1, d); caches (B, Hkv, T, hd).
    ``length`` is scalar or per-sequence (B,) — continuous batching admits
    requests mid-run, so every sequence carries its own position.
    Returns (out (B,1,d), new_k_cache, new_v_cache)."""
    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (x.shape[0],))
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bhsk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bhsk", x, p["wv"])
    if cfg.rope:
        cos, sin = rope_angles(length, cfg.resolved_head_dim, cfg.rope_theta)
        q = apply_rope(q, cos[:, None, None], sin[:, None, None])
        k = apply_rope(k, cos[:, None, None], sin[:, None, None])
    T = k_cache.shape[2]
    slot = length % T                      # ring for windowed layers
    upd = lambda c, u, s: jax.lax.dynamic_update_slice(c, u, (0, s, 0))
    k_cache = jax.vmap(upd)(k_cache, k.astype(k_cache.dtype), slot)
    v_cache = jax.vmap(upd)(v_cache, v.astype(v_cache.dtype), slot)
    if window is None:
        o = decode_attention_ref(q, k_cache, v_cache, length + 1)
    else:
        # ring cache: all T slots valid once full; positions are implicit
        valid = jnp.minimum(length + 1, T)
        o = decode_attention_ref(q, k_cache, v_cache, valid)
    out = jnp.einsum("bhsk,hkd->bsd", o, p["wo"])
    return out, k_cache, v_cache


# ---------------------------------------------------------------------------
# MLA (Multi-head Latent Attention, MiniCPM3/DeepSeek-style)
# ---------------------------------------------------------------------------

def mla_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, m, H = cfg.d_model, cfg.mla, cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wdq": ParamSpec((d, m.q_lora_rank), ("embed", "lora")),
        "q_norm": ParamSpec((m.q_lora_rank,), ("lora",), init="zeros"),
        "wuq": ParamSpec((m.q_lora_rank, H, qk), ("lora", "heads", "head_dim")),
        "wdkv": ParamSpec((d, m.kv_lora_rank + m.qk_rope_head_dim),
                          ("embed", "lora")),
        "kv_norm": ParamSpec((m.kv_lora_rank,), ("lora",), init="zeros"),
        "wuk": ParamSpec((m.kv_lora_rank, H, m.qk_nope_head_dim),
                         ("lora", "heads", "head_dim")),
        "wuv": ParamSpec((m.kv_lora_rank, H, m.v_head_dim),
                         ("lora", "heads", "head_dim")),
        "wo": ParamSpec((H, m.v_head_dim, d), ("heads", "head_dim", "embed")),
    }


def _mla_qkv(p, x, cfg, q_offset: int):
    m = cfg.mla
    B, S, _ = x.shape
    cq = rms_norm(x @ p["wdq"], p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bhsk", cq, p["wuq"])
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    ckv = x @ p["wdkv"]
    latent, k_rope = jnp.split(ckv, [m.kv_lora_rank], axis=-1)
    latent = rms_norm(latent, p["kv_norm"], cfg.norm_eps)
    pos = q_offset + jnp.arange(S)
    cos, sin = rope_angles(pos, m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos[None, None], sin[None, None])
    k_rope = apply_rope(k_rope, cos[None], sin[None])      # (B, S, rope_dim)
    return q_nope, q_rope, latent, k_rope


def mla_apply(p, x: jax.Array, cfg: ModelConfig, *, q_offset: int = 0,
              analysis: bool = False, batch_shard: bool = False) -> jax.Array:
    """Naive (expanded) MLA for train/prefill."""
    m = cfg.mla
    q_nope, q_rope, latent, k_rope = _mla_qkv(p, x, cfg, q_offset)
    k_nope = jnp.einsum("bsr,rhk->bhsk", latent, p["wuk"])
    v = jnp.einsum("bsr,rhk->bhsk", latent, p["wuv"])
    H = cfg.n_heads
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, None],
                                  (*k_nope.shape[:3], m.qk_rope_head_dim))],
        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    if batch_shard:
        q, k, v = batch_shard_constraint(q, k, v)
    if analysis:
        S_ = x.shape[1]
        o = flash_attention_ref(q, k, v, causal=True, q_offset=q_offset,
                                unroll=True, block_q=min(4096, S_),
                                block_k=min(4096, S_))
    else:
        o = flash_attention_ref(q, k, v, causal=True, q_offset=q_offset)
    return jnp.einsum("bhsk,hkd->bsd", o, p["wo"])


def mla_decode(p, x: jax.Array, cfg: ModelConfig, latent_cache, rope_cache,
               length: jax.Array):
    """Absorbed MLA decode: the cache holds only (latent, k_rope) —
    (B, T, r) and (B, T, rope_dim).  ``length`` is scalar or per-sequence
    (B,).  Score = q_nope·W_uk·latent + q_rope·k_rope."""
    m = cfg.mla
    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (x.shape[0],))
    cos, sin = rope_angles(length, m.qk_rope_head_dim, cfg.rope_theta)
    cq = rms_norm(x @ p["wdq"], p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bhsk", cq, p["wuq"])
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    ckv = x @ p["wdkv"]
    lat_t, k_rope_t = jnp.split(ckv, [m.kv_lora_rank], axis=-1)
    lat_t = rms_norm(lat_t, p["kv_norm"], cfg.norm_eps)
    q_rope = apply_rope(q_rope, cos[:, None, None], sin[:, None, None])
    k_rope_t = apply_rope(k_rope_t, cos[:, None], sin[:, None])

    upd = lambda c, u, s: jax.lax.dynamic_update_slice(c, u, (s, 0))
    latent_cache = jax.vmap(upd)(latent_cache,
                                 lat_t.astype(latent_cache.dtype), length)
    rope_cache = jax.vmap(upd)(rope_cache,
                               k_rope_t.astype(rope_cache.dtype), length)

    # absorbed attention
    q_eff = jnp.einsum("bhsk,rhk->bhsr", q_nope, p["wuk"])    # (B,H,1,r)
    s = (jnp.einsum("bhsr,btr->bhst", q_eff.astype(jnp.float32),
                    latent_cache.astype(jnp.float32))
         + jnp.einsum("bhsk,btk->bhst", q_rope.astype(jnp.float32),
                      rope_cache.astype(jnp.float32)))
    s = s / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    T = latent_cache.shape[1]
    mask = jnp.arange(T)[None] <= length[:, None]
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhst,btr->bhsr", pattn,
                       latent_cache.astype(jnp.float32))
    o = jnp.einsum("bhsr,rhk->bhsk", o_lat.astype(x.dtype), p["wuv"])
    out = jnp.einsum("bhsk,hkd->bsd", o, p["wo"])
    return out, latent_cache, rope_cache
