from .step import loss_fn, make_train_step, train_step

__all__ = ["loss_fn", "make_train_step", "train_step"]
