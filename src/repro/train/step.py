"""Training step: loss, gradient accumulation (microbatch scan), optional
int8 gradient compression, AdamW update — plus the pjit factory used by the
launcher and the multi-pod dry-run."""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..config import (ModelConfig, RunConfig, ShapeConfig,
                      resolve_run_config)
from ..core.policy import OperatingPoint, PolicyTable
from ..distributed.compression import compress_grads
from ..distributed.sharding import input_pspecs, param_pspecs
from ..models.model import forward
from ..optim import OptState, adamw_update

Pytree = Any

__all__ = ["loss_fn", "train_step", "make_train_step", "resolve_run_config"]


def loss_fn(params: Pytree, batch: Dict[str, jax.Array], cfg: ModelConfig,
            rc: RunConfig) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits = forward(params, batch, cfg, rc)           # compute dtype
    labels = batch["labels"]
    lg = logits.astype(jnp.float32)
    # sharded-vocab-friendly cross entropy: logsumexp reduces over the
    # (possibly model-sharded) vocab axis via psum of (B,S) partials, and the
    # label term is a masked sum — no all-gather of the logits, unlike
    # take_along_axis (EXPERIMENTS.md §Perf, phi3 hillclimb #2)
    lse = jax.scipy.special.logsumexp(lg, axis=-1)
    onehot = jax.nn.one_hot(labels, cfg.vocab, dtype=lg.dtype)
    true_logit = jnp.sum(lg * onehot, axis=-1)
    nll = lse - true_logit
    loss = nll.mean()
    acc = (lg.argmax(-1) == labels).mean()
    return loss, {"loss": loss, "accuracy": acc}


def _grads(params, batch, cfg, rc):
    if rc.microbatch and rc.microbatch > 1:
        mb = rc.microbatch
        B = batch["labels"].shape[0]
        assert B % mb == 0, "global batch must divide microbatch count"
        split = jax.tree_util.tree_map(
            lambda a: a.reshape(mb, B // mb, *a.shape[1:]), batch)

        def acc_step(carry, mbatch):
            g_acc, l_acc = carry
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mbatch, cfg, rc)
            g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
            return (g_acc, l_acc + loss), None

        g0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (g, loss_sum), _ = jax.lax.scan(acc_step, (g0, jnp.zeros(())), split)
        g = jax.tree_util.tree_map(lambda x: x / mb, g)
        return g, {"loss": loss_sum / mb}
    (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
        params, batch, cfg, rc)
    return g, metrics


def train_step(params: Pytree, opt: OptState, batch: Dict[str, jax.Array],
               cfg: ModelConfig, rc: RunConfig,
               rng: Optional[jax.Array] = None
               ) -> Tuple[Pytree, OptState, Dict[str, jax.Array]]:
    grads, metrics = _grads(params, batch, cfg, rc)
    if rc.grad_compression:
        key = rng if rng is not None else jax.random.PRNGKey(opt.step)
        grads = compress_grads(key, grads)
    params, opt, om = adamw_update(params, opt, grads, rc)
    return params, opt, {**metrics, **om}


def make_train_step(cfg: ModelConfig, shape: ShapeConfig, rc: RunConfig,
                    mesh: Mesh,
                    operating_point: Optional[OperatingPoint] = None,
                    policy_table: Optional[PolicyTable] = None):
    """Returns (jitted step, in/out shardings) for pjit execution and AOT
    lowering (the dry-run calls .lower on this).  The ``"train"`` workload's
    execution policy resolves through :func:`resolve_run_config` at factory
    time — calibrated when an artifact exists, default otherwise, pinned by
    an explicit ``operating_point``."""
    rc, _op = resolve_run_config(rc, "train", operating_point, policy_table)
    pspec = param_pspecs(cfg, mesh, rc)
    o_state = OptState(step=P(), mu=pspec, nu=pspec)
    in_batch = input_pspecs(cfg, shape, mesh)
    metrics = None  # replicated

    def ns(tree):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s, tree,
            is_leaf=lambda x: isinstance(x, P))

    step = jax.jit(
        partial(train_step, cfg=cfg, rc=rc),
        in_shardings=(ns(pspec), ns(o_state), ns(in_batch)),
        out_shardings=(ns(pspec), ns(o_state), None),
        donate_argnums=(0, 1),
    )
    return step, (pspec, o_state, in_batch)
