"""Calibration-front drift gate: recompute a pinned smoke-grid Pareto
front and fail when a code change silently moves the committed one.

The machine model is deterministic pure Python, so the Pareto front of a
pinned grid is a *golden artifact*: any cycles/energy drift means the
simulator's timing or energy semantics changed.  The committed baseline
(``benchmarks/data/front_baseline.json``) stores, per kernel, the full
(IPC, energy) front of :data:`PINNED_GRID` as config->metrics points; this
section recomputes the front and fails when

* a baseline front point disappeared or a new one appeared (the front
  *moved*), or
* a matching configuration's cycles differ at all, or its energy/IPC drift
  beyond :data:`REL_TOL` (float-repr headroom only).

A deliberate semantics change regenerates the baseline with::

    PYTHONPATH=src python -m benchmarks.front_diff --update

and the diff of ``front_baseline.json`` becomes part of the review — the
drift is visible in the PR instead of silently shipping inside a green CI.
"""
import json
import os
import sys
import time

from repro.core import grid, pareto_by_kernel, run_sweep

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE_PATH = os.path.join(ROOT, "benchmarks", "data",
                             "front_baseline.json")

#: the pinned grid: small enough for CI smoke, crossing every policy, the
#: depth axis, both unrolls, and a 2-core cluster row so the cluster path
#: is inside the drift gate too
PINNED_GRID = dict(kernels=["expf", "dequant_dot"],
                   queue_depths=(1, 2, 4), queue_latencies=(1,),
                   unrolls=(4, 8), n_samples=16, n_cores=(1, 2))

#: relative tolerance for float metrics (energy/IPC): generous only against
#: repr round-tripping — any real model change is far bigger
REL_TOL = 1e-9

#: keys identifying one configuration on the front
CONFIG_KEYS = ("kernel", "policy", "queue_depth", "queue_latency", "unroll",
               "n_cores", "tcdm_banks")
#: pinned metrics per configuration
METRIC_KEYS = ("cycles", "ipc", "energy")


def compute_fronts():
    recs = run_sweep(grid(**PINNED_GRID), workers=1)
    bad = [r for r in recs if not r.ok or not r.equivalent]
    if bad:
        raise AssertionError(
            f"{len(bad)} pinned-grid points failed to simulate cleanly, "
            f"e.g. {bad[0]}")
    fronts = {}
    for kernel, front in pareto_by_kernel(recs).items():
        fronts[kernel] = [
            {**{k: getattr(r, k) for k in CONFIG_KEYS},
             **{k: getattr(r, k) for k in METRIC_KEYS}}
            for r in front]
    return fronts


def _key(point):
    return tuple(point[k] for k in CONFIG_KEYS)


def _sortable(key):
    """Order keys whose optional slots (tcdm_banks) mix None with ints."""
    return tuple((v is None, "" if v is None else v) for v in key)


def _fmt(key):
    return ", ".join(f"{k}={v}" for k, v in zip(CONFIG_KEYS, key))


def diff_fronts(baseline, current):
    """Human-readable drift list (empty = gate passes)."""
    problems = []
    for kernel in sorted(set(baseline) | set(current)):
        if kernel not in current:
            problems.append(f"{kernel}: kernel missing from recomputed front")
            continue
        if kernel not in baseline:
            problems.append(f"{kernel}: kernel absent from the committed "
                            f"baseline (regenerate with --update)")
            continue
        base = {_key(p): p for p in baseline[kernel]}
        cur = {_key(p): p for p in current[kernel]}
        for k in sorted(base.keys() - cur.keys(), key=_sortable):
            problems.append(f"{kernel}: front point vanished ({_fmt(k)})")
        for k in sorted(cur.keys() - base.keys(), key=_sortable):
            problems.append(f"{kernel}: new front point appeared ({_fmt(k)})")
        for k in sorted(base.keys() & cur.keys(), key=_sortable):
            b, c = base[k], cur[k]
            if b["cycles"] != c["cycles"]:
                problems.append(
                    f"{kernel}: cycles moved {b['cycles']} -> {c['cycles']} "
                    f"({_fmt(k)})")
            for m in ("ipc", "energy"):
                ref = abs(b[m]) or 1.0
                if abs(b[m] - c[m]) / ref > REL_TOL:
                    problems.append(
                        f"{kernel}: {m} drifted {b[m]!r} -> {c[m]!r} "
                        f"({_fmt(k)})")
    return problems


def run():
    t0 = time.time()
    current = compute_fronts()
    if not os.path.exists(BASELINE_PATH):
        raise AssertionError(
            f"no committed front baseline at {BASELINE_PATH}; generate one "
            f"with: PYTHONPATH=src python -m benchmarks.front_diff --update")
    with open(BASELINE_PATH) as f:
        baseline = json.load(f)["fronts"]
    problems = diff_fronts(baseline, current)
    if problems:
        raise AssertionError(
            "the committed Pareto front moved:\n  " + "\n  ".join(problems)
            + "\nIf the semantics change is deliberate, regenerate with: "
              "PYTHONPATH=src python -m benchmarks.front_diff --update "
              "and include the baseline diff in the PR")
    us = (time.time() - t0) * 1e6
    rows = [(f"front_diff_{kernel}_points", us, float(len(front)))
            for kernel, front in sorted(current.items())]
    rows.append(("front_diff_drift_findings", us, 0.0))
    return rows


def update_baseline():
    os.makedirs(os.path.dirname(BASELINE_PATH), exist_ok=True)
    payload = {"grid": {k: (list(v) if isinstance(v, (tuple, list)) else v)
                        for k, v in PINNED_GRID.items()},
               "rel_tol": REL_TOL,
               "fronts": compute_fronts()}
    with open(BASELINE_PATH, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {BASELINE_PATH}")


def main():
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived:.4f}")


#: the gate is already CI-sized; smoke runs the identical pinned grid
smoke = main


if __name__ == "__main__":
    if "--update" in sys.argv[1:]:
        update_baseline()
    else:
        main()
