"""Batch-engine scale gate: the PR-7 acceptance benchmark for the
vectorized sweep pipeline and the front-guided adaptive search.

Two sections, both written into ``artifacts/BENCH_sweep_scale.json``:

* **throughput** — the 2880-point asymmetric-geometry grid (every
  single-core kernel x {copift, copiftv2} x the full depth axis x
  high-visibility latencies x the i2f/f2i depth-override axes) through the
  PR-2 event engine and the batched max-recurrence engine, serially, warm
  (``*_cached``) and cold (``*_uncached``).  The gate is
  ``speedup_cached >= SPEEDUP_GATE`` (>=10x points/sec): warm-cache mode is
  the steady-state of any real sweep — every rung after the first, every
  repeat of a calibration grid — and is the regime the batch engine exists
  for.  Cold rates are reported (not gated): a cold pass is dominated by
  lowering, which both engines share.  The warm passes also re-check the
  PR-7 bit-identity contract end to end: the batch sweep's records must
  equal the event sweep's on every point (minus the ``engine`` column).

* **adaptive** — a 103,680-point grid (the throughput axes widened to ten
  depths, eight latencies, and three unrolls) run through
  ``adaptive_sweep`` at the default fidelity ladder, then checked against
  an exhaustive run of a 5184-point differential slice (every
  ``SLICE_STRIDE``-th grid point): the slice is a subset of the full grid,
  so the full grid's Pareto fronts dominate the slice's, and the adaptive
  fronts must therefore cover the slice's exhaustive fronts within the
  search's own dominance tolerance.  Failing either direction of that
  cover means the pruning rule dropped a front-defining point.

``--smoke`` shrinks both sections to CI scale (a 32-point throughput grid
and a 256-point adaptive grid) and drops the speedup gate — tiny grids
measure fork/alloc noise, not engine throughput — while keeping every
correctness assertion; it writes ``BENCH_sweep_scale_smoke.json`` so the
committed full-run artifact is never clobbered by CI.
"""
import argparse
import dataclasses
import gc
import json
import os
import time

from repro.core import (ExecutionPolicy, front_matches, grid,
                        pareto_by_kernel, run_sweep)
from repro.core.search import DEFAULT_TOLERANCE, adaptive_sweep
from repro.core.sweep import clear_worker_caches

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(ROOT, "artifacts", "BENCH_sweep_scale.json")
SMOKE_OUT_PATH = os.path.join(ROOT, "artifacts",
                              "BENCH_sweep_scale_smoke.json")

#: acceptance threshold: warm-cache batch engine vs warm-cache event engine
SPEEDUP_GATE = 10.0

#: every single-core kernel; cluster_matmul needs n_cores >= 2 and the
#: batch engine delegates clustered points anyway
SINGLE_CORE_KERNELS = ("box_muller", "dequant_dot", "expf", "histf", "logf",
                       "poly_lcg")
POLICIES = (ExecutionPolicy.COPIFT, ExecutionPolicy.COPIFTV2)

#: the 2880-point gate grid: 6 kernels x 2 policies x 5 depths x 2 lats
#: x 4 i2f x 6 f2i asymmetric geometries at the full sample count
THROUGHPUT_GRID = dict(kernels=SINGLE_CORE_KERNELS, policies=POLICIES,
                       queue_depths=(1, 2, 4, 8, 16), queue_latencies=(4, 8),
                       unrolls=(8,), i2f_depths=(None, 2, 8, 16),
                       f2i_depths=(None, 1, 2, 4, 8, 16), n_samples=128)

#: the >=100k adaptive demonstration grid:
#: 6 kernels x 2 policies x 10 depths x 8 latencies x 3 unrolls x 6 i2f
#: x 6 f2i = 103,680 points
ADAPTIVE_GRID = dict(kernels=SINGLE_CORE_KERNELS, policies=POLICIES,
                     queue_depths=(1, 2, 3, 4, 5, 6, 8, 10, 12, 16),
                     queue_latencies=(1, 2, 3, 4, 5, 6, 7, 8),
                     unrolls=(2, 4, 8),
                     i2f_depths=(None, 1, 2, 4, 8, 16),
                     f2i_depths=(None, 1, 2, 4, 8, 16), n_samples=128)

#: every SLICE_STRIDE-th adaptive-grid point forms the differential slice
#: that also runs exhaustively (103680 / 20 = 5184 points)
SLICE_STRIDE = 20

SMOKE_THROUGHPUT_GRID = dict(kernels=("expf", "histf"), policies=POLICIES,
                             queue_depths=(1, 4), queue_latencies=(4, 8),
                             i2f_depths=(None, 2), n_samples=32)
SMOKE_ADAPTIVE_GRID = dict(kernels=("expf", "histf"), policies=POLICIES,
                           queue_depths=(1, 2, 4, 8),
                           queue_latencies=(1, 4), unrolls=(4, 8),
                           i2f_depths=(None, 2), f2i_depths=(None, 2),
                           n_samples=64)
SMOKE_SLICE_STRIDE = 3

#: timed repetitions per warm mode; best run wins (same hygiene as
#: benchmarks/sweep_perf.py — the slow repeats measure scheduler noise)
REPEATS = 3


def _jsonable_grid(grid_kw):
    def conv(v):
        if isinstance(v, (tuple, list)):
            return [x.value if isinstance(x, ExecutionPolicy) else x
                    for x in v]
        return v
    return {k: conv(v) for k, v in grid_kw.items()}


def _timed_sweep(points, *, cold):
    """One serial sweep pass under a paused GC: (wall seconds, records)."""
    if cold:
        clear_worker_caches()
    gc.collect()
    gc.disable()
    try:
        t0 = time.perf_counter()
        recs = run_sweep(points, workers=1)
        dt = time.perf_counter() - t0
    finally:
        gc.enable()
    return dt, recs


def _strip_engine(rec):
    d = dataclasses.asdict(rec)
    d.pop("engine")
    return d


def measure_throughput(grid_kw, repeats=REPEATS):
    """Warm + cold points/sec for the event and batch engines on one grid,
    with a full record-level batch-vs-event differential on the warm pass."""
    pts_event = grid(engine="event", **grid_kw)
    pts_batch = [dataclasses.replace(p, engine="batch") for p in pts_event]
    modes = {}
    warm_recs = {}
    for name, pts in (("event", pts_event), ("batch", pts_batch)):
        cold_s, recs = _timed_sweep(pts, cold=True)
        warm_best = None
        for _ in range(repeats):
            warm_s, recs = _timed_sweep(pts, cold=False)
            warm_best = warm_s if warm_best is None else min(warm_best,
                                                             warm_s)
        warm_recs[name] = recs
        bad = [r for r in recs if r.status == "deadlock"
               or (r.ok and (not r.equivalent or r.fifo_violations))]
        if bad:
            raise AssertionError(
                f"{name}: {len(bad)} points deadlocked or diverged from "
                f"the interpreter, e.g. {bad[0]}")
        n = len(pts)
        modes[f"{name}_uncached"] = dict(
            engine=name, cached=False, points=n, wall_s=round(cold_s, 4),
            points_per_sec=round(n / cold_s, 3))
        modes[f"{name}_cached"] = dict(
            engine=name, cached=True, points=n, wall_s=round(warm_best, 4),
            points_per_sec=round(n / warm_best, 3))
    mismatch = [i for i, (a, b) in
                enumerate(zip(warm_recs["event"], warm_recs["batch"]))
                if _strip_engine(a) != _strip_engine(b)]
    if mismatch:
        raise AssertionError(
            f"batch engine diverged from the event engine on "
            f"{len(mismatch)}/{len(pts_event)} records, first at index "
            f"{mismatch[0]}: {warm_recs['batch'][mismatch[0]]}")
    result = {"grid": _jsonable_grid(grid_kw), "n_points": len(pts_event),
              "modes": modes, "records_identical": True}
    for kind in ("cached", "uncached"):
        result[f"speedup_{kind}"] = round(
            modes[f"batch_{kind}"]["points_per_sec"]
            / modes[f"event_{kind}"]["points_per_sec"], 3)
    return result


def measure_adaptive(grid_kw, slice_stride, tolerance=DEFAULT_TOLERANCE):
    """Time ``adaptive_sweep`` over the full grid, then verify its fronts
    cover the exhaustive fronts of the every-``slice_stride``-th-point
    differential slice within the search tolerance."""
    points = grid(engine="batch", **grid_kw)
    clear_worker_caches()
    t0 = time.perf_counter()
    recs, meta = adaptive_sweep(points, workers=1, tolerance=tolerance)
    wall_s = time.perf_counter() - t0

    sliced = points[::slice_stride]
    ref = run_sweep(sliced, workers=1)
    ref_fronts = pareto_by_kernel(ref)
    got_fronts = pareto_by_kernel(recs)
    fronts = {}
    failures = []
    for kernel, ref_front in sorted(ref_fronts.items()):
        ok, slack = front_matches(got_fronts.get(kernel, []), ref_front,
                                  tolerance=tolerance)
        fronts[kernel] = dict(ok=ok, slack=round(slack, 6),
                              ref_front=len(ref_front),
                              adaptive_front=len(got_fronts.get(kernel, [])))
        if not ok:
            failures.append(kernel)
    if failures:
        raise AssertionError(
            f"adaptive fronts fail to cover the exhaustive slice fronts "
            f"within tolerance {tolerance}: {failures} ({fronts})")
    return {"grid": _jsonable_grid(grid_kw), "n_points": len(points),
            "wall_s": round(wall_s, 4),
            "points_per_sec": round(len(points) / wall_s, 3),
            "search": meta,
            "slice": {"stride": slice_stride, "n_points": len(sliced),
                      "tolerance": tolerance, "fronts": fronts}}


def run(*, throughput_grid=None, adaptive_grid=None, slice_stride=None,
        repeats=REPEATS, gate=True, out_path=OUT_PATH):
    throughput = measure_throughput(throughput_grid or THROUGHPUT_GRID,
                                    repeats=repeats)
    if gate and throughput["speedup_cached"] < SPEEDUP_GATE:
        raise AssertionError(
            f"batch engine speedup gate: {throughput['speedup_cached']}x "
            f"cached < required {SPEEDUP_GATE}x")
    adaptive = measure_adaptive(adaptive_grid or ADAPTIVE_GRID,
                                slice_stride or SLICE_STRIDE)
    if gate and adaptive["n_points"] < 100_000:
        raise AssertionError(
            f"adaptive demonstration grid shrank below the 100k-point "
            f"contract: {adaptive['n_points']}")
    result = {"speedup_gate": SPEEDUP_GATE if gate else None,
              "throughput": throughput, "adaptive": adaptive}
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)

    rows = []
    for name in sorted(throughput["modes"]):
        m = throughput["modes"][name]
        rows.append((f"sweep_scale_{name}_points_per_sec",
                     1e6 / m["points_per_sec"], m["points_per_sec"]))
    for kind in ("cached", "uncached"):
        rows.append((f"sweep_scale_speedup_{kind}", 0.0,
                     throughput[f"speedup_{kind}"]))
    rows.append(("sweep_scale_adaptive_points_per_sec",
                 1e6 / adaptive["points_per_sec"],
                 adaptive["points_per_sec"]))
    rows.append(("sweep_scale_adaptive_full_fidelity_frac", 0.0,
                 adaptive["search"]["n_full_fidelity"]
                 / adaptive["n_points"]))
    return rows, out_path


def main():
    rows, out_path = run()
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived:.4f}")
    print(f"# wrote {out_path}")


def smoke():
    """CI-scale grids, no speedup gate (tiny grids measure noise, not the
    engine), every correctness assertion kept, separate artifact name."""
    rows, out_path = run(throughput_grid=SMOKE_THROUGHPUT_GRID,
                         adaptive_grid=SMOKE_ADAPTIVE_GRID,
                         slice_stride=SMOKE_SLICE_STRIDE, repeats=1,
                         gate=False, out_path=SMOKE_OUT_PATH)
    if not rows:
        raise AssertionError("sweep_scale smoke produced no rows")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived:.4f}")
    print(f"# wrote {out_path}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-scale grids, no speedup gate")
    args = ap.parse_args()
    smoke() if args.smoke else main()
