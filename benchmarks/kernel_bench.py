"""Kernel micro-benchmarks (interpret mode on CPU: correctness-coupled
relative timings; the queue-depth sweep is the kernel-level COPIFT-vs-v2
experiment — on real TPU depth>=2 overlaps DMA with the MXU)."""
import time

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention, moe_gemm, queue_matmul, ssm_scan
from repro.kernels.queue_matmul.ref import matmul_ref


def _time(fn, *args, reps=3):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (256, 512), jnp.float32)
    w = jax.random.normal(key, (512, 256), jnp.float32)
    base = _time(lambda a, b: matmul_ref(a, b), x, w)
    rows.append(("kernel_matmul_xla_ref", base, 1.0))
    for depth in (1, 2, 4):
        us = _time(lambda a, b, d=depth: queue_matmul(a, b, depth=d), x, w)
        rows.append((f"kernel_queue_matmul_depth{depth}", us, us / base))

    q = jax.random.normal(key, (1, 4, 512, 64))
    k = jax.random.normal(key, (1, 4, 512, 64))
    v = jax.random.normal(key, (1, 4, 512, 64))
    us = _time(lambda a, b, c: flash_attention(a, b, c, causal=True), q, k, v)
    rows.append(("kernel_flash_attention_512", us, 0.0))

    xs = jax.random.normal(key, (1, 256, 128)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(key, (1, 256, 128))) * 0.1
    A = -jnp.abs(jax.random.normal(key, (128, 16)))
    Bm = jax.random.normal(key, (1, 256, 16))
    C = jax.random.normal(key, (1, 256, 16))
    us = _time(lambda *a: ssm_scan(*a), xs, dt, A, Bm, C)
    rows.append(("kernel_ssm_scan_256x128", us, 0.0))

    xe = jax.random.normal(key, (4, 128, 256))
    we = jax.random.normal(key, (4, 256, 128))
    for depth in (1, 2):
        us = _time(lambda a, b, d=depth: moe_gemm(a, b, depth=d), xe, we)
        rows.append((f"kernel_moe_gemm_depth{depth}", us, 0.0))
    return rows


def main():
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived:.4f}")


if __name__ == "__main__":
    main()
