"""Regenerate the EXPERIMENTS.md §Tables block from dry-run artifacts.

Usage: PYTHONPATH=src python -m benchmarks.report   (rewrites everything
after the '## §Tables' marker in EXPERIMENTS.md)."""
import glob
import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..")
ART = os.path.join(ROOT, "artifacts")
MARKER = "## §Tables"


def load(dirname, variant, mesh="pod16x16"):
    cells = {}
    for p in sorted(glob.glob(os.path.join(
            ART, dirname, f"*_{mesh}_*_{variant}.json"))):
        with open(p) as f:
            a = json.load(f)
        cells[(a["arch"], a["shape"])] = a
    return cells


def render() -> str:
    base = load("dryrun_baseline", "analysis")
    opt = load("dryrun", "analysis")
    dep = load("dryrun", "deploy")
    dep2 = load("dryrun", "deploy", "pod2x16x16")

    L = [MARKER, "", "Regenerate with `python -m benchmarks.report`.", ""]
    L += ["### Roofline — optimized (current code), analysis variant, 256 chips",
          "",
          "| arch | shape | t_compute | t_memory | t_collective | bound "
          "| useful | MFU | step vs baseline |",
          "|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape), a in sorted(opt.items()):
        r = a["roofline"]
        b = base.get((arch, shape), {}).get("roofline", {})
        gain = (b.get("step_time", 0) / r["step_time"]) if r["step_time"] else 0
        L.append(f"| {arch} | {shape} | {r['t_compute']:.2e} | "
                 f"{r['t_memory']:.2e} | {r['t_collective']:.2e} | "
                 f"{r['bottleneck']} | {r['useful_flops_ratio']:.3f} | "
                 f"{r['mfu']:.4f} | {gain:.1f}× |")

    L += ["", "### Roofline — paper-faithful baseline "
          "(artifacts/dryrun_baseline)", "",
          "| arch | shape | t_compute | t_memory | t_collective | bound | MFU |",
          "|---|---|---|---|---|---|---|"]
    for (arch, shape), a in sorted(base.items()):
        r = a["roofline"]
        L.append(f"| {arch} | {shape} | {r['t_compute']:.2e} | "
                 f"{r['t_memory']:.2e} | {r['t_collective']:.2e} | "
                 f"{r['bottleneck']} | {r['mfu']:.4f} |")

    L += ["", "### Dry-run — deployable lowering: compile gate + per-device state",
          "",
          "All cells lower + compile on both meshes.  `state` = exact analytic",
          "per-device persistent bytes (params + optimizer + caches) from the",
          "real leaf shardings; v5e HBM = 16 GB.  (XLA:CPU `memory_analysis`",
          "logical-buffer bytes are also recorded in the artifacts but do not",
          "map 1:1 to per-device TPU HBM.)", "",
          "| arch | shape | state GB @256 | state GB @512 "
          "| collective GB/dev @256 (AR/AG/RS/A2A/CP) |",
          "|---|---|---|---|---|"]
    for (arch, shape), a in sorted(dep.items()):
        g = a.get("analytic_device_gb", {}).get("total_gb", float("nan"))
        g2 = dep2.get((arch, shape), {}).get(
            "analytic_device_gb", {}).get("total_gb", float("nan"))
        c = a["collectives"]
        cs = "/".join(f"{c.get(k, 0)/1e9:.1f}" for k in
                      ("all-reduce", "all-gather", "reduce-scatter",
                       "all-to-all", "collective-permute"))
        flag = " ⚠" if g > 16 else ""
        L.append(f"| {arch} | {shape} | {g:.2f}{flag} | {g2:.2f} | {cs} |")
    L += ["", "⚠ nemotron-4-340b train at 256 chips: fp32 params + Adam of a "
          "341B model is ~21 GB/chip even fully sharded over all 256 devices "
          "— the 512-chip mesh brings it under 16 GB (capacity finding; the "
          "256-chip lowering still partitions and compiles).", ""]
    return "\n".join(L)


def main() -> None:
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(path) as f:
        text = f.read()
    idx = text.find(MARKER)
    if idx < 0:
        text = text + "\n" + render()
    else:
        text = text[:idx] + render()
    with open(path, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md §Tables regenerated")


if __name__ == "__main__":
    main()
