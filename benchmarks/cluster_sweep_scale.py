"""Cluster-batch-engine scale gate: the PR-8 acceptance benchmark for the
vectorized lockstep cluster engine (``core.batch_cluster``).

One headline section plus a reported-only slice, both written into
``artifacts/BENCH_cluster_sweep_scale.json``:

* **throughput** — a 1128-point cluster/pipeline grid over
  ``n_cores in {2, 4, 8}``: three work-partitioned kernels under the
  depth-insensitive policies across the full depth x visibility-latency
  plane, a COPIFTv2 slice, and the pipelined ``cluster_matmul``
  producer/consumer points across the channel-FIFO x DMA-buffering plane —
  through the scalar event ``ClusterStepper`` path and the batched
  max-recurrence cluster engine, serially, warm (``*_cached``) and cold
  (``*_uncached``).  The gate is ``speedup_cached >= SPEEDUP_GATE`` (>=8x
  points/sec): warm-cache mode is the steady-state of any real sweep, and
  the speedup scales with the number of configurations sharing one
  partitioned program set (the grid keeps >=8 runtime configs per group,
  32 for most).  The warm passes also re-check the PR-8 bit-identity
  contract end to end: the batch sweep's records must equal the event
  sweep's on every point (minus the ``engine`` column).

* **banked** — a small finite-bank slice, reported but *not* gated: heavy
  TCDM contention trips the zero-contention oracle and delegates to the
  scalar engine by design (soundness over speed), so its speedup is
  expected to hover near 1x.  The record-level equality assertion still
  applies — delegation must be invisible in the results.

``--smoke`` shrinks the grids to CI scale and drops the speedup gate —
tiny grids measure fork/alloc noise, not engine throughput — while keeping
every correctness assertion; it writes
``BENCH_cluster_sweep_scale_smoke.json`` so the committed full-run
artifact is never clobbered by CI.
"""
import argparse
import dataclasses
import gc
import json
import os
import time

from repro.core import ExecutionPolicy, grid, run_sweep
from repro.core.sweep import clear_worker_caches

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(ROOT, "artifacts", "BENCH_cluster_sweep_scale.json")
SMOKE_OUT_PATH = os.path.join(ROOT, "artifacts",
                              "BENCH_cluster_sweep_scale_smoke.json")

#: acceptance threshold: warm-cache batch cluster engine vs the warm-cache
#: scalar event ClusterStepper path
SPEEDUP_GATE = 8.0

CORES = (2, 4, 8)
#: runtime axis shared by every sub-grid: queue-visibility latency never
#: shapes the lowered schedule, so it widens each batch group for free
QLATS = (1, 2, 3, 4, 5, 6, 8, 10)

#: work-partitioned sub-grid, depth-insensitive policies: queue depth does
#: not shape their lowering either, so one partitioned program set serves
#: the whole depth x latency plane (48 configs per group) —
#: 3 kernels x 2 policies x 6 depths x 8 lats x 3 core counts = 864 points
WORK_DI_GRID = dict(kernels=("poly_lcg", "histf", "dequant_dot"),
                    policies=(ExecutionPolicy.BASELINE,
                              ExecutionPolicy.COPIFT),
                    queue_depths=(1, 2, 3, 4, 6, 8), queue_latencies=QLATS,
                    unrolls=(4,), n_cores=CORES, n_samples=64)

#: COPIFTv2 slice: depth shapes the schedule, so each group only spans the
#: latency axis (8 configs) — 3 kernels x 8 lats x 3 core counts = 72 points
WORK_V2_GRID = dict(kernels=("poly_lcg", "histf", "dequant_dot"),
                    policies=(ExecutionPolicy.COPIFTV2,),
                    queue_depths=(4,), queue_latencies=QLATS,
                    unrolls=(4,), n_cores=CORES, n_samples=64)

#: pipelined producer/consumer sub-grid: channel depth and visibility are
#: runtime fabric properties (32 configs per group), DMA buffering shapes
#: the schedule — 8 lats x 4 cq depths x 3 core counts x 2 bufferings = 192
PIPE_GRID = dict(kernels=("cluster_matmul",),
                 policies=(ExecutionPolicy.COPIFTV2,),
                 queue_depths=(4,), queue_latencies=QLATS, unrolls=(8,),
                 n_cores=CORES, pipelines=(True,), cq_depths=(2, 4, 8, 16),
                 dma_buffers=(1, 2), n_samples=64)

GATE_GRIDS = (WORK_DI_GRID, WORK_V2_GRID, PIPE_GRID)

#: finite-bank contention slice, reported only: the zero-contention oracle
#: delegates conflicting points to the scalar engine, so this measures the
#: delegation overhead, not the lockstep engine
BANKED_GRID = dict(kernels=("histf",),
                   policies=(ExecutionPolicy.COPIFTV2,),
                   queue_depths=(4,), queue_latencies=(1, 2),
                   unrolls=(4,), n_cores=(2, 4), tcdm_banks=(8, 16),
                   n_samples=64)

SMOKE_GATE_GRIDS = (
    dict(kernels=("poly_lcg",), policies=(ExecutionPolicy.COPIFT,),
         queue_depths=(2, 4), queue_latencies=(1, 2), unrolls=(4,),
         n_cores=(2,), n_samples=32),
    dict(kernels=("cluster_matmul",), policies=(ExecutionPolicy.COPIFTV2,),
         queue_depths=(4,), queue_latencies=(1, 2), unrolls=(8,),
         n_cores=(2,), pipelines=(True,), cq_depths=(2, 4), n_samples=64),
)
SMOKE_BANKED_GRID = dict(kernels=("histf",),
                         policies=(ExecutionPolicy.COPIFTV2,),
                         queue_depths=(4,), queue_latencies=(1,),
                         unrolls=(4,), n_cores=(2,), tcdm_banks=(8,),
                         n_samples=32)

#: timed repetitions per warm mode; best run wins (same hygiene as
#: benchmarks/sweep_scale.py — the slow repeats measure scheduler noise)
REPEATS = 3


def _jsonable_grid(grid_kw):
    def conv(v):
        if isinstance(v, (tuple, list)):
            return [x.value if isinstance(x, ExecutionPolicy) else x
                    for x in v]
        return v
    return {k: conv(v) for k, v in grid_kw.items()}


def _points(grids):
    pts = []
    for grid_kw in grids:
        pts.extend(grid(engine="event", **grid_kw))
    return pts


def _timed_sweep(points, *, cold):
    """One serial sweep pass under a paused GC: (wall seconds, records)."""
    if cold:
        clear_worker_caches()
    gc.collect()
    gc.disable()
    try:
        t0 = time.perf_counter()
        recs = run_sweep(points, workers=1)
        dt = time.perf_counter() - t0
    finally:
        gc.enable()
    return dt, recs


def _strip_engine(rec):
    d = dataclasses.asdict(rec)
    d.pop("engine")
    return d


def measure_throughput(grids, repeats=REPEATS):
    """Warm + cold points/sec for the scalar-cluster and batch-cluster
    paths on one grid set, with a full record-level batch-vs-event
    differential on the warm pass."""
    pts_event = _points(grids)
    pts_batch = [dataclasses.replace(p, engine="batch") for p in pts_event]
    modes = {}
    warm_recs = {}
    for name, pts in (("event", pts_event), ("batch", pts_batch)):
        cold_s, recs = _timed_sweep(pts, cold=True)
        warm_best = None
        for _ in range(repeats):
            warm_s, recs = _timed_sweep(pts, cold=False)
            warm_best = warm_s if warm_best is None else min(warm_best,
                                                             warm_s)
        warm_recs[name] = recs
        bad = [r for r in recs if r.status == "deadlock"
               or (r.ok and (not r.equivalent or r.fifo_violations))]
        if bad:
            raise AssertionError(
                f"{name}: {len(bad)} points deadlocked or diverged from "
                f"the interpreter, e.g. {bad[0]}")
        n = len(pts)
        modes[f"{name}_uncached"] = dict(
            engine=name, cached=False, points=n, wall_s=round(cold_s, 4),
            points_per_sec=round(n / cold_s, 3))
        modes[f"{name}_cached"] = dict(
            engine=name, cached=True, points=n, wall_s=round(warm_best, 4),
            points_per_sec=round(n / warm_best, 3))
    mismatch = [i for i, (a, b) in
                enumerate(zip(warm_recs["event"], warm_recs["batch"]))
                if _strip_engine(a) != _strip_engine(b)]
    if mismatch:
        raise AssertionError(
            f"batch cluster engine diverged from the event engine on "
            f"{len(mismatch)}/{len(pts_event)} records, first at index "
            f"{mismatch[0]}: {warm_recs['batch'][mismatch[0]]}")
    n_cl = sum(1 for p in pts_event if p.clustered)
    result = {"grids": [_jsonable_grid(g) for g in grids],
              "n_points": len(pts_event), "n_clustered": n_cl,
              "core_counts": sorted({p.n_cores for p in pts_event}),
              "modes": modes, "records_identical": True}
    for kind in ("cached", "uncached"):
        result[f"speedup_{kind}"] = round(
            modes[f"batch_{kind}"]["points_per_sec"]
            / modes[f"event_{kind}"]["points_per_sec"], 3)
    return result


def run(*, gate_grids=GATE_GRIDS, banked_grid=BANKED_GRID, repeats=REPEATS,
        gate=True, out_path=OUT_PATH):
    throughput = measure_throughput(gate_grids, repeats=repeats)
    if gate and throughput["n_points"] < 1000:
        raise AssertionError(
            f"gate grid shrank below the 1000-point contract: "
            f"{throughput['n_points']}")
    if gate and throughput["speedup_cached"] < SPEEDUP_GATE:
        raise AssertionError(
            f"batch cluster engine speedup gate: "
            f"{throughput['speedup_cached']}x cached < required "
            f"{SPEEDUP_GATE}x")
    banked = measure_throughput([banked_grid], repeats=repeats)
    result = {"speedup_gate": SPEEDUP_GATE if gate else None,
              "throughput": throughput, "banked": banked}
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)

    rows = []
    for section, res in (("", throughput), ("banked_", banked)):
        for name in sorted(res["modes"]):
            m = res["modes"][name]
            rows.append((f"cluster_sweep_scale_{section}{name}"
                         f"_points_per_sec",
                         1e6 / m["points_per_sec"], m["points_per_sec"]))
        for kind in ("cached", "uncached"):
            rows.append((f"cluster_sweep_scale_{section}speedup_{kind}",
                         0.0, res[f"speedup_{kind}"]))
    return rows, out_path


def main():
    rows, out_path = run()
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived:.4f}")
    print(f"# wrote {out_path}")


def smoke():
    """CI-scale grids, no speedup gate (tiny grids measure noise, not the
    engine), every correctness assertion kept, separate artifact name."""
    rows, out_path = run(gate_grids=SMOKE_GATE_GRIDS,
                         banked_grid=SMOKE_BANKED_GRID, repeats=1,
                         gate=False, out_path=SMOKE_OUT_PATH)
    if not rows:
        raise AssertionError("cluster_sweep_scale smoke produced no rows")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived:.4f}")
    print(f"# wrote {out_path}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-scale grids, no speedup gate")
    args = ap.parse_args()
    smoke() if args.smoke else main()
