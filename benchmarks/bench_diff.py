"""Bench-drift gate: compare the committed ``artifacts/BENCH_*.json``
headline metrics against freshly recomputed values and fail CI when a code
change silently moves them.

Two classes of check, matched to how reproducible each metric is:

* **exact compares** — metrics that are pure functions of the committed
  code (virtual-time simulation, pinned seeds, no wall clock):

  - ``BENCH_serve_slo.json`` is regenerated end-to-end (full config, same
    pinned traces) and deep-compared field-for-field: cycles-equivalent
    totals, SLO attainment, straggler weights — everything.  Any diff means
    the serving semantics changed.
  - ``BENCH_cluster.json``'s strong-scaling points are recomputed via
    ``run_point`` and compared (cycles exactly, derived floats within
    :data:`REL_TOL`), including the headline 1->4-core speedup.

* **floor checks** — metrics that embed wall-clock throughput (sweep-engine
  points/sec ratios, the live engine's chunked-prefill TTFT gains in
  ``BENCH_serve_prefill.json``) cannot be exactly reproduced on a different
  machine, so the committed values are only checked against static floors:
  the gate catches a regression that slipped into a committed artifact, not
  machine noise.  ``BENCH_serve_prefill.json`` additionally must assert
  bit-exactness (its ``headline.bit_exact`` flag) and a bounded chunk-jit
  cache.

A per-metric delta table prints to stdout and, when ``$GITHUB_STEP_SUMMARY``
is set, is appended there so the drift is visible on the job page without
opening logs.  Any failed row exits non-zero.

A deliberate semantics change regenerates the exact-compare baselines::

    PYTHONPATH=src python -m benchmarks.bench_diff --update

(this rewrites ``BENCH_serve_slo.json`` and ``BENCH_cluster.json`` in
place; the artifact diff becomes part of the PR review).  The floor-checked
artifacts are refreshed by their own sections (``benchmarks.sweep_perf``,
``benchmarks.sweep_scale``, ``benchmarks.cluster_sweep_scale``).
"""
import json
import os
import sys
import tempfile
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ART = os.path.join(ROOT, "artifacts")

#: relative tolerance for recomputed floats: generous only against float
#: repr round-tripping — any real model change is far bigger
REL_TOL = 1e-9

#: static floors for wall-clock-dependent committed metrics:
#: (artifact, key path, floor, what the metric is)
FLOORS = (
    ("BENCH_sweep.json", ("speedup_event_cached",), 2.0,
     "event engine cached-sweep speedup over uncached cycle engine"),
    ("BENCH_sweep_scale.json", ("throughput", "speedup_cached"), 10.0,
     "batch engine cached 2880-pt sweep speedup"),
    ("BENCH_cluster_sweep_scale.json", ("throughput", "speedup_cached"),
     8.0, "batch cluster engine cached 1128-pt sweep speedup"),
)

#: strong-scaling point fields compared exactly vs within :data:`REL_TOL`
_EXACT_FIELDS = ("n_cores", "tcdm_banks", "cycles", "bank_stalls")
_FLOAT_FIELDS = ("throughput", "speedup", "ipc", "ipc_per_core",
                 "energy_per_sample")


def _load(name):
    path = os.path.join(ART, name)
    if not os.path.exists(path):
        raise AssertionError(
            f"committed baseline artifacts/{name} is missing; regenerate "
            f"it (see --update / the owning benchmark section) and commit")
    with open(path) as f:
        return json.load(f)


def _close(a, b):
    if isinstance(a, float) or isinstance(b, float):
        ref = max(abs(a), abs(b), 1.0)
        return abs(a - b) / ref <= REL_TOL
    return a == b


def _deep_diff(base, cur, path, problems):
    """Structural + value diff; floats within REL_TOL, all else exact."""
    if isinstance(base, dict) and isinstance(cur, dict):
        for k in sorted(set(base) | set(cur)):
            if k not in cur:
                problems.append(f"{path}.{k}: vanished from recomputation")
            elif k not in base:
                problems.append(f"{path}.{k}: new field not in baseline")
            else:
                _deep_diff(base[k], cur[k], f"{path}.{k}", problems)
    elif isinstance(base, list) and isinstance(cur, list):
        if len(base) != len(cur):
            problems.append(
                f"{path}: length {len(base)} -> {len(cur)}")
        else:
            for i, (b, c) in enumerate(zip(base, cur)):
                _deep_diff(b, c, f"{path}[{i}]", problems)
    elif isinstance(base, (int, float)) and isinstance(cur, (int, float)) \
            and not isinstance(base, bool) and not isinstance(cur, bool):
        if not _close(base, cur):
            problems.append(f"{path}: {base!r} -> {cur!r}")
    elif base != cur:
        problems.append(f"{path}: {base!r} -> {cur!r}")


def _row(metric, baseline, current, check, ok):
    delta = (current - baseline
             if isinstance(baseline, (int, float))
             and isinstance(current, (int, float)) else None)
    return {"metric": metric, "baseline": baseline, "current": current,
            "delta": delta, "check": check,
            "status": "ok" if ok else "FAIL"}


def check_serve_slo(rows, problems):
    """Full regeneration + bit-level (float-tolerant) compare."""
    from . import serve_slo
    committed = _load("BENCH_serve_slo.json")
    with tempfile.TemporaryDirectory() as td:
        tmp = os.path.join(td, "regen.json")
        serve_slo.run(cfg=serve_slo.FULL, out_path=tmp)
        with open(tmp) as f:
            regen = json.load(f)
    local = []
    _deep_diff(committed, regen, "serve_slo", local)
    problems.extend(local)
    for k in sorted(committed.get("headline", {})):
        b = committed["headline"][k]
        c = regen.get("headline", {}).get(k)
        rows.append(_row(f"serve_slo.headline.{k}", b, c,
                         f"exact (rtol {REL_TOL:g})", _close(b, c)))
    rows.append(_row("serve_slo.full_report_fields_drifted", 0,
                     len(local), "== 0", not local))


def check_cluster_strong(rows, problems):
    """Recompute every committed strong-scaling point via ``run_point``."""
    from repro.core import SweepPoint, run_point
    committed = _load("BENCH_cluster.json")
    strong = committed.get("strong_scaling", {})
    n_drift = 0
    for kernel in sorted(strong):
        n_samples = strong[kernel]["n_samples"]
        base_tp = None
        for i, pt in enumerate(strong[kernel]["points"]):
            rec = run_point(SweepPoint(
                kernel=kernel, policy="copiftv2", n_samples=n_samples,
                n_cores=pt["n_cores"], tcdm_banks=pt["tcdm_banks"]))
            if not rec.ok or not rec.equivalent:
                problems.append(
                    f"cluster.{kernel}.x{pt['n_cores']}: recompute failed "
                    f"({rec.status}: {rec.detail or 'diverged'})")
                continue
            if base_tp is None:
                base_tp = rec.throughput
            cur = {"n_cores": rec.n_cores, "tcdm_banks": rec.tcdm_banks,
                   "cycles": rec.cycles, "bank_stalls": rec.bank_stalls,
                   "throughput": rec.throughput,
                   "speedup": rec.throughput / base_tp,
                   "ipc": rec.ipc, "ipc_per_core": rec.ipc_per_core,
                   "energy_per_sample": rec.energy / rec.n_samples}
            for field in _EXACT_FIELDS + _FLOAT_FIELDS:
                exact = field in _EXACT_FIELDS
                same = (pt[field] == cur[field] if exact
                        else _close(pt[field], cur[field]))
                if not same:
                    n_drift += 1
                    problems.append(
                        f"cluster.{kernel}.x{pt['n_cores']}.{field}: "
                        f"{pt[field]!r} -> {cur[field]!r}")
            if i == 0 and pt["speedup"] != 1.0:
                problems.append(
                    f"cluster.{kernel}: first strong-scaling point is not "
                    f"the 1x baseline (speedup={pt['speedup']!r})")
    head = committed.get("headline", {})
    if head:
        kernel = head["kernel"]
        pts = {p["n_cores"]: p for p in strong[kernel]["points"]}
        c = round(pts[4]["speedup"], 4)
        rows.append(_row(f"cluster.headline.speedup_4c[{kernel}]",
                         head["speedup_4c"], c,
                         f"exact (rtol {REL_TOL:g})",
                         _close(head["speedup_4c"], c)))
    rows.append(_row("cluster.strong_scaling_fields_drifted", 0, n_drift,
                     "== 0", n_drift == 0))


def check_serve_prefill(rows, problems):
    """Committed live-engine chunked-prefill gate artifact.  Wall-clock and
    cycles TTFT gains are floor-checked against the embedded bar (the wall
    number is machine-dependent, so no exact compare); the bit-exactness
    flag and the bounded chunk-jit-cache count must hold outright."""
    art = _load("BENCH_serve_prefill.json")
    head = art["headline"]
    bar = head["min_required"]
    for key in ("ttft_wall_gain", "ttft_cycles_gain"):
        ok = head[key] >= bar
        if not ok:
            problems.append(
                f"BENCH_serve_prefill.json:headline.{key} = {head[key]} "
                f"fell below the {bar} floor")
        rows.append(_row(f"serve_prefill.headline.{key}", bar, head[key],
                         f">= {bar}", ok))
    ok = head["bit_exact"] is True
    if not ok:
        problems.append(
            "BENCH_serve_prefill.json: chunked prefill was committed "
            "without bit-exactness vs the token-by-token path")
    rows.append(_row("serve_prefill.headline.bit_exact", True,
                     head["bit_exact"], "== True", ok))
    compiles, bound = art["prefill_compiles"], art["max_prefill_compiles"]
    ok = compiles <= bound
    if not ok:
        problems.append(
            f"BENCH_serve_prefill.json: {compiles} prefill compiles "
            f"exceed the log2(chunk)+1 = {bound} bound")
    rows.append(_row("serve_prefill.prefill_compiles", bound, compiles,
                     f"<= {bound}", ok))


def check_floors(rows, problems):
    """Committed wall-clock ratios and gated gains stay above their bars."""
    floors = list(FLOORS)
    # the gated headline gains carry their own floor inside the artifact
    serve = _load("BENCH_serve_slo.json")["headline"]
    floors.append(("BENCH_serve_slo.json",
                   ("headline", "throughput_at_slo_gain_bursty"),
                   serve["min_required"],
                   "continuous vs wave batching throughput-at-SLO (bursty)"))
    cluster = _load("BENCH_cluster.json")["headline"]
    floors.append(("BENCH_cluster.json", ("headline", "speedup_4c"),
                   cluster["min_required"],
                   "1->4 core strong-scaling speedup"))
    for name, keys, floor, _what in floors:
        node = _load(name)
        for k in keys:
            node = node[k]
        ok = node >= floor
        if not ok:
            problems.append(
                f"{name}:{'.'.join(keys)} = {node} fell below the "
                f"{floor} floor")
        rows.append(_row(f"{name.removeprefix('BENCH_').removesuffix('.json')}"
                         f".{'.'.join(keys)}", floor, node, f">= {floor}",
                         ok))


def _fmt_cell(v):
    if isinstance(v, float):
        return f"{v:.4f}"
    return "" if v is None else str(v)


def render_table(rows):
    head = ("metric", "baseline", "current", "delta", "check", "status")
    lines = ["| " + " | ".join(head) + " |",
             "|" + "---|" * len(head)]
    for r in rows:
        lines.append("| " + " | ".join(_fmt_cell(r[k]) for k in head) + " |")
    return "\n".join(lines)


def _emit_summary(table, problems):
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if not summary:
        return
    with open(summary, "a") as f:
        f.write("## bench-drift gate\n\n")
        f.write(table + "\n\n")
        if problems:
            f.write(f"**{len(problems)} drift finding(s):**\n\n")
            for p in problems:
                f.write(f"- `{p}`\n")
        else:
            f.write("No drift: committed benchmark baselines match the "
                    "recomputation and every floor holds.\n")


def run():
    t0 = time.time()
    rows, problems = [], []
    for check in (check_serve_slo, check_cluster_strong,
                  check_serve_prefill, check_floors):
        try:
            check(rows, problems)
        except AssertionError as e:
            problems.append(str(e))
    table = render_table(rows)
    print(table)
    _emit_summary(table, problems)
    if problems:
        raise AssertionError(
            "committed benchmark baselines drifted:\n  "
            + "\n  ".join(problems)
            + "\nIf the change is deliberate, regenerate with: "
              "PYTHONPATH=src python -m benchmarks.bench_diff --update "
              "and include the artifact diff in the PR")
    us = (time.time() - t0) * 1e6
    return [("bench_diff_metrics_checked", us, float(len(rows))),
            ("bench_diff_drift_findings", us, 0.0)]


def update_baselines():
    """Regenerate the exact-compare golden artifacts in place."""
    from . import cluster_scaling, serve_slo
    serve_slo.run(cfg=serve_slo.FULL, out_path=serve_slo.OUT_PATH)
    print(f"wrote {serve_slo.OUT_PATH}")
    cluster_scaling.run(cfg=cluster_scaling.FULL,
                        out_path=cluster_scaling.OUT_PATH)
    print(f"wrote {cluster_scaling.OUT_PATH}")


def main():
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived:.4f}")


if __name__ == "__main__":
    if "--update" in sys.argv[1:]:
        update_baselines()
    else:
        main()
