"""Mesh-level policy experiment: bulk all-gather vs COPIFTv2 ring matmul.

Runs in a subprocess with 8 host devices (the parent process must keep the
default device count for the other benchmarks).  Reports wall time and the
HLO collective op counts for both policies."""
import json
import subprocess
import sys

_CHILD = r"""
import json, time
import jax, jax.numpy as jnp
from repro.distributed.collective_matmul import tp_matmul
from repro.core.policy import ExecutionPolicy as EP
from repro.launch.mesh import make_local_mesh

mesh = make_local_mesh(2, 4)
x = jax.random.normal(jax.random.PRNGKey(0), (2048, 1024), jnp.float32)
w = jax.random.normal(jax.random.PRNGKey(1), (1024, 2048), jnp.float32)
out = {}
for pol in (EP.COPIFT, EP.COPIFTV2):
    f = jax.jit(lambda a, b, p=pol: tp_matmul(a, b, mesh, policy=p))
    y = f(x, w); y.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(5):
        y = f(x, w)
    y.block_until_ready()
    us = (time.perf_counter() - t0) / 5 * 1e6
    hlo = f.lower(x, w).compile().as_text()
    out[pol.value] = {
        "us": us,
        "all_gather_ops": hlo.count(" all-gather("),
        "permute_ops": hlo.count(" collective-permute("),
    }
print(json.dumps(out))
"""


def run():
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": "src"}
    import os
    env = {**os.environ, **env}
    res = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                         capture_output=True, text=True, timeout=300)
    if res.returncode != 0:
        return [("collective_policy_error", 0.0, 0.0)]
    data = json.loads(res.stdout.strip().splitlines()[-1])
    rows = []
    for pol, d in data.items():
        rows.append((f"collective_{pol}_us", d["us"], 0.0))
        rows.append((f"collective_{pol}_allgather_ops", 0.0,
                     d["all_gather_ops"]))
        rows.append((f"collective_{pol}_permute_ops", 0.0, d["permute_ops"]))
    return rows


def main():
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived:.4f}")


if __name__ == "__main__":
    main()
