"""Benchmark entry point — one section per paper table/figure plus the
framework-level experiments.  Prints ``name,us_per_call,derived`` CSV."""
import sys
import traceback


def main() -> None:
    from . import collective_policy, fig3, kernel_bench, roofline_table
    sections = [
        ("fig3 (paper Fig.3a/b/c via the machine model)", fig3),
        ("kernels (interpret-mode micro-bench)", kernel_bench),
        ("collective policy (bulk vs ring)", collective_policy),
        ("roofline (from dry-run artifacts)", roofline_table),
    ]
    failed = []
    for title, mod in sections:
        print(f"# --- {title} ---")
        try:
            mod.main()
        except Exception as e:
            failed.append(title)
            print(f"# SECTION FAILED: {e}")
            traceback.print_exc()
    if failed:
        sys.exit(f"benchmark sections failed: {failed}")


if __name__ == "__main__":
    main()
