"""Benchmark entry point — one section per paper table/figure plus the
framework-level experiments.  Prints ``name,us_per_call,derived`` CSV.

``--smoke`` runs the CI-grade path: every section that defines a ``smoke()``
hook runs its tiny-grid variant, and **nothing is caught** — any section
failure exits non-zero immediately, so sections cannot silently rot.
"""
import argparse
import sys
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grids, no failure-swallowing (CI gate)")
    args = ap.parse_args(argv)

    if args.smoke:
        # deliberately no try/except: a smoke failure must fail the run
        from . import dse, fig3, sweep_perf
        for title, fn in [
            ("fig3 smoke (machine model, small n)", fig3.smoke),
            ("dse smoke (tiny sweep grid + equivalence fuzz)", dse.smoke),
            ("sweep_perf smoke (event vs cycle engine throughput)",
             sweep_perf.smoke),
        ]:
            print(f"# --- {title} ---")
            fn()
        return

    from . import (collective_policy, dse, fig3, kernel_bench,
                   roofline_table, sweep_perf)
    sections = [
        ("fig3 (paper Fig.3a/b/c via the machine model)", fig3),
        ("dse (design-space sweep + Pareto fronts)", dse),
        ("sweep_perf (DSE points/sec, event vs cycle engine)", sweep_perf),
        ("kernels (interpret-mode micro-bench)", kernel_bench),
        ("collective policy (bulk vs ring)", collective_policy),
        ("roofline (from dry-run artifacts)", roofline_table),
    ]
    failed = []
    for title, mod in sections:
        print(f"# --- {title} ---")
        try:
            mod.main()
        except Exception as e:
            failed.append(title)
            print(f"# SECTION FAILED: {e}")
            traceback.print_exc()
    if failed:
        sys.exit(f"benchmark sections failed: {failed}")


if __name__ == "__main__":
    main()
