"""Benchmark entry point — one section per paper table/figure plus the
framework-level experiments.  Prints ``name,us_per_call,derived`` CSV.

``--smoke`` runs the CI-grade path: every section that defines a ``smoke()``
hook runs its tiny-grid variant.  Failures are never swallowed: every
section still runs (so one broken section cannot hide another), a
per-section ``PASS``/``FAIL`` summary prints at the end, and any failure
exits non-zero — the CI smoke job cannot go green on a silently broken
section.
"""
import argparse
import sys
import traceback


def _run_sections(sections) -> None:
    """Run every (title, callable) section, print a per-section pass/fail
    summary, and exit non-zero if anything raised."""
    failures = []
    statuses = []
    for title, fn in sections:
        print(f"# --- {title} ---")
        try:
            fn()
            statuses.append((title, "PASS", ""))
        except Exception as e:
            traceback.print_exc()
            failures.append(title)
            statuses.append((title, "FAIL", f" ({type(e).__name__}: {e})"))
    print("# --- summary ---")
    for title, verdict, detail in statuses:
        print(f"# {verdict}: {title}{detail}")
    if failures:
        sys.exit(f"benchmark sections failed: {failures}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grids, per-section pass/fail, non-zero exit "
                         "on any failure (CI gate)")
    args = ap.parse_args(argv)

    if args.smoke:
        from . import (calibration, cluster_pipeline, cluster_scaling,
                       cluster_sweep_scale, dse, fig3, front_diff,
                       serve_slo, sweep_perf, sweep_scale)
        _run_sections([
            ("fig3 smoke (machine model, small n)", fig3.smoke),
            ("dse smoke (tiny sweep grid + equivalence fuzz)", dse.smoke),
            ("sweep_perf smoke (event vs cycle engine throughput)",
             sweep_perf.smoke),
            ("sweep_scale smoke (batch engine parity + adaptive front "
             "cover)", sweep_scale.smoke),
            ("cluster_sweep_scale smoke (batch cluster engine parity on "
             "cluster/pipeline grids)", cluster_sweep_scale.smoke),
            ("calibration smoke (Pareto-selected vs hard-coded default)",
             calibration.smoke),
            ("cluster scaling smoke (weak/strong 1-4 cores + bank "
             "contention)", cluster_scaling.smoke),
            ("cluster pipeline smoke (producer/consumer pairs vs work "
             "partition on a bank-starved TCDM)", cluster_pipeline.smoke),
            ("front diff (committed Pareto-front drift gate)",
             front_diff.smoke),
            ("serve SLO smoke (continuous vs wave batching under "
             "trace-driven load)", serve_slo.smoke),
            ("serve prefill smoke (live chunked prefill vs token-by-token "
             "TTFT, bit-exact)", serve_slo.prefill_smoke),
        ])
        return

    from . import (calibration, cluster_pipeline, cluster_scaling,
                   cluster_sweep_scale, collective_policy, dse, fig3,
                   front_diff, kernel_bench, roofline_table, serve_slo,
                   sweep_perf, sweep_scale)
    _run_sections([
        ("fig3 (paper Fig.3a/b/c via the machine model)", fig3.main),
        ("dse (design-space sweep + Pareto fronts)", dse.main),
        ("sweep_perf (DSE points/sec, event vs cycle engine)",
         sweep_perf.main),
        ("sweep_scale (batch engine >=10x gate + adaptive front cover)",
         sweep_scale.main),
        ("cluster_sweep_scale (batch cluster engine >=8x gate on "
         "cluster/pipeline grids)", cluster_sweep_scale.main),
        ("calibration (Pareto-selected operating points vs defaults)",
         calibration.main),
        ("cluster scaling (weak/strong 1-8 cores + bank contention)",
         cluster_scaling.main),
        ("cluster pipeline (producer/consumer pairs vs work partition)",
         cluster_pipeline.main),
        ("front diff (committed Pareto-front drift gate)", front_diff.main),
        ("serve SLO (continuous vs wave batching under trace-driven load)",
         serve_slo.main),
        ("serve prefill (live chunked prefill >=2x TTFT gate, bit-exact)",
         serve_slo.prefill_main),
        ("kernels (interpret-mode micro-bench)", kernel_bench.main),
        ("collective policy (bulk vs ring)", collective_policy.main),
        ("roofline (from dry-run artifacts)", roofline_table.main),
    ])


if __name__ == "__main__":
    main()
