"""Paper Fig. 3 reproduction: (a) IPC per kernel per policy, (b) power,
(c) speedup + energy-efficiency of COPIFTv2 over COPIFT."""
import time

from repro.core import (PAPER_CLAIMS, MachineConfig, TransformConfig,
                        run_suite, summarize)
from repro.core.policy import ExecutionPolicy as P


def run(n_samples: int = 512):
    t0 = time.time()
    suite = run_suite(n_samples, TransformConfig(n_samples=n_samples),
                      MachineConfig())
    elapsed = (time.time() - t0) * 1e6 / (len(suite) * 3)
    rows = []
    # --- fig 3a: IPC ---
    for name, c in suite.items():
        rows.append((f"fig3a_ipc_{name}_baseline", elapsed, c.ipc(P.BASELINE)))
        rows.append((f"fig3a_ipc_{name}_copift", elapsed, c.ipc(P.COPIFT)))
        rows.append((f"fig3a_ipc_{name}_copiftv2", elapsed, c.ipc(P.COPIFTV2)))
    # --- fig 3b: power (relative units) ---
    for name, c in suite.items():
        rows.append((f"fig3b_power_{name}_v2_over_copift", elapsed,
                     c.results[P.COPIFTV2].power / c.results[P.COPIFT].power))
    # --- fig 3c: speedup + energy gain over COPIFT ---
    for name, c in suite.items():
        rows.append((f"fig3c_speedup_{name}", elapsed,
                     c.speedup(P.COPIFTV2, P.COPIFT)))
        rows.append((f"fig3c_energy_{name}", elapsed,
                     c.energy_gain(P.COPIFTV2, P.COPIFT)))
    # --- headline claims vs paper ---
    s = summarize(suite)
    for k, v in s.items():
        rows.append((f"claims_{k}", elapsed, v))
        rows.append((f"claims_{k}_paper", 0.0, PAPER_CLAIMS[k]))
    return rows


def main():
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived:.4f}")


def smoke():
    """CI-grade: small sample count, assert the headline stays in band."""
    rows = run(n_samples=64)
    peak = next(v for n, _, v in rows if n == "claims_peak_ipc_v2")
    assert 1.4 <= peak <= 2.0, f"peak IPC out of band at n=64: {peak}"
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived:.4f}")


if __name__ == "__main__":
    main()
