"""Render the roofline table from dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads artifacts/dryrun/*_analysis.json (true loop-unrolled totals for the
three terms) and *_deploy.json (memory footprint / compile gate)."""
import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load(variant="analysis", mesh="pod16x16"):
    cells = {}
    for p in sorted(glob.glob(os.path.join(ART, f"*_{mesh}_*_{variant}.json"))):
        with open(p) as f:
            a = json.load(f)
        cells[(a["arch"], a["shape"])] = a
    return cells


def table(cells):
    hdr = (f"{'arch':<22} {'shape':<12} {'t_comp':>9} {'t_mem':>9} "
           f"{'t_coll':>9} {'bound':<10} {'useful':>7} {'mfu':>7}")
    lines = [hdr, "-" * len(hdr)]
    for (arch, shape), a in sorted(cells.items()):
        r = a["roofline"]
        lines.append(
            f"{arch:<22} {shape:<12} {r['t_compute']:>9.2e} "
            f"{r['t_memory']:>9.2e} {r['t_collective']:>9.2e} "
            f"{r['bottleneck']:<10} {r['useful_flops_ratio']:>7.3f} "
            f"{r['mfu']:>7.4f}")
    return "\n".join(lines)


def run():
    rows = []
    for (arch, shape), a in sorted(load().items()):
        r = a["roofline"]
        rows.append((f"roofline_{arch}_{shape}_mfu", a["compile_s"] * 1e6,
                     r["mfu"]))
    return rows


def main():
    cells = load()
    if not cells:
        print("roofline_no_artifacts,0,0")
        return
    print(table(cells))
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived:.6f}")


if __name__ == "__main__":
    main()
