"""Serve-SLO benchmark: a trace-driven load generator over the virtual-time
serving simulation (``repro.serve.scheduler.simulate_serve``).

Two pinned-seed arrival traces with mixed prompt/output lengths drive the
continuous-batching scheduler against the static (wave) baseline:

* **poisson** — memoryless arrivals at ~70% of the cluster's best decode
  service rate (steady load, the queueing-theory regime the ``serve-slo``
  calibration objective analyses);
* **bursty** — groups of near-simultaneous arrivals separated by long lulls
  (the regime where wave batching hurts most: short requests drain and
  their slots idle until the wave's longest request completes).

Everything is simulated in cycles-equivalent over a *pinned paper-default
operating point* (not the live PolicyTable — the gate must be hermetic
w.r.t. whatever calibration artifacts exist on the machine), so the whole
benchmark is exactly deterministic: the committed
``artifacts/BENCH_serve_slo.json`` is a golden artifact that
``benchmarks/bench_diff.py`` regenerates and compares bit-for-bit in CI.

Gates (smoke and full):

* continuous batching delivers >= :data:`MIN_CONTINUOUS_GAIN` x the static
  baseline's **throughput-at-SLO** on the bursty trace (tokens of requests
  that met their latency budget, per cycle);
* continuous batching *meets the p99 bound* (normalized p99 latency within
  :data:`SLO_P99_PER_TOKEN`) on both traces;
* continuous energy-per-token beats static on the bursty trace (padded
  slots burn energy; fewer idle slots = fewer wasted joules);
* straggler-aware dispatch flags exactly the injected slow host (no
  false-dead hosts) and beats rigid equal-share dispatch by
  >= :data:`MIN_STRAGGLER_GAIN` x on wall cycles;
* two runs of the same trace produce identical reports (determinism).

Writes ``artifacts/BENCH_serve_slo.json`` (``BENCH_serve_slo_smoke.json``
under ``--smoke``) with the cost model, the SLO, per-trace per-mode reports
and the headline gains.  Emits ``name,us_per_call,derived`` CSV rows like
every other section.

A second, live-engine section (``run_prefill`` / ``--prefill``) gates the
real chunked-prefill path: bit-exact tokens and cache vs the token-by-token
reference, >= :data:`MIN_PREFILL_TTFT_GAIN` x TTFT at prompt_len >= 64, and
a bounded chunk-bucket jit cache.  It writes
``artifacts/BENCH_serve_prefill.json``.
"""
import json
import os
import sys
import time

import numpy as np

from repro.core.policy import OperatingPoint
from repro.serve.scheduler import (AdmissionControl, HostDispatch, ServeSLO,
                                   StepCostModel, TraceRequest,
                                   simulate_serve)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(ROOT, "artifacts", "BENCH_serve_slo.json")
PREFILL_OUT_PATH = os.path.join(ROOT, "artifacts", "BENCH_serve_prefill.json")

#: the acceptance bar: continuous batching must beat wave batching by this
#: factor on bursty-trace throughput-at-SLO
MIN_CONTINUOUS_GAIN = 1.3
#: straggler-aware dispatch must beat rigid equal-share dispatch by this
#: factor on total cycles when one of four hosts runs 3x slow
MIN_STRAGGLER_GAIN = 1.5

#: the SLO: p99 normalized latency (cycles per work-token, queueing
#: included) and the per-request budget slack (absolute cycles)
SLO_P99_PER_TOKEN = 700.0
SLO_BASE_CYCLES = 800.0

N_SLOTS = 8
PREFILL_CHUNK = 8
#: mixed request shapes (drawn per request from the pinned seed)
PROMPT_LENS = (4, 8, 16)
MAX_NEWS = (4, 8, 16, 48)

FULL = dict(n_requests=160, seed=7, poisson_util=0.7,
            burst_size=16, burst_gap_steps=40)
SMOKE = dict(n_requests=48, seed=7, poisson_util=0.7,
             burst_size=12, burst_gap_steps=40)


def _cost_model() -> StepCostModel:
    """The pinned paper-default operating point's step costs (hermetic:
    never reads the live calibration artifacts)."""
    return StepCostModel.from_operating_point(OperatingPoint())


def _shapes(rng: np.random.RandomState, n: int):
    prompts = rng.choice(PROMPT_LENS, size=n)
    news = rng.choice(MAX_NEWS, size=n)
    return prompts, news


def poisson_trace(cost: StepCostModel, n: int, seed: int,
                  util: float) -> list:
    """Memoryless arrivals at ``util`` x the best decode service rate."""
    rng = np.random.RandomState(seed)
    prompts, news = _shapes(rng, n)
    step_cycles, _ = cost.step_cost(N_SLOTS, 0)
    token_rate = N_SLOTS / step_cycles              # tokens/cycle, all busy
    req_rate = util * token_rate / float(np.mean(MAX_NEWS))
    gaps = rng.exponential(1.0 / req_rate, size=n)
    arrivals = np.cumsum(gaps)
    return [TraceRequest(i, float(arrivals[i]), int(prompts[i]),
                         int(news[i])) for i in range(n)]


def bursty_trace(cost: StepCostModel, n: int, seed: int, burst_size: int,
                 burst_gap_steps: int) -> list:
    """Bursts of near-simultaneous arrivals separated by multi-wave lulls."""
    rng = np.random.RandomState(seed + 1)
    prompts, news = _shapes(rng, n)
    step_cycles, _ = cost.step_cost(N_SLOTS, 0)
    out, t = [], 0.0
    for i in range(n):
        if i and i % burst_size == 0:
            t += burst_gap_steps * step_cycles      # lull between bursts
        t += float(rng.exponential(0.2 * step_cycles))
        out.append(TraceRequest(i, t, int(prompts[i]), int(news[i])))
    return out


def _simulate(trace, cost, mode, dispatch=None):
    slo = ServeSLO(p99_cycles_per_token=SLO_P99_PER_TOKEN,
                   base_cycles=SLO_BASE_CYCLES)
    return simulate_serve(
        trace, N_SLOTS, cost, mode=mode, slo=slo,
        admission=AdmissionControl(max_pending=256),
        prefill_chunk=PREFILL_CHUNK, dispatch=dispatch)


def run(cfg=None, out_path=OUT_PATH):
    cfg = cfg or FULL
    t0 = time.time()
    cost = _cost_model()
    traces = {
        "poisson": poisson_trace(cost, cfg["n_requests"], cfg["seed"],
                                 cfg["poisson_util"]),
        "bursty": bursty_trace(cost, cfg["n_requests"], cfg["seed"],
                               cfg["burst_size"], cfg["burst_gap_steps"]),
    }
    rows, results = [], {}
    for name, trace in traces.items():
        results[name] = {}
        for mode in ("continuous", "static"):
            rep = _simulate(trace, cost, mode)
            if rep.n_unfinished:
                raise AssertionError(
                    f"{name}/{mode}: {rep.n_unfinished} admitted requests "
                    f"never completed (scheduler stuck or max_steps hit)")
            results[name][mode] = rep.to_dict()
            rows.append((f"serve_slo_{name}_{mode}_tput_at_slo", 0.0,
                         rep.slo["throughput_at_slo"]))
            rows.append((f"serve_slo_{name}_{mode}_p99", 0.0,
                         rep.p99_latency))

    # determinism: the whole pipeline must be replayable bit-for-bit
    again = _simulate(traces["bursty"], cost, "continuous").to_dict()
    if again != results["bursty"]["continuous"]:
        raise AssertionError("serve simulation is not deterministic: two "
                             "runs of the pinned bursty trace differ")

    # gate: continuous meets the p99 bound on both traces
    for name in traces:
        cont = results[name]["continuous"]
        if not cont["slo"]["p99_met"]:
            raise AssertionError(
                f"{name}: continuous batching missed the p99 bound "
                f"({cont['p99_latency']:.1f} > {SLO_P99_PER_TOKEN} "
                f"cyc/tok)")

    # gate: >=1.3x throughput-at-SLO over wave batching on the bursty trace
    gain = (results["bursty"]["continuous"]["slo"]["throughput_at_slo"]
            / max(results["bursty"]["static"]["slo"]["throughput_at_slo"],
                  1e-12))
    if gain < MIN_CONTINUOUS_GAIN:
        raise AssertionError(
            f"continuous batching gains only {gain:.2f}x throughput-at-SLO "
            f"over the static baseline on the bursty trace "
            f"(required {MIN_CONTINUOUS_GAIN}x)")
    rows.append(("serve_slo_bursty_tput_at_slo_gain", 0.0, gain))

    # gate: fewer idle padded slots = lower J/token
    e_cont = results["bursty"]["continuous"]["energy_per_token"]
    e_stat = results["bursty"]["static"]["energy_per_token"]
    if e_cont >= e_stat:
        raise AssertionError(
            f"continuous J/token {e_cont:.1f} did not beat static "
            f"{e_stat:.1f} on the bursty trace")
    rows.append(("serve_slo_bursty_energy_gain", 0.0, e_stat / e_cont))

    # gate: straggler-aware dispatch adapts (and declares nobody dead)
    slow_host = 3
    adaptive = HostDispatch(4, min_samples=8)
    adaptive.set_speed(slow_host, 3.0)
    rep_adapt = _simulate(traces["bursty"], cost, "continuous",
                          dispatch=adaptive)
    rigid = HostDispatch(4, min_samples=8, threshold=float("inf"))
    rigid.set_speed(slow_host, 3.0)
    rep_rigid = _simulate(traces["bursty"], cost, "continuous",
                          dispatch=rigid)
    if rep_adapt.straggler["flagged_hosts"] != [slow_host]:
        raise AssertionError(
            f"straggler dispatch flagged "
            f"{rep_adapt.straggler['flagged_hosts']}, expected "
            f"[{slow_host}]")
    if rep_adapt.straggler["dead_hosts"]:
        raise AssertionError(
            f"slow-but-beating hosts declared dead: "
            f"{rep_adapt.straggler['dead_hosts']}")
    straggler_gain = rep_rigid.total_cycles / rep_adapt.total_cycles
    if straggler_gain < MIN_STRAGGLER_GAIN:
        raise AssertionError(
            f"straggler-aware dispatch gains only {straggler_gain:.2f}x "
            f"over rigid dispatch (required {MIN_STRAGGLER_GAIN}x)")
    rows.append(("serve_slo_straggler_gain", 0.0, straggler_gain))

    report = {
        "cost_model": {
            "cycles_decode_token": cost.cycles_decode_token,
            "energy_decode_token": cost.energy_decode_token,
            "cycles_prefill_token": cost.cycles_prefill_token,
            "energy_prefill_token": cost.energy_prefill_token,
            "overhead_cycles": cost.overhead_cycles,
            "source": cost.source,
        },
        "slo": {"p99_cycles_per_token": SLO_P99_PER_TOKEN,
                "base_cycles": SLO_BASE_CYCLES},
        "config": {"n_slots": N_SLOTS, "prefill_chunk": PREFILL_CHUNK,
                   "prompt_lens": list(PROMPT_LENS),
                   "max_news": list(MAX_NEWS), **cfg},
        "results": results,
        "straggler": {"slow_host": slow_host, "slowdown": 3.0,
                      "adaptive": rep_adapt.straggler,
                      "adaptive_cycles": rep_adapt.total_cycles,
                      "rigid_cycles": rep_rigid.total_cycles,
                      "gain": straggler_gain},
        "headline": {"throughput_at_slo_gain_bursty": gain,
                     "min_required": MIN_CONTINUOUS_GAIN,
                     "p99_met": True,
                     "straggler_gain": straggler_gain},
    }
    us = (time.time() - t0) * 1e6 / max(len(rows), 1)
    rows = [(name, us, derived) for name, _z, derived in rows]

    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    return rows


def main():
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived:.4f}")
    print(f"# wrote {OUT_PATH}")


def smoke():
    """Smaller trace, separate artifact — every gate still enforced."""
    out = os.path.join(ROOT, "artifacts", "BENCH_serve_slo_smoke.json")
    rows = run(cfg=SMOKE, out_path=out)
    if not rows:
        raise AssertionError("serve_slo smoke produced no rows")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived:.4f}")


# ---------------------------------------------------------------------------
# live-engine chunked-prefill gate
# ---------------------------------------------------------------------------
# This section runs the *real* jitted engine (not the virtual-time
# simulation): the chunked prefill path (`models.model.prefill_step` driven
# by `ServeEngine(prefill="chunked")`) against the token-by-token reference
# (`prefill="token"`) on the same params, prompt and pinned cost model.
#
# Gates:
# * generated tokens AND final cache rows are bit-exact between the two
#   paths (the chunk kernel scans the same decode_step body, so any diff is
#   a real bug, not float noise);
# * cycles-equivalent TTFT (deterministic: pinned cost model, fixed
#   prompt) improves >= MIN_PREFILL_TTFT_GAIN x;
# * measured wall-clock TTFT (median over trials, warm jits) improves
#   >= MIN_PREFILL_TTFT_GAIN x in full mode (a softer
#   MIN_PREFILL_TTFT_GAIN_SMOKE bar under --smoke: CI machines are noisy);
# * the chunk-bucket jit cache stays bounded: at most
#   log2(prefill_chunk) + 1 compiled prefill programs.

#: the acceptance bar from ROADMAP item 3's residual gap: chunked prefill
#: must at least halve TTFT at prompt_len >= 64
MIN_PREFILL_TTFT_GAIN = 2.0
#: smoke keeps a softer wall-clock bar (shared CI machines); the
#: deterministic cycles-domain gate stays at MIN_PREFILL_TTFT_GAIN
MIN_PREFILL_TTFT_GAIN_SMOKE = 1.2

PREFILL_FULL = dict(arch="phi3-mini-3.8b", prompt_len=64, max_new=8,
                    batch_slots=2, prefill_chunk=16, trials=5, seed=0)
PREFILL_SMOKE = dict(arch="phi3-mini-3.8b", prompt_len=64, max_new=4,
                     batch_slots=2, prefill_chunk=16, trials=3, seed=0)


def _prefill_engines(cfg):
    """Both engines (chunked + token reference) over shared params and the
    pinned paper-default operating point — hermetic w.r.t. live
    calibration artifacts, like the rest of this benchmark."""
    import jax
    from repro.config import RunConfig
    from repro.configs import get_reduced
    from repro.models import init_model_params
    from repro.serve import ServeEngine

    mcfg = get_reduced(cfg["arch"])
    rc = RunConfig(dtype="float32", param_dtype="float32", remat=False)
    params = init_model_params(jax.random.PRNGKey(cfg["seed"]), mcfg)
    rng = np.random.RandomState(cfg["seed"] + 1)
    prompt = [int(t) for t in rng.randint(0, mcfg.vocab,
                                          size=cfg["prompt_len"])]
    max_len = cfg["prompt_len"] + cfg["max_new"] + 8
    cost = _cost_model()

    def mk(prefill):
        return ServeEngine(params, mcfg, rc,
                           batch_slots=cfg["batch_slots"], max_len=max_len,
                           operating_point=OperatingPoint(),
                           cost_model=cost, prefill=prefill,
                           prefill_chunk=cfg["prefill_chunk"])
    return mk, prompt


def _measure_ttft(eng, prompt, max_new, trials):
    """Warm run (compiles) + ``trials`` timed runs; returns the warm run's
    generated tokens, the cycles-domain TTFT, and per-trial wall TTFTs."""
    import math as _math
    rid0 = eng.submit(prompt, max_new=max_new)
    eng.run(max_steps=100_000)
    tokens = list(eng.finished[rid0].generated)
    sreq = eng.sched.requests[rid0]
    ttft_cycles = sreq.first_token - sreq.arrival
    walls = []
    for _ in range(trials):
        rid = eng.submit(prompt, max_new=max_new)
        t0 = time.time()
        while not eng.requests[rid].generated:
            eng.step()
        walls.append(time.time() - t0)
        eng.run(max_steps=100_000)           # drain before the next trial
    assert _math.isfinite(ttft_cycles)
    return tokens, ttft_cycles, walls


def run_prefill(cfg=None, out_path=PREFILL_OUT_PATH,
                min_wall_gain=MIN_PREFILL_TTFT_GAIN):
    import jax.numpy as jnp
    cfg = cfg or PREFILL_FULL
    t0 = time.time()
    mk, prompt = _prefill_engines(cfg)

    chunked = mk("chunked")
    token = mk("token")
    tok_c, cyc_c, walls_c = _measure_ttft(chunked, prompt, cfg["max_new"],
                                          cfg["trials"])
    tok_t, cyc_t, walls_t = _measure_ttft(token, prompt, cfg["max_new"],
                                          cfg["trials"])

    # gate: bit-exact generated tokens and final cache rows.  Only the
    # serving slot's rows are compared: free-slot rows are junk by design
    # (the unmasked token-by-token reference advances them every step, the
    # masked chunk path never touches them) and are zeroed before reuse.
    def _slot_rows(cache, i):
        return {k: (v if v.ndim == 0 else v[i] if v.ndim == 1 else v[:, i])
                for k, v in cache.items()}

    rows_c = _slot_rows(chunked.cache, 0)
    rows_t = _slot_rows(token.cache, 0)
    tokens_exact = tok_c == tok_t
    cache_exact = (set(rows_c) == set(rows_t) and all(
        bool(jnp.array_equal(rows_c[k], rows_t[k])) for k in rows_c))
    if not (tokens_exact and cache_exact):
        raise AssertionError(
            f"chunked prefill is not bit-exact with the token-by-token "
            f"path: tokens_exact={tokens_exact} cache_exact={cache_exact} "
            f"(chunked={tok_c} token={tok_t})")

    # gate: bounded chunk-bucket jit cache
    import math
    max_compiles = int(math.log2(cfg["prefill_chunk"])) + 1
    if chunked.prefill_compiles > max_compiles:
        raise AssertionError(
            f"chunk-bucket jit cache unbounded: {chunked.prefill_compiles} "
            f"compiles > log2({cfg['prefill_chunk']})+1 = {max_compiles}")

    # gate: deterministic cycles-domain TTFT gain (pinned cost model)
    cycles_gain = cyc_t / max(cyc_c, 1e-9)
    if cycles_gain < MIN_PREFILL_TTFT_GAIN:
        raise AssertionError(
            f"chunked prefill gains only {cycles_gain:.2f}x cycles-domain "
            f"TTFT at prompt_len={cfg['prompt_len']} "
            f"(required {MIN_PREFILL_TTFT_GAIN}x)")

    # gate: measured wall-clock TTFT gain (median over warm trials)
    wall_c = float(np.median(walls_c))
    wall_t = float(np.median(walls_t))
    wall_gain = wall_t / max(wall_c, 1e-12)
    if wall_gain < min_wall_gain:
        raise AssertionError(
            f"chunked prefill gains only {wall_gain:.2f}x wall-clock TTFT "
            f"at prompt_len={cfg['prompt_len']} (required {min_wall_gain}x)")

    report = {
        "config": dict(cfg),
        "ttft": {
            "cycles_chunked": cyc_c, "cycles_token": cyc_t,
            "wall_s_chunked": walls_c, "wall_s_token": walls_t,
            "wall_s_chunked_median": wall_c, "wall_s_token_median": wall_t,
        },
        "steps": {"chunked": chunked._n_steps, "token": token._n_steps},
        "prefill_compiles": chunked.prefill_compiles,
        "max_prefill_compiles": max_compiles,
        "headline": {
            "ttft_wall_gain": wall_gain,
            "ttft_cycles_gain": cycles_gain,
            "bit_exact": bool(tokens_exact and cache_exact),
            "min_required": MIN_PREFILL_TTFT_GAIN,
        },
    }
    rows = [
        ("serve_prefill_ttft_wall_gain", 0.0, wall_gain),
        ("serve_prefill_ttft_cycles_gain", 0.0, cycles_gain),
        ("serve_prefill_compiles", 0.0, float(chunked.prefill_compiles)),
    ]
    us = (time.time() - t0) * 1e6 / max(len(rows), 1)
    rows = [(name, us, derived) for name, _z, derived in rows]

    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    return rows


def prefill_main():
    for name, us, derived in run_prefill():
        print(f"{name},{us:.1f},{derived:.4f}")
    print(f"# wrote {PREFILL_OUT_PATH}")


def prefill_smoke():
    """Smaller run, separate artifact; the wall-clock bar softens to
    MIN_PREFILL_TTFT_GAIN_SMOKE but bit-exactness, the cycles-domain gain
    and the bounded jit cache are still hard gates."""
    out = os.path.join(ROOT, "artifacts", "BENCH_serve_prefill_smoke.json")
    rows = run_prefill(cfg=PREFILL_SMOKE, out_path=out,
                       min_wall_gain=MIN_PREFILL_TTFT_GAIN_SMOKE)
    if not rows:
        raise AssertionError("serve_prefill smoke produced no rows")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived:.4f}")


if __name__ == "__main__":
    if "--prefill" in sys.argv[1:]:
        prefill_main()
    else:
        main()
