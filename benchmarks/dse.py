"""Design-space exploration section: sweep the machine-model grid and report
per-policy geomean IPC/efficiency, the peak-IPC point, per-kernel Pareto-front
sizes, and the equivalence-fuzzer verdict.  Emits ``name,us_per_call,derived``
CSV rows like the other sections."""
import time

from repro.core import (grid, pareto_by_kernel, run_sweep, sweep_summary)


def run(queue_depths=(1, 2, 4, 8), queue_latencies=(1, 2), unrolls=(4, 8),
        n_samples=32, kernels=None, workers=None):
    pts = grid(kernels=kernels, queue_depths=queue_depths,
               queue_latencies=queue_latencies, unrolls=unrolls,
               n_samples=n_samples)
    t0 = time.time()
    recs = run_sweep(pts, workers=workers)
    us = (time.time() - t0) * 1e6 / max(len(recs), 1)
    s = sweep_summary(recs)
    rows = [(f"dse_{k}", us, v) for k, v in sorted(s.items())]
    for kernel, front in pareto_by_kernel(recs).items():
        rows.append((f"dse_pareto_size_{kernel}", us, float(len(front))))
    bad = [r for r in recs if r.status == "deadlock"
           or (r.ok and (not r.equivalent or r.fifo_violations))]
    if bad:
        raise AssertionError(
            f"{len(bad)} swept configurations deadlocked or diverged from "
            f"the baseline interpreter, e.g. {bad[0]}")
    return rows


def main():
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived:.4f}")


def smoke():
    """Tiny CI grid: 2 kernels x 3 policies x 2 depths, serial."""
    rows = run(queue_depths=(2, 4), queue_latencies=(1,), unrolls=(4,),
               n_samples=16, kernels=["expf", "dequant_dot"], workers=1)
    if not rows:
        raise AssertionError("dse smoke produced no rows")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived:.4f}")


if __name__ == "__main__":
    main()
