"""Pipelined-cluster benchmark: producer/consumer core pairs vs the PR-5
work partition on a bank-starved TCDM (``transform.partition_pipeline`` +
the ``core.cluster`` channel/DMA fabric).

The setup is deliberately contention-heavy: ``cluster_matmul`` (two packed
operand loads per sample) on a cluster with ``n_cores // 2`` TCDM banks and
a high conflict penalty.  Under the PR-5 work partition every core issues
its own loads, so 2N load streams collide on N banks and the ``*_bank``
stall share dominates the makespan.  The pipelined split sends each pair's
loads through the producer core's DMA engine (bulk transfers, conflict-free
by the Snitch cluster's zero-stall premise) and streams unpacked operands
over the inter-core channels, so the consumer cores' FP pipelines stay fed
— back-pressure (bank + ``cq_full`` + DMA-wait) stalls approach zero.

Gates (the PR-6 acceptance bar):

* the pipelined cluster beats the work partition on aggregate IPC by
  >= :data:`MIN_IPC_RATIO` at every core count;
* the pipelined *back-pressure stall share* — stalled issue slots charged
  to ``*_bank`` + ``*_cq_full`` + ``*_dma``, over ``cycles x 2 x n_cores``
  issue slots — stays <= :data:`MAX_BACKPRESSURE_SHARE` (near-zero), while
  the work partition's stays >= :data:`MIN_PARTITION_SHARE` (the
  contention is binding, so the comparison means something);
* zero FIFO-order violations (intra-core queues and inter-core channels),
  outputs bit-identical to the sequential interpreter, and event/cycle
  engine parity on the headline point.

``cq_empty`` stalls are *excluded* from the back-pressure share on
purpose: a consumer's INT stream idling on an empty channel while its FP
unit drains is slack, not contention — the makespan already charges it.

Writes ``artifacts/BENCH_cluster_pipeline.json``
(``BENCH_cluster_pipeline_smoke.json`` under ``--smoke``)::

    {
      "points": [{"n_cores", "tcdm_banks", "partition": {...},
                  "pipeline": {...}, "ipc_ratio"}, ...],
      "headline": {"n_cores", "ipc_pipeline", "ipc_partition",
                   "ipc_ratio", "backpressure_share", "max_share"}
    }

Emits ``name,us_per_call,derived`` CSV rows like every other section.
"""
import json
import os
import time

from repro.core import (ClusterConfig, ClusterStepper, ExecutionPolicy,
                        KERNELS)
from repro.core.transform import (TransformConfig, partition_kernel,
                                  partition_pipeline)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(ROOT, "artifacts", "BENCH_cluster_pipeline.json")

KERNEL = "cluster_matmul"
#: TCDM pressure: half as many banks as cores, steep conflict penalty —
#: the regime the pipelined split is built for
BANK_CONFLICT_PENALTY = 8
#: pipelined aggregate IPC must beat the work partition by this factor
MIN_IPC_RATIO = 1.5
#: pipelined back-pressure stall share (bank + cq_full + dma slots over
#: all 2*n_cores issue slots per cycle) must stay below this — "near zero"
MAX_BACKPRESSURE_SHARE = 0.05
#: ... while the partition's share must exceed this, or the TCDM pressure
#: is not binding and the comparison is vacuous
MIN_PARTITION_SHARE = 0.15

FULL = dict(cores=(4, 8), n_samples=512)
SMOKE = dict(cores=(4,), n_samples=256)


def _backpressure_share(res, n_cores):
    lost = sum(v for k, v in res.stalls.items()
               if k.endswith(("_bank", "_cq_full", "_dma")))
    return lost / (res.cycles * 2 * n_cores)


def _run_leg(progs, ccfg, engine="event"):
    return ClusterStepper(progs, ccfg, engine=engine).run()


def _check_outputs(res, dfg, n_samples, owners):
    """Bit-exact equivalence of the concatenated owner-core outputs against
    the sequential interpreter."""
    ref = dfg.eval_reference(n_samples)
    chunk = n_samples // len(owners)
    for node in dfg.outputs():
        got = [core.env.get(f"{node.name}@{i}")
               for core in owners for i in range(chunk)]
        if got != ref[node.name]:
            raise AssertionError(
                f"{KERNEL}: output {node.name} diverged from the "
                f"sequential interpreter")


def _leg_entry(res, n_cores):
    s = res.summary()
    return {
        "cycles": s["cycles"],
        "ipc": s["ipc"],
        "bank_stalls": s["bank_stalls"],
        "cq_stalls": s["cq_stalls"],
        "dma_stalls": s["dma_stalls"],
        "backpressure_share": round(_backpressure_share(res, n_cores), 6),
        "energy": s["energy"],
    }


def run(cfg=None, out_path=OUT_PATH):
    cfg = cfg or FULL
    dfg = KERNELS[KERNEL]
    n = cfg["n_samples"]
    tcfg = TransformConfig(unroll=8, batch=min(32, n), queue_depth=4,
                           n_samples=n)
    rows, points = [], []
    t0 = time.time()
    headline = None
    for nc in cfg["cores"]:
        banks = nc // 2
        ccfg = ClusterConfig(n_cores=nc, tcdm_banks=banks,
                             bank_conflict_penalty=BANK_CONFLICT_PENALTY,
                             cq_depth=4, dma_buffers=2)
        part_progs = partition_kernel(dfg, ExecutionPolicy.COPIFTV2, tcfg, nc)
        pipe_progs = partition_pipeline(dfg, tcfg, nc, dma_buffers=2)
        part = _run_leg(part_progs, ccfg)
        pipe = _run_leg(pipe_progs, ccfg)

        if part.fifo_violations or pipe.fifo_violations:
            raise AssertionError(
                f"{KERNEL} x{nc}: FIFO-order violations (partition "
                f"{part.fifo_violations}, pipeline {pipe.fifo_violations})")
        _check_outputs(part, dfg, n, part.core_results)
        _check_outputs(pipe, dfg, n, pipe.core_results[1::2])

        pe, qe = _leg_entry(part, nc), _leg_entry(pipe, nc)
        ratio = qe["ipc"] / pe["ipc"]
        if ratio < MIN_IPC_RATIO:
            raise AssertionError(
                f"{KERNEL} x{nc}: pipelined IPC {qe['ipc']:.3f} is only "
                f"{ratio:.2f}x the partition's {pe['ipc']:.3f} "
                f"(need >= {MIN_IPC_RATIO}x)")
        if qe["backpressure_share"] > MAX_BACKPRESSURE_SHARE:
            raise AssertionError(
                f"{KERNEL} x{nc}: pipelined back-pressure share "
                f"{qe['backpressure_share']:.4f} > {MAX_BACKPRESSURE_SHARE} "
                f"— the channel/DMA fabric is not hiding the TCDM")
        if pe["backpressure_share"] < MIN_PARTITION_SHARE:
            raise AssertionError(
                f"{KERNEL} x{nc}: partition back-pressure share "
                f"{pe['backpressure_share']:.4f} < {MIN_PARTITION_SHARE} — "
                f"TCDM pressure is not binding, the comparison is vacuous")

        points.append({"n_cores": nc, "tcdm_banks": banks,
                       "partition": pe, "pipeline": qe,
                       "ipc_ratio": round(ratio, 4)})
        rows.append((f"cluster_pipeline_{KERNEL}_x{nc}_ipc", 0.0, qe["ipc"]))
        rows.append((f"cluster_pipeline_{KERNEL}_x{nc}_ipc_ratio", 0.0,
                     ratio))
        rows.append((f"cluster_pipeline_{KERNEL}_x{nc}_backpressure", 0.0,
                     qe["backpressure_share"]))
        if headline is None:
            headline = {"n_cores": nc, "ipc_pipeline": round(qe["ipc"], 4),
                        "ipc_partition": round(pe["ipc"], 4),
                        "ipc_ratio": round(ratio, 4),
                        "backpressure_share": qe["backpressure_share"],
                        "max_share": MAX_BACKPRESSURE_SHARE}
            # engine parity on the headline point: the event-driven core
            # must agree with the per-cycle reference bit-for-bit
            ref = _run_leg(pipe_progs, ccfg, engine="cycle")
            if (ref.cycles != pipe.cycles or ref.energy != pipe.energy
                    or ref.stalls != pipe.stalls):
                raise AssertionError(
                    f"{KERNEL} x{nc}: event/cycle engine divergence "
                    f"(cycles {pipe.cycles} vs {ref.cycles})")

    us = (time.time() - t0) * 1e6 / max(len(rows), 1)
    rows = [(name, us, derived) for name, _z, derived in rows]

    report = {"points": points, "headline": headline}
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    return rows


def main():
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived:.4f}")
    print(f"# wrote {OUT_PATH}")


def smoke():
    """4-core point only, smaller sample count, separate artifact — the CI
    gate still enforces the IPC-ratio and back-pressure bars plus
    event/cycle engine parity."""
    out = os.path.join(ROOT, "artifacts",
                       "BENCH_cluster_pipeline_smoke.json")
    rows = run(cfg=SMOKE, out_path=out)
    if not rows:
        raise AssertionError("cluster pipeline smoke produced no rows")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived:.4f}")


if __name__ == "__main__":
    main()
