"""Calibration section: prove the DSE-calibrated operating points beat the
old hard-coded defaults on the swept grid.

For every kernel the section (1) runs the calibration pipeline (sweep →
Pareto front → objective selection → artifact), (2) re-simulates the old
hard-coded configurations — the paper's headline point (COPIFTv2, queue
depth 4, latency 1, unroll 8: the machine-model/OperatingPoint default) and
the pre-policy-layer queue_matmul consumer point (depth 2) — and asserts
the contract the CI gate relies on:

* the selected point is a member of the swept Pareto front (non-dominated
  by every ok record in the sweep);
* NO hard-coded default dominates the calibrated selection — going through
  calibration cannot make any kernel strictly worse than what any consumer
  previously hard-coded.

Emits ``name,us_per_call,derived`` CSV rows (IPC / energy gains of the
calibrated point over the default) and writes
``artifacts/BENCH_calibration.json`` plus the per-kernel calibration
artifacts themselves (``artifacts/calibration/<kernel>.json``), so the CI
smoke job uploads a consumable policy table on every build.
"""
import json
import os
import time

from repro.core import SweepPoint, run_point
from repro.core.calibrate import calibrate, never_dominated_by

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(ROOT, "artifacts", "BENCH_calibration.json")

#: the hard-coded configurations the policy layer replaced: the paper's
#: headline point (machine model / OperatingPoint fallback) and the old
#: ``queue_matmul`` consumer default (depth=2, no K-loop unrolling — unroll
#: has no schedule analogue below 1, so 1 is the closest machine point)
DEFAULT_POINTS = {
    "paper_headline": dict(policy="copiftv2", queue_depth=4,
                           queue_latency=1, unroll=8),
    "queue_matmul_pre_policy": dict(policy="copiftv2", queue_depth=2,
                                    queue_latency=1, unroll=1),
}
DEFAULT_POINT = DEFAULT_POINTS["paper_headline"]


def run(grid_kw=None, kernels=None, objective="max-ipc", workers=None,
        out_path=OUT_PATH, artifact_dir=None):
    t0 = time.time()
    records = calibrate(kernels=kernels, objective=objective,
                        grid_kw=grid_kw, workers=workers,
                        out_dir=artifact_dir)
    us = (time.time() - t0) * 1e6 / max(len(records), 1)

    rows, report = [], {}
    for kernel, rec in sorted(records.items()):
        sel = rec.selected
        if sel not in rec.front:
            raise AssertionError(
                f"{kernel}: calibrated point is not on the swept Pareto "
                f"front: {sel}")
        defaults = {}
        for name, cfg in DEFAULT_POINTS.items():
            pt = run_point(SweepPoint(kernel=kernel,
                                      n_samples=rec.grid["n_samples"], **cfg))
            if not pt.ok:
                continue             # an infeasible legacy point dominates nothing
            defaults[name] = pt
            if not never_dominated_by(rec, pt):
                raise AssertionError(
                    f"{kernel}: hard-coded {name} point (ipc={pt.ipc:.4f}, "
                    f"energy={pt.energy:.1f}) dominates the calibrated "
                    f"point {sel} — selection under {rec.objective} "
                    f"regressed")
        if "paper_headline" not in defaults:
            raise AssertionError(
                f"{kernel}: the paper headline point no longer simulates")
        default = defaults["paper_headline"]
        ipc_gain = sel["ipc"] / default.ipc
        energy_gain = default.energy / sel["energy"]
        rows.append((f"calibration_{kernel}_ipc_gain", us, ipc_gain))
        rows.append((f"calibration_{kernel}_energy_gain", us, energy_gain))
        report[kernel] = {
            "objective": rec.objective,
            "selected": sel,
            "default": {**DEFAULT_POINT, "ipc": default.ipc,
                        "energy": default.energy},
            "ipc_gain": round(ipc_gain, 4),
            "energy_gain": round(energy_gain, 4),
            "front_size": len(rec.front),
            "rationale": rec.rationale,
        }
    rows.append(("calibration_kernels", us, float(len(records))))
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump({"default_point": DEFAULT_POINT, "kernels": report},
                  f, indent=2, sort_keys=True)
    return rows


def main():
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived:.4f}")
    print(f"# wrote {OUT_PATH}")


def smoke():
    """Tiny CI grid over two kernels.  Artifacts land in a dedicated
    ``artifacts/calibration_smoke/`` directory — a smoke-grid selection must
    never overwrite the live policy table in ``artifacts/calibration/``
    that queue_matmul/serve/train load (the CI smoke job produces the real
    table with a full ``explore.py calibrate`` run instead)."""
    rows = run(kernels=["expf", "dequant_dot"],
               grid_kw=dict(queue_depths=(1, 2, 4), queue_latencies=(1,),
                            unrolls=(4, 8), n_samples=16),
               workers=1,
               out_path=os.path.join(ROOT, "artifacts",
                                     "BENCH_calibration_smoke.json"),
               artifact_dir=os.path.join(ROOT, "artifacts",
                                         "calibration_smoke"))
    if not any(name.endswith("_ipc_gain") for name, _u, _d in rows):
        raise AssertionError("calibration smoke produced no gain rows")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived:.4f}")


if __name__ == "__main__":
    main()
