"""Sweep-throughput benchmark: points/sec for the DSE pipeline, per engine.

Times the same fixed high-latency grid (the stall-heavy corner where the
event-driven time-skip core and the per-worker lowering/reference memos
matter most) through four pipeline variants:

* ``cycle_uncached`` — naive per-cycle stepper, no memos: the pre-event-core
  pipeline, kept as the speedup baseline.
* ``cycle_cached``   — naive stepper + per-worker memos (isolates caching).
* ``event_uncached`` — time-skip stepper, no memos (isolates the engine).
* ``event_cached``   — the current default pipeline.

Every variant runs serially in-process (pool fan-out would only add fork
noise to a throughput ratio) and re-validates that each point still matches
the baseline interpreter, so the benchmark doubles as an equivalence check.
Emits ``name,us_per_call,derived`` CSV rows like the other sections and
writes ``artifacts/BENCH_sweep.json`` so the perf trajectory is tracked
PR-over-PR; the headline ratio is ``speedup_event_cached`` (default pipeline
vs pre-event-core pipeline).
"""
import dataclasses
import gc
import json
import os
import time

from repro.core import ExecutionPolicy
from repro.core.sweep import clear_worker_caches, grid, run_point

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(ROOT, "artifacts", "BENCH_sweep.json")

#: The acceptance grid: high visibility latencies across the full depth axis,
#: over the two queue/communication policies whose schedules the queue
#: geometry actually shapes.  BASELINE is excluded on purpose — it has no
#: queues, so a depth x latency grid of baseline points is ten copies of one
#: point and would only dilute a throughput ratio with redundant work.
FULL_GRID = dict(policies=(ExecutionPolicy.COPIFT, ExecutionPolicy.COPIFTV2),
                 queue_depths=(1, 2, 4, 8, 16), queue_latencies=(4, 8),
                 unrolls=(8,), n_samples=128)
SMOKE_GRID = dict(kernels=["expf", "box_muller"],
                  policies=(ExecutionPolicy.COPIFT, ExecutionPolicy.COPIFTV2),
                  queue_depths=(1, 4), queue_latencies=(4, 8), unrolls=(8,),
                  n_samples=16)

MODES = (
    ("cycle_uncached", "cycle", False),
    ("cycle_cached", "cycle", True),
    ("event_uncached", "event", False),
    ("event_cached", "event", True),
)

#: timing repetitions per mode; best run wins (standard throughput hygiene:
#: the slower repeats mostly measure scheduler contention and allocator/GC
#: noise, which on small shared CI hosts routinely costs 2x)
REPEATS = 4


def _time_once(points, engine, use_caches):
    """One cold serial pass of a pipeline variant: (wall seconds, records).

    GC is paused while the clock runs (collection debt from other variants
    must not land in this one) and every pass re-validates interpreter
    equivalence.
    """
    pts = [dataclasses.replace(p, engine=engine) for p in points]
    clear_worker_caches()
    gc.collect()
    gc.disable()
    try:
        t0 = time.time()
        recs = [run_point(p, use_caches=use_caches) for p in pts]
        dt = time.time() - t0
    finally:
        gc.enable()
    bad = [r for r in recs if r.status == "deadlock"
           or (r.ok and (not r.equivalent or r.fifo_violations))]
    if bad:
        raise AssertionError(
            f"{engine}/cached={use_caches}: {len(bad)} points deadlocked or "
            f"diverged from the interpreter, e.g. {bad[0]}")
    return dt, recs


def _time_modes(points):
    """Best-of-:data:`REPEATS` wall time per mode, with the repeats
    round-robined across modes so a noisy scheduling window penalizes every
    variant evenly instead of whichever mode it happened to land on."""
    best = {name: None for name, _e, _c in MODES}
    cycles = {}
    for _ in range(REPEATS):
        for name, engine, cached in MODES:
            dt, recs = _time_once(points, engine, cached)
            if best[name] is None or dt < best[name]:
                best[name] = dt
            cycles[name] = sum(r.cycles for r in recs)
    return {
        name: dict(engine=engine, cached=cached, points=len(points),
                   wall_s=round(best[name], 4),
                   points_per_sec=round(len(points) / best[name], 3),
                   cycles_total=cycles[name])
        for name, engine, cached in MODES
    }


def run(grid_kw=None, out_path=OUT_PATH):
    points = grid(**(grid_kw or FULL_GRID))

    def jsonable(v):
        if isinstance(v, (tuple, list)):
            return [x.value if isinstance(x, ExecutionPolicy) else x
                    for x in v]
        return v

    result = {"grid": {k: jsonable(v)
                       for k, v in (grid_kw or FULL_GRID).items()},
              "n_points": len(points), "modes": _time_modes(points)}
    base = result["modes"]["cycle_uncached"]["points_per_sec"]
    for name, _e, _c in MODES:
        result[f"speedup_{name}"] = round(
            result["modes"][name]["points_per_sec"] / base, 3)
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    rows = []
    for name, _e, _c in MODES:
        m = result["modes"][name]
        us = 1e6 / m["points_per_sec"]
        rows.append((f"sweep_perf_{name}_points_per_sec", us,
                     m["points_per_sec"]))
        rows.append((f"sweep_perf_speedup_{name}", us,
                     result[f"speedup_{name}"]))
    return rows


def main():
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived:.4f}")
    print(f"# wrote {OUT_PATH}")


def smoke():
    """Tiny grid, separate artifact name: CI tracks shape, not the ratio
    (a 16-sample smoke grid is too small for a stable speedup number)."""
    rows = run(grid_kw=SMOKE_GRID,
               out_path=os.path.join(ROOT, "artifacts",
                                     "BENCH_sweep_smoke.json"))
    if not rows:
        raise AssertionError("sweep_perf smoke produced no rows")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived:.4f}")


if __name__ == "__main__":
    main()
