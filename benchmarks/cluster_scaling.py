"""Cluster scaling benchmark: weak/strong scaling of the N-core machine
model (``core.cluster``) plus a TCDM bank-contention study.

Three sections, all through the standard sweep pipeline (every point still
re-checks bit-identical equivalence against the sequential interpreter):

* **strong scaling** — fixed total sample count split across 1..N cores of
  a conflict-free cluster; the headline is the aggregate-throughput speedup
  at 4 cores on a contention-light kernel (no TCDM traffic at all), gated
  at >= :data:`MIN_SPEEDUP_4C` (the PR-5 acceptance bar of 3x).
* **weak scaling** — fixed per-core sample count, so the makespan should
  stay ~flat while aggregate throughput grows ~linearly.
* **contention** — a memory-heavy kernel at 4 cores across a bank axis
  (conflict-free -> 8 -> 2 banks): throughput must degrade monotonically
  as banks get scarcer and the ``*_bank`` stall cause must appear.

Writes ``artifacts/BENCH_cluster.json`` (``BENCH_cluster_smoke.json`` under
``--smoke``) with the schema::

    {
      "strong_scaling": {kernel: {"n_samples": N, "points": [
          {"n_cores", "tcdm_banks", "cycles", "throughput", "speedup",
           "ipc", "ipc_per_core", "energy_per_sample", "bank_stalls"}, ...]}},
      "weak_scaling":   {kernel: {"per_core_samples": N, "points": [...]}},
      "contention":     {kernel: {"n_cores": 4, "points": [...]}},
      "headline": {"kernel", "speedup_4c", "min_required"}
    }

``speedup`` is aggregate throughput (samples/cycle over the makespan)
relative to the 1-core point of the same row; ``energy_per_sample``
includes the interconnect energy charged per TCDM access in multi-core
clusters.  Emits ``name,us_per_call,derived`` CSV rows like every other
section.
"""
import json
import os
import time

from repro.core import SweepPoint, run_point

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(ROOT, "artifacts", "BENCH_cluster.json")

#: the PR-5 acceptance bar: >=3x aggregate throughput from 1 -> 4 cores on
#: a contention-light kernel
MIN_SPEEDUP_4C = 3.0

#: contention-light headline kernel: pure compute, zero TCDM accesses
#: (poly_lcg is IALU/IMUL/CVT/FMA only), so scaling is limited purely by
#: per-core schedule fill, not by the shared memory model
STRONG_KERNEL = "poly_lcg"
#: memory-heavy kernel for the bank-contention study (LW+SW per sample)
CONTENTION_KERNEL = "histf"

FULL = dict(strong_kernels=("poly_lcg", "expf", "dequant_dot"),
            strong_n=128, weak_per_core=32, cores=(1, 2, 4, 8),
            contention_cores=4, banks_axis=(None, 8, 2))
SMOKE = dict(strong_kernels=("poly_lcg", "expf"),
             strong_n=64, weak_per_core=16, cores=(1, 2, 4),
             contention_cores=4, banks_axis=(None, 2))


def _point(kernel, n_samples, n_cores, banks):
    rec = run_point(SweepPoint(kernel=kernel, policy="copiftv2",
                               n_samples=n_samples, n_cores=n_cores,
                               tcdm_banks=banks))
    if not rec.ok or not rec.equivalent or rec.fifo_violations:
        raise AssertionError(
            f"{kernel} x{n_cores} banks={banks}: cluster point failed "
            f"({rec.status}: {rec.detail or 'diverged from interpreter'})")
    return rec


def _entry(rec, base_throughput=None):
    return {
        "n_cores": rec.n_cores,
        "tcdm_banks": rec.tcdm_banks,
        "cycles": rec.cycles,
        "throughput": rec.throughput,
        "speedup": (rec.throughput / base_throughput
                    if base_throughput else 1.0),
        "ipc": rec.ipc,
        "ipc_per_core": rec.ipc_per_core,
        "energy_per_sample": rec.energy / rec.n_samples,
        "bank_stalls": rec.bank_stalls,
    }


def run(cfg=None, out_path=OUT_PATH):
    cfg = cfg or FULL
    rows, report = [], {"strong_scaling": {}, "weak_scaling": {},
                        "contention": {}}
    t0 = time.time()

    # -- strong scaling: fixed total work, 1..N cores ------------------------
    for kernel in cfg["strong_kernels"]:
        pts = []
        base = None
        for nc in cfg["cores"]:
            if cfg["strong_n"] % nc:
                continue
            rec = _point(kernel, cfg["strong_n"], nc, None)
            if base is None:
                base = rec.throughput
            pts.append(_entry(rec, base))
            rows.append((f"cluster_strong_{kernel}_x{nc}", 0.0,
                         pts[-1]["speedup"]))
        report["strong_scaling"][kernel] = {
            "n_samples": cfg["strong_n"], "points": pts}

    # -- weak scaling: fixed per-core work -----------------------------------
    for kernel in (STRONG_KERNEL,):
        pts = []
        base = None
        for nc in cfg["cores"]:
            rec = _point(kernel, cfg["weak_per_core"] * nc, nc, None)
            if base is None:
                base = rec.throughput
            pts.append(_entry(rec, base))
            rows.append((f"cluster_weak_{kernel}_x{nc}", 0.0,
                         pts[-1]["speedup"]))
        report["weak_scaling"][kernel] = {
            "per_core_samples": cfg["weak_per_core"], "points": pts}

    # -- bank contention at fixed core count ---------------------------------
    nc = cfg["contention_cores"]
    pts = []
    base = None
    prev_tp = None
    for banks in cfg["banks_axis"]:
        rec = _point(CONTENTION_KERNEL, cfg["weak_per_core"] * nc, nc, banks)
        if base is None:
            base = rec.throughput
        e = _entry(rec, base)
        pts.append(e)
        tag = "inf" if banks is None else banks
        rows.append((f"cluster_contention_{CONTENTION_KERNEL}_b{tag}", 0.0,
                     e["throughput"]))
        if prev_tp is not None and e["throughput"] > prev_tp * (1 + 1e-12):
            raise AssertionError(
                f"{CONTENTION_KERNEL} x{nc}: throughput rose from "
                f"{prev_tp:.5f} to {e['throughput']:.5f} as banks shrank to "
                f"{banks} — the contention model is not binding")
        prev_tp = e["throughput"]
    if pts[-1]["bank_stalls"] == 0:
        raise AssertionError(
            f"{CONTENTION_KERNEL} x{nc} with {cfg['banks_axis'][-1]} banks "
            f"recorded no bank stalls — the arbiter never fired")
    report["contention"][CONTENTION_KERNEL] = {"n_cores": nc, "points": pts}

    # -- the acceptance gate --------------------------------------------------
    strong = report["strong_scaling"][STRONG_KERNEL]["points"]
    by_cores = {p["n_cores"]: p for p in strong}
    speedup_4c = by_cores[4]["speedup"]
    if speedup_4c < MIN_SPEEDUP_4C:
        raise AssertionError(
            f"{STRONG_KERNEL}: 1->4 core aggregate-throughput speedup "
            f"{speedup_4c:.2f}x < required {MIN_SPEEDUP_4C}x")
    report["headline"] = {"kernel": STRONG_KERNEL,
                          "speedup_4c": round(speedup_4c, 4),
                          "min_required": MIN_SPEEDUP_4C}
    us = (time.time() - t0) * 1e6 / max(len(rows), 1)
    rows = [(name, us, derived) for name, _z, derived in rows]
    rows.append((f"cluster_headline_speedup_4c_{STRONG_KERNEL}", us,
                 speedup_4c))

    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    return rows


def main():
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived:.4f}")
    print(f"# wrote {OUT_PATH}")


def smoke():
    """Tiny grids (cores 1/2/4), separate artifact — the CI gate still
    enforces the >=3x strong-scaling bar and the contention monotonicity."""
    out = os.path.join(ROOT, "artifacts", "BENCH_cluster_smoke.json")
    rows = run(cfg=SMOKE, out_path=out)
    if not rows:
        raise AssertionError("cluster scaling smoke produced no rows")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived:.4f}")


if __name__ == "__main__":
    main()
